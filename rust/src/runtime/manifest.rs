//! The artifact manifest written by `python/compile/aot.py`.

use crate::model::ModelConfig;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One tensor in an entry signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub entries: Vec<EntrySpec>,
}

fn tensor_specs(j: &Json) -> Vec<TensorSpec> {
    j.as_arr()
        .map(|a| {
            a.iter()
                .map(|t| TensorSpec {
                    name: t.get("name").as_str().unwrap_or("").to_string(),
                    shape: t.get("shape").usize_vec(),
                    dtype: t.get("dtype").as_str().unwrap_or("f32").to_string(),
                })
                .collect()
        })
        .unwrap_or_default()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let config = ModelConfig::from_json(j.get("config"))
            .context("manifest missing model config")?;
        let mut entries = Vec::new();
        if let Some(obj) = j.get("entries").as_obj() {
            for (name, e) in obj {
                entries.push(EntrySpec {
                    name: name.clone(),
                    file: e.get("file").as_str().unwrap_or("").to_string(),
                    inputs: tensor_specs(e.get("inputs")),
                    outputs: tensor_specs(e.get("outputs")),
                });
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), config, entries })
    }

    pub fn entry(&self, name: &str) -> Option<&EntrySpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Default artifact directory: `$TSGO_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("TSGO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("tsgo_manifest_test");
        write_manifest(
            &dir,
            r#"{
              "config": {"vocab":256,"d_model":64,"n_layers":2,"n_heads":2,"ffn":128,"seq_len":64},
              "entries": {
                "forward_logits": {
                  "file": "forward_logits.hlo.txt",
                  "inputs": [{"name":"tokens","shape":[1,64],"dtype":"i32"}],
                  "outputs": [{"name":"logits","shape":[1,64,256],"dtype":"f32"}]
                }
              }
            }"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.d_model, 64);
        let e = m.entry("forward_logits").unwrap();
        assert_eq!(e.inputs[0].shape, vec![1, 64]);
        assert_eq!(e.outputs[0].dtype, "f32");
        assert!(m.entry("nope").is_none());
    }

    #[test]
    fn missing_manifest_is_error() {
        assert!(Manifest::load(Path::new("/nonexistent_dir_xyz")).is_err());
    }
}
