//! The PJRT execution engine.
//!
//! Compiles each HLO-text artifact once (lazily, cached) on a shared CPU
//! PJRT client and runs it from the rust hot path. All entries are lowered
//! with `return_tuple=True` on the python side, so outputs are decomposed
//! tuples.

use super::manifest::Manifest;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// A loaded artifact engine. Cheap to share behind an `Arc`.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Open the artifact directory (compiles nothing yet).
    pub fn open(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine { manifest, client, cache: Mutex::new(HashMap::new()) })
    }

    /// Open the default artifact directory if it exists.
    pub fn open_default() -> Option<Engine> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            match Engine::open(&dir) {
                Ok(e) => Some(e),
                Err(err) => {
                    eprintln!("warning: artifacts present but unusable: {err:#}");
                    None
                }
            }
        } else {
            None
        }
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.manifest.entry(name).is_some()
    }

    fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let entry = self
            .manifest
            .entry(name)
            .with_context(|| format!("no artifact entry '{name}'"))?;
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compile artifact '{name}'"))?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an entry with positional literal inputs; returns the
    /// decomposed output tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute artifact '{name}'"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch output of '{name}'"))?;
        Ok(lit.to_tuple()?)
    }

    /// Pre-compile every entry (used by the CLI `warmup` and benches).
    pub fn warmup(&self) -> Result<Vec<String>> {
        let mut loaded = Vec::new();
        let names: Vec<String> =
            self.manifest.entries.iter().map(|e| e.name.clone()).collect();
        for name in names {
            self.load(&name)?;
            loaded.push(name);
        }
        Ok(loaded)
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in rust/tests/runtime_e2e.rs
    // (they are skipped when `make artifacts` has not run). Here we only test
    // the failure paths that need no artifacts.
    use super::*;

    #[test]
    fn open_missing_dir_fails() {
        assert!(Engine::open(Path::new("/no/such/dir")).is_err());
    }

    #[test]
    fn unknown_entry_fails() {
        let dir = std::env::temp_dir().join("tsgo_engine_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"config":{"vocab":256,"d_model":64,"n_layers":2,"n_heads":2,"ffn":128,"seq_len":64},"entries":{}}"#,
        )
        .unwrap();
        let e = Engine::open(&dir).unwrap();
        assert!(!e.has_entry("forward_logits"));
        assert!(e.execute("forward_logits", &[]).is_err());
    }
}
