//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! `python/compile/aot.py` lowers the L2 JAX graphs (which embed the L1
//! Pallas kernels) to **HLO text** + a JSON manifest describing every entry
//! point's input/output shapes. This module loads those artifacts through
//! the `xla` crate (PJRT CPU client), compiles each entry once, and exposes
//! typed execution to the rest of the system. Python never runs here.
//!
//! Artifacts are optional: every consumer has a native fallback, and the
//! [`Engine`] reports which path is active so benches can compare them.

pub mod convert;
pub mod engine;
pub mod forward;
pub mod manifest;
pub mod train;

pub use convert::{literal_to_matrix, matrix_to_literal, tokens_to_literal, vec_to_literal};
pub use engine::Engine;
pub use forward::{forward_logits_artifact, perplexity_artifact};
pub use manifest::{EntrySpec, Manifest, TensorSpec};
pub use train::{train, TrainConfig, TrainOutcome};
