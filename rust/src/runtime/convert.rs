//! Tensor ⇄ `xla::Literal` marshalling.

use crate::tensor::Matrix;
use anyhow::Result;

/// `[rows, cols]` f32 matrix → literal.
pub fn matrix_to_literal(m: &Matrix) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
}

/// f32 vector → rank-1 literal.
pub fn vec_to_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Token ids → i32 literal of the given shape (row-major).
pub fn tokens_to_literal(tokens: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(tokens).reshape(&dims)?)
}

/// Literal → matrix, reading the literal's own shape. Rank-1 literals become
/// a single row; higher ranks collapse leading axes into rows.
pub fn literal_to_matrix(lit: &xla::Literal) -> Result<Matrix> {
    let shape = lit.array_shape()?;
    let dims = shape.dims();
    let data: Vec<f32> = lit.to_vec()?;
    let (rows, cols) = match dims.len() {
        0 => (1, 1),
        1 => (1, dims[0] as usize),
        n => {
            let cols = dims[n - 1] as usize;
            (data.len() / cols.max(1), cols)
        }
    };
    Ok(Matrix::from_vec(rows, cols, data))
}

/// Literal → flat f32 vec.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matrix_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(3, 5, 1.0, &mut rng);
        let lit = matrix_to_literal(&m).unwrap();
        let back = literal_to_matrix(&lit).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn tokens_shape() {
        let lit = tokens_to_literal(&[1, 2, 3, 4, 5, 6], &[2, 3]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        let v: Vec<i32> = lit.to_vec().unwrap();
        assert_eq!(v, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn rank1_becomes_row() {
        let lit = vec_to_literal(&[1.0, 2.0, 3.0]);
        let m = literal_to_matrix(&lit).unwrap();
        assert_eq!((m.rows, m.cols), (1, 3));
    }

    #[test]
    fn rank3_collapses_leading() {
        let data: Vec<f32> = (0..24).map(|i| i as f32).collect();
        let lit = xla::Literal::vec1(&data).reshape(&[2, 3, 4]).unwrap();
        let m = literal_to_matrix(&lit).unwrap();
        assert_eq!((m.rows, m.cols), (6, 4));
        assert_eq!(m[(5, 3)], 23.0);
    }
}
