//! The rust training loop over the AOT `train_step` artifact.
//!
//! Parameters and optimizer moments live as PJRT literals across steps —
//! the loop feeds each step's outputs straight back as the next step's
//! inputs, so weights never round-trip through rust until training ends.

use super::convert::tokens_to_literal;
use super::engine::Engine;
use super::forward::weight_literals;
use crate::calib::Batch;
use crate::model::{ModelWeights, Preset};
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};

/// Training-loop configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub seed: u64,
    /// Log every n steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 300, seed: 7, log_every: 25 }
    }
}

/// Outcome of a training run.
pub struct TrainOutcome {
    pub weights: ModelWeights,
    pub losses: Vec<f32>,
}

/// Train from scratch on `corpus` bytes using the artifact's train batch
/// shape. Returns the trained weights and the per-step loss curve.
pub fn train(engine: &Engine, corpus: &[u8], cfg: &TrainConfig) -> Result<TrainOutcome> {
    let mcfg = engine.manifest.config;
    let entry = engine
        .manifest
        .entry("train_step")
        .context("artifact 'train_step' missing (re-run `make artifacts`)")?;
    // tokens input is at position 3n+1; its shape is [B, S]
    let n = crate::model::ModelWeights::param_manifest(&mcfg).len();
    let tok_spec = &entry.inputs[3 * n + 1];
    let (batch, seq) = (tok_spec.shape[0], tok_spec.shape[1]);
    ensure!(corpus.len() > seq + 1, "corpus too small for training");

    // init params in rust (so the whole run is reproducible from one seed)
    let mut rng = Rng::new(cfg.seed);
    let init = ModelWeights::init(mcfg, &mut rng);
    let mut params = weight_literals(&init)?;
    let zeros: Vec<xla::Literal> = init
        .flat_params()
        .iter()
        .map(|(_, shape, _)| {
            let data = vec![0.0f32; shape.iter().product()];
            let lit = xla::Literal::vec1(&data);
            match shape.len() {
                2 => lit.reshape(&[shape[0] as i64, shape[1] as i64]).unwrap(),
                _ => lit,
            }
        })
        .collect();
    let mut m: Vec<xla::Literal> = zeros.iter().map(clone_literal).collect();
    let mut v = zeros;

    let mut losses = Vec::with_capacity(cfg.steps);
    let mut data_rng = rng.fork(0x7261696e);
    for step in 1..=cfg.steps {
        // sample a random batch
        let mut toks = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = data_rng.below(corpus.len() - seq - 1);
            toks.extend(corpus[start..start + seq].iter().map(|&b| b as i32));
        }
        let b = Batch {
            batch,
            seq_len: seq,
            tokens: toks.iter().map(|&t| t as u8).collect(),
        };
        let targets: Vec<i32> = b.shifted_targets().iter().map(|&t| t as i32).collect();
        let mut mask = vec![1.0f32; batch * seq];
        for bi in 0..batch {
            mask[bi * seq + seq - 1] = 0.0;
        }

        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 * n + 4);
        inputs.extend(params.drain(..));
        inputs.extend(m.drain(..));
        inputs.extend(v.drain(..));
        inputs.push(xla::Literal::scalar(step as i32));
        inputs.push(tokens_to_literal(&toks, &[batch, seq])?);
        inputs.push(tokens_to_literal(&targets, &[batch, seq])?);
        inputs.push(
            xla::Literal::vec1(&mask).reshape(&[batch as i64, seq as i64])?,
        );

        let mut out = engine.execute("train_step", &inputs)?;
        ensure!(out.len() == 1 + 3 * n, "train_step returned {} outputs", out.len());
        let loss: f32 = out[0].to_vec::<f32>()?[0];
        losses.push(loss);
        let rest: Vec<xla::Literal> = out.drain(1..).collect();
        let mut it = rest.into_iter();
        params = (&mut it).take(n).collect();
        m = (&mut it).take(n).collect();
        v = (&mut it).take(n).collect();

        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            println!("  step {step:>5}  loss {loss:.4}");
        }
    }

    // materialize final weights
    let manifest = ModelWeights::param_manifest(&mcfg);
    let mut named: std::collections::BTreeMap<String, Vec<f32>> = Default::default();
    for ((name, _), lit) in manifest.iter().zip(&params) {
        named.insert(name.clone(), lit.to_vec::<f32>()?);
    }
    let weights = ModelWeights::from_named(mcfg, |name, shape| {
        let v = named
            .get(name)
            .cloned()
            .with_context(|| format!("missing trained tensor {name}"))?;
        ensure!(v.len() == shape.iter().product::<usize>(), "shape mismatch {name}");
        Ok(v)
    })?;
    Ok(TrainOutcome { weights, losses })
}

fn clone_literal(l: &xla::Literal) -> xla::Literal {
    // Literal has no Clone; round-trip through raw data.
    let shape = l.array_shape().expect("array literal");
    let data: Vec<f32> = l.to_vec().expect("f32 literal");
    let dims: Vec<i64> = shape.dims().to_vec();
    xla::Literal::vec1(&data).reshape(&dims).expect("reshape")
}

/// Convenience: pick the preset matching the engine's config (for logs).
pub fn engine_preset(engine: &Engine) -> Option<Preset> {
    [Preset::Tiny, Preset::Small, Preset::Base]
        .into_iter()
        .find(|p| p.config() == engine.manifest.config)
}
