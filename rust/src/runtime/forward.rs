//! Artifact-backed model execution: logits and perplexity through the
//! AOT-compiled `forward_logits` entry (the fast path for evaluation), with
//! shape checks against the manifest.

use super::convert::{literal_to_matrix, tokens_to_literal, vec_to_literal};
use super::engine::Engine;
use crate::model::ModelWeights;
use crate::tensor::Matrix;
use anyhow::{ensure, Context, Result};

/// Flatten model weights into the artifact's positional parameter literals.
pub fn weight_literals(w: &ModelWeights) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::new();
    for (_, shape, data) in w.flat_params() {
        let lit = match shape.len() {
            1 => vec_to_literal(data),
            2 => xla::Literal::vec1(data).reshape(&[shape[0] as i64, shape[1] as i64])?,
            _ => anyhow::bail!("unexpected param rank {}", shape.len()),
        };
        out.push(lit);
    }
    Ok(out)
}

/// Run `forward_logits` for one sequence; returns logits `[S, vocab]`.
///
/// The artifact was lowered for `[1, seq_len]` tokens; shorter sequences are
/// right-padded (causality makes padding inert for the reported prefix).
pub fn forward_logits_artifact(
    engine: &Engine,
    w: &ModelWeights,
    tokens: &[u8],
) -> Result<Matrix> {
    let entry = engine
        .manifest
        .entry("forward_logits")
        .context("artifact 'forward_logits' missing")?;
    let seq_len = *entry
        .inputs
        .last()
        .context("bad manifest")?
        .shape
        .last()
        .context("bad manifest")?;
    ensure!(
        tokens.len() <= seq_len,
        "sequence ({}) longer than artifact seq_len ({seq_len})",
        tokens.len()
    );
    ensure!(
        w.config == engine.manifest.config,
        "model config does not match artifacts (run `make artifacts`)"
    );
    let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
    padded.resize(seq_len, 0);

    let mut inputs = weight_literals(w)?;
    inputs.push(tokens_to_literal(&padded, &[1, seq_len])?);
    let outputs = engine.execute("forward_logits", &inputs)?;
    let logits = literal_to_matrix(&outputs[0])?; // [1*S, vocab]
    Ok(logits.slice(0, tokens.len(), 0, logits.cols))
}

/// Perplexity through the artifact path.
pub fn perplexity_artifact(
    engine: &Engine,
    w: &ModelWeights,
    data: &[u8],
    seq_len: usize,
    max_windows: usize,
) -> Result<f64> {
    let mut err = None;
    let ppl = crate::eval::ppl::perplexity_with(data, seq_len, max_windows, |t| {
        match forward_logits_artifact(engine, w, t) {
            Ok(m) => m,
            Err(e) => {
                err = Some(e);
                Matrix::zeros(t.len(), w.config.vocab)
            }
        }
    });
    match err {
        Some(e) => Err(e),
        None => Ok(ppl),
    }
}
