//! Synthetic zero-shot task suite — the "0-shot avg" column of Tables 1–2.
//!
//! The paper averages 8 multiple-choice commonsense benchmarks scored by
//! (length-normalized) log-likelihood. We build the same *measurement* on
//! the synthetic corpora: four task families whose ground truth comes from
//! the corpus generator's regularities, scored exactly like lm-eval-harness
//! (pick the choice with the highest per-byte log-likelihood under the
//! model). Quantization that damages the model's learned structure shows up
//! as accuracy loss here even when PPL shifts are subtle.
//!
//! Families:
//! * **cloze** — real corpus continuation vs corrupted continuations;
//! * **copy** — `A B A B A _` pattern completion vs wrong token;
//! * **case** — sentence-initial capitalization convention;
//! * **odd-one-out** — in-distribution word vs cross-corpus word.

use crate::calib::corpus::{Corpus, CorpusKind};
use crate::model::ModelExec;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// One multiple-choice item: shared prompt, k choices, index of the answer.
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub prompt: Vec<u8>,
    pub choices: Vec<Vec<u8>>,
    pub answer: usize,
    pub family: &'static str,
}

/// Per-family and aggregate accuracy.
#[derive(Clone, Debug)]
pub struct TaskReport {
    pub per_family: Vec<(String, f64, usize)>,
    pub average: f64,
}

/// Build the full suite from a corpus (deterministic in seed).
pub fn build_suite(corpus: &Corpus, n_per_family: usize, seed: u64) -> Vec<TaskItem> {
    let mut rng = Rng::new(seed);
    let mut items = Vec::new();
    let data = &corpus.bytes;

    // -- cloze: true continuation vs byte-shuffled continuation -------------
    for _ in 0..n_per_family {
        let plen = 24 + rng.below(16);
        let clen = 8;
        let start = rng.below(data.len() - plen - clen - 1);
        let prompt = data[start..start + plen].to_vec();
        let truth = data[start + plen..start + plen + clen].to_vec();
        let mut corrupt = truth.clone();
        // shuffle until different
        loop {
            rng.shuffle(&mut corrupt);
            if corrupt != truth {
                break;
            }
        }
        let mut corrupt2 = truth.clone();
        for b in corrupt2.iter_mut() {
            *b = b.wrapping_add(13) & 0x7f;
        }
        let answer = rng.below(3);
        let mut choices = vec![corrupt, corrupt2];
        choices.insert(answer, truth);
        items.push(TaskItem { prompt, choices, answer, family: "cloze" });
    }

    // -- copy: repeated bigram pattern ---------------------------------------
    for _ in 0..n_per_family {
        let a = data[rng.below(data.len())];
        let mut b = data[rng.below(data.len())];
        if b == a {
            b = b.wrapping_add(1);
        }
        let mut prompt = Vec::new();
        for _ in 0..4 {
            prompt.push(a);
            prompt.push(b);
        }
        prompt.push(a);
        let wrong = a; // repeating `a` breaks the alternation
        let answer = rng.below(2);
        let mut choices = vec![vec![wrong]];
        choices.insert(answer, vec![b]);
        items.push(TaskItem { prompt, choices, answer, family: "copy" });
    }

    // -- case: sentence starts are capitalized -------------------------------
    for _ in 0..n_per_family {
        // find a ". " boundary
        let mut idx = None;
        for _ in 0..200 {
            let i = rng.below(data.len() - 40);
            if data[i] == b'.' && data[i + 1] == b' ' && data[i + 2].is_ascii_uppercase() {
                idx = Some(i);
                break;
            }
        }
        let Some(i) = idx else { continue };
        let pstart = i.saturating_sub(20);
        let prompt = data[pstart..i + 2].to_vec();
        let upper = data[i + 2];
        let lower = upper.to_ascii_lowercase();
        let answer = rng.below(2);
        let mut choices = vec![vec![lower]];
        choices.insert(answer, vec![upper]);
        items.push(TaskItem { prompt, choices, answer, family: "case" });
    }

    // -- odd-one-out: in-distribution continuation vs other-corpus bytes -----
    let other = Corpus::generate(
        match corpus.kind {
            CorpusKind::SynthWiki => CorpusKind::SynthC4,
            CorpusKind::SynthC4 => CorpusKind::SynthWiki,
        },
        data.len().min(50_000),
        seed ^ 0xABCD,
    );
    for _ in 0..n_per_family {
        let plen = 32;
        let clen = 10;
        let start = rng.below(data.len() - plen - clen - 1);
        let prompt = data[start..start + plen].to_vec();
        let truth = data[start + plen..start + plen + clen].to_vec();
        let ostart = rng.below(other.bytes.len() - clen - 1);
        let foreign = other.bytes[ostart..ostart + clen].to_vec();
        if foreign == truth {
            continue;
        }
        let answer = rng.below(2);
        let mut choices = vec![foreign];
        choices.insert(answer, truth);
        items.push(TaskItem { prompt, choices, answer, family: "odd1out" });
    }

    items
}

/// Length-normalized log-likelihood of `continuation` given `prompt`.
fn choice_score(logits_fn: &mut dyn FnMut(&[u8]) -> Matrix, prompt: &[u8], cont: &[u8]) -> f64 {
    let mut seq = prompt.to_vec();
    seq.extend_from_slice(cont);
    let logits = logits_fn(&seq);
    let mut ll = 0.0f64;
    for (k, &target) in cont.iter().enumerate() {
        let t = prompt.len() + k - 1; // logits at position t predict t+1
        let row = logits.row(t);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f64 =
            row.iter().map(|v| ((v - maxv) as f64).exp()).sum::<f64>().ln() + maxv as f64;
        ll += row[target as usize] as f64 - lse;
    }
    ll / cont.len() as f64
}

/// Score the suite with an arbitrary logits function.
pub fn task_suite_with(
    items: &[TaskItem],
    mut logits_fn: impl FnMut(&[u8]) -> Matrix,
) -> TaskReport {
    let mut per: std::collections::BTreeMap<&'static str, (usize, usize)> =
        std::collections::BTreeMap::new();
    for item in items {
        let scores: Vec<f64> = item
            .choices
            .iter()
            .map(|c| choice_score(&mut logits_fn, &item.prompt, c))
            .collect();
        let pick = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let e = per.entry(item.family).or_insert((0, 0));
        e.1 += 1;
        if pick == item.answer {
            e.0 += 1;
        }
    }
    let per_family: Vec<(String, f64, usize)> = per
        .iter()
        .map(|(f, (c, n))| (f.to_string(), *c as f64 / *n as f64 * 100.0, *n))
        .collect();
    let average = if per_family.is_empty() {
        0.0
    } else {
        per_family.iter().map(|(_, a, _)| a).sum::<f64>() / per_family.len() as f64
    };
    TaskReport { per_family, average }
}

/// Score the suite with a model's native forward (parallel over items),
/// generic over the execution representation (dense or packed).
pub fn task_suite<M: ModelExec>(m: &M, items: &[TaskItem]) -> TaskReport {
    // Parallelize by scoring items concurrently; reuse task_suite_with for
    // the aggregation by pre-computing picks.
    let picks: Vec<(usize, &'static str, bool)> =
        crate::util::threadpool::parallel_map_items(items, |item| {
            let mut f = |t: &[u8]| crate::model::forward_logits(m, t);
            let scores: Vec<f64> = item
                .choices
                .iter()
                .map(|c| choice_score(&mut f, &item.prompt, c))
                .collect();
            let pick = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            (pick, item.family, pick == item.answer)
        });
    let mut per: std::collections::BTreeMap<&'static str, (usize, usize)> =
        std::collections::BTreeMap::new();
    for (_, family, correct) in picks {
        let e = per.entry(family).or_insert((0, 0));
        e.1 += 1;
        if correct {
            e.0 += 1;
        }
    }
    let per_family: Vec<(String, f64, usize)> = per
        .iter()
        .map(|(f, (c, n))| (f.to_string(), *c as f64 / *n as f64 * 100.0, *n))
        .collect();
    let average = if per_family.is_empty() {
        0.0
    } else {
        per_family.iter().map(|(_, a, _)| a).sum::<f64>() / per_family.len() as f64
    };
    TaskReport { per_family, average }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;

    fn corpus() -> Corpus {
        Corpus::generate(CorpusKind::SynthWiki, 60_000, 1)
    }

    #[test]
    fn suite_is_deterministic_and_balanced() {
        let c = corpus();
        let a = build_suite(&c, 10, 7);
        let b = build_suite(&c, 10, 7);
        assert_eq!(a.len(), b.len());
        assert!(a.len() >= 30, "should have ≥3 full families, got {}", a.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
        // answers aren't always index 0
        assert!(a.iter().any(|i| i.answer != 0));
    }

    #[test]
    fn oracle_scorer_gets_full_marks() {
        // A scorer that assigns probability 1 to exactly the corpus bytes
        // should ace cloze/copy/case. Build oracle from a bigram table of
        // the corpus itself... simpler: peek at the right answer by giving
        // the true choice bytes high logits through a closure with state.
        let c = corpus();
        let items = build_suite(&c, 6, 3);
        // Oracle: for each sequence, logits that put mass on the actual next
        // byte of that very sequence (teacher forcing) — perfect LL for the
        // true continuation, garbage for corrupted ones only if they differ.
        let rep = task_suite_with(&items, |seq| {
            let mut logits = Matrix::zeros(seq.len(), 256);
            for t in 0..seq.len() - 1 {
                logits[(t, seq[t + 1] as usize)] = 30.0;
            }
            logits
        });
        // teacher-forcing oracle scores every choice equally (it "predicts"
        // whatever it sees), so this is a *metric plumbing* test: it must run
        // all families and produce finite numbers.
        assert!(rep.average.is_finite());
        assert!(!rep.per_family.is_empty());
    }

    #[test]
    fn random_model_near_chance() {
        let mut rng = crate::util::rng::Rng::new(5);
        let w = crate::model::ModelWeights::init(Preset::Tiny.config(), &mut rng);
        let c = corpus();
        let items = build_suite(&c, 8, 11);
        let rep = task_suite(&w, &items);
        // chance is 33% (cloze) / 50% (others); random init should land well
        // below 90 and above 10.
        assert!((10.0..90.0).contains(&rep.average), "avg={}", rep.average);
    }
}
