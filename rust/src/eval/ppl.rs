//! Perplexity evaluation — the Wiki2/C4 columns of Tables 1–3.
//!
//! PPL = exp(mean NLL of next-token prediction) over contiguous windows of
//! the test split, the standard lm-eval protocol the paper uses. The logits
//! function is pluggable so the same code path evaluates the native forward
//! and the AOT HLO artifact.

use crate::calib::batcher::eval_windows;
use crate::kvpool::{KvPool, PoolCfg};
use crate::model::{forward_logits, DecodeState, KvSpec, ModelExec};
use crate::tensor::Matrix;
use anyhow::{ensure, Result};

/// NLL of one next-token prediction given a logits row.
fn row_nll(row: &[f32], target: usize) -> f64 {
    let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f64 =
        row.iter().map(|v| ((v - maxv) as f64).exp()).sum::<f64>().ln() + maxv as f64;
    lse - row[target] as f64
}

/// Teacher-forced mean NLL of one window through a KV-cached decode state,
/// prefilling in spans of `chunk` tokens (PR 7). Bit-identical to the
/// historical one-token-per-step loop for every chunk size: `step_span`
/// computes each row with the exact per-position op order of the one-token
/// step, and the NLL terms are accumulated in the same left-to-right f64
/// order the row loop always used.
fn window_decode_nll<M: ModelExec>(st: &mut DecodeState<M>, win: &[u8], chunk: usize) -> f64 {
    let n = win.len() - 1;
    let chunk = chunk.max(1);
    let mut total = 0.0f64;
    let mut t = 0usize;
    while t < n {
        let len = chunk.min(n - t);
        let logits = st.step_span(&win[t..t + len]);
        for r in 0..len {
            total += row_nll(logits.row(r), win[t + r + 1] as usize);
        }
        t += len;
    }
    total / n as f64
}

/// Mean NLL of a window given its logits `[T, vocab]`.
pub fn window_nll(logits: &Matrix, tokens: &[u8]) -> f64 {
    let n = tokens.len() - 1;
    let mut total = 0.0f64;
    for t in 0..n {
        total += row_nll(logits.row(t), tokens[t + 1] as usize);
    }
    total / n as f64
}

/// Perplexity with a caller-supplied logits function (native or runtime).
pub fn perplexity_with(
    data: &[u8],
    seq_len: usize,
    max_windows: usize,
    mut logits_fn: impl FnMut(&[u8]) -> Matrix,
) -> f64 {
    let windows = eval_windows(data, seq_len, max_windows);
    assert!(!windows.is_empty(), "no evaluation windows");
    let mut nll = 0.0f64;
    for w in &windows {
        nll += window_nll(&logits_fn(w), w);
    }
    (nll / windows.len() as f64).exp()
}

/// Perplexity of a model (native forward, parallel over windows). Generic
/// over the execution representation — `tsgo eval --packed` runs exactly
/// this on an [`crate::model::ExecModel`] with fused dequant GEMMs.
pub fn perplexity<M: ModelExec>(m: &M, data: &[u8], seq_len: usize, max_windows: usize) -> f64 {
    let windows = eval_windows(data, seq_len, max_windows);
    assert!(!windows.is_empty(), "no evaluation windows");
    let nlls = crate::util::threadpool::parallel_map_items(&windows, |win| {
        window_nll(&forward_logits(m, win), win)
    });
    (nlls.iter().sum::<f64>() / nlls.len() as f64).exp()
}

/// Perplexity measured through the serve-path KV-cached decode instead of
/// the full-sequence forward: every window is teacher-forced token by token
/// through a [`DecodeState`] with the given KV representation. With
/// [`KvSpec::DenseF32`] this matches [`perplexity`] up to the decode path's
/// usual 1e-4-level logit agreement; with a packed spec the difference *is*
/// the KV-quantization accuracy cost — the ppl-delta number `tsgo eval
/// --kv-bits` reports.
pub fn decode_perplexity<M: ModelExec>(
    m: &M,
    data: &[u8],
    seq_len: usize,
    max_windows: usize,
    kv: KvSpec,
) -> f64 {
    let windows = eval_windows(data, seq_len, max_windows);
    assert!(!windows.is_empty(), "no evaluation windows");
    let chunk = crate::serve::default_prefill_chunk();
    let nlls = crate::util::threadpool::parallel_map_items(&windows, |win| {
        let mut st = DecodeState::with_kv(m, kv);
        window_decode_nll(&mut st, win, chunk)
    });
    (nlls.iter().sum::<f64>() / nlls.len() as f64).exp()
}

/// [`decode_perplexity`] with the KV caches paged out of one shared
/// budget-bounded [`KvPool`] (`tsgo eval --kv-bits N --kv-pool-mb M`).
/// Numerically identical to the contiguous run — paging never changes
/// bytes, only where they live — so the interesting outputs are the
/// side effects: the run proves every window decodes inside the budget.
///
/// Eval needs no preemption machinery: window demand is known up front
/// (`seq_len` rows per cache), so admission is simply "run at most as many
/// windows at once as the pool can hold"; errors if even one window's
/// peak demand exceeds the budget.
pub fn decode_perplexity_pooled<M: ModelExec>(
    m: &M,
    data: &[u8],
    seq_len: usize,
    max_windows: usize,
    kv: KvSpec,
    pc: PoolCfg,
) -> Result<f64> {
    let windows = eval_windows(data, seq_len, max_windows);
    ensure!(!windows.is_empty(), "no evaluation windows");
    let cfg = m.config();
    let pool = KvPool::new(pc, kv, cfg);
    // Peak pages one window holds: K and V per layer, each spanning
    // ceil(seq_len / page_tokens) pages.
    let per_window = 2 * cfg.n_layers * pool.pages_for_rows(seq_len);
    ensure!(
        per_window <= pool.total_pages(),
        "kv pool too small for one {seq_len}-token eval window: it needs {per_window} \
         pages but the pool holds {} — raise --kv-pool-mb",
        pool.total_pages()
    );
    let lanes = (pool.total_pages() / per_window)
        .min(crate::util::threadpool::num_threads())
        .max(1);
    let prefill = crate::serve::default_prefill_chunk();
    let mut nll = 0.0f64;
    for chunk in windows.chunks(lanes) {
        let nlls = crate::util::threadpool::parallel_map_items(chunk, |win| {
            let mut st = DecodeState::with_kv_pool(m, kv, Some(&pool));
            window_decode_nll(&mut st, win, prefill)
        });
        nll += nlls.iter().sum::<f64>();
    }
    Ok((nll / windows.len() as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::{Corpus, CorpusKind};
    use crate::model::{ModelWeights, Preset};
    use crate::util::rng::Rng;

    #[test]
    fn random_model_ppl_near_vocab_size() {
        let mut rng = Rng::new(1);
        let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
        let c = Corpus::generate(CorpusKind::SynthWiki, 5_000, 2);
        let ppl = perplexity(&w, &c.bytes, 48, 4);
        // untrained byte model ≈ uniform → PPL ≈ 256
        assert!((150.0..400.0).contains(&ppl), "ppl={ppl}");
    }

    #[test]
    fn perplexity_with_matches_native() {
        let mut rng = Rng::new(2);
        let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
        let c = Corpus::generate(CorpusKind::SynthC4, 3_000, 3);
        let a = perplexity(&w, &c.bytes, 32, 3);
        let b = perplexity_with(&c.bytes, 32, 3, |t| forward_logits(&w, t));
        assert!((a - b).abs() < 1e-9 * a);
    }

    #[test]
    fn decode_ppl_matches_forward_ppl_with_f32_kv() {
        let mut rng = Rng::new(4);
        let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
        let c = Corpus::generate(CorpusKind::SynthWiki, 4_000, 6);
        let a = perplexity(&w, &c.bytes, 32, 3);
        let b = decode_perplexity(&w, &c.bytes, 32, 3, KvSpec::DenseF32);
        assert!((a - b).abs() < 1e-3 * a, "forward {a} vs decode {b}");
    }

    #[test]
    fn quantized_kv_ppl_within_tolerance() {
        // The documented accuracy bars: int8-KV decode ppl within 2% of the
        // f32-KV decode ppl, int4 within 5% (ROADMAP "Quantized KV cache").
        let mut rng = Rng::new(5);
        let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
        let c = Corpus::generate(CorpusKind::SynthC4, 4_000, 9);
        let base = decode_perplexity(&w, &c.bytes, 32, 3, KvSpec::DenseF32);
        for (bits, tol) in [(8u8, 0.02), (4, 0.05)] {
            let q = decode_perplexity(
                &w,
                &c.bytes,
                32,
                3,
                KvSpec::PackedGroupwise { bits, group: 64 },
            );
            let delta = (q / base - 1.0).abs();
            assert!(delta < tol, "int{bits}: ppl {q} vs {base} (delta {delta:.4})");
        }
    }

    #[test]
    fn pooled_decode_ppl_is_bit_identical_to_contiguous() {
        // Paging moves KV bytes, never changes them; chunked lane summation
        // adds the same f64s in the same left-to-right order. So the pooled
        // ppl must equal the contiguous ppl to the last bit.
        let mut rng = Rng::new(6);
        let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
        let c = Corpus::generate(CorpusKind::SynthWiki, 4_000, 11);
        let kv = KvSpec::PackedGroupwise { bits: 8, group: 64 };
        let a = decode_perplexity(&w, &c.bytes, 32, 3, kv);
        let pc = PoolCfg { budget_bytes: 1 << 20, page_tokens: 8 };
        let b = decode_perplexity_pooled(&w, &c.bytes, 32, 3, kv, pc).unwrap();
        assert_eq!(a.to_bits(), b.to_bits(), "contiguous {a} vs pooled {b}");
        // and a budget below one window's peak demand is a clean error
        let tiny = PoolCfg { budget_bytes: 1, page_tokens: 8 };
        let err = decode_perplexity_pooled(&w, &c.bytes, 32, 3, kv, tiny)
            .unwrap_err()
            .to_string();
        assert!(err.contains("kv pool too small"), "{err}");
    }

    #[test]
    fn decode_nll_is_chunk_invariant_to_the_bit() {
        // The chunked teacher-forcing spine: any prefill-chunk size yields
        // the same f64 NLL, bit for bit, as the one-token loop.
        let mut rng = Rng::new(7);
        let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
        let c = Corpus::generate(CorpusKind::SynthWiki, 3_000, 13);
        let windows = eval_windows(&c.bytes, 32, 2);
        let kv = KvSpec::PackedGroupwise { bits: 8, group: 64 };
        for win in &windows {
            let mut st1 = DecodeState::with_kv(&w, kv);
            let base = window_decode_nll(&mut st1, win, 1);
            for chunk in [3usize, 16, 64] {
                let mut st = DecodeState::with_kv(&w, kv);
                let nll = window_decode_nll(&mut st, win, chunk);
                assert_eq!(base.to_bits(), nll.to_bits(), "chunk {chunk} diverged");
            }
        }
    }

    #[test]
    fn oracle_bigram_table_beats_random() {
        // Sanity for the metric itself: a "model" that knows the next token
        // exactly achieves PPL → 1.
        let tokens: Vec<u8> = (0..64).map(|i| (i % 7) as u8).collect();
        let mut nll_sum = 0.0;
        {
            // build perfect logits
            let mut logits = Matrix::zeros(64, 256);
            for t in 0..63 {
                logits[(t, tokens[t + 1] as usize)] = 50.0;
            }
            nll_sum += window_nll(&logits, &tokens);
        }
        let ppl = (nll_sum).exp();
        assert!(ppl < 1.01, "oracle ppl = {ppl}");
    }
}
