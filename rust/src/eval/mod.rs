//! Evaluation harness: perplexity and the synthetic zero-shot suite.

pub mod ppl;
pub mod tasks;

pub use ppl::{decode_perplexity, decode_perplexity_pooled, perplexity, perplexity_with};
pub use tasks::{task_suite, TaskReport};
