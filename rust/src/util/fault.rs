//! Deterministic fault injection for the serving stack.
//!
//! Production recovery paths (worker respawn, pipeline rebuild, step
//! timeouts) are only trustworthy if they run in CI, and they only run in
//! CI if the faults that trigger them can be injected *deterministically* —
//! "kill the worker handling the 17th step job", not "kill something
//! eventually". This module is that plane: a small set of **named fault
//! points** compiled into the serve/shard hot paths, armed at runtime by a
//! spec string, and hit-counted so a test can aim at an exact evaluation.
//!
//! # Fault points
//!
//! | name                 | where it fires                    | effect           |
//! |----------------------|-----------------------------------|------------------|
//! | `step_worker_panic`  | [`run_job`] (pool + inline paths) | worker panics    |
//! | `step_worker_slow_ms`| [`run_job`]                       | sleeps `value` ms|
//! | `shard_worker_panic` | shard span/act processing         | shard panics     |
//! | `channel_drop`       | step-pool reply send              | reply is lost    |
//! | `admit_exhaust`      | backend admission                 | verdict = Defer  |
//!
//! [`run_job`]: crate::serve
//!
//! # Grammar
//!
//! ```text
//! TSGO_FAULT ::= entry (',' entry)*
//! entry      ::= point ('=' value)? ('@hit=' N)?
//! ```
//!
//! `value` is the fault's u64 payload (milliseconds for
//! `step_worker_slow_ms`; ignored elsewhere), default 0. `N` is the 1-based
//! evaluation count at which the fault fires — **exactly once**, on the Nth
//! time execution passes that point after arming — default 1. Examples:
//!
//! ```text
//! TSGO_FAULT=step_worker_panic@hit=17        # the 17th step job panics
//! TSGO_FAULT=step_worker_slow_ms=20@hit=3    # the 3rd job sleeps 20 ms
//! TSGO_FAULT=admit_exhaust,shard_worker_panic@hit=5
//! ```
//!
//! Arming: `DynamicBatcher::spawn` arms `BatcherConfig::faults` when set,
//! else the `TSGO_FAULT` env var (re-armed — counters reset — per spawn, so
//! each server/test sees the same deterministic schedule). Tests can also
//! call [`arm`]/[`disarm`] directly.
//!
//! # Zero cost when unarmed
//!
//! The plane is compiled in unconditionally — production binaries carry it —
//! so the unarmed fast path must be free. [`fire`] is `#[inline]` and its
//! first (and, unarmed, only) instruction is one **relaxed atomic load** of
//! a process-global flag; the spec lookup, hit counter, and mutex live
//! behind that branch in a `#[cold]` function. The decode benches record a
//! `fault_armed` decode row next to the plain one to keep the "negligible
//! overhead" claim measured, not asserted.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Maximum entries one plan can hold. Fixed so [`FaultPlan`] stays `Copy`
/// (it rides inside `BatcherConfig`, which is passed by value everywhere).
pub const MAX_FAULTS: usize = 8;

/// A named point in the serving stack where a fault can be injected. See
/// the module docs for where each one lives and what it does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// Panic inside a decode step job (pool worker or inline fast path).
    StepWorkerPanic,
    /// Sleep `value` milliseconds inside a decode step job.
    StepWorkerSlowMs,
    /// Panic inside a shard worker while processing a span/activation.
    ShardWorkerPanic,
    /// Drop a step-pool reply instead of sending it (a lost message).
    ChannelDrop,
    /// Make backend admission report an exhausted pool (`Defer`) once.
    AdmitExhaust,
}

impl FaultPoint {
    /// Every point, in grammar-name order.
    pub const ALL: [FaultPoint; 5] = [
        FaultPoint::StepWorkerPanic,
        FaultPoint::StepWorkerSlowMs,
        FaultPoint::ShardWorkerPanic,
        FaultPoint::ChannelDrop,
        FaultPoint::AdmitExhaust,
    ];

    /// The grammar name (`TSGO_FAULT` spelling) of this point.
    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::StepWorkerPanic => "step_worker_panic",
            FaultPoint::StepWorkerSlowMs => "step_worker_slow_ms",
            FaultPoint::ShardWorkerPanic => "shard_worker_panic",
            FaultPoint::ChannelDrop => "channel_drop",
            FaultPoint::AdmitExhaust => "admit_exhaust",
        }
    }

    fn from_name(s: &str) -> Option<FaultPoint> {
        FaultPoint::ALL.into_iter().find(|p| p.name() == s)
    }
}

impl std::fmt::Display for FaultPoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One armed fault: fire at `point`, carrying `value`, on the `hit`-th
/// evaluation (1-based) after arming — exactly once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub point: FaultPoint,
    pub value: u64,
    pub hit: u64,
}

/// A parsed, inert fault schedule (the `TSGO_FAULT` grammar as data).
/// `Copy` by design — it travels inside `BatcherConfig`. Arm it with
/// [`arm`] to make it live.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    entries: [Option<FaultSpec>; MAX_FAULTS],
}

impl FaultPlan {
    /// Parse the `TSGO_FAULT` grammar (see module docs).
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        let mut n = 0usize;
        for raw in s.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            if n >= MAX_FAULTS {
                return Err(format!("fault spec holds more than {MAX_FAULTS} entries"));
            }
            let (head, hit) = match entry.split_once("@hit=") {
                Some((h, nstr)) => {
                    let hit: u64 = nstr
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad hit count in fault entry '{entry}'"))?;
                    if hit == 0 {
                        return Err(format!("hit count must be >= 1 in '{entry}'"));
                    }
                    (h.trim(), hit)
                }
                None => (entry, 1),
            };
            let (name, value) = match head.split_once('=') {
                Some((p, v)) => (
                    p.trim(),
                    v.trim()
                        .parse::<u64>()
                        .map_err(|_| format!("bad value in fault entry '{entry}'"))?,
                ),
                None => (head, 0),
            };
            let point = FaultPoint::from_name(name).ok_or_else(|| {
                format!(
                    "unknown fault point '{name}' (known: {})",
                    FaultPoint::ALL.map(FaultPoint::name).join(", ")
                )
            })?;
            plan.entries[n] = Some(FaultSpec { point, value, hit });
            n += 1;
        }
        Ok(plan)
    }

    /// A one-entry plan — the common test spelling.
    pub fn single(point: FaultPoint, value: u64, hit: u64) -> FaultPlan {
        FaultPlan::default().with(point, value, hit)
    }

    /// Builder: append one entry. Panics past [`MAX_FAULTS`] — this is
    /// config-time API, not a runtime path.
    pub fn with(mut self, point: FaultPoint, value: u64, hit: u64) -> FaultPlan {
        assert!(hit >= 1, "fault hit counts are 1-based");
        let slot = self
            .entries
            .iter_mut()
            .find(|e| e.is_none())
            .expect("fault plan full (MAX_FAULTS entries)");
        *slot = Some(FaultSpec { point, value, hit });
        self
    }

    /// No entries → arming this plan disarms the plane.
    pub fn is_empty(&self) -> bool {
        self.entries.iter().all(Option::is_none)
    }

    /// The armed entries, in order.
    pub fn specs(&self) -> impl Iterator<Item = FaultSpec> + '_ {
        self.entries.iter().flatten().copied()
    }
}

impl std::fmt::Display for FaultPlan {
    /// Prints the `TSGO_FAULT` grammar; round-trips through [`FaultPlan::parse`].
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        for s in self.specs() {
            if !first {
                f.write_str(",")?;
            }
            first = false;
            write!(f, "{}", s.point)?;
            if s.value != 0 {
                write!(f, "={}", s.value)?;
            }
            if s.hit != 1 {
                write!(f, "@hit={}", s.hit)?;
            }
        }
        Ok(())
    }
}

/// The live (armed) plan: specs plus per-entry evaluation counters. Kept
/// separate from [`FaultPlan`] so the inert config type stays `Copy` and
/// the counters reset on every (re-)arm.
struct ArmedPlan {
    entries: Vec<(FaultSpec, AtomicU64)>,
}

impl ArmedPlan {
    fn new(plan: &FaultPlan) -> ArmedPlan {
        ArmedPlan {
            entries: plan.specs().map(|s| (s, AtomicU64::new(0))).collect(),
        }
    }

    /// Count one evaluation of `point`; `Some(value)` exactly when an
    /// entry's counter reaches its `hit`.
    fn check(&self, point: FaultPoint) -> Option<u64> {
        let mut fired = None;
        for (spec, count) in &self.entries {
            if spec.point == point {
                let n = count.fetch_add(1, Ordering::Relaxed) + 1;
                if n == spec.hit {
                    fired = Some(spec.value);
                }
            }
        }
        fired
    }
}

/// The one-load unarmed gate. Relaxed is enough: arming happens-before the
/// work it targets through channel/thread creation, and a stale `false`
/// read during a racy re-arm only delays a fault by one evaluation.
static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<ArmedPlan>>> = Mutex::new(None);

/// Arm `plan` process-wide, resetting all hit counters. An empty plan
/// disarms.
pub fn arm(plan: &FaultPlan) {
    let armed = (!plan.is_empty()).then(|| Arc::new(ArmedPlan::new(plan)));
    let mut guard = PLAN.lock().unwrap_or_else(|p| p.into_inner());
    ARMED.store(armed.is_some(), Ordering::Relaxed);
    *guard = armed;
}

/// Disarm the plane (every [`fire`] returns `None` again).
pub fn disarm() {
    arm(&FaultPlan::default());
}

/// Arm from `TSGO_FAULT` when it is set and parses; returns whether the
/// plane is now armed from the env. A malformed spec is a loud no-op (a
/// typo'd chaos run must not silently test nothing), an unset var leaves
/// the current state alone.
pub fn arm_from_env() -> bool {
    let Ok(spec) = std::env::var("TSGO_FAULT") else {
        return false;
    };
    match FaultPlan::parse(&spec) {
        Ok(plan) => {
            arm(&plan);
            !plan.is_empty()
        }
        Err(e) => {
            eprintln!("warning: ignoring malformed TSGO_FAULT '{spec}': {e}");
            false
        }
    }
}

/// Whether any fault schedule is currently armed.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Evaluate a fault point: `Some(value)` iff an armed entry for `point`
/// just reached its hit count. This is the call compiled into hot paths —
/// unarmed it is a single relaxed load and a predictable branch.
#[inline]
pub fn fire(point: FaultPoint) -> Option<u64> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    fire_slow(point)
}

#[cold]
fn fire_slow(point: FaultPoint) -> Option<u64> {
    let plan = {
        let guard = PLAN.lock().unwrap_or_else(|p| p.into_inner());
        guard.clone()
    };
    plan.and_then(|p| p.check(point))
}

/// Panic at `point` when its fault fires (the `*_panic` points).
#[inline]
pub fn maybe_panic(point: FaultPoint) {
    if fire(point).is_some() {
        panic!("injected fault: {point}");
    }
}

/// Sleep the fired value in milliseconds at `point` (`step_worker_slow_ms`).
#[inline]
pub fn maybe_sleep(point: FaultPoint) {
    if let Some(ms) = fire(point) {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// `true` when the fault at `point` fires (valueless points).
#[inline]
pub fn fires(point: FaultPoint) -> bool {
    fire(point).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these tests never call `arm` — the global plane is shared with
    // every other test in this binary (a worker panic armed here could kill
    // an unrelated batcher test's decode). Counter semantics are tested on
    // `ArmedPlan` directly; global arm/disarm behaviour is exercised in
    // `tests/fault_injection.rs`, which owns its own process and serializes.

    #[test]
    fn grammar_round_trips() {
        for spec in [
            "step_worker_panic",
            "step_worker_slow_ms=20@hit=3",
            "shard_worker_panic@hit=5",
            "channel_drop,admit_exhaust@hit=2",
            "step_worker_panic@hit=17,step_worker_slow_ms=250",
        ] {
            let plan = FaultPlan::parse(spec).unwrap();
            assert_eq!(plan.to_string(), spec, "display must round-trip the grammar");
            assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
        }
    }

    #[test]
    fn parse_defaults_and_whitespace() {
        let plan = FaultPlan::parse(" step_worker_panic , channel_drop@hit=4 ").unwrap();
        let specs: Vec<FaultSpec> = plan.specs().collect();
        assert_eq!(specs[0], FaultSpec { point: FaultPoint::StepWorkerPanic, value: 0, hit: 1 });
        assert_eq!(specs[1], FaultSpec { point: FaultPoint::ChannelDrop, value: 0, hit: 4 });
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" , ").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "no_such_point",
            "step_worker_panic@hit=0",
            "step_worker_panic@hit=x",
            "step_worker_slow_ms=abc",
            "step_worker_slow_ms=-4",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' must not parse");
        }
        let nine = vec!["channel_drop"; MAX_FAULTS + 1].join(",");
        assert!(FaultPlan::parse(&nine).is_err(), "over-long plans must not parse");
    }

    #[test]
    fn armed_plan_fires_exactly_on_the_nth_hit() {
        let plan = FaultPlan::single(FaultPoint::StepWorkerSlowMs, 20, 3);
        let armed = ArmedPlan::new(&plan);
        assert_eq!(armed.check(FaultPoint::StepWorkerSlowMs), None);
        // a different point never consumes this point's counter
        assert_eq!(armed.check(FaultPoint::ChannelDrop), None);
        assert_eq!(armed.check(FaultPoint::StepWorkerSlowMs), None);
        assert_eq!(armed.check(FaultPoint::StepWorkerSlowMs), Some(20), "3rd hit fires");
        assert_eq!(armed.check(FaultPoint::StepWorkerSlowMs), None, "fires exactly once");
    }

    #[test]
    fn independent_points_count_independently() {
        let plan = FaultPlan::single(FaultPoint::AdmitExhaust, 0, 1)
            .with(FaultPoint::StepWorkerPanic, 0, 2);
        let armed = ArmedPlan::new(&plan);
        assert_eq!(armed.check(FaultPoint::AdmitExhaust), Some(0));
        assert_eq!(armed.check(FaultPoint::StepWorkerPanic), None);
        assert_eq!(armed.check(FaultPoint::StepWorkerPanic), Some(0));
        assert_eq!(armed.check(FaultPoint::AdmitExhaust), None);
    }
}
