//! Benchmark harness substrate (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean/std/percentiles, throughput
//! accounting, and a table printer used by all `rust/benches/*` targets to
//! regenerate the paper's tables.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub std: Duration,
    pub p50: Duration,
    pub p95: Duration,
    /// Optional work units per iteration (elements, tokens, FLOPs).
    pub units_per_iter: Option<f64>,
}

impl Measurement {
    /// Units per second (if `units_per_iter` set).
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / self.mean.as_secs_f64())
    }
}

/// Time `f` with `warmup` untimed and `iters` timed iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    bench_units(name, warmup, iters, None, &mut f)
}

/// Like [`bench`] with a throughput unit count per iteration.
pub fn bench_units<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    units_per_iter: Option<f64>,
    f: &mut F,
) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = crate::util::mean(&samples);
    let std = crate::util::stddev(&samples);
    Measurement {
        name: name.to_string(),
        iters: iters.max(1),
        mean: Duration::from_secs_f64(mean),
        std: Duration::from_secs_f64(std),
        p50: Duration::from_secs_f64(crate::util::percentile(&samples, 50.0)),
        p95: Duration::from_secs_f64(crate::util::percentile(&samples, 95.0)),
        units_per_iter,
    }
}

/// Print a set of measurements as an aligned table.
pub fn print_measurements(title: &str, ms: &[Measurement]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10} {:>14}",
        "benchmark", "mean", "std", "p50", "p95", "throughput"
    );
    for m in ms {
        let tp = m
            .throughput()
            .map(|t| {
                if t >= 1e9 {
                    format!("{:.2} G/s", t / 1e9)
                } else if t >= 1e6 {
                    format!("{:.2} M/s", t / 1e6)
                } else if t >= 1e3 {
                    format!("{:.2} K/s", t / 1e3)
                } else {
                    format!("{t:.1} /s")
                }
            })
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10} {:>14}",
            m.name,
            crate::util::fmt_duration(m.mean),
            crate::util::fmt_duration(m.std),
            crate::util::fmt_duration(m.p50),
            crate::util::fmt_duration(m.p95),
            tp
        );
    }
}

/// Simple markdown-style table printer for paper-table reproductions.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
    pub fn print(&self, title: &str) {
        println!("\n== {title} ==\n{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let m = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(m.mean > Duration::ZERO);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn throughput_computed() {
        let mut f = || std::thread::sleep(Duration::from_millis(1));
        let m = bench_units("sleep", 0, 3, Some(1000.0), &mut f);
        let tp = m.throughput().unwrap();
        assert!(tp > 0.0 && tp < 1_100_000.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "wiki2"]);
        t.row(vec!["GPTQ".into(), "214.7".into()]);
        t.row(vec!["ours".into(), "63.31".into()]);
        let s = t.render();
        assert!(s.contains("| GPTQ"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
