//! Tiny CLI argument parser substrate (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args, with
//! typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;

/// Declarative option spec used for `usage()` and validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    specs: Vec<OptSpec>,
}

impl Args {
    /// Parse `argv` (without the program/subcommand names) against `specs`.
    /// Unknown `--options` are rejected so typos fail loudly.
    pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args, String> {
        let mut out = Args { specs: specs.to_vec(), ..Default::default() };
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}"))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag, takes no value"));
                    }
                    out.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    out.options.insert(key, val);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str()).or_else(|| {
            self.specs
                .iter()
                .find(|s| s.name == name)
                .and_then(|s| s.default)
        })
    }

    pub fn str(&self, name: &str) -> String {
        self.get(name).unwrap_or("").to_string()
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        let v = self.get(name).ok_or_else(|| format!("missing --{name}"))?;
        v.parse().map_err(|_| format!("--{name}: '{v}' is not an integer"))
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        let v = self.get(name).ok_or_else(|| format!("missing --{name}"))?;
        v.parse().map_err(|_| format!("--{name}: '{v}' is not an integer"))
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        let v = self.get(name).ok_or_else(|| format!("missing --{name}"))?;
        v.parse().map_err(|_| format!("--{name}: '{v}' is not a number"))
    }
}

/// Render a usage block for a command.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for spec in specs {
        let head = if spec.is_flag {
            format!("  --{}", spec.name)
        } else {
            format!("  --{} <v>", spec.name)
        };
        let def = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("{head:<26} {}{def}\n", spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "preset", help: "model preset", default: Some("small"), is_flag: false },
            OptSpec { name: "bits", help: "bit width", default: Some("2"), is_flag: false },
            OptSpec { name: "verbose", help: "log more", default: None, is_flag: true },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::parse(&sv(&["--preset", "base", "--verbose", "pos1"]), &specs()).unwrap();
        assert_eq!(a.get("preset"), Some("base"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&sv(&["--bits=3"]), &specs()).unwrap();
        assert_eq!(a.usize("bits").unwrap(), 3);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[], &specs()).unwrap();
        assert_eq!(a.get("preset"), Some("small"));
        assert_eq!(a.usize("bits").unwrap(), 2);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::parse(&sv(&["--nope", "x"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&sv(&["--bits"]), &specs()).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(Args::parse(&sv(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let a = Args::parse(&sv(&["--bits", "two"]), &specs()).unwrap();
        assert!(a.usize("bits").is_err());
    }

    #[test]
    fn usage_renders() {
        let u = usage("tsgo quantize", "quantize a checkpoint", &specs());
        assert!(u.contains("--preset"));
        assert!(u.contains("[default: small]"));
    }
}
