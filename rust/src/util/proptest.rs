//! Property-testing harness substrate (the proptest crate is unavailable
//! offline). Seeded generators + a check loop with linear input shrinking.
//!
//! Usage (no_run: doctest binaries can't locate the xla rpath at exec time):
//! ```no_run
//! use tsgo::util::proptest::{check, prop_assert, Gen};
//! check("sum is commutative", 100, |g| {
//!     let a = g.f32_in(-10.0, 10.0);
//!     let b = g.f32_in(-10.0, 10.0);
//!     prop_assert(((a + b) - (b + a)).abs() < 1e-6, "commutes")
//! });
//! ```

use crate::util::rng::Rng;

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Assert helper returning a `PropResult`.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Generator handle passed to properties; wraps the seeded RNG and records a
/// "size" knob that the runner anneals from small to large so early failures
/// are small ones.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    /// Vector of normal(0, std) values with length scaled by current size.
    pub fn normal_vec(&mut self, len: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(len, std)
    }
    /// A "sized" dimension: in [1, max(1, size)].
    pub fn dim(&mut self, cap: usize) -> usize {
        self.usize_in(1, self.size.clamp(1, cap))
    }
}

/// Run `prop` `cases` times with annealed sizes; panics with the seed and
/// message of the first failure (re-run reproducibly with that seed).
pub fn check<F: FnMut(&mut Gen) -> PropResult>(name: &str, cases: usize, mut prop: F) {
    let base_seed = std::env::var("TSGO_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        // anneal size: first quarter of cases are tiny
        let size = 2 + (case * 32) / cases.max(1);
        let mut g = Gen { rng: Rng::new(seed), size };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed}, size {size}): {msg}\n\
                 reproduce with TSGO_PROP_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 50, |g| {
            n += 1;
            let x = g.f64_in(0.0, 1.0);
            prop_assert((0.0..1.0).contains(&x), "in range")
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |g| {
            let x = g.usize_in(0, 100);
            prop_assert(x < 101, "ok")?;
            prop_assert(false, "always fails")
        });
    }

    #[test]
    fn sizes_anneal_upward() {
        let mut sizes = vec![];
        check("sizes", 64, |g| {
            sizes.push(g.size);
            Ok(())
        });
        assert!(sizes[0] < sizes[sizes.len() - 1]);
    }
}
