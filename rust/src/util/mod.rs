//! Infrastructure substrates built from scratch for the offline environment:
//! RNG, JSON, CLI parsing, thread pool, benchmark harness and a small
//! property-testing harness (no rand/serde/clap/rayon/criterion/proptest
//! crates are available offline).

pub mod bench;
pub mod cli;
pub mod fault;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod scratch;
pub mod threadpool;

/// Shareable raw pointer for disjoint parallel writes (workers must write
/// non-overlapping regions). The accessor method keeps closure capture on the
/// wrapper (Sync) rather than the raw pointer field (not Sync).
pub struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    #[inline]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// Format a `std::time::Duration` compactly (`1.234s`, `56.7ms`, `890µs`).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
        assert_eq!(percentile(&[5.0, 1.0, 3.0], 50.0), 3.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn duration_formatting() {
        use std::time::Duration;
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000s");
        assert!(fmt_duration(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(12)).ends_with("ms"));
    }
}
