//! Scoped thread-pool substrate (rayon is unavailable offline).
//!
//! Provides `parallel_for` / `parallel_map` over index ranges with dynamic
//! work-stealing via an atomic cursor — the pattern used by the blocked GEMM,
//! Hessian accumulation and the per-projection quantization workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: `TSGO_THREADS` env var override, else
/// `std::thread::available_parallelism()`. Resolved once and cached — the
/// count cannot meaningfully change mid-process, and this sits on the
/// per-token decode path (twice per parallel region via [`auto_chunk`]).
pub fn num_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("TSGO_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    })
}

/// Steal-chunk size derived from the machine's parallelism instead of a
/// per-call-site constant: spread `n` items over ~`OVERSUB` steals per
/// worker ([`num_threads`], i.e. `TSGO_THREADS` or all cores), so small `n`
/// still balances across threads and large `n` doesn't thrash the cursor.
pub fn auto_chunk(n: usize) -> usize {
    const OVERSUB: usize = 4;
    (n / (num_threads() * OVERSUB)).max(1)
}

/// [`parallel_for_chunked`] with an [`auto_chunk`]-derived chunk size — the
/// default way to parallelize an index range.
pub fn parallel_for_auto<F: Fn(usize) + Sync>(n: usize, f: F) {
    parallel_for_chunked(n, auto_chunk(n), f)
}

/// Run `f(i)` for every `i in 0..n`, distributing indices across threads
/// with an atomic cursor (chunked to reduce contention). `f` must be Sync.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    parallel_for_chunked(n, 1, f)
}

/// Like [`parallel_for`] but each steal grabs `chunk` consecutive indices.
pub fn parallel_for_chunked<F: Fn(usize) + Sync>(n: usize, chunk: usize, f: F) {
    let nt = num_threads().min(n.max(1));
    if n == 0 {
        return;
    }
    if nt <= 1 || n == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    let chunk = chunk.max(1);
    std::thread::scope(|s| {
        for _ in 0..nt {
            s.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + chunk).min(n) {
                    f(i);
                }
            });
        }
    });
}

/// Parallel map over `0..n` preserving order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let out: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    parallel_for(n, |i| {
        let v = f(i);
        out.lock().unwrap()[i] = Some(v);
    });
    out.into_inner().unwrap().into_iter().map(|x| x.unwrap()).collect()
}

/// Parallel map over a slice of items.
pub fn parallel_map_items<I: Sync, T: Send, F: Fn(&I) -> T + Sync>(items: &[I], f: F) -> Vec<T> {
    parallel_map(items.len(), |i| f(&items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunked_covers_all() {
        let sum = AtomicU64::new(0);
        parallel_for_chunked(101, 7, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 100 * 101 / 2);
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_items() {
        let items = vec!["a", "bb", "ccc"];
        assert_eq!(parallel_map_items(&items, |s| s.len()), vec![1, 2, 3]);
    }

    #[test]
    fn auto_chunk_spreads_work() {
        assert_eq!(auto_chunk(0), 1);
        assert_eq!(auto_chunk(1), 1);
        let nt = num_threads();
        // enough items that every worker gets multiple steals
        let n = nt * 64;
        let c = auto_chunk(n);
        assert!(c >= 1 && c * nt <= n, "chunk {c} for n={n}, nt={nt}");
        let sum = AtomicU64::new(0);
        parallel_for_auto(n, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn empty_and_single() {
        parallel_for(0, |_| panic!("must not run"));
        let v = parallel_map(1, |i| i + 1);
        assert_eq!(v, vec![1]);
    }
}
