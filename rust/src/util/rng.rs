//! Deterministic PRNG substrate (xoshiro256** + splitmix64 seeding).
//!
//! Every stochastic component in the system (corpus generation, weight init,
//! calibration sampling, property tests) draws from this RNG so runs are
//! exactly reproducible from a single `u64` seed.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream for a named sub-component.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's method without bias correction is fine for our n << 2^64.
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of N(0, std²) f32 samples.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() as f32) * std).collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    ///
    /// The floating-point leftover fallback lands on the last *positive*
    /// weight, never on a zero-weight tail entry — callers like top-k/top-p
    /// sampling mask out candidates by zeroing their weight and rely on masked
    /// indices being unreachable.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        let mut last_positive = weights.len() - 1;
        for (i, w) in weights.iter().enumerate() {
            if *w > 0.0 {
                last_positive = i;
            }
            x -= w;
            if x <= 0.0 && *w > 0.0 {
                return i;
            }
        }
        last_positive
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for n in 1..50 {
            for _ in 0..100 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let s = crate::util::stddev(&xs);
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((s - 1.0).abs() < 0.03, "std {s}");
    }

    #[test]
    fn weighted_sampling_prefers_heavy() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] * 4);
        assert!(counts[2] > counts[1] * 4);
    }

    #[test]
    fn weighted_never_picks_zero_weight() {
        let mut r = Rng::new(13);
        // Zero-weight head, tail, and interior entries must be unreachable
        // even via the floating-point leftover fallback.
        for _ in 0..10_000 {
            let i = r.weighted(&[0.0, 1.0, 0.0, 2.0, 0.0, 0.0]);
            assert!(i == 1 || i == 3, "picked masked index {i}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
