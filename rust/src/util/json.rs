//! Minimal JSON substrate (parser + writer) — serde is unavailable offline.
//!
//! Used for the artifact manifest (`artifacts/manifest.json` written by
//! `python/compile/aot.py`), run configs, benchmark reports and the serve
//! protocol. Supports the full JSON data model; numbers are stored as f64
//! (adequate: all our integers are small shapes/counts).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Array of usizes (e.g. a shape). Empty vec if not an array.
    pub fn usize_vec(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default()
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }
    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, thiserror::Error)]
#[error("json error at byte {at}: {msg}")]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our manifests;
                            // map lone surrogates to replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 run
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shape":[2,64],"name":"q_proj","ok":true,"f":1.25}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn usize_vec_accessor() {
        let v = Json::parse("[2, 64, 128]").unwrap();
        assert_eq!(v.usize_vec(), vec![2, 64, 128]);
    }

    #[test]
    fn writer_escapes_controls() {
        let s = Json::Str("a\"b\u{1}".into()).to_string();
        assert_eq!(s, "\"a\\\"b\\u0001\"");
    }
}
