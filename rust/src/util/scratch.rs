//! Reusable f32 scratch buffers for the packed execution hot path.
//!
//! The fused GEMV/GEMM needs per-call working memory (folded activations,
//! per-group sums). Allocating it per call puts `vec![0.0; n]` — an
//! allocation *plus* a zeroing memset — on the per-token decode path.
//! Checkout order instead: a lock-free **per-thread** cache first (the
//! decode loop reuses its own buffers with zero synchronization), then a
//! bounded process-wide overflow pool shared across threads (so buffers
//! survive the short-lived scoped workers the threadpool spawns). Both
//! layers are byte-capped: a large prefill burst can't pin its multi-MB
//! fold buffers for the process lifetime.
//!
//! Contract: checked-out buffers have **arbitrary contents** (stale data
//! from a previous use). Every consumer must fully overwrite what it reads —
//! which `fold_activation`/`group_sums` guarantee (see the full-overwrite
//! contract on [`crate::tensor::packed::group_sums`]).

use std::cell::RefCell;
use std::sync::Mutex;

/// Buffers the shared pool retains; beyond this the excess is freed.
const MAX_POOLED: usize = 64;
/// Total f32 capacity the shared pool may retain (≈ 32 MB).
const MAX_POOLED_ELEMS: usize = 8 << 20;
/// Buffers each thread caches locally (lock-free fast path).
const MAX_LOCAL: usize = 8;
/// Largest buffer (in f32s, ≈ 1 MB) kept in a thread-local cache; bigger
/// ones go to the shared pool so per-thread retention stays ≤ ~8 MB and
/// prefill-sized buffers are reusable across threads.
const MAX_LOCAL_BUF_ELEMS: usize = 256 << 10;

struct Pool {
    bufs: Vec<Vec<f32>>,
    /// Sum of `capacity()` over `bufs`.
    elems: usize,
}

static POOL: Mutex<Pool> = Mutex::new(Pool { bufs: Vec::new(), elems: 0 });

thread_local! {
    static LOCAL: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// A checked-out scratch buffer; derefs to `[f32]` of exactly the requested
/// length and returns its storage to a cache on drop.
pub struct ScratchF32 {
    buf: Vec<f32>,
}

impl std::ops::Deref for ScratchF32 {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.buf
    }
}

impl std::ops::DerefMut for ScratchF32 {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }
}

impl Drop for ScratchF32 {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        if buf.capacity() == 0 {
            return;
        }
        if buf.capacity() <= MAX_LOCAL_BUF_ELEMS {
            let kept = LOCAL.with(|l| {
                let mut l = l.borrow_mut();
                if l.len() < MAX_LOCAL {
                    l.push(buf);
                    return None;
                }
                Some(buf)
            });
            let Some(buf) = kept else { return };
            return pool_return(buf);
        }
        pool_return(buf);
    }
}

fn pool_return(buf: Vec<f32>) {
    let mut pool = POOL.lock().unwrap();
    if pool.bufs.len() < MAX_POOLED && pool.elems + buf.capacity() <= MAX_POOLED_ELEMS {
        pool.elems += buf.capacity();
        pool.bufs.push(buf);
    } // else: drop the storage — retention stays bounded in bytes
}

/// Check out a scratch buffer of exactly `len` f32s with ARBITRARY contents.
///
/// Size-aware: a buffer whose capacity already fits `len` is preferred —
/// local cache first (lock-free), then the shared pool — so a decode thread
/// alternating column-sized and group-sized checkouts never reallocates,
/// and a prefill-sized request finds its prefill-sized buffer in the shared
/// pool instead of repeatedly regrowing a small local one. Only when
/// nothing fits anywhere does it fall back to regrowing an undersized
/// local buffer (or a fresh allocation).
pub fn take_f32(len: usize) -> ScratchF32 {
    let recycled = LOCAL
        .with(|l| take_fitting(&mut l.borrow_mut(), len))
        .or_else(|| {
            let mut pool = POOL.lock().unwrap();
            let buf = take_fitting(&mut pool.bufs, len);
            if let Some(b) = &buf {
                pool.elems -= b.capacity();
            }
            buf
        })
        .or_else(|| LOCAL.with(|l| l.borrow_mut().pop()));
    let mut buf = recycled.unwrap_or_default();
    if buf.capacity() < len {
        // growth path: drop stale contents so resize doesn't copy them
        // across the reallocation
        buf.clear();
    }
    // resize, not a fresh vec: reuses capacity; zero-fills only growth.
    buf.resize(len, 0.0);
    ScratchF32 { buf }
}

/// Remove and return a buffer whose capacity already fits `len`, if any.
fn take_fitting(list: &mut Vec<Vec<f32>>, len: usize) -> Option<Vec<f32>> {
    let i = list.iter().position(|b| b.capacity() >= len)?;
    Some(list.swap_remove(i))
}

/// Buffers currently parked in the *shared* pool (observability / tests).
pub fn pooled() -> usize {
    POOL.lock().unwrap().bufs.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_length_is_exact() {
        // Caches are global/thread-local and tests run concurrently, so
        // nothing is asserted about WHICH allocation comes back — only the
        // contracts: exact length, writable, shared pool never over cap.
        let mut a = take_f32(512);
        assert_eq!(a.len(), 512);
        a[0] = 7.0;
        a[511] = -7.0;
        drop(a);
        let b = take_f32(512);
        assert_eq!(b.len(), 512);
        assert!(pooled() <= MAX_POOLED);
    }

    #[test]
    fn local_cache_reuses_storage_on_one_thread() {
        // On a single thread with the local cache warm, a same-size
        // checkout must come back without reallocating. Runs on a fresh
        // thread so the local cache state is deterministic.
        std::thread::spawn(|| {
            let (ptr, cacheable) = {
                let a = take_f32(128);
                (a.as_ptr() as usize, a.buf.capacity() <= MAX_LOCAL_BUF_ELEMS)
            };
            if !cacheable {
                // take_f32 recycled an oversized buffer from the shared
                // pool; it went back there on drop and another test thread
                // may legally have taken it — nothing deterministic to
                // assert in that case.
                return;
            }
            let b = take_f32(128);
            assert_eq!(b.as_ptr() as usize, ptr, "local cache should hand back the same buffer");
        })
        .join()
        .unwrap();
    }

    #[test]
    fn resize_across_sizes_keeps_length_contract() {
        {
            let mut small = take_f32(8);
            for v in small.iter_mut() {
                *v = f32::NAN;
            }
        }
        // A later larger checkout may recycle that storage; contents are
        // arbitrary by contract ("overwrite before read") — only the length
        // must be exact.
        let big = take_f32(1024);
        assert_eq!(big.len(), 1024);
        let empty = take_f32(0);
        assert_eq!(empty.len(), 0);
        // oversized buffers must route to the shared pool, not the local
        // cache, when dropped
        let huge = take_f32(MAX_LOCAL_BUF_ELEMS + 1);
        assert_eq!(huge.len(), MAX_LOCAL_BUF_ELEMS + 1);
        drop(huge);
        assert!(pooled() <= MAX_POOLED);
    }
}
