//! # tsgo — Two-Stage Grid Optimization for Group-wise Quantization of LLMs
//!
//! A from-scratch reproduction of the paper's post-training-quantization
//! system as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the quantization coordinator: calibration
//!   streaming, Hessian/deviation statistics, the GPTQ inner loop, the
//!   paper's two-stage group-scale optimization ([`quant::stage1`],
//!   [`quant::stage2`]), the layer-by-layer pipeline ([`pipeline`]),
//!   evaluation ([`eval`]) and a batched generation server ([`serve`])
//!   with an optional layer-sharded pipeline-parallel topology ([`shard`]),
//!   a budget-bounded paged KV memory pool ([`kvpool`]), and a lock-free
//!   telemetry plane ([`obs`]) scraped via `--metrics-addr` or the
//!   `{"stats": true}` control line.
//! * **L2 (python/compile)** — the Llamette transformer forward/backward in
//!   JAX, AOT-lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot-spots (Hessian accumulation, stage-1 grid search, fused
//!   dequantize-matmul), lowered inside the L2 graphs.
//!
//! Python never runs at runtime: the [`runtime`] module loads the HLO
//! artifacts via PJRT (`xla` crate) and executes them from Rust. Every
//! artifact-backed op also has a native Rust fallback so the algorithm layer
//! is fully testable without artifacts.

pub mod calib;
pub mod eval;
pub mod kvpool;
pub mod model;
pub mod obs;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
