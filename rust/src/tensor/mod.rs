//! Dense tensor + linear algebra substrate.
//!
//! The quantization algorithms operate on per-layer weight matrices and
//! Hessians (≤ a few thousand on a side), so a compact row-major f32 matrix
//! with a blocked, multi-threaded GEMM and a Cholesky-based solver family is
//! the whole substrate GPTQ needs. [`packed`] adds the deployment half:
//! bit-packed integer storage and the fused group-wise dequant GEMV kernels
//! the packed execution path runs on.

pub mod kernels;
pub mod linalg;
pub mod matrix;
pub mod packed;

pub use linalg::{cholesky_lower, cholesky_inverse_upper, invert_spd, solve_lower, solve_upper};
pub use matrix::Matrix;
pub use packed::PackedInts;
