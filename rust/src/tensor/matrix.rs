//! Row-major f32 matrix with blocked, threaded GEMM.

use crate::util::rng::Rng;
use crate::util::threadpool::parallel_for_auto;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols, std) }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// Extract a sub-matrix `[r0..r1) x [c0..c1)`.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for (ro, r) in (r0..r1).enumerate() {
            out.row_mut(ro).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Write `sub` into this matrix at offset (r0, c0).
    pub fn set_slice(&mut self, r0: usize, c0: usize, sub: &Matrix) {
        assert!(r0 + sub.rows <= self.rows && c0 + sub.cols <= self.cols);
        for r in 0..sub.rows {
            self.row_mut(r0 + r)[c0..c0 + sub.cols].copy_from_slice(sub.row(r));
        }
    }

    pub fn scale_inplace(&mut self, a: f32) {
        for x in &mut self.data {
            *x *= a;
        }
    }

    pub fn add_inplace(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += *y;
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Frobenius norm squared.
    pub fn frob2(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// `self @ other` — blocked, threaded GEMM.
    ///
    /// The kernel packs nothing (sizes here are small) but tiles over K and
    /// parallelizes over row blocks; the inner loop is an axpy over a full
    /// output row which autovectorizes well.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "gemm shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        const RB: usize = 32; // row block per steal
        let out_ptr = crate::util::SendPtr(out.data.as_mut_ptr());
        parallel_for_auto(m.div_ceil(RB), |rb| {
            let r0 = rb * RB;
            let r1 = (r0 + RB).min(m);
            for r in r0..r1 {
                // SAFETY: each worker writes a disjoint set of output rows.
                let orow: &mut [f32] = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.get().add(r * n), n)
                };
                let arow = self.row(r);
                for kk in 0..k {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = other.row(kk);
                    for (o, b) in orow.iter_mut().zip(brow) {
                        *o += a * *b;
                    }
                }
            }
        });
        out
    }

    /// `self @ other.T` without materializing the transpose (dot-product
    /// kernel; good when `other` rows are contiguous).
    pub fn matmul_bt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "gemm_bt shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Matrix::zeros(m, n);
        let out_ptr = crate::util::SendPtr(out.data.as_mut_ptr());
        parallel_for_auto(m, |r| {
            let orow: &mut [f32] =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(r * n), n) };
            let arow = self.row(r);
            for (c, o) in orow.iter_mut().enumerate() {
                *o = dot(arow, other.row(c));
            }
        });
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, v.len());
        (0..self.rows).map(|r| dot(self.row(r), v)).collect()
    }
}

/// Dot product with 4-way unrolling (autovectorizes to SIMD).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 33, 9), (64, 64, 64), (1, 7, 1)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-4, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_bt_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(13, 27, 1.0, &mut rng);
        let b = Matrix::randn(11, 27, 1.0, &mut rng);
        let got = a.matmul_bt(&b);
        let want = a.matmul(&b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(8, 8, 1.0, &mut rng);
        let i = Matrix::eye(8);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-6);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(5, 9, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn slice_roundtrip() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(10, 12, 1.0, &mut rng);
        let s = a.slice(2, 7, 3, 11);
        assert_eq!((s.rows, s.cols), (5, 8));
        let mut b = Matrix::zeros(10, 12);
        b.set_slice(2, 3, &s);
        assert_eq!(b.slice(2, 7, 3, 11), s);
        assert_eq!(s[(0, 0)], a[(2, 3)]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(7, 5, 1.0, &mut rng);
        let v: Vec<f32> = rng.normal_vec(5, 1.0);
        let vm = Matrix::from_vec(5, 1, v.clone());
        let want = a.matmul(&vm);
        let got = a.matvec(&v);
        for i in 0..7 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-5);
        }
    }

    #[test]
    fn prop_matmul_associative_with_identity_scaling() {
        check("A(Bv) == (AB)v", 30, |g| {
            let m = g.dim(12);
            let k = g.dim(12);
            let n = g.dim(12);
            let mut rng = g.rng.fork(7);
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let v = Matrix::randn(n, 1, 1.0, &mut rng);
            let lhs = a.matmul(&b.matmul(&v));
            let rhs = a.matmul(&b).matmul(&v);
            prop_assert(lhs.max_abs_diff(&rhs) < 1e-3, "associativity")
        });
    }

    #[test]
    fn frob_and_sub() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        let b = Matrix::zeros(1, 2);
        assert_eq!(a.sub(&b).frob2(), 25.0);
    }
}
