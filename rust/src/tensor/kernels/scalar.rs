//! Portable dequant dot kernels: the sequential reference and the
//! lane-striped scalar implementation the SIMD kernels mirror bit for bit.

use super::{block_bounds, chunk8};

/// Sequential in-register unpack dot — the original `dot_span` body. Exact
/// for every bit width 1..=8, any span offset and any ragged tail; the
/// striped kernels delegate their unaligned head/tail spans here.
///
/// Two paths: a word-at-a-time loop when values never straddle word
/// boundaries and the span starts word-aligned (bits ∈ {1,2,4,8} with
/// aligned groups — the common deployment shapes), and a streaming 64-bit
/// bit-buffer for everything else (3-bit, ragged starts).
#[inline]
pub fn dot_span_seq(words: &[u32], bits: u8, c0: usize, c1: usize, x: &[f32]) -> f32 {
    debug_assert!(c1 <= x.len());
    if c0 >= c1 {
        return 0.0;
    }
    let b = bits as usize;
    let mask = (1u32 << bits) - 1;
    if 32 % b == 0 && (c0 * b) % 32 == 0 {
        // Aligned path: each word holds 32/bits whole values.
        let vpw = 32 / b;
        let mut acc = 0.0f32;
        let mut j = c0;
        let mut wi = c0 * b / 32;
        while j < c1 {
            let mut w = words[wi];
            wi += 1;
            let n = vpw.min(c1 - j);
            for _ in 0..n {
                acc += (w & mask) as f32 * x[j];
                w >>= bits;
                j += 1;
            }
        }
        acc
    } else {
        // Streaming path: keep unconsumed bits in a u64 buffer (≤ 39 bits
        // live at any point since bits ≤ 8), refill one word at a time.
        let bit0 = c0 * b;
        let mut wi = bit0 / 32;
        let off = bit0 % 32;
        let mut buf = (words[wi] >> off) as u64;
        let mut have = 32 - off;
        wi += 1;
        let mut acc = 0.0f32;
        for xj in &x[c0..c1] {
            if have < b {
                buf |= (words[wi] as u64) << have;
                wi += 1;
                have += 32;
            }
            acc += ((buf as u32) & mask) as f32 * xj;
            buf >>= b;
            have -= b;
        }
        acc
    }
}

/// Sequential unpack dot with **f64 accumulation** — same streaming unpack
/// scheme as [`dot_span_seq`], for quantization-time consumers that go on
/// to subtract two large uncentered sums (the stage-2 CD denominators
/// compute `Σ q_j H_ij − z Σ H_ij`, where `q ≈ z` makes the difference tiny
/// relative to either term; f32 accumulation of the first sum would be
/// amplified catastrophically by that cancellation, f64 keeps it ~1e-13).
pub fn dot_span_f64(words: &[u32], bits: u8, c0: usize, c1: usize, x: &[f32]) -> f64 {
    debug_assert!(c1 <= x.len());
    if c0 >= c1 {
        return 0.0;
    }
    let b = bits as usize;
    let mask = (1u32 << bits) - 1;
    let bit0 = c0 * b;
    let mut wi = bit0 / 32;
    let off = bit0 % 32;
    let mut buf = (words[wi] >> off) as u64;
    let mut have = 32 - off;
    wi += 1;
    let mut acc = 0.0f64;
    for xj in &x[c0..c1] {
        if have < b {
            buf |= (words[wi] as u64) << have;
            wi += 1;
            have += 32;
        }
        acc += ((buf as u32) & mask) as f64 * *xj as f64;
        buf >>= b;
        have -= b;
    }
    acc
}

/// Sequential dequant **axpy** over a packed span: for every column
/// `j ∈ [c0, c1)`, `out[j − c0] += a · q_j + b`.
///
/// This is the `probs · V` half of the quantized-KV attend path: with
/// `a = w_t · s_g` and `b = −a · z_g`, accumulating one cached row into the
/// context is `ctx += a·q + b` per element. Unlike the dot kernels there is
/// **no cross-element reduction** — every output element owns an independent
/// `mul, add, add` chain — so any 8-wide vectorization of the same per-lane
/// ops is bit-identical to this loop by construction (the property
/// [`super::x86::axpy_span_avx2`] rides on).
pub fn axpy_span_seq(
    words: &[u32],
    bits: u8,
    c0: usize,
    c1: usize,
    a: f32,
    b: f32,
    out: &mut [f32],
) {
    if c0 >= c1 {
        return;
    }
    debug_assert!(out.len() >= c1 - c0);
    let bw = bits as usize;
    let mask = (1u32 << bits) - 1;
    // Streaming 64-bit bit-buffer unpack, same scheme as `dot_span_seq`.
    let bit0 = c0 * bw;
    let mut wi = bit0 / 32;
    let off = bit0 % 32;
    let mut buf = (words[wi] >> off) as u64;
    let mut have = 32 - off;
    wi += 1;
    for o in out[..c1 - c0].iter_mut() {
        if have < bw {
            buf |= (words[wi] as u64) << have;
            wi += 1;
            have += 32;
        }
        let q = ((buf as u32) & mask) as f32;
        *o += a * q + b;
        buf >>= bw;
        have -= bw;
    }
}

/// Fixed pairwise reduction over 8 partial sums. The AVX2 horizontal sum
/// (`x86::hsum8`) performs these exact additions in this exact order —
/// change one and bit-identity across tables breaks.
#[inline]
pub fn hsum8_tree(a: [f32; 8]) -> f32 {
    let s0 = [a[0] + a[4], a[1] + a[5], a[2] + a[6], a[3] + a[7]];
    let s1 = [s0[0] + s0[2], s0[1] + s0[3]];
    s1[0] + s1[1]
}

/// Lane-striped dot for bits ∈ {2, 3, 4, 8}: sequential head, 8-wide chunk
/// blocks into 8 independent accumulators (breaking the sequential
/// dependence chain — faster scalar, and the exact lane semantics of the
/// AVX2 kernels), pairwise-tree reduction, sequential tail. The final
/// combination order `(head + blocks) + tail` is part of the bit-identity
/// contract.
pub fn dot_span_lanes(words: &[u32], bits: u8, c0: usize, c1: usize, x: &[f32]) -> f32 {
    debug_assert!(c1 <= x.len());
    if c0 >= c1 {
        return 0.0;
    }
    let (head_end, main_end) = block_bounds(bits, c0, c1);
    let head = dot_span_seq(words, bits, c0, head_end, x);
    let b = bits as usize;
    let mask = (1u64 << b) - 1;
    let mut acc = [0.0f32; 8];
    let mut j = head_end;
    while j < main_end {
        let chunk = chunk8(words, b, j);
        for (l, a) in acc.iter_mut().enumerate() {
            *a += ((chunk >> (b * l)) & mask) as f32 * x[j + l];
        }
        j += 8;
    }
    let tail = dot_span_seq(words, bits, main_end, c1, x);
    (head + hsum8_tree(acc)) + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::packed::PackedInts;
    use crate::util::rng::Rng;

    #[test]
    fn hsum8_tree_is_the_documented_order() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        assert_eq!(hsum8_tree(a), 36.0);
        // order check against a value where association matters
        let b = [1e8f32, 1.0, -1e8, 1.0, 1e8, 1.0, -1e8, 1.0];
        // s0 = [2e8, 2.0, -2e8, 2.0]; s1 = [0.0, 4.0]; total 4.0
        assert_eq!(hsum8_tree(b), 4.0);
    }

    #[test]
    fn f64_dot_matches_exact_reference() {
        let mut rng = Rng::new(29);
        for bits in [1u8, 2, 3, 4, 5, 8] {
            let n = 97;
            let max = 1usize << bits;
            let vals: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() as usize % max) as u8).collect();
            let x: Vec<f32> = rng.normal_vec(n, 1.0);
            let p = PackedInts::pack(&vals, bits);
            for (c0, c1) in [(0, n), (7, 93), (33, 34), (5, 5)] {
                let got = dot_span_f64(&p.words, bits, c0, c1, &x);
                let want: f64 = vals[c0..c1]
                    .iter()
                    .zip(&x[c0..c1])
                    .map(|(&q, &v)| q as f64 * v as f64)
                    .sum();
                assert!(
                    (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                    "bits={bits} span=({c0},{c1}): {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn axpy_seq_matches_reference_all_widths() {
        let mut rng = Rng::new(17);
        for bits in [1u8, 2, 3, 4, 5, 8] {
            let n = 97;
            let max = 1usize << bits;
            let vals: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() as usize % max) as u8).collect();
            let p = PackedInts::pack(&vals, bits);
            let (a, b) = (0.37f32, -0.81f32);
            for (c0, c1) in [(0, n), (7, 93), (33, 34), (5, 5)] {
                let mut out: Vec<f32> = rng.normal_vec(n.max(c1 - c0), 1.0);
                let before = out.clone();
                axpy_span_seq(&p.words, bits, c0, c1, a, b, &mut out);
                for (k, (got, old)) in out.iter().zip(&before).enumerate() {
                    let want = if k < c1 - c0 {
                        old + (a * vals[c0 + k] as f32 + b)
                    } else {
                        *old
                    };
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "bits={bits} span=({c0},{c1}) k={k}"
                    );
                }
            }
        }
    }

    #[test]
    fn lanes_match_seq_within_rounding_all_widths() {
        let mut rng = Rng::new(3);
        for bits in super::super::STRIPED_BITS {
            let n = 131;
            let max = 1usize << bits;
            let vals: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() as usize % max) as u8).collect();
            let x: Vec<f32> = rng.normal_vec(n, 1.0);
            let p = PackedInts::pack(&vals, bits);
            for (c0, c1) in [(0, n), (0, 64), (64, n), (7, 93), (33, 34), (5, 5), (9, 9)] {
                let a = dot_span_lanes(&p.words, bits, c0, c1, &x);
                let b = dot_span_seq(&p.words, bits, c0, c1, &x);
                assert!(
                    (a - b).abs() <= 1e-3 * b.abs().max(1.0),
                    "bits={bits} span=({c0},{c1}): lanes {a} vs seq {b}"
                );
            }
        }
    }
}
