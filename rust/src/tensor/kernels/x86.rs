//! AVX2 dequant dot kernels (x86_64).
//!
//! Same lane-striped algorithm as [`super::scalar::dot_span_lanes`], with
//! the 8 lanes living in one `__m256`:
//!
//! * 2/3/4-bit — one [`super::chunk8`] window per block, fanned out with a
//!   per-lane variable shift (`vpsrlvd`) + mask, converted to f32.
//! * 8-bit — the packed row *is* a byte stream on little-endian; 8 bytes
//!   are widened with `vpmovzxbd` per block.
//!
//! Deliberately `mul + add`, **not** FMA: a fused multiply-add skips the
//! intermediate rounding and would break bit-identity with the scalar
//! reference (the property the dispatch layer tests ride on). The unpack
//! itself is integer-exact either way, and the packed hot path is memory-
//! bound — the win is the 8-wide unpack, not the last flop.

#![cfg(target_arch = "x86_64")]

use super::scalar::{axpy_span_seq, dot_span_seq};
use super::{block_bounds, chunk8};
use std::arch::x86_64::*;

/// Runtime gate for installing [`dot_span_avx2`] into a table.
pub(crate) fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2")
}

/// AVX2 dequant dot for bits ∈ {2, 3, 4, 8}. Bit-identical to
/// [`super::scalar::dot_span_lanes`].
///
/// Crate-private (see the `mod x86` declaration): must only be reached
/// through a kernel table installed after [`avx2_available`] returned true.
pub(crate) fn dot_span_avx2(words: &[u32], bits: u8, c0: usize, c1: usize, x: &[f32]) -> f32 {
    debug_assert!(avx2_available(), "dot_span_avx2 reached without AVX2");
    debug_assert!(c1 <= x.len());
    if c0 >= c1 {
        return 0.0;
    }
    // SAFETY: this function pointer is only installed into a kernel table
    // after `avx2_available()` returned true (see `kernels::best_table`).
    unsafe { dot_span_avx2_impl(words, bits, c0, c1, x) }
}

#[target_feature(enable = "avx2")]
unsafe fn dot_span_avx2_impl(words: &[u32], bits: u8, c0: usize, c1: usize, x: &[f32]) -> f32 {
    let (head_end, main_end) = block_bounds(bits, c0, c1);
    let head = dot_span_seq(words, bits, c0, head_end, x);
    let main = match bits {
        2 | 3 | 4 => srlv_blocks(words, bits as usize, head_end, main_end, x),
        8 => byte_blocks(words, head_end, main_end, x),
        _ => 0.0, // never installed for other widths; block_bounds made main empty
    };
    let tail = dot_span_seq(words, bits, main_end, c1, x);
    (head + main) + tail
}

/// Blocks for sub-byte widths: chunk → per-lane shift → mask → f32 → mul/add.
#[target_feature(enable = "avx2")]
unsafe fn srlv_blocks(words: &[u32], b: usize, j0: usize, j1: usize, x: &[f32]) -> f32 {
    let bi = b as i32;
    let shifts = _mm256_setr_epi32(0, bi, 2 * bi, 3 * bi, 4 * bi, 5 * bi, 6 * bi, 7 * bi);
    let mask = _mm256_set1_epi32(((1u32 << b) - 1) as i32);
    let mut acc = _mm256_setzero_ps();
    let mut j = j0;
    while j < j1 {
        // ≤ 32 bits for b ∈ {2,3,4}: the whole block fits one i32 lane seed.
        let chunk = chunk8(words, b, j) as u32;
        let lanes =
            _mm256_and_si256(_mm256_srlv_epi32(_mm256_set1_epi32(chunk as i32), shifts), mask);
        let vals = _mm256_cvtepi32_ps(lanes);
        let xs = _mm256_loadu_ps(x.as_ptr().add(j));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(vals, xs));
        j += 8;
    }
    hsum8(acc)
}

/// Blocks for 8-bit: widen 8 packed bytes per step.
#[target_feature(enable = "avx2")]
unsafe fn byte_blocks(words: &[u32], j0: usize, j1: usize, x: &[f32]) -> f32 {
    let bytes = words.as_ptr() as *const u8;
    let mut acc = _mm256_setzero_ps();
    let mut j = j0;
    while j < j1 {
        let q8 = _mm_loadl_epi64(bytes.add(j) as *const __m128i);
        let vals = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(q8));
        let xs = _mm256_loadu_ps(x.as_ptr().add(j));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(vals, xs));
        j += 8;
    }
    hsum8(acc)
}

/// AVX2 dequant axpy for bits ∈ {2, 3, 4, 8}: `out[j − c0] += a · q_j + b`
/// over the span. Bit-identical to [`super::scalar::axpy_span_seq`] — every
/// element is an independent `mul, add, add` chain (no reduction), and both
/// implementations perform those ops in the same order per element.
///
/// Crate-private like [`dot_span_avx2`]: only reachable through a kernel
/// table installed after [`avx2_available`] returned true.
#[allow(clippy::too_many_arguments)]
pub(crate) fn axpy_span_avx2(
    words: &[u32],
    bits: u8,
    c0: usize,
    c1: usize,
    a: f32,
    b: f32,
    out: &mut [f32],
) {
    debug_assert!(avx2_available(), "axpy_span_avx2 reached without AVX2");
    if c0 >= c1 {
        return;
    }
    // Real assert: the main loop stores 8 lanes at a time through a raw
    // pointer, and the table function pointers are reachable from safe code
    // (`KernelTable.axpy` is pub) — a short `out` must panic, not corrupt.
    assert!(out.len() >= c1 - c0, "axpy kernel: out too short ({} < {})", out.len(), c1 - c0);
    // SAFETY: installed into a table only after `avx2_available()`.
    unsafe { axpy_span_avx2_impl(words, bits, c0, c1, a, b, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn axpy_span_avx2_impl(
    words: &[u32],
    bits: u8,
    c0: usize,
    c1: usize,
    a: f32,
    b: f32,
    out: &mut [f32],
) {
    let (head_end, main_end) = block_bounds(bits, c0, c1);
    axpy_span_seq(words, bits, c0, head_end, a, b, out);
    let bw = bits as usize;
    let av = _mm256_set1_ps(a);
    let bv = _mm256_set1_ps(b);
    let mut j = head_end;
    if bits == 8 {
        let bytes = words.as_ptr() as *const u8;
        while j < main_end {
            let q8 = _mm_loadl_epi64(bytes.add(j) as *const __m128i);
            let vals = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(q8));
            let o = out.as_mut_ptr().add(j - c0);
            let t = _mm256_add_ps(_mm256_mul_ps(av, vals), bv);
            _mm256_storeu_ps(o, _mm256_add_ps(_mm256_loadu_ps(o), t));
            j += 8;
        }
    } else {
        let bi = bw as i32;
        let shifts =
            _mm256_setr_epi32(0, bi, 2 * bi, 3 * bi, 4 * bi, 5 * bi, 6 * bi, 7 * bi);
        let mask = _mm256_set1_epi32(((1u32 << bw) - 1) as i32);
        while j < main_end {
            let chunk = chunk8(words, bw, j) as u32;
            let lanes = _mm256_and_si256(
                _mm256_srlv_epi32(_mm256_set1_epi32(chunk as i32), shifts),
                mask,
            );
            let vals = _mm256_cvtepi32_ps(lanes);
            let o = out.as_mut_ptr().add(j - c0);
            let t = _mm256_add_ps(_mm256_mul_ps(av, vals), bv);
            _mm256_storeu_ps(o, _mm256_add_ps(_mm256_loadu_ps(o), t));
            j += 8;
        }
    }
    axpy_span_seq(words, bits, main_end, c1, a, b, &mut out[main_end - c0..]);
}

/// Horizontal sum matching `scalar::hsum8_tree` addition for addition:
/// `[a0+a4, a1+a5, a2+a6, a3+a7]` → `[s0+s2, s1+s3]` → scalar.
#[target_feature(enable = "avx2")]
unsafe fn hsum8(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps(v, 1);
    let s = _mm_add_ps(lo, hi);
    let s2 = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s3 = _mm_add_ss(s2, _mm_shuffle_ps(s2, s2, 0b01));
    _mm_cvtss_f32(s3)
}
