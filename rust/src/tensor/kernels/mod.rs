//! Runtime-dispatched dequant dot kernels — the compute half of the packed
//! execution path.
//!
//! [`crate::tensor::packed::dot_span`] is the single hot primitive every
//! packed GEMV/GEMM group iteration runs (one integer×activation dot per
//! `(row, group)` span). This module turns it into a dispatch point: a
//! [`KernelTable`] of per-bit-width function pointers selected **once** at
//! startup from CPU feature detection (`is_x86_feature_detected!` on
//! x86_64, portable scalar everywhere else), behind the same signature, so
//! `QuantizedLinear::gemv_into`/`forward`, `model/exec.rs` and the stage-2
//! CD sweep need no call-site changes.
//!
//! Two algorithm families:
//!
//! * **sequential** ([`scalar::dot_span_seq`]) — the original in-register
//!   unpack loop; exact for every bit width 1..=8, any span offset, any
//!   ragged tail. It remains the fallback for widths without a specialized
//!   kernel and handles the unaligned head/tail of every striped span.
//! * **lane-striped** — 2/3/4/8-bit spans are split into head (sequential)
//!   + 8-wide value blocks + tail (sequential). Each block is one bit
//!   *chunk* (`chunk8`) fanned out to 8 f32 lanes, multiplied against 8
//!   activations, and accumulated into 8 independent partial sums that are
//!   reduced by a fixed pairwise tree ([`scalar::hsum8_tree`]).
//!
//! The portable lane-striped kernels ([`scalar`]) and the AVX2 ones
//! ([`x86`]) perform **the same IEEE f32 operations in the same order,
//! lane for lane** (vector mul + add, never FMA — a fused multiply-add
//! skips the intermediate rounding and would diverge), so the dispatched
//! SIMD kernels are *bit-identical* to the scalar reference — property
//! tested below — and `TSGO_FORCE_SCALAR=1` reproduces dispatched numerics
//! exactly while debugging.

pub mod scalar;
// Crate-private: `dot_span_avx2` executes AVX2 instructions unconditionally
// and is only sound to call via a table installed after feature detection —
// exposing it `pub` would make that UB reachable from safe downstream code.
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Signature every dequant dot kernel implements: integer × activation dot
/// `Σ_{j∈[c0,c1)} q_j x[j]` over one packed row (same contract as
/// [`crate::tensor::packed::dot_span`]).
pub type DotSpanFn = fn(words: &[u32], bits: u8, c0: usize, c1: usize, x: &[f32]) -> f32;

/// Signature of the dequant **axpy** kernels: `out[j − c0] += a·q_j + b` for
/// `j ∈ [c0, c1)` over one packed row (same contract as
/// [`crate::tensor::packed::axpy_span`]). The `probs · V` half of the
/// quantized-KV attend path; elementwise, so bit-identity across tables is
/// structural rather than reduction-order-sensitive.
pub type AxpySpanFn =
    fn(words: &[u32], bits: u8, c0: usize, c1: usize, a: f32, b: f32, out: &mut [f32]);

/// One resolved kernel per bit width. Index = bits (0 unused; `PackedInts`
/// guarantees 1..=8).
pub struct KernelTable {
    /// Table-level name shown by `tsgo kernels` ("scalar" / "avx2").
    pub name: &'static str,
    pub dot: [DotSpanFn; 9],
    /// Per-bit-width kernel label ("scalar-seq", "scalar-lanes8",
    /// "avx2-srlv", "avx2-bytes").
    pub labels: [&'static str; 9],
    /// Dequant axpy kernels (KV-cache attend `probs · V`).
    pub axpy: [AxpySpanFn; 9],
    pub axpy_labels: [&'static str; 9],
}

/// Bit widths with a specialized lane-striped kernel; everything else runs
/// the sequential path in every table.
pub const STRIPED_BITS: [u8; 4] = [2, 3, 4, 8];

/// The portable table: lane-striped scalar for 2/3/4/8 bits, sequential for
/// the rest. This is both the `TSGO_FORCE_SCALAR` fallback and the
/// bit-exactness reference the SIMD kernels are tested against.
pub fn scalar_table() -> &'static KernelTable {
    static T: OnceLock<KernelTable> = OnceLock::new();
    T.get_or_init(|| {
        let mut dot = [scalar::dot_span_seq as DotSpanFn; 9];
        let mut labels = ["scalar-seq"; 9];
        for b in STRIPED_BITS {
            dot[b as usize] = scalar::dot_span_lanes;
            labels[b as usize] = "scalar-lanes8";
        }
        KernelTable {
            name: "scalar",
            dot,
            labels,
            // axpy is elementwise: the sequential loop IS the lane-exact
            // reference for every width.
            axpy: [scalar::axpy_span_seq as AxpySpanFn; 9],
            axpy_labels: ["scalar-seq"; 9],
        }
    })
}

#[cfg(target_arch = "x86_64")]
fn avx2_table() -> &'static KernelTable {
    static T: OnceLock<KernelTable> = OnceLock::new();
    T.get_or_init(|| {
        let mut dot = [scalar::dot_span_seq as DotSpanFn; 9];
        let mut labels = ["scalar-seq"; 9];
        let mut axpy = [scalar::axpy_span_seq as AxpySpanFn; 9];
        let mut axpy_labels = ["scalar-seq"; 9];
        for b in STRIPED_BITS {
            dot[b as usize] = x86::dot_span_avx2;
            labels[b as usize] = if b == 8 { "avx2-bytes" } else { "avx2-srlv" };
            axpy[b as usize] = x86::axpy_span_avx2;
            axpy_labels[b as usize] = if b == 8 { "avx2-bytes" } else { "avx2-srlv" };
        }
        KernelTable { name: "avx2", dot, labels, axpy, axpy_labels }
    })
}

/// The best table this CPU supports, detected once.
pub fn best_table() -> &'static KernelTable {
    static T: OnceLock<&'static KernelTable> = OnceLock::new();
    T.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        if x86::avx2_available() {
            return avx2_table();
        }
        scalar_table()
    })
}

/// Dispatch override: benches and the forced-dispatch tests flip this at
/// runtime; `TSGO_FORCE_SCALAR=1` seeds it on first use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForcedKernel {
    /// Environment-seeded default (scalar iff `TSGO_FORCE_SCALAR=1`).
    Auto,
    /// Always the portable scalar table.
    Scalar,
    /// Always the detected best table.
    Best,
}

const FORCE_UNSET: u8 = u8::MAX;
const FORCE_AUTO_SCALAR: u8 = 0;
const FORCE_AUTO_BEST: u8 = 1;
const FORCE_SCALAR: u8 = 2;
const FORCE_BEST: u8 = 3;

static FORCE: AtomicU8 = AtomicU8::new(FORCE_UNSET);

fn env_force_scalar() -> bool {
    matches!(
        std::env::var("TSGO_FORCE_SCALAR").as_deref(),
        Ok("1") | Ok("true") | Ok("yes")
    )
}

/// Serializes unit tests that mutate the process-wide forcing state (the
/// library test binary runs tests on threads; two tests flipping `FORCE`
/// concurrently would make table-name assertions racy). Integration-test
/// binaries each get their own process and don't need it.
#[cfg(test)]
pub(crate) fn force_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static L: OnceLock<std::sync::Mutex<()>> = OnceLock::new();
    L.get_or_init(|| std::sync::Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Override kernel selection process-wide (tests / benches). `Auto` restores
/// the environment-driven default.
pub fn set_forced(f: ForcedKernel) {
    let v = match f {
        ForcedKernel::Auto => {
            if env_force_scalar() {
                FORCE_AUTO_SCALAR
            } else {
                FORCE_AUTO_BEST
            }
        }
        ForcedKernel::Scalar => FORCE_SCALAR,
        ForcedKernel::Best => FORCE_BEST,
    };
    FORCE.store(v, Ordering::Relaxed);
}

/// The table `dot_span` dispatches through right now: the forced override
/// if set, else `TSGO_FORCE_SCALAR`, else the detected best.
pub fn active_table() -> &'static KernelTable {
    let mut f = FORCE.load(Ordering::Relaxed);
    if f == FORCE_UNSET {
        f = if env_force_scalar() { FORCE_AUTO_SCALAR } else { FORCE_AUTO_BEST };
        FORCE.store(f, Ordering::Relaxed);
    }
    match f {
        FORCE_AUTO_SCALAR | FORCE_SCALAR => scalar_table(),
        _ => best_table(),
    }
}

/// Everything `tsgo kernels` prints: detected CPU features, forcing state,
/// and the per-bit-width dispatch rows.
pub struct DispatchInfo {
    /// Name of the table `dot_span` currently routes through.
    pub active: &'static str,
    /// Name of the best table the CPU supports (ignoring forcing).
    pub best: &'static str,
    pub forced_scalar: bool,
    /// `(feature, detected)` pairs (empty off x86_64).
    pub cpu_features: Vec<(&'static str, bool)>,
    /// `(bits, scalar dot label, active dot label, active axpy label)` per
    /// bit width 1..=8.
    pub rows: Vec<(u8, &'static str, &'static str, &'static str)>,
}

/// Snapshot the dispatch state for reporting.
pub fn dispatch_info() -> DispatchInfo {
    let active = active_table(); // also seeds FORCE from the environment
    let scalar = scalar_table();
    let forced_scalar = matches!(
        FORCE.load(Ordering::Relaxed),
        FORCE_AUTO_SCALAR | FORCE_SCALAR
    );
    #[cfg(target_arch = "x86_64")]
    let cpu_features = vec![
        ("sse2", is_x86_feature_detected!("sse2")),
        ("avx", is_x86_feature_detected!("avx")),
        ("avx2", is_x86_feature_detected!("avx2")),
        ("fma", is_x86_feature_detected!("fma")),
    ];
    #[cfg(not(target_arch = "x86_64"))]
    let cpu_features = Vec::new();
    DispatchInfo {
        active: active.name,
        best: best_table().name,
        forced_scalar,
        cpu_features,
        rows: (1u8..=8)
            .map(|b| {
                (
                    b,
                    scalar.labels[b as usize],
                    active.labels[b as usize],
                    active.axpy_labels[b as usize],
                )
            })
            .collect(),
    }
}

/// Split `[c0, c1)` into sequential head, 8-wide striped main blocks and
/// sequential tail; returns `(head_end, main_end)`. Blocks for 2/4/8-bit
/// must start at `j ≡ 0 (mod 8)` so every [`chunk8`] window is word-aligned;
/// 3-bit blocks stream from any offset (their 24-bit window is assembled
/// from at most two words, which `PackedInts::words_needed` keeps in bounds
/// whenever the window actually straddles). The scalar and SIMD kernels both
/// call this, so they make identical split decisions — a precondition for
/// bit-identity.
#[inline]
pub(crate) fn block_bounds(bits: u8, c0: usize, c1: usize) -> (usize, usize) {
    debug_assert!(c0 <= c1);
    let b = bits as usize;
    if !matches!(b, 2 | 3 | 4 | 8) {
        return (c1, c1);
    }
    let head_end = if b == 3 { c0 } else { c0.next_multiple_of(8).min(c1) };
    let main_end = head_end + (c1 - head_end) / 8 * 8;
    (head_end, main_end)
}

/// Gather the `8·bits`-bit window holding values `j..j+8` into a `u64`
/// (value `j+l` at bit `l·bits`). Callers guarantee the block layout of
/// [`block_bounds`]: 2/4/8-bit windows start word-aligned (`j % 8 == 0`),
/// 3-bit windows may straddle two words.
#[inline]
pub(crate) fn chunk8(words: &[u32], b: usize, j: usize) -> u64 {
    let bit = j * b;
    let wi = bit / 32;
    let off = bit % 32;
    match b {
        8 => (words[wi] as u64) | ((words[wi + 1] as u64) << 32),
        4 => words[wi] as u64,
        2 => ((words[wi] >> off) & 0xFFFF) as u64,
        3 => {
            let mut v = (words[wi] >> off) as u64;
            if off > 8 {
                // window straddles: off+24 > 32. In-bounds: a straddling
                // window implies words_needed covers wi+1 (off ≥ 9 ⇒ the
                // row's bit count reaches past word wi).
                v |= (words[wi + 1] as u64) << (32 - off);
            }
            v & 0xFF_FFFF
        }
        _ => unreachable!("chunk8 is only defined for bits 2/3/4/8"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::packed::PackedInts;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::rng::Rng;

    fn reference_dot(vals: &[u8], c0: usize, c1: usize, x: &[f32]) -> f64 {
        vals[c0..c1]
            .iter()
            .zip(&x[c0..c1])
            .map(|(&q, &v)| q as f64 * v as f64)
            .sum()
    }

    #[test]
    fn tables_resolve_and_cover_all_widths() {
        let s = scalar_table();
        let b = best_table();
        let a = active_table();
        assert_eq!(s.name, "scalar");
        assert!(a.name == s.name || a.name == b.name);
        for bits in 1u8..=8 {
            assert!(!s.labels[bits as usize].is_empty());
            assert!(!b.labels[bits as usize].is_empty());
            assert!(!s.axpy_labels[bits as usize].is_empty());
            assert!(!b.axpy_labels[bits as usize].is_empty());
        }
        let info = dispatch_info();
        assert_eq!(info.rows.len(), 8);
    }

    #[test]
    fn prop_axpy_kernels_bit_identical_across_tables() {
        // The KV-attend acceptance bar: the dispatched axpy kernel must
        // produce the exact same f32 bits as the scalar reference for every
        // specialized width, span offset and ragged tail (trivial on
        // non-AVX2 hosts; real on AVX2 ones).
        check("axpy kernels bit-identical to scalar reference", 120, |g| {
            let bits = STRIPED_BITS[g.usize_in(0, 3)];
            let n = g.usize_in(1, 400);
            let max = 1usize << bits;
            let mut rng = g.rng.fork(13);
            let vals: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() as usize % max) as u8).collect();
            let p = PackedInts::pack(&vals, bits);
            let c0 = g.usize_in(0, n - 1);
            let c1 = g.usize_in(c0, n);
            let a = rng.normal() as f32;
            let bconst = rng.normal() as f32;
            let init: Vec<f32> = rng.normal_vec(n, 1.0);
            let mut s_out = init.clone();
            let mut b_out = init.clone();
            (scalar_table().axpy[bits as usize])(&p.words, bits, c0, c1, a, bconst, &mut s_out);
            (best_table().axpy[bits as usize])(&p.words, bits, c0, c1, a, bconst, &mut b_out);
            for (k, (sa, sb)) in s_out.iter().zip(&b_out).enumerate() {
                if sa.to_bits() != sb.to_bits() {
                    return Err(format!(
                        "bits={bits} span=({c0},{c1}) k={k}: scalar {sa} vs dispatched {sb}"
                    ));
                }
            }
            // and both match the exact reference
            for (k, (got, before)) in s_out.iter().zip(&init).take(c1 - c0).enumerate() {
                let want = before + (a * vals[c0 + k] as f32 + bconst);
                if got.to_bits() != want.to_bits() {
                    return Err(format!("bits={bits} k={k}: {got} vs reference {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn block_bounds_alignment_and_coverage() {
        // 4-bit: head rounds c0 up to a multiple of 8, main is a multiple
        // of 8 long, tail is the remainder.
        assert_eq!(block_bounds(4, 0, 64), (0, 64));
        assert_eq!(block_bounds(4, 5, 64), (8, 64));
        assert_eq!(block_bounds(4, 5, 7), (7, 7));
        assert_eq!(block_bounds(4, 5, 13), (8, 8));
        // 3-bit streams from any offset.
        assert_eq!(block_bounds(3, 5, 64), (5, 61));
        // widths without a striped kernel: everything sequential.
        assert_eq!(block_bounds(5, 0, 64), (64, 64));
    }

    #[test]
    fn chunk8_matches_get_for_all_striped_widths() {
        let mut rng = Rng::new(7);
        for bits in STRIPED_BITS {
            let max = 1usize << bits;
            let vals: Vec<u8> =
                (0..160).map(|_| (rng.next_u64() as usize % max) as u8).collect();
            let p = PackedInts::pack(&vals, bits);
            let b = bits as usize;
            let starts: Vec<usize> = if bits == 3 {
                (0..152).collect() // any offset
            } else {
                (0..19).map(|k| k * 8).collect() // word-aligned blocks
            };
            for j in starts {
                let chunk = chunk8(&p.words, b, j);
                for l in 0..8 {
                    let got = ((chunk >> (b * l)) & ((1u64 << b) - 1)) as u8;
                    assert_eq!(got, vals[j + l], "bits={bits} j={j} lane={l}");
                }
            }
        }
    }

    #[test]
    fn prop_striped_kernels_bit_identical_across_tables() {
        // The acceptance bar: for every specialized width, every span offset
        // (group boundaries straddling words) and every ragged tail, the
        // dispatched kernel returns the exact same f32 bits as the scalar
        // reference. On non-AVX2 hosts best == scalar and this holds
        // trivially; on AVX2 hosts it checks the SIMD lanes for real.
        check("SIMD kernels bit-identical to scalar reference", 120, |g| {
            let bits = STRIPED_BITS[g.usize_in(0, 3)];
            let n = g.usize_in(1, 400);
            let max = 1usize << bits;
            let mut rng = g.rng.fork(5);
            let vals: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() as usize % max) as u8).collect();
            let x: Vec<f32> = rng.normal_vec(n, 1.0);
            let p = PackedInts::pack(&vals, bits);
            let c0 = g.usize_in(0, n - 1);
            let c1 = g.usize_in(c0, n);
            let a = (scalar_table().dot[bits as usize])(&p.words, bits, c0, c1, &x);
            let b = (best_table().dot[bits as usize])(&p.words, bits, c0, c1, &x);
            prop_assert(
                a.to_bits() == b.to_bits(),
                &format!(
                    "bits={bits} span=({c0},{c1}) n={n}: scalar {a} ({:#010x}) vs \
                     dispatched {b} ({:#010x})",
                    a.to_bits(),
                    b.to_bits()
                ),
            )
        });
    }

    #[test]
    fn prop_lane_kernels_match_sequential_reference() {
        // Mathematical correctness of the striped decomposition itself,
        // against an f64 reference (the striped sum order differs from the
        // sequential one by rounding only).
        check("lane-striped kernels match f64 reference", 120, |g| {
            let bits = STRIPED_BITS[g.usize_in(0, 3)];
            let n = g.usize_in(1, 400);
            let max = 1usize << bits;
            let mut rng = g.rng.fork(9);
            let vals: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() as usize % max) as u8).collect();
            let x: Vec<f32> = rng.normal_vec(n, 1.0);
            let p = PackedInts::pack(&vals, bits);
            let c0 = g.usize_in(0, n - 1);
            let c1 = g.usize_in(c0, n);
            let want = reference_dot(&vals, c0, c1, &x);
            for (label, table) in [("scalar", scalar_table()), ("best", best_table())] {
                let got = (table.dot[bits as usize])(&p.words, bits, c0, c1, &x) as f64;
                if (got - want).abs() > 1e-3 * want.abs().max(1.0) {
                    return Err(format!(
                        "{label} bits={bits} span=({c0},{c1}): {got} vs {want}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn striped_exercises_group_boundaries_straddling_words() {
        // Deterministic straddle battery: every (bits, span) pair that a
        // group-size-8/16/24 layout can produce at the start of a row,
        // including spans entirely inside the sequential head.
        let mut rng = Rng::new(23);
        for bits in STRIPED_BITS {
            let n = 200;
            let max = 1usize << bits;
            let vals: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() as usize % max) as u8).collect();
            let x: Vec<f32> = rng.normal_vec(n, 1.0);
            let p = PackedInts::pack(&vals, bits);
            for group in [8usize, 16, 24] {
                for g in 0..n / group {
                    let (c0, c1) = (g * group, ((g + 1) * group).min(n));
                    let a = (scalar_table().dot[bits as usize])(&p.words, bits, c0, c1, &x);
                    let b = (best_table().dot[bits as usize])(&p.words, bits, c0, c1, &x);
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "bits={bits} group={group} span=({c0},{c1})"
                    );
                    let want = reference_dot(&vals, c0, c1, &x);
                    assert!(
                        (a as f64 - want).abs() <= 1e-3 * want.abs().max(1.0),
                        "bits={bits} span=({c0},{c1}): {a} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn forcing_flips_the_active_table() {
        let _guard = force_test_lock();
        set_forced(ForcedKernel::Scalar);
        assert_eq!(active_table().name, "scalar");
        set_forced(ForcedKernel::Best);
        assert_eq!(active_table().name, best_table().name);
        set_forced(ForcedKernel::Auto);
        let _ = active_table(); // env-seeded; just must not panic
    }
}
