//! Bit-packed integer storage + fused group-wise dequant kernels.
//!
//! [`PackedInts`] is the storage primitive every quantized linear uses:
//! integers packed along the input dimension into `u32` words
//! (little-endian bit order, values may straddle word boundaries for
//! 3-bit). The kernels below are the *execution* half of the format — the
//! CPU mirror of the L1 Pallas dequant-matmul: they compute group-wise
//! dequant × activation dot products straight from the packed words,
//! unpacking in-register, so serving and eval never materialize a dense
//! weight row.
//!
//! The group-wise affine dequant `w_j = s_g (q_j − z_g)` factors out of the
//! dot product per group:
//!
//! ```text
//! Σ_{j∈g} s_g (q_j − z_g) x_j  =  s_g ( Σ_{j∈g} q_j x_j  −  z_g Σ_{j∈g} x_j )
//! ```
//!
//! so the kernel needs one integer dot per `(row, group)` plus per-group
//! activation sums that are computed **once per activation row and shared
//! across every output row** — the same decomposition the fused VMEM kernel
//! uses, and the reason the packed path touches `bits/32` of the bytes the
//! dense f32 path reads.

/// Bit-packed unsigned integers (1–8 bits per value).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedInts {
    pub bits: u8,
    pub len: usize,
    pub words: Vec<u32>,
}

impl PackedInts {
    /// Number of `u32` words needed to hold `len` values at `bits` width —
    /// the invariant `words.len()` must satisfy for `get`/`unpack`/the
    /// kernels to be defined. Checkpoint loaders validate against this.
    #[inline]
    pub fn words_needed(len: usize, bits: u8) -> usize {
        (len * bits as usize).div_ceil(32)
    }

    /// Pack `vals` (each < 2^bits) into a little-endian bit stream.
    pub fn pack(vals: &[u8], bits: u8) -> PackedInts {
        assert!(matches!(bits, 1..=8), "bits must be 1..=8");
        let mut words = vec![0u32; Self::words_needed(vals.len(), bits)];
        for (i, &v) in vals.iter().enumerate() {
            debug_assert!((v as u32) < (1u32 << bits), "value {v} out of range for {bits} bits");
            let bit = i * bits as usize;
            let word = bit / 32;
            let off = bit % 32;
            words[word] |= (v as u32) << off;
            let spill = off + bits as usize;
            if spill > 32 {
                words[word + 1] |= (v as u32) >> (32 - off);
            }
        }
        PackedInts { bits, len: vals.len(), words }
    }

    /// `true` iff `words` holds enough words for `len` values — the
    /// invariant `pack` establishes and deserialized payloads must satisfy.
    #[inline]
    pub fn is_complete(&self) -> bool {
        self.words.len() >= Self::words_needed(self.len, self.bits)
    }

    /// Unpack back to bytes. Panics on a truncated `words` vec (`get`
    /// rejects identically); checkpoint loads surface that as an `Err`
    /// before any decode path can reach it.
    pub fn unpack(&self) -> Vec<u8> {
        assert!(self.is_complete(), "truncated PackedInts: {} words < {} needed",
            self.words.len(), Self::words_needed(self.len, self.bits));
        let bits = self.bits as usize;
        let mask = (1u32 << bits) - 1;
        (0..self.len)
            .map(|i| {
                let bit = i * bits;
                let word = bit / 32;
                let off = bit % 32;
                let mut v = self.words[word] >> off;
                if off + bits > 32 {
                    v |= self.words[word + 1] << (32 - off);
                }
                (v & mask) as u8
            })
            .collect()
    }

    /// Random access. Panics on a truncated `words` vec — consistently with
    /// [`PackedInts::unpack`], instead of silently dropping straddling high
    /// bits the way an unchecked read would.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        assert!(self.is_complete(), "truncated PackedInts: {} words < {} needed",
            self.words.len(), Self::words_needed(self.len, self.bits));
        let bits = self.bits as usize;
        let mask = (1u32 << bits) - 1;
        let bit = i * bits;
        let word = bit / 32;
        let off = bit % 32;
        let mut v = self.words[word] >> off;
        if off + bits > 32 {
            v |= self.words[word + 1] << (32 - off);
        }
        (v & mask) as u8
    }

    /// Size in bytes of the packed payload.
    pub fn nbytes(&self) -> usize {
        self.words.len() * 4
    }
}

/// Integer × activation dot over columns `c0..c1` of a packed row:
/// `Σ_{j∈[c0,c1)} q_j x[j]`, unpacking in-register.
///
/// Routed through the runtime-selected kernel table
/// ([`crate::tensor::kernels`]): lane-striped scalar or AVX2 for 2/3/4/8-bit
/// spans, the sequential streaming unpack for everything else. Same
/// signature as the pre-dispatch scalar kernel, so every caller —
/// [`packed_row_dot`], the fused GEMV/GEMM, the stage-2 CD sweep — picks up
/// the SIMD paths without change.
#[inline]
pub fn dot_span(words: &[u32], bits: u8, c0: usize, c1: usize, x: &[f32]) -> f32 {
    debug_assert!(c1 <= x.len());
    debug_assert!(matches!(bits, 1..=8));
    if c0 >= c1 {
        return 0.0;
    }
    (crate::tensor::kernels::active_table().dot[bits as usize])(words, bits, c0, c1, x)
}

/// Dequant **axpy** over columns `c0..c1` of a packed row:
/// `out[j − c0] += a · q_j + b` — the `probs · V` primitive of the
/// quantized-KV attend path. With `a = w · s_g` and `b = −a · z_g` this
/// accumulates one softmax-weighted dequantized cache row into the context
/// without materializing it.
///
/// Routed through the runtime-selected kernel table like [`dot_span`];
/// elementwise (no reduction), so the dispatched kernel is bit-identical to
/// the scalar one by construction.
#[inline]
pub fn axpy_span(words: &[u32], bits: u8, c0: usize, c1: usize, a: f32, b: f32, out: &mut [f32]) {
    debug_assert!(matches!(bits, 1..=8));
    if c0 >= c1 {
        return;
    }
    // Real assert, not debug: the AVX2 kernel stores through raw pointers,
    // so a short `out` from a safe caller must panic here in release builds
    // too, never write past the slice.
    assert!(out.len() >= c1 - c0, "axpy_span: out too short ({} < {})", out.len(), c1 - c0);
    (crate::tensor::kernels::active_table().axpy[bits as usize])(words, bits, c0, c1, a, b, out)
}

/// Fused group-wise dequant GEMV for one packed row:
/// `y = Σ_g s[g] · ( Σ_{j∈g} q_j x[j] − z[g] · gsum[g] )`.
///
/// `x` is the activation in *stored* column order (act-order gather and AWQ
/// channel divisors already folded in — see `QuantizedLinear::fold_activation`)
/// and `gsum[g] = Σ_{j∈g} x[j]` is precomputed once per activation row and
/// shared across all output rows.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn packed_row_dot(
    words: &[u32],
    bits: u8,
    cols: usize,
    group_size: usize,
    scales: &[f32],
    zeros: &[f32],
    x: &[f32],
    gsum: &[f32],
) -> f32 {
    let n_g = cols.div_ceil(group_size);
    debug_assert!(scales.len() >= n_g && zeros.len() >= n_g && gsum.len() >= n_g);
    debug_assert!(words.len() >= PackedInts::words_needed(cols, bits));
    let mut y = 0.0f32;
    for g in 0..n_g {
        let c0 = g * group_size;
        let c1 = (c0 + group_size).min(cols);
        let qdot = dot_span(words, bits, c0, c1, x);
        y += scales[g] * (qdot - zeros[g] * gsum[g]);
    }
    y
}

/// Per-group activation sums `gsum[g] = Σ_{j∈g} x[j]` (the shared zero-point
/// term of [`packed_row_dot`]).
///
/// Overwrites **every** element of `gsum` — including the ragged tail group —
/// and requires `gsum.len()` to be exactly the group count, so callers can
/// hand it a dirty reused scratch buffer without zeroing it first.
#[inline]
pub fn group_sums(x: &[f32], group_size: usize, gsum: &mut [f32]) {
    debug_assert_eq!(
        gsum.len(),
        x.len().div_ceil(group_size.max(1)),
        "gsum must be exactly the group count (full overwrite contract)"
    );
    for (g, chunk) in x.chunks(group_size).enumerate() {
        gsum[g] = chunk.iter().sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::rng::Rng;

    #[test]
    fn pack_roundtrip_all_widths() {
        for bits in [1u8, 2, 3, 4, 5, 8] {
            let max = 1u32 << bits;
            let vals: Vec<u8> = (0..1000u32).map(|i| ((i * 7 + 3) % max) as u8).collect();
            let p = PackedInts::pack(&vals, bits);
            assert_eq!(p.unpack(), vals, "bits={bits}");
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(p.get(i), v, "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn pack_density() {
        // 3-bit: 1000 values -> 3000 bits -> 94 words.
        let p = PackedInts::pack(&vec![5u8; 1000], 3);
        assert_eq!(p.words.len(), 94);
        assert_eq!(p.nbytes(), 376);
        assert_eq!(PackedInts::words_needed(1000, 3), 94);
    }

    #[test]
    fn prop_pack_roundtrip() {
        check("pack/unpack roundtrip", 60, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let n = g.usize_in(1, 300);
            let vals: Vec<u8> =
                (0..n).map(|_| g.usize_in(0, (1usize << bits) - 1) as u8).collect();
            let p = PackedInts::pack(&vals, bits);
            prop_assert(p.unpack() == vals, "roundtrip")
        });
    }

    #[test]
    #[should_panic(expected = "truncated PackedInts")]
    fn unpack_rejects_truncated_words() {
        let mut p = PackedInts::pack(&[7u8; 33], 3); // 99 bits -> 4 words
        p.words.pop();
        let _ = p.unpack();
    }

    #[test]
    #[should_panic(expected = "truncated PackedInts")]
    fn get_rejects_truncated_words() {
        // Regression: `get` used to silently drop the straddling high bits
        // of the last value when the words vec was short, while `unpack`
        // panicked — they must reject identically.
        let mut p = PackedInts::pack(&[7u8; 33], 3);
        p.words.pop();
        let _ = p.get(0);
    }

    fn reference_dot(vals: &[u8], c0: usize, c1: usize, x: &[f32]) -> f32 {
        vals[c0..c1].iter().zip(&x[c0..c1]).map(|(&q, &v)| q as f32 * v).sum()
    }

    #[test]
    fn dot_span_matches_reference_all_widths() {
        let mut rng = Rng::new(11);
        for bits in [1u8, 2, 3, 4, 5, 8] {
            let n = 130; // odd size: exercises ragged ends
            let max = 1usize << bits;
            let vals: Vec<u8> = (0..n).map(|i| ((i * 13 + 5) % max) as u8).collect();
            let x: Vec<f32> = rng.normal_vec(n, 1.0);
            let p = PackedInts::pack(&vals, bits);
            for (c0, c1) in [(0, n), (0, 64), (64, n), (7, 93), (33, 34), (5, 5)] {
                let got = dot_span(&p.words, bits, c0, c1, &x);
                let want = reference_dot(&vals, c0, c1, &x);
                assert!(
                    (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "bits={bits} span=({c0},{c1}): {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn prop_packed_row_dot_matches_scalar_dequant() {
        check("fused row dot == scalar dequant dot", 40, |g| {
            let bits = [2u8, 3, 4, 8][g.usize_in(0, 3)];
            let group = [8usize, 16, 32][g.usize_in(0, 2)];
            // non-multiple cols exercise the ragged tail group
            let cols = g.usize_in(1, 5) * group + g.usize_in(0, group - 1);
            let n_g = cols.div_ceil(group);
            let max = 1usize << bits;
            let mut rng = g.rng.fork(3);
            let vals: Vec<u8> = (0..cols).map(|_| (rng.next_u64() as usize % max) as u8).collect();
            let x: Vec<f32> = rng.normal_vec(cols, 1.0);
            let scales: Vec<f32> = (0..n_g).map(|_| 0.01 + rng.normal().abs() as f32).collect();
            let zeros: Vec<f32> =
                (0..n_g).map(|_| (rng.next_u64() % max as u64) as f32).collect();
            let p = PackedInts::pack(&vals, bits);
            let mut gsum = vec![0.0f32; n_g];
            group_sums(&x, group, &mut gsum);
            let got = packed_row_dot(&p.words, bits, cols, group, &scales, &zeros, &x, &gsum);
            let want: f32 = (0..cols)
                .map(|j| scales[j / group] * (vals[j] as f32 - zeros[j / group]) * x[j])
                .sum();
            prop_assert(
                (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                &format!("bits={bits} group={group} cols={cols}: {got} vs {want}"),
            )
        });
    }

    #[test]
    fn axpy_span_accumulates_dequant_rows() {
        // Accumulating rows with (a = w·s, b = −a·z) must equal the explicit
        // softmax-weighted dequant sum — the KV-attend decomposition.
        let mut rng = Rng::new(31);
        for bits in [2u8, 3, 4, 8] {
            let n = 48;
            let max = 1usize << bits;
            let rows: Vec<Vec<u8>> = (0..3)
                .map(|_| (0..n).map(|_| (rng.next_u64() as usize % max) as u8).collect())
                .collect();
            let packed: Vec<PackedInts> =
                rows.iter().map(|r| PackedInts::pack(r, bits)).collect();
            let weights = [0.2f32, 0.5, 0.3];
            let (s, z) = (0.37f32, 2.0f32);
            let mut out = vec![0.0f32; n];
            for (p, &w) in packed.iter().zip(&weights) {
                let a = w * s;
                axpy_span(&p.words, bits, 0, n, a, -(a * z), &mut out);
            }
            for j in 0..n {
                let want: f32 = rows
                    .iter()
                    .zip(&weights)
                    .map(|(r, &w)| (w * s) * (r[j] as f32 - z))
                    .sum();
                assert!(
                    (out[j] - want).abs() <= 1e-5 * want.abs().max(1.0),
                    "bits={bits} j={j}: {} vs {want}",
                    out[j]
                );
            }
        }
    }

    #[test]
    fn group_sums_ragged_tail() {
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let mut gsum = [0.0f32; 3];
        group_sums(&x, 2, &mut gsum);
        assert_eq!(gsum, [3.0, 7.0, 5.0]);
    }
}
