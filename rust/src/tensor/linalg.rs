//! Cholesky-based SPD solver family.
//!
//! GPTQ needs `chol(H⁻¹)` (upper) for its error-compensation sweep; stage-2
//! CD needs quadratic forms over Hessian blocks. Everything is derived from
//! a single f64-accumulating Cholesky factorization for numerical stability
//! (H is accumulated from f32 activations and can be ill-conditioned).

use super::matrix::Matrix;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor L of SPD `a` (a = L Lᵀ).
/// Accumulates in f64; fails if a pivot is non-positive.
pub fn cholesky_lower(a: &Matrix) -> Result<Matrix> {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "cholesky needs square input");
    let mut l64 = vec![0.0f64; n * n];
    let ad = &a.data;
    for i in 0..n {
        for j in 0..=i {
            let mut s = ad[i * n + j] as f64;
            for k in 0..j {
                s -= l64[i * n + k] * l64[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    bail!("cholesky: non-positive pivot {s:.3e} at {i} (matrix not SPD; increase damping)");
                }
                l64[i * n + j] = s.sqrt();
            } else {
                l64[i * n + j] = s / l64[j * n + j];
            }
        }
    }
    Ok(Matrix::from_vec(n, n, l64.into_iter().map(|x| x as f32).collect()))
}

/// Solve `L y = b` for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        let row = l.row(i);
        for k in 0..i {
            s -= row[k] as f64 * y[k] as f64;
        }
        y[i] = (s / row[i] as f64) as f32;
    }
    y
}

/// Solve `U x = b` for upper-triangular U (back substitution).
pub fn solve_upper(u: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = u.rows;
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = b[i] as f64;
        let row = u.row(i);
        for k in i + 1..n {
            s -= row[k] as f64 * x[k] as f64;
        }
        x[i] = (s / row[i] as f64) as f32;
    }
    x
}

/// Inverse of an SPD matrix via Cholesky (solves against identity columns).
pub fn invert_spd(a: &Matrix) -> Result<Matrix> {
    let n = a.rows;
    let l = cholesky_lower(a)?;
    let lt = l.transpose();
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for c in 0..n {
        e[c] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_upper(&lt, &y);
        for r in 0..n {
            inv[(r, c)] = x[r];
        }
        e[c] = 0.0;
    }
    Ok(inv)
}

/// GPTQ's factor: the **upper** Cholesky factor U of `H⁻¹` with
/// `H⁻¹ = Uᵀ U` (torch's `linalg.cholesky(·, upper=True)` convention).
/// The diagonal entries `U[j,j]` scale the per-column error and row
/// `U[j, j+1:]` drives compensation: with `H⁻¹ = L Lᵀ`, the Gaussian-
/// elimination update of `H⁻¹` after fixing coordinate j leaves exactly the
/// trailing submatrix of L, and the compensation direction
/// `H⁻¹[F, j]/H⁻¹[j,j] = L[F, j]/L[j,j] = U[j, F]ᵀ/U[j,j]`.
pub fn cholesky_inverse_upper(h: &Matrix) -> Result<Matrix> {
    let inv = invert_spd(h)?;
    cholesky_upper(&inv)
}

/// Upper-triangular Cholesky: A = Uᵀ U, i.e. U = (lower factor)ᵀ.
pub fn cholesky_upper(a: &Matrix) -> Result<Matrix> {
    Ok(cholesky_lower(a)?.transpose())
}

/// Quadratic form xᵀ A y accumulated in f64.
pub fn quad_form(x: &[f32], a: &Matrix, y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), a.rows);
    debug_assert_eq!(y.len(), a.cols);
    let mut total = 0.0f64;
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        let mut s = 0.0f64;
        for (aij, &yj) in row.iter().zip(y) {
            s += *aij as f64 * yj as f64;
        }
        total += xi as f64 * s;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::rng::Rng;

    /// Random SPD matrix A = GᵀG + n·I.
    fn rand_spd(n: usize, rng: &mut Rng) -> Matrix {
        let g = Matrix::randn(n, n, 1.0, rng);
        let mut a = g.transpose().matmul(&g);
        for i in 0..n {
            a[(i, i)] += n as f32 * 0.1 + 0.5;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 16, 64] {
            let a = rand_spd(n, &mut rng);
            let l = cholesky_lower(&a).unwrap();
            let rec = l.matmul(&l.transpose());
            assert!(rec.max_abs_diff(&a) < 1e-2 * n as f32, "n={n}");
        }
    }

    #[test]
    fn cholesky_upper_reconstructs() {
        let mut rng = Rng::new(2);
        for n in [1, 3, 8, 32] {
            let a = rand_spd(n, &mut rng);
            let u = cholesky_upper(&a).unwrap();
            let rec = u.transpose().matmul(&u);
            assert!(rec.max_abs_diff(&a) < 1e-2 * n as f32, "n={n}");
            // U really is upper-triangular
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(u[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Rng::new(3);
        for n in [1, 4, 24] {
            let a = rand_spd(n, &mut rng);
            let inv = invert_spd(&a).unwrap();
            let prod = a.matmul(&inv);
            assert!(prod.max_abs_diff(&Matrix::eye(n)) < 5e-3, "n={n}");
        }
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(4);
        let a = rand_spd(12, &mut rng);
        let l = cholesky_lower(&a).unwrap();
        let b: Vec<f32> = rng.normal_vec(12, 1.0);
        let y = solve_lower(&l, &b);
        let got = l.matvec(&y);
        for i in 0..12 {
            assert!((got[i] - b[i]).abs() < 1e-3);
        }
        let lt = l.transpose();
        let x = solve_upper(&lt, &b);
        let got = lt.matvec(&x);
        for i in 0..12 {
            assert!((got[i] - b[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn gptq_factor_identity() {
        // chol_inv_upper(I) must be I.
        let u = cholesky_inverse_upper(&Matrix::eye(6)).unwrap();
        assert!(u.max_abs_diff(&Matrix::eye(6)) < 1e-5);
    }

    #[test]
    fn gptq_factor_satisfies_uut() {
        let mut rng = Rng::new(5);
        let h = rand_spd(10, &mut rng);
        let u = cholesky_inverse_upper(&h).unwrap();
        let hinv = invert_spd(&h).unwrap();
        let rec = u.transpose().matmul(&u);
        assert!(rec.max_abs_diff(&hinv) < 5e-3);
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let mut a = Matrix::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky_lower(&a).is_err());
        assert!(cholesky_upper(&a).is_err());
    }

    #[test]
    fn quad_form_matches_matmul() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(5, 7, 1.0, &mut rng);
        let x = rng.normal_vec(5, 1.0);
        let y = rng.normal_vec(7, 1.0);
        let want: f64 = {
            let ay = a.matvec(&y);
            x.iter().zip(&ay).map(|(xi, ai)| *xi as f64 * *ai as f64).sum()
        };
        assert!((quad_form(&x, &a, &y) - want).abs() < 1e-3);
    }

    #[test]
    fn prop_quadform_positive_on_spd() {
        check("xᵀHx > 0 for SPD H", 40, |g| {
            let n = g.dim(16);
            let mut rng = g.rng.fork(3);
            let h = rand_spd(n, &mut rng);
            let x = rng.normal_vec(n, 1.0);
            let q = quad_form(&x, &h, &x);
            let norm2: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
            if norm2 < 1e-9 {
                return Ok(());
            }
            prop_assert(q > 0.0, "positive definite quadratic form")
        });
    }
}
