//! Round-to-nearest (RTN) baseline quantizer.
//!
//! Quantizes every weight independently onto the group grid — no error
//! compensation. This is the "nearest quantized value is assigned to each
//! weight" assumption under which all the scale searches (Eq. 2/4) are
//! derived, and the weakest baseline in the evaluation.

use super::format::QuantizedLinear;
use super::scale::{quantize_group, GroupScales, QuantSpec};
use crate::tensor::Matrix;

/// Quantize `w` row-by-row with the given (fixed) group scales.
pub fn rtn_quantize(w: &Matrix, scales: &GroupScales, spec: &QuantSpec) -> QuantizedLinear {
    let g = spec.group_size;
    let qmax = spec.qmax();
    let ints: Vec<Vec<u8>> = (0..w.rows)
        .map(|r| {
            let row = w.row(r);
            let mut out = Vec::with_capacity(w.cols);
            for (gi, chunk) in row.chunks(g).enumerate() {
                let s = scales.scales[(r, gi)];
                let z = scales.zeros[(r, gi)];
                out.extend(quantize_group(chunk, s, z, qmax));
            }
            out
        })
        .collect();
    QuantizedLinear::from_ints(
        &ints,
        spec.bits,
        g,
        scales.scales.clone(),
        scales.zeros.clone(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scale::{compute_group_scales, ScaleMetric};
    use crate::util::rng::Rng;

    #[test]
    fn rtn_roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(8, 128, 1.0, &mut rng);
        let spec = QuantSpec::new(4, 32);
        let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
        let q = rtn_quantize(&w, &scales, &spec);
        let d = q.dequantize();
        // 4-bit minmax: error bounded by ~s/2 per weight; loose global check.
        let mse = crate::quant::metrics::weight_mse(&w, &d);
        assert!(mse < 0.02, "mse={mse}");
    }

    #[test]
    fn rtn_exact_when_weights_on_grid() {
        // Weights already exactly on a 2-bit grid quantize losslessly.
        let spec = QuantSpec::new(2, 4);
        let w = Matrix::from_vec(1, 4, vec![0.0, 0.5, 1.0, 1.5]);
        let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
        let q = rtn_quantize(&w, &scales, &spec);
        let d = q.dequantize();
        assert!(d.max_abs_diff(&w) < 1e-6);
    }

    #[test]
    fn lower_bits_higher_error() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(4, 64, 1.0, &mut rng);
        let mut last = 0.0;
        for bits in [8u8, 4, 3, 2] {
            let spec = QuantSpec::new(bits, 32);
            let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
            let q = rtn_quantize(&w, &scales, &spec);
            let mse = crate::quant::metrics::weight_mse(&w, &q.dequantize());
            assert!(mse >= last, "bits={bits}: {mse} < {last}");
            last = mse;
        }
    }
}
