//! [`QuantPlan`] — per-layer quantizer/spec assignment.
//!
//! A plan is a default `(quantizer, bits, group)` plus an ordered list of
//! rules. Each rule selects `(layer, kind)` pairs and patches the quantizer
//! and/or the spec; rules apply in order, later rules override earlier ones,
//! so mixed-precision runs ("4-bit `wv`/`wo`, 2-bit everything else, AWQ for
//! layer 0") are first-class.
//!
//! ## String grammar
//!
//! ```text
//! plan    := head (';' rule)*
//! head    := NAME [':' opt (',' opt)*]       opt  := 'bits=' N | 'group=' N
//! rule    := sel (',' sel)* '=' act ('+' act)*
//! sel     := 'l' N            -- layer index
//!          | 'wq'|'wk'|'wv'|'wo'|'w1'|'w2'|'w3'
//!          | '*'              -- every linear
//! act     := NAME | 'bits' N | 'group' N
//! ```
//!
//! Example: `ours:bits=2,group=64;wv,wo=bits4;l0=awq` quantizes everything
//! 2-bit with the paper's method, except `wv`/`wo` at 4 bits and all of
//! layer 0 with AWQ. Within one rule, layer selectors and kind selectors
//! combine with AND (`l0,wv=rtn` is layer 0's `wv` only); listing several
//! selectors of the same axis unions them.

use super::api::{quantizer_names, resolve_quantizer, LayerQuantizer};
use super::scale::QuantSpec;
use crate::model::LinearKind;
use anyhow::{anyhow, bail};
use std::fmt;
use std::sync::Arc;

/// Optional overrides a rule applies to the effective [`QuantSpec`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecPatch {
    pub bits: Option<u8>,
    pub group: Option<usize>,
}

impl SpecPatch {
    pub fn is_empty(&self) -> bool {
        self.bits.is_none() && self.group.is_none()
    }
}

/// One plan rule: a `(layer, kind)` selector plus the patch it applies.
/// Empty `layers`/`kinds` match every layer/kind respectively.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PlanRule {
    pub layers: Vec<usize>,
    pub kinds: Vec<LinearKind>,
    pub quantizer: Option<String>,
    pub patch: SpecPatch,
}

impl PlanRule {
    /// A rule matching every linear; narrow it with the builder methods.
    pub fn any() -> PlanRule {
        PlanRule::default()
    }

    pub fn layer(mut self, layer: usize) -> PlanRule {
        self.layers.push(layer);
        self
    }

    pub fn kind(mut self, kind: LinearKind) -> PlanRule {
        self.kinds.push(kind);
        self
    }

    pub fn quantizer(mut self, name: &str) -> PlanRule {
        self.quantizer = Some(name.to_string());
        self
    }

    pub fn bits(mut self, bits: u8) -> PlanRule {
        self.patch.bits = Some(bits);
        self
    }

    pub fn group(mut self, group: usize) -> PlanRule {
        self.patch.group = Some(group);
        self
    }

    /// Does this rule apply to `(layer, kind)`?
    pub fn matches(&self, layer: usize, kind: LinearKind) -> bool {
        (self.layers.is_empty() || self.layers.contains(&layer))
            && (self.kinds.is_empty() || self.kinds.contains(&kind))
    }
}

/// An ordered per-layer quantization plan. See the module docs for the
/// string grammar; build programmatically with [`QuantPlan::uniform`] +
/// [`QuantPlan::with_rule`].
#[derive(Clone, Debug, PartialEq)]
pub struct QuantPlan {
    /// Default quantizer name (must be registered).
    pub quantizer: String,
    /// Default bit width.
    pub bits: u8,
    /// Default group size.
    pub group: usize,
    pub rules: Vec<PlanRule>,
}

fn kind_from_label(s: &str) -> Option<LinearKind> {
    LinearKind::ALL.iter().copied().find(|k| k.label() == s)
}

fn parse_bits(v: &str) -> crate::Result<u8> {
    let b: u8 = v
        .parse()
        .map_err(|_| anyhow!("bits must be an integer in 1..=8, got '{v}'"))?;
    if !(1..=8).contains(&b) {
        bail!("bits must be in 1..=8, got {b}");
    }
    Ok(b)
}

fn parse_group(v: &str) -> crate::Result<usize> {
    let g: usize = v
        .parse()
        .map_err(|_| anyhow!("group must be a positive integer, got '{v}'"))?;
    if g == 0 {
        bail!("group must be > 0");
    }
    Ok(g)
}

impl QuantPlan {
    /// Uniform plan: one quantizer + spec for every linear. (The effective
    /// spec is re-derived as `QuantSpec::new(bits, group)` at resolve time,
    /// so custom `grid_points`/`beta_min` tweaks do not carry through a
    /// plan — they are per-call knobs, not plan state.)
    pub fn uniform(quantizer: &str, spec: QuantSpec) -> QuantPlan {
        QuantPlan {
            quantizer: quantizer.to_string(),
            bits: spec.bits,
            group: spec.group_size,
            rules: Vec::new(),
        }
    }

    /// Append a rule (builder style). Rules apply in insertion order; later
    /// rules override earlier ones where both match.
    pub fn with_rule(mut self, rule: PlanRule) -> QuantPlan {
        self.rules.push(rule);
        self
    }

    /// Parse a plan string with `bits`/`group` falling back to the given
    /// defaults when the head clause does not set them.
    pub fn parse_with_defaults(
        s: &str,
        default_bits: u8,
        default_group: usize,
    ) -> crate::Result<QuantPlan> {
        let s = s.trim();
        if s.is_empty() {
            bail!("empty plan string (expected e.g. 'ours' or 'ours:bits=2,group=64;wv,wo=bits4')");
        }
        let mut clauses = s.split(';');
        let head = clauses.next().unwrap().trim();
        let (name, opts) = match head.split_once(':') {
            Some((n, o)) => (n.trim(), Some(o)),
            None => (head, None),
        };
        if resolve_quantizer(name).is_none() {
            bail!("unknown quantizer '{name}' (available: {})", quantizer_names());
        }
        let mut plan = QuantPlan {
            quantizer: name.to_string(),
            bits: default_bits,
            group: default_group,
            rules: Vec::new(),
        };
        if let Some(opts) = opts {
            for kv in opts.split(',') {
                let kv = kv.trim();
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    anyhow!("plan option '{kv}' must be key=value (bits=N or group=N)")
                })?;
                match k.trim() {
                    "bits" => plan.bits = parse_bits(v.trim())?,
                    "group" => plan.group = parse_group(v.trim())?,
                    other => bail!("unknown plan option '{other}' (expected bits or group)"),
                }
            }
        }
        for (ri, clause) in clauses.enumerate() {
            let clause = clause.trim();
            if clause.is_empty() {
                continue; // tolerate a trailing ';'
            }
            let (sel, act) = clause.split_once('=').ok_or_else(|| {
                anyhow!(
                    "rule {} ('{clause}') must be selector=action, e.g. 'wv,wo=bits4' or 'l0=awq'",
                    ri + 1
                )
            })?;
            let mut rule = PlanRule::any();
            for atom in sel.split(',') {
                let atom = atom.trim();
                if atom.is_empty() {
                    bail!("rule {}: empty selector atom", ri + 1);
                }
                if atom == "*" {
                    continue; // matches everything
                } else if let Some(kind) = kind_from_label(atom) {
                    rule.kinds.push(kind);
                } else if let Some(rest) = atom.strip_prefix('l') {
                    let idx: usize = rest.parse().map_err(|_| {
                        anyhow!("rule {}: bad layer selector '{atom}' (use l<N>, e.g. l0)", ri + 1)
                    })?;
                    rule.layers.push(idx);
                } else {
                    bail!(
                        "rule {}: unknown selector '{atom}' (use wq|wk|wv|wo|w1|w2|w3, l<N> or *)",
                        ri + 1
                    );
                }
            }
            for atom in act.split('+') {
                let atom = atom.trim();
                if atom.is_empty() {
                    bail!("rule {}: empty action atom", ri + 1);
                }
                if resolve_quantizer(atom).is_some() {
                    rule.quantizer = Some(atom.to_string());
                } else if let Some(v) = atom.strip_prefix("bits") {
                    rule.patch.bits = Some(parse_bits(v.trim_start_matches('='))?);
                } else if let Some(v) = atom.strip_prefix("group") {
                    rule.patch.group = Some(parse_group(v.trim_start_matches('='))?);
                } else {
                    bail!(
                        "rule {}: unknown action '{atom}' (use a quantizer name [{}], bits<N> or group<N>)",
                        ri + 1,
                        quantizer_names()
                    );
                }
            }
            if rule.quantizer.is_none() && rule.patch.is_empty() {
                bail!("rule {} has no action", ri + 1);
            }
            plan.rules.push(rule);
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Parse with the repo-default INT2 / group-64 spec as fallback.
    pub fn parse(s: &str) -> crate::Result<QuantPlan> {
        Self::parse_with_defaults(s, 2, 64)
    }

    /// Check every referenced quantizer name and spec value; called by
    /// [`Self::parse_with_defaults`] and by the pipeline before a run, so
    /// hand-built plans fail fast too.
    pub fn validate(&self) -> crate::Result<()> {
        let check_name = |name: &str| -> crate::Result<()> {
            if resolve_quantizer(name).is_none() {
                bail!("unknown quantizer '{name}' (available: {})", quantizer_names());
            }
            Ok(())
        };
        check_name(&self.quantizer)?;
        if !(1..=8).contains(&self.bits) {
            bail!("bits must be in 1..=8, got {}", self.bits);
        }
        if self.group == 0 {
            bail!("group must be > 0");
        }
        for (ri, rule) in self.rules.iter().enumerate() {
            if let Some(name) = &rule.quantizer {
                check_name(name)?;
            }
            if let Some(b) = rule.patch.bits {
                if !(1..=8).contains(&b) {
                    bail!("rule {}: bits must be in 1..=8, got {b}", ri + 1);
                }
            }
            if rule.patch.group == Some(0) {
                bail!("rule {}: group must be > 0", ri + 1);
            }
            if rule.quantizer.is_none() && rule.patch.is_empty() {
                bail!("rule {} has no action", ri + 1);
            }
        }
        Ok(())
    }

    /// The effective `(quantizer, spec)` for one linear.
    pub fn resolve(
        &self,
        layer: usize,
        kind: LinearKind,
    ) -> crate::Result<(Arc<dyn LayerQuantizer>, QuantSpec)> {
        let mut name = self.quantizer.as_str();
        let mut bits = self.bits;
        let mut group = self.group;
        for rule in &self.rules {
            if rule.matches(layer, kind) {
                if let Some(q) = &rule.quantizer {
                    name = q;
                }
                if let Some(b) = rule.patch.bits {
                    bits = b;
                }
                if let Some(g) = rule.patch.group {
                    group = g;
                }
            }
        }
        let q = resolve_quantizer(name)
            .ok_or_else(|| anyhow!("unknown quantizer '{name}' (available: {})", quantizer_names()))?;
        Ok((q, QuantSpec::new(bits, group)))
    }

    /// True when no rule ever overrides the defaults.
    pub fn is_uniform(&self) -> bool {
        self.rules.is_empty()
    }
}

impl fmt::Display for QuantPlan {
    /// Canonical plan string; `parse(display(p)) == p` (property-tested).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:bits={},group={}", self.quantizer, self.bits, self.group)?;
        for rule in &self.rules {
            write!(f, ";")?;
            let mut first = true;
            let mut sep = |f: &mut fmt::Formatter<'_>| -> fmt::Result {
                if !first {
                    write!(f, ",")?;
                }
                first = false;
                Ok(())
            };
            if rule.layers.is_empty() && rule.kinds.is_empty() {
                write!(f, "*")?;
            } else {
                for l in &rule.layers {
                    sep(f)?;
                    write!(f, "l{l}")?;
                }
                for k in &rule.kinds {
                    sep(f)?;
                    write!(f, "{}", k.label())?;
                }
            }
            write!(f, "=")?;
            let mut first_act = true;
            if let Some(q) = &rule.quantizer {
                write!(f, "{q}")?;
                first_act = false;
            }
            if let Some(b) = rule.patch.bits {
                if !first_act {
                    write!(f, "+")?;
                }
                write!(f, "bits{b}")?;
                first_act = false;
            }
            if let Some(g) = rule.patch.group {
                if !first_act {
                    write!(f, "+")?;
                }
                write!(f, "group{g}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::api::QUANTIZER_NAMES;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn bare_name_is_a_valid_plan() {
        let p = QuantPlan::parse_with_defaults("ours", 2, 64).unwrap();
        assert_eq!(p.quantizer, "ours");
        assert_eq!((p.bits, p.group), (2, 64));
        assert!(p.is_uniform());
    }

    #[test]
    fn head_options_override_defaults() {
        let p = QuantPlan::parse_with_defaults("gptq:bits=4,group=32", 2, 64).unwrap();
        assert_eq!((p.bits, p.group), (4, 32));
    }

    #[test]
    fn issue_example_parses_and_resolves() {
        let p = QuantPlan::parse("ours:bits=2,group=64;wv,wo=bits4;l0=awq").unwrap();
        assert_eq!(p.rules.len(), 2);
        // wv at layer 3: 4 bits, ours
        let (q, spec) = p.resolve(3, LinearKind::Wv).unwrap();
        assert_eq!(q.name(), "ours");
        assert_eq!((spec.bits, spec.group_size), (4, 64));
        // w1 at layer 3: default 2-bit ours
        let (q, spec) = p.resolve(3, LinearKind::W1).unwrap();
        assert_eq!(q.name(), "ours");
        assert_eq!(spec.bits, 2);
        // layer 0 wv: awq (later rule) at 4 bits (earlier rule)
        let (q, spec) = p.resolve(0, LinearKind::Wv).unwrap();
        assert_eq!(q.name(), "awq");
        assert_eq!(spec.bits, 4);
    }

    #[test]
    fn and_semantics_within_a_rule() {
        let p = QuantPlan::parse("gptq:bits=2,group=64;l1,wo=rtn").unwrap();
        assert_eq!(p.resolve(1, LinearKind::Wo).unwrap().0.name(), "rtn");
        assert_eq!(p.resolve(1, LinearKind::Wq).unwrap().0.name(), "gptq");
        assert_eq!(p.resolve(0, LinearKind::Wo).unwrap().0.name(), "gptq");
    }

    #[test]
    fn star_selector_matches_everything() {
        let p = QuantPlan::parse("gptq:bits=4,group=64;*=bits3").unwrap();
        assert_eq!(p.resolve(5, LinearKind::W2).unwrap().1.bits, 3);
    }

    #[test]
    fn builder_matches_string_form() {
        let built = QuantPlan::uniform("ours", QuantSpec::new(2, 64))
            .with_rule(PlanRule::any().kind(LinearKind::Wv).kind(LinearKind::Wo).bits(4))
            .with_rule(PlanRule::any().layer(0).quantizer("awq"));
        let parsed = QuantPlan::parse("ours:bits=2,group=64;wv,wo=bits4;l0=awq").unwrap();
        assert_eq!(built, parsed);
    }

    #[test]
    fn bad_strings_give_actionable_errors() {
        let cases: [(&str, &str); 8] = [
            ("", "empty plan"),
            ("frobnicate", "unknown quantizer"),
            ("ours:bits=12", "bits must be in 1..=8"),
            ("ours:speed=9", "unknown plan option"),
            ("ours;wv", "selector=action"),
            ("ours;zz=bits4", "unknown selector"),
            ("ours;wv=frobnicate", "unknown action"),
            ("ours;lx=rtn", "bad layer selector"),
        ];
        for (s, want) in cases {
            let err = QuantPlan::parse(s).unwrap_err().to_string();
            assert!(err.contains(want), "'{s}' → '{err}' (wanted '{want}')");
        }
    }

    #[test]
    fn mixed_plan_reports_non_uniform() {
        let p = QuantPlan::parse("ours;wv=bits4").unwrap();
        assert!(!p.is_uniform());
    }

    #[test]
    fn validate_rejects_hand_built_garbage() {
        let mut p = QuantPlan::uniform("ours", QuantSpec::new(2, 64));
        p.quantizer = "nope".into();
        assert!(p.validate().is_err());
        let p2 = QuantPlan::uniform("ours", QuantSpec::new(2, 64))
            .with_rule(PlanRule::any().kind(LinearKind::Wq));
        assert!(p2.validate().is_err(), "no-op rule must be rejected");
    }

    #[test]
    fn prop_display_parse_roundtrip() {
        check("plan display→parse is identity", 80, |g| {
            let quantizer = QUANTIZER_NAMES[g.usize_in(0, QUANTIZER_NAMES.len() - 1)];
            let bits = g.usize_in(1, 8) as u8;
            let group = [16, 32, 64, 128][g.usize_in(0, 3)];
            let mut plan = QuantPlan::uniform(quantizer, QuantSpec::new(bits, group));
            let n_rules = g.usize_in(0, 3);
            for _ in 0..n_rules {
                let mut rule = PlanRule::any();
                for _ in 0..g.usize_in(0, 2) {
                    rule = rule.layer(g.usize_in(0, 5));
                }
                for _ in 0..g.usize_in(0, 2) {
                    let k = LinearKind::ALL[g.usize_in(0, 6)];
                    rule = rule.kind(k);
                }
                // at least one action, chosen from quantizer/bits/group
                match g.usize_in(0, 2) {
                    0 => {
                        rule = rule
                            .quantizer(QUANTIZER_NAMES[g.usize_in(0, QUANTIZER_NAMES.len() - 1)]);
                    }
                    1 => rule = rule.bits(g.usize_in(1, 8) as u8),
                    _ => rule = rule.group([16, 32, 64][g.usize_in(0, 2)]),
                }
                if g.bool() {
                    rule = rule.bits(g.usize_in(1, 8) as u8);
                }
                plan = plan.with_rule(rule);
            }
            let s = plan.to_string();
            let reparsed = QuantPlan::parse_with_defaults(&s, plan.bits, plan.group)
                .map_err(|e| format!("'{s}' failed to reparse: {e}"))?;
            prop_assert(reparsed == plan, &format!("roundtrip mismatch for '{s}'"))
        });
    }

    #[test]
    fn display_is_canonical_fixed_point() {
        // display(parse(s)) is already canonical: parsing it again changes
        // nothing, including for shorthand inputs.
        for s in ["ours", "rtn:group=32", "ours;wv,wo=bits4;l0=awq+group32"] {
            let p1 = QuantPlan::parse(s).unwrap();
            let canon = p1.to_string();
            let p2 = QuantPlan::parse(&canon).unwrap();
            assert_eq!(p1, p2, "{s}");
            assert_eq!(canon, p2.to_string(), "{s}");
        }
    }
}
