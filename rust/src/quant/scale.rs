//! Uniform affine quantization primitives and the β-grid scale search.
//!
//! The quantization grid per group is `q = s · (w_int − z)` with
//! `w_int = clamp(round(w/s) + z, 0, 2^b − 1)` and
//! `s = β · (max(w) − min(w)) / (2^b − 1)` (the paper's footnote 1, extended
//! with the standard asymmetric zero-point GPTQ uses for Llama weights).
//!
//! The grid search over β is shared by:
//! * the **stock GPTQ baseline** — minimizes `‖q − w‖²` (the `H = I`
//!   assumption the paper criticizes), and
//! * the **paper's Stage 1** — minimizes `(q − w)ᵀ H_ii (q − w)` (Eq. 4).

use crate::tensor::{linalg::quad_form, Matrix};

/// Static quantization parameters for one linear layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantSpec {
    pub bits: u8,
    pub group_size: usize,
    /// Number of β candidates in the grid search.
    pub grid_points: usize,
    /// Smallest β tried (largest is always 1.0).
    pub beta_min: f32,
}

impl QuantSpec {
    pub fn new(bits: u8, group_size: usize) -> QuantSpec {
        // Lower bit-widths tolerate (and benefit from) more aggressive
        // clipping, so the β range widens as bits shrink — GPTQ's practice.
        let beta_min = match bits {
            1 | 2 => 0.35,
            3 => 0.50,
            _ => 0.60,
        };
        QuantSpec { bits, group_size, grid_points: 40, beta_min }
    }

    pub fn qmax(&self) -> i32 {
        (1i32 << self.bits) - 1
    }

    pub fn n_groups(&self, cols: usize) -> usize {
        cols.div_ceil(self.group_size)
    }

    /// The β candidates (ascending, last is exactly 1.0).
    pub fn beta_grid(&self) -> Vec<f32> {
        let m = self.grid_points.max(2);
        (0..m)
            .map(|i| self.beta_min + (1.0 - self.beta_min) * i as f32 / (m - 1) as f32)
            .collect()
    }
}

/// Which objective the grid search minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleMetric {
    /// `‖q − w‖²` — stock GPTQ (assumes `H = I`).
    L2,
    /// `(q − w)ᵀ H_ii (q − w)` — the paper's Stage 1 (Eq. 4).
    HessianBlock,
}

/// Per-(row, group) scales and zero-points for one layer.
#[derive(Clone, Debug)]
pub struct GroupScales {
    /// `[rows, n_groups]`.
    pub scales: Matrix,
    /// `[rows, n_groups]`, integer zero-points stored as f32.
    pub zeros: Matrix,
    pub group_size: usize,
    pub bits: u8,
}

impl GroupScales {
    #[inline]
    pub fn scale(&self, row: usize, col: usize) -> f32 {
        self.scales[(row, col / self.group_size)]
    }
    #[inline]
    pub fn zero(&self, row: usize, col: usize) -> f32 {
        self.zeros[(row, col / self.group_size)]
    }
}

/// (scale, zero) for one group of weights at clipping factor β.
/// Degenerate (all-equal) groups get scale = ε so round(w/s) stays finite.
pub fn minmax_scale(w: &[f32], bits: u8, beta: f32) -> (f32, f32) {
    let qmax = ((1i32 << bits) - 1) as f32;
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in w {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    // Grid must contain 0 (GPTQ convention) so zero-point is exact.
    lo = lo.min(0.0) * beta;
    hi = hi.max(0.0) * beta;
    let mut s = (hi - lo) / qmax;
    if s < 1e-10 {
        s = 1e-10;
    }
    let z = (-lo / s).round().clamp(0.0, qmax);
    (s, z)
}

/// Quantize one value onto the grid: returns the integer in [0, qmax].
#[inline]
pub fn quantize_value(w: f32, s: f32, z: f32, qmax: i32) -> u8 {
    ((w / s).round() + z).clamp(0.0, qmax as f32) as u8
}

/// Dequantize an integer.
#[inline]
pub fn dequantize_value(q: u8, s: f32, z: f32) -> f32 {
    s * (q as f32 - z)
}

/// Quantize a group; returns integers.
pub fn quantize_group(w: &[f32], s: f32, z: f32, qmax: i32) -> Vec<u8> {
    w.iter().map(|&x| quantize_value(x, s, z, qmax)).collect()
}

/// Round-trip error vector `dequant(quant(w)) − w` for a group.
pub fn group_error(w: &[f32], s: f32, z: f32, qmax: i32) -> Vec<f32> {
    w.iter()
        .map(|&x| dequantize_value(quantize_value(x, s, z, qmax), s, z) - x)
        .collect()
}

/// Grid-search the best (scale, zero) for a single group of weights under
/// the given metric. `h_block` must be the `[g, g]` Hessian diagonal block
/// when `metric == HessianBlock` (ignored for L2).
pub fn search_group_scale(
    w: &[f32],
    spec: &QuantSpec,
    metric: ScaleMetric,
    h_block: Option<&Matrix>,
) -> (f32, f32) {
    let qmax = spec.qmax();
    let mut best = (f32::INFINITY as f64, 0.0f32, 0.0f32);
    for beta in spec.beta_grid() {
        let (s, z) = minmax_scale(w, spec.bits, beta);
        let err = group_error(w, s, z, qmax);
        let loss = match metric {
            ScaleMetric::L2 => err.iter().map(|e| (*e as f64) * (*e as f64)).sum(),
            ScaleMetric::HessianBlock => {
                let h = h_block.expect("HessianBlock metric needs H_ii");
                quad_form(&err, h, &err)
            }
        };
        if loss < best.0 {
            best = (loss, s, z);
        }
    }
    (best.1, best.2)
}

/// Compute scales for a whole `[rows, cols]` weight matrix.
///
/// * `metric = L2`, `hessian = None` → the stock GPTQ grid init.
/// * `metric = HessianBlock`, `hessian = Some(H)` → the paper's Stage 1;
///   `H_ii` blocks are sliced out of the full `[cols, cols]` Hessian
///   (Fig. 1: no extra statistics are gathered).
///
/// Vectorized across rows (§Perf): for each (group, β) candidate the error
/// matrix `E: [rows, g]` is built in one pass and the quadratic loss
/// evaluated as `rowsum((E · H_ii) ∘ E)` through the threaded GEMM — the
/// same structure as the L1 Pallas kernel — rather than per-row scalar
/// quadratic forms (7.2× faster on the `small` preset; see EXPERIMENTS.md).
pub fn compute_group_scales(
    w: &Matrix,
    spec: &QuantSpec,
    metric: ScaleMetric,
    hessian: Option<&Matrix>,
) -> GroupScales {
    let rows = w.rows;
    let n_g = spec.n_groups(w.cols);
    let g = spec.group_size;
    let qmaxf = spec.qmax() as f32;
    let betas = spec.beta_grid();
    let mut scales = Matrix::zeros(rows, n_g);
    let mut zeros = Matrix::zeros(rows, n_g);

    for gi in 0..n_g {
        let c0 = gi * g;
        let c1 = ((gi + 1) * g).min(w.cols);
        let gw = c1 - c0;
        let hblk = hessian.map(|h| h.slice(c0, c1, c0, c1));

        // per-row min/max of the group, computed once
        let mut lo0 = vec![f32::INFINITY; rows];
        let mut hi0 = vec![f32::NEG_INFINITY; rows];
        for r in 0..rows {
            for &x in &w.row(r)[c0..c1] {
                lo0[r] = lo0[r].min(x);
                hi0[r] = hi0[r].max(x);
            }
        }

        let mut best_loss = vec![f64::INFINITY; rows];
        let mut best_s = vec![0.0f32; rows];
        let mut best_z = vec![0.0f32; rows];
        let mut e = Matrix::zeros(rows, gw);
        let mut svec = vec![0.0f32; rows];
        let mut zvec = vec![0.0f32; rows];

        for &beta in &betas {
            // scales/zeros + error matrix for this candidate (parallel rows)
            {
                let e_ptr = crate::util::SendPtr(e.data.as_mut_ptr());
                let s_ptr = crate::util::SendPtr(svec.as_mut_ptr());
                let z_ptr = crate::util::SendPtr(zvec.as_mut_ptr());
                crate::util::threadpool::parallel_for_auto(rows, |r| {
                    let lo = lo0[r].min(0.0) * beta;
                    let hi = hi0[r].max(0.0) * beta;
                    let mut s = (hi - lo) / qmaxf;
                    if s < 1e-10 {
                        s = 1e-10;
                    }
                    let z = (-lo / s).round().clamp(0.0, qmaxf);
                    // SAFETY: disjoint rows per worker.
                    unsafe {
                        *s_ptr.get().add(r) = s;
                        *z_ptr.get().add(r) = z;
                        let erow =
                            std::slice::from_raw_parts_mut(e_ptr.get().add(r * gw), gw);
                        for (ev, &x) in erow.iter_mut().zip(&w.row(r)[c0..c1]) {
                            let q = ((x / s).round() + z).clamp(0.0, qmaxf);
                            *ev = s * (q - z) - x;
                        }
                    }
                });
            }
            // loss per row under the chosen metric
            let losses: Vec<f64> = match (&metric, &hblk) {
                (ScaleMetric::L2, _) => (0..rows)
                    .map(|r| e.row(r).iter().map(|v| (*v as f64) * (*v as f64)).sum())
                    .collect(),
                (ScaleMetric::HessianBlock, Some(h)) => {
                    let eh = e.matmul(h); // threaded [rows, gw]·[gw, gw]
                    (0..rows)
                        .map(|r| {
                            e.row(r)
                                .iter()
                                .zip(eh.row(r))
                                .map(|(a, b)| *a as f64 * *b as f64)
                                .sum()
                        })
                        .collect()
                }
                (ScaleMetric::HessianBlock, None) => {
                    panic!("HessianBlock metric needs a Hessian")
                }
            };
            for r in 0..rows {
                if losses[r] < best_loss[r] {
                    best_loss[r] = losses[r];
                    best_s[r] = svec[r];
                    best_z[r] = zvec[r];
                }
            }
        }
        for r in 0..rows {
            scales[(r, gi)] = best_s[r];
            zeros[(r, gi)] = best_z[r];
        }
    }
    GroupScales { scales, zeros, group_size: g, bits: spec.bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::rng::Rng;

    #[test]
    fn beta_grid_spans_range() {
        let spec = QuantSpec::new(2, 64);
        let grid = spec.beta_grid();
        assert_eq!(grid.len(), 40);
        assert!((grid[0] - 0.35).abs() < 1e-6);
        assert!((grid[grid.len() - 1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn minmax_covers_range_at_beta1() {
        let w = [-1.0f32, -0.2, 0.3, 2.0];
        let (s, z) = minmax_scale(&w, 4, 1.0);
        // extremes must round-trip within one step
        for &x in &w {
            let q = quantize_value(x, s, z, 15);
            let d = dequantize_value(q, s, z);
            assert!((d - x).abs() <= s * 0.5 + 1e-6, "x={x} d={d}");
        }
    }

    #[test]
    fn zero_is_exact_on_grid() {
        let w = [-0.7f32, 0.9, 0.1];
        for bits in [2u8, 3, 4] {
            let (s, z) = minmax_scale(&w, bits, 1.0);
            let q = quantize_value(0.0, s, z, (1 << bits) - 1);
            assert_eq!(dequantize_value(q, s, z), 0.0, "bits={bits}");
        }
    }

    #[test]
    fn degenerate_group_is_finite() {
        let w = [0.5f32; 8];
        let (s, z) = minmax_scale(&w, 2, 1.0);
        assert!(s > 0.0 && z.is_finite());
        let q = quantize_group(&w, s, z, 3);
        assert!(q.iter().all(|&v| v <= 3));
    }

    #[test]
    fn l2_grid_no_worse_than_beta1() {
        let mut rng = Rng::new(1);
        let spec = QuantSpec::new(2, 64);
        for _ in 0..20 {
            let w = rng.normal_vec(64, 1.0);
            // inject an outlier so clipping matters
            let mut w = w;
            w[0] = 8.0;
            let (s1, z1) = minmax_scale(&w, 2, 1.0);
            let e1: f64 =
                group_error(&w, s1, z1, 3).iter().map(|e| (*e as f64).powi(2)).sum();
            let (s, z) = search_group_scale(&w, &spec, ScaleMetric::L2, None);
            let e: f64 = group_error(&w, s, z, 3).iter().map(|e| (*e as f64).powi(2)).sum();
            assert!(e <= e1 + 1e-9, "grid {e} vs minmax {e1}");
        }
    }

    #[test]
    fn hessian_metric_no_worse_than_l2_under_hessian_loss() {
        // Stage-1 claim: optimizing under H_ii can only improve the H_ii loss
        // relative to picking via L2 (same grid).
        let mut rng = Rng::new(2);
        let g = 32;
        let spec = QuantSpec::new(2, g);
        for _ in 0..10 {
            let w: Vec<f32> = rng.normal_vec(g, 1.0);
            let x = Matrix::randn(g, 48, 1.0, &mut rng);
            let h = x.matmul_bt(&x); // SPD-ish g×g
            let (sl, zl) = search_group_scale(&w, &spec, ScaleMetric::L2, None);
            let (sh, zh) =
                search_group_scale(&w, &spec, ScaleMetric::HessianBlock, Some(&h));
            let el = group_error(&w, sl, zl, 3);
            let eh = group_error(&w, sh, zh, 3);
            let ll = quad_form(&el, &h, &el);
            let lh = quad_form(&eh, &h, &eh);
            assert!(lh <= ll + 1e-6, "hess {lh} vs l2 {ll}");
        }
    }

    #[test]
    fn compute_group_scales_shapes() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(6, 100, 1.0, &mut rng);
        let spec = QuantSpec::new(3, 32);
        let gs = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
        assert_eq!((gs.scales.rows, gs.scales.cols), (6, 4)); // ceil(100/32)
        assert!(gs.scales.data.iter().all(|&s| s > 0.0));
        assert!(gs
            .zeros
            .data
            .iter()
            .all(|&z| (0.0..=7.0).contains(&z) && z.fract() == 0.0));
    }

    #[test]
    fn prop_quantize_in_range() {
        check("quantized ints within [0, qmax]", 60, |g| {
            let bits = g.usize_in(2, 4) as u8;
            let n = g.usize_in(1, 64);
            let w = g.normal_vec(n, 2.0);
            let beta = g.f32_in(0.3, 1.0);
            let (s, z) = minmax_scale(&w, bits, beta);
            let qmax = (1i32 << bits) - 1;
            let q = quantize_group(&w, s, z, qmax);
            prop_assert(q.iter().all(|&v| (v as i32) <= qmax), "in range")
        });
    }

    #[test]
    fn prop_roundtrip_error_bounded_at_beta1() {
        check("|dequant−w| ≤ s/2 inside the clip range", 40, |g| {
            let n = g.usize_in(1, 64);
            let w = g.normal_vec(n, 1.0);
            let (s, z) = minmax_scale(&w, 4, 1.0);
            let err = group_error(&w, s, z, 15);
            prop_assert(
                err.iter().all(|e| e.abs() <= s * 0.5 + 1e-5),
                "bounded round-trip error",
            )
        });
    }
}
