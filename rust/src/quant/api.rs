//! The unified quantization API: the [`LayerQuantizer`] trait and its
//! registry.
//!
//! Every quantization algorithm in the system — the paper's two-stage method,
//! the stock GPTQ baseline, and the related-work baselines (RTN, AWQ-lite,
//! act-order GPTQ) — implements the same contract: weight matrix + Hessian
//! (+ optional upstream-error matrix) + [`QuantSpec`] in, a unified
//! [`LayerQuantResult`] carrying a [`QuantizedLinear`] and phase timings out.
//! The pipeline, CLI, benches and serving path are all written against this
//! trait, so adding an algorithm (or composing them per layer via
//! [`super::plan::QuantPlan`]) never touches the orchestration code.
//!
//! Registered names (see [`resolve_quantizer`]):
//!
//! | name       | implementation                                     |
//! |------------|----------------------------------------------------|
//! | `rtn`      | round-to-nearest on the L2 grid ([`Rtn`])          |
//! | `awq`      | activation-aware channel scaling ([`Awq`])         |
//! | `actorder` | GPTQ with descending-diagonal column permutation   |
//! | `gptq`     | stock GPTQ ([`TwoStage::GPTQ`])                    |
//! | `stage1`   | paper's Stage 1 only ([`TwoStage::STAGE1_ONLY`])   |
//! | `stage2`   | paper's Stage 2 only ([`TwoStage::STAGE2_ONLY`])   |
//! | `ours`     | the full two-stage method ([`TwoStage::OURS`])     |

use super::format::QuantizedLinear;
use super::gptq::{self, GptqConfig};
use super::metrics;
use super::scale::{QuantSpec, ScaleMetric};
use super::stage2::Stage2Config;
use super::{actorder, awq, rtn, stage1, stage2};
use crate::tensor::Matrix;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Shared tunables every quantizer may consult (damping, lazy-batch block
/// size, CD sweep count). One context serves a whole pipeline run.
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantContext {
    pub gptq: GptqConfig,
    pub stage2: Stage2Config,
}

/// Everything measured while quantizing one linear layer.
#[derive(Clone, Debug)]
pub struct LayerQuantResult {
    pub quantized: QuantizedLinear,
    /// Layer-wise reconstruction loss (Eq. 3) on the damped Hessian.
    pub layer_loss: f64,
    /// Same, before stage 2 ran (equal to `layer_loss` for quantizers
    /// without a refinement phase).
    pub loss_before_stage2: f64,
    /// Wall-clock per phase (zero for phases a quantizer does not have).
    pub time_scales: Duration,
    pub time_gptq: Duration,
    pub time_stage2: Duration,
}

/// One quantization algorithm for a single linear layer.
///
/// `w` is the FP weight matrix `[out, in]`, `h` the raw accumulated Hessian
/// `E[XXᵀ]` (damping is applied inside each implementation so every method
/// scores its loss on the same damped matrix), and `r` the optional
/// upstream-deviation correlation `R = E[ΔX Xᵀ]` (Eq. 9) — quantizers that
/// cannot use it must ignore it.
pub trait LayerQuantizer: Send + Sync {
    /// The registered name (`rtn`, `awq`, `actorder`, `gptq`, `stage1`,
    /// `stage2`, `ours`).
    fn name(&self) -> &'static str;

    /// Whether this quantizer consumes the upstream-error matrix `r`; the
    /// pipeline only pays for deviation statistics when some assigned
    /// quantizer wants them.
    fn wants_deviation(&self) -> bool {
        false
    }

    /// Quantize one layer end-to-end.
    fn quantize(
        &self,
        w: &Matrix,
        h: &Matrix,
        r: Option<&Matrix>,
        spec: &QuantSpec,
        ctx: &QuantContext,
    ) -> crate::Result<LayerQuantResult>;
}

/// All registered quantizer names, in presentation order.
pub const QUANTIZER_NAMES: [&str; 7] =
    ["rtn", "awq", "actorder", "gptq", "stage1", "stage2", "ours"];

/// Look up a quantizer by registered name.
pub fn resolve_quantizer(name: &str) -> Option<Arc<dyn LayerQuantizer>> {
    match name {
        "rtn" => Some(Arc::new(Rtn)),
        "awq" => Some(Arc::new(Awq)),
        "actorder" => Some(Arc::new(ActOrderGptq)),
        "gptq" => Some(Arc::new(TwoStage::GPTQ)),
        "stage1" => Some(Arc::new(TwoStage::STAGE1_ONLY)),
        "stage2" => Some(Arc::new(TwoStage::STAGE2_ONLY)),
        "ours" => Some(Arc::new(TwoStage::OURS)),
        _ => None,
    }
}

/// `a|b|c` list of registered names for error messages and help text.
pub fn quantizer_names() -> String {
    QUANTIZER_NAMES.join("|")
}

/// Damped Hessian for loss scoring, without touching any weights (the
/// dead-column zeroing of [`gptq::prepare_hessian`] is a no-op on an empty
/// weight matrix, and the damped matrix itself does not depend on `w`).
/// Quantizers that also need the dead-column-zeroed working weights
/// (Rtn, TwoStage) call `prepare_hessian` on their own clone instead.
fn damped_hessian(h: &Matrix, ctx: &QuantContext) -> Matrix {
    let mut no_weights = Matrix::zeros(0, 0);
    gptq::prepare_hessian(h, &mut no_weights, ctx.gptq.percdamp)
}

/// Round-to-nearest baseline: L2 grid scales, independent per-weight
/// rounding, no error compensation.
#[derive(Clone, Copy, Debug, Default)]
pub struct Rtn;

impl LayerQuantizer for Rtn {
    fn name(&self) -> &'static str {
        "rtn"
    }

    fn quantize(
        &self,
        w: &Matrix,
        h: &Matrix,
        _r: Option<&Matrix>,
        spec: &QuantSpec,
        ctx: &QuantContext,
    ) -> crate::Result<LayerQuantResult> {
        let mut wwork = w.clone();
        let hd = gptq::prepare_hessian(h, &mut wwork, ctx.gptq.percdamp);
        let t0 = Instant::now();
        let scales = stage1::baseline_init(&wwork, spec);
        let time_scales = t0.elapsed();
        let t1 = Instant::now();
        let quantized = rtn::rtn_quantize(&wwork, &scales, spec);
        let time_gptq = t1.elapsed();
        let layer_loss = metrics::layer_loss(w, &quantized.dequantize(), &hd);
        Ok(LayerQuantResult {
            quantized,
            layer_loss,
            loss_before_stage2: layer_loss,
            time_scales,
            time_gptq,
            time_stage2: Duration::ZERO,
        })
    }
}

/// AWQ-lite baseline: per-input-channel scaling by activation magnitude
/// (α grid-searched against the true layer loss), RTN on the scaled grid.
/// The channel divisors ride along inside the returned [`QuantizedLinear`]
/// (`channel_scales`), so the result dequantizes — and round-trips through
/// checkpoints — losslessly.
#[derive(Clone, Copy, Debug, Default)]
pub struct Awq;

impl LayerQuantizer for Awq {
    fn name(&self) -> &'static str {
        "awq"
    }

    fn quantize(
        &self,
        w: &Matrix,
        h: &Matrix,
        _r: Option<&Matrix>,
        spec: &QuantSpec,
        ctx: &QuantContext,
    ) -> crate::Result<LayerQuantResult> {
        let t0 = Instant::now();
        let result = awq::awq_quantize(w, h, spec);
        let time_scales = t0.elapsed();
        let quantized = result.into_quantized_linear();
        let hd = damped_hessian(h, ctx);
        let layer_loss = metrics::layer_loss(w, &quantized.dequantize(), &hd);
        Ok(LayerQuantResult {
            quantized,
            layer_loss,
            loss_before_stage2: layer_loss,
            time_scales,
            time_gptq: Duration::ZERO,
            time_stage2: Duration::ZERO,
        })
    }
}

/// GPTQ with act-order (`desc_act`) column permutation. The permutation
/// rides along inside the returned [`QuantizedLinear`] (`perm`), so the
/// result dequantizes — and round-trips through checkpoints — losslessly in
/// the original column order.
#[derive(Clone, Copy, Debug, Default)]
pub struct ActOrderGptq;

impl LayerQuantizer for ActOrderGptq {
    fn name(&self) -> &'static str {
        "actorder"
    }

    fn quantize(
        &self,
        w: &Matrix,
        h: &Matrix,
        _r: Option<&Matrix>,
        spec: &QuantSpec,
        ctx: &QuantContext,
    ) -> crate::Result<LayerQuantResult> {
        let t0 = Instant::now();
        let pq = actorder::gptq_quantize_actorder(w, h, spec, ScaleMetric::L2, &ctx.gptq)?;
        let time_gptq = t0.elapsed();
        let quantized = pq.into_quantized_linear();
        let hd = damped_hessian(h, ctx);
        let layer_loss = metrics::layer_loss(w, &quantized.dequantize(), &hd);
        Ok(LayerQuantResult {
            quantized,
            layer_loss,
            loss_before_stage2: layer_loss,
            time_scales: Duration::ZERO,
            time_gptq,
            time_stage2: Duration::ZERO,
        })
    }
}

/// The GPTQ family with the paper's two optional stages around the sweep:
///
/// 1. group scales — stock L2 grid, or Stage-1 input-aware grid (Eq. 4);
/// 2. the GPTQ compensated sweep with those scales frozen;
/// 3. optional Stage-2 CD refinement of the scales (error-aware via `r`).
///
/// The four on/off combinations are the Table-3 ablation cells; both-off is
/// the stock GPTQ baseline and both-on is the paper's method.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TwoStage {
    /// Stage 1: input-aware (H_ii-weighted) grid init instead of L2 grid.
    pub stage1: bool,
    /// Stage 2: CD refinement of scales after the GPTQ sweep.
    pub stage2: bool,
}

impl TwoStage {
    /// Stock GPTQ baseline.
    pub const GPTQ: TwoStage = TwoStage { stage1: false, stage2: false };
    /// The paper's full method.
    pub const OURS: TwoStage = TwoStage { stage1: true, stage2: true };
    /// Ablation rows of Table 3.
    pub const STAGE1_ONLY: TwoStage = TwoStage { stage1: true, stage2: false };
    pub const STAGE2_ONLY: TwoStage = TwoStage { stage1: false, stage2: true };
}

impl LayerQuantizer for TwoStage {
    fn name(&self) -> &'static str {
        match (self.stage1, self.stage2) {
            (false, false) => "gptq",
            (true, false) => "stage1",
            (false, true) => "stage2",
            (true, true) => "ours",
        }
    }

    fn wants_deviation(&self) -> bool {
        self.stage2
    }

    fn quantize(
        &self,
        w: &Matrix,
        h: &Matrix,
        r: Option<&Matrix>,
        spec: &QuantSpec,
        ctx: &QuantContext,
    ) -> crate::Result<LayerQuantResult> {
        let mut wwork = w.clone();
        let hd = gptq::prepare_hessian(h, &mut wwork, ctx.gptq.percdamp);

        let t0 = Instant::now();
        let scales = if self.stage1 {
            stage1::stage1_init(&wwork, &hd, spec)
        } else {
            stage1::baseline_init(&wwork, spec)
        };
        let time_scales = t0.elapsed();

        let t1 = Instant::now();
        let u = crate::tensor::cholesky_inverse_upper(&hd)?;
        let mut quantized = gptq::gptq_sweep(&wwork, &u, &scales, spec, &ctx.gptq);
        let time_gptq = t1.elapsed();

        let loss_before_stage2 = metrics::layer_loss(w, &quantized.dequantize(), &hd);

        let t2 = Instant::now();
        if self.stage2 {
            stage2::refine_quantized_linear(w, &mut quantized, &hd, r, &ctx.stage2);
        }
        let time_stage2 = t2.elapsed();

        let layer_loss = if self.stage2 {
            metrics::layer_loss(w, &quantized.dequantize(), &hd)
        } else {
            loss_before_stage2
        };

        Ok(LayerQuantResult {
            quantized,
            layer_loss,
            loss_before_stage2,
            time_scales,
            time_gptq,
            time_stage2,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn correlated_problem(out: usize, inp: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(out, inp, 1.0, &mut rng);
        let t = inp * 6;
        let mut x = Matrix::zeros(inp, t);
        for c in 0..t {
            let mut prev = 0.0f32;
            for r in 0..inp {
                let energy = if r % 8 == 0 { 5.0 } else { 0.4 };
                let v = 0.5 * prev + rng.normal() as f32 * energy;
                x[(r, c)] = v;
                prev = v;
            }
        }
        let mut h = x.matmul_bt(&x);
        h.scale_inplace(1.0 / t as f32);
        (w, h)
    }

    #[test]
    fn registry_resolves_every_name_consistently() {
        for name in QUANTIZER_NAMES {
            let q = resolve_quantizer(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(q.name(), name, "registered name must match trait name");
        }
        assert!(resolve_quantizer("nope").is_none());
        assert!(quantizer_names().contains("actorder"));
    }

    #[test]
    fn every_quantizer_returns_finite_result() {
        let (w, h) = correlated_problem(8, 64, 1);
        let spec = QuantSpec::new(2, 32);
        let ctx = QuantContext::default();
        for name in QUANTIZER_NAMES {
            let q = resolve_quantizer(name).unwrap();
            let res = q.quantize(&w, &h, None, &spec, &ctx).unwrap();
            assert!(res.layer_loss.is_finite() && res.layer_loss >= 0.0, "{name}");
            let d = res.quantized.dequantize();
            assert_eq!((d.rows, d.cols), (8, 64), "{name}");
            assert!(d.data.iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn trait_gptq_matches_direct_sweep() {
        // The trait path must be the same algorithm as calling the stages
        // directly — identical integers for the stock-GPTQ config.
        let (w, h) = correlated_problem(6, 48, 2);
        let spec = QuantSpec::new(3, 16);
        let ctx = QuantContext::default();
        let via_trait = TwoStage::GPTQ.quantize(&w, &h, None, &spec, &ctx).unwrap();
        let direct = {
            let mut wwork = w.clone();
            let hd = gptq::prepare_hessian(&h, &mut wwork, ctx.gptq.percdamp);
            let scales = stage1::baseline_init(&wwork, &spec);
            let u = crate::tensor::cholesky_inverse_upper(&hd).unwrap();
            gptq::gptq_sweep(&wwork, &u, &scales, &spec, &ctx.gptq)
        };
        for r in 0..w.rows {
            assert_eq!(
                via_trait.quantized.qweight[r].unpack(),
                direct.qweight[r].unpack(),
                "row {r}"
            );
        }
    }

    #[test]
    fn ours_beats_gptq_through_the_trait() {
        let (w, h) = correlated_problem(16, 64, 3);
        let spec = QuantSpec::new(2, 32);
        let ctx = QuantContext::default();
        let gptq_loss = TwoStage::GPTQ.quantize(&w, &h, None, &spec, &ctx).unwrap().layer_loss;
        let ours_loss = TwoStage::OURS.quantize(&w, &h, None, &spec, &ctx).unwrap().layer_loss;
        assert!(
            ours_loss < gptq_loss,
            "ours {ours_loss} should beat gptq {gptq_loss}"
        );
    }

    #[test]
    fn deviation_flag_only_on_stage2() {
        assert!(!Rtn.wants_deviation());
        assert!(!Awq.wants_deviation());
        assert!(!ActOrderGptq.wants_deviation());
        assert!(!TwoStage::GPTQ.wants_deviation());
        assert!(!TwoStage::STAGE1_ONLY.wants_deviation());
        assert!(TwoStage::STAGE2_ONLY.wants_deviation());
        assert!(TwoStage::OURS.wants_deviation());
    }
}
