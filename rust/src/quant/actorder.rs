//! Activation-order (act-order / `desc_act`) extension of the GPTQ sweep.
//!
//! GPTQ's optional refinement (and a common production setting in
//! GPTQ-for-LLaMa / AutoGPTQ): quantize columns in order of decreasing
//! Hessian diagonal, so the columns that matter most are fixed early, while
//! later (low-energy) columns absorb the compensation error. Implemented as
//! a column permutation of `(W, H)` before the standard sweep and an inverse
//! permutation of the resulting integers.
//!
//! With group-wise scales the permutation changes group membership — groups
//! are formed over the *permuted* columns (AutoGPTQ's `desc_act=True`
//! behaviour with `group_size`). Scales must therefore be computed on the
//! permuted weights; this module owns that bookkeeping and returns a
//! [`PermutedQuant`] carrying the inverse map the deployment side needs
//! (it changes the dequant gather order, which is why act-order kernels are
//! slower in practice — the trade-off the paper's Table settings avoid by
//! keeping natural order).

use super::format::QuantizedLinear;
use super::gptq::{gptq_sweep, GptqConfig};
use super::scale::{compute_group_scales, QuantSpec, ScaleMetric};
use crate::tensor::{cholesky_inverse_upper, Matrix};
use anyhow::Result;

/// Result of an act-order quantization: the quantized layer lives in
/// *permuted* column space; `perm[j]` is the original column of permuted
/// column `j`, `inv[c]` the permuted position of original column `c`.
#[derive(Clone, Debug)]
pub struct PermutedQuant {
    pub quantized: QuantizedLinear,
    pub perm: Vec<usize>,
    pub inv: Vec<usize>,
}

impl PermutedQuant {
    /// Dequantize back into the ORIGINAL column order.
    pub fn dequantize_unpermuted(&self) -> Matrix {
        let q = self.quantized.dequantize();
        let mut out = Matrix::zeros(q.rows, q.cols);
        for r in 0..q.rows {
            let src = q.row(r);
            let dst = out.row_mut(r);
            for (j, &orig) in self.perm.iter().enumerate() {
                dst[orig] = src[j];
            }
        }
        out
    }

    /// Lossless conversion into the unified [`QuantizedLinear`]: the
    /// permutation becomes the layer's `perm` gather, so `.dequantize()`
    /// lands bit-for-bit on [`Self::dequantize_unpermuted`] (original
    /// column order) and the layer round-trips through checkpoints.
    pub fn into_quantized_linear(self) -> QuantizedLinear {
        let mut q = self.quantized;
        q.perm = Some(self.perm.iter().map(|&p| p as u32).collect());
        q
    }
}

/// Sort columns by descending damped-Hessian diagonal.
pub fn act_order_permutation(h: &Matrix) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..h.rows).collect();
    idx.sort_by(|&a, &b| {
        h[(b, b)]
            .partial_cmp(&h[(a, a)])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx
}

fn permute_columns(m: &Matrix, perm: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(m.rows, m.cols);
    for r in 0..m.rows {
        let src = m.row(r);
        let dst = out.row_mut(r);
        for (j, &p) in perm.iter().enumerate() {
            dst[j] = src[p];
        }
    }
    out
}

fn permute_sym(h: &Matrix, perm: &[usize]) -> Matrix {
    let n = h.rows;
    let mut out = Matrix::zeros(n, n);
    for (i, &pi) in perm.iter().enumerate() {
        for (j, &pj) in perm.iter().enumerate() {
            out[(i, j)] = h[(pi, pj)];
        }
    }
    out
}

/// GPTQ with act-order: permute → scales (L2 or stage-1 metric) → sweep.
pub fn gptq_quantize_actorder(
    w: &Matrix,
    h: &Matrix,
    spec: &QuantSpec,
    metric: ScaleMetric,
    cfg: &GptqConfig,
) -> Result<PermutedQuant> {
    let mut wwork = w.clone();
    let hd = super::gptq::prepare_hessian(h, &mut wwork, cfg.percdamp);
    let perm = act_order_permutation(&hd);
    let mut inv = vec![0usize; perm.len()];
    for (j, &p) in perm.iter().enumerate() {
        inv[p] = j;
    }
    let wp = permute_columns(&wwork, &perm);
    let hp = permute_sym(&hd, &perm);
    let hess_opt = matches!(metric, ScaleMetric::HessianBlock).then_some(&hp);
    let scales = compute_group_scales(&wp, spec, metric, hess_opt);
    let u = cholesky_inverse_upper(&hp)?;
    let quantized = gptq_sweep(&wp, &u, &scales, spec, cfg);
    Ok(PermutedQuant { quantized, perm, inv })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::{gptq_quantize, prepare_hessian};
    use crate::quant::metrics::layer_loss;
    use crate::util::rng::Rng;

    fn skewed_problem(out: usize, inp: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(out, inp, 1.0, &mut rng);
        let t = inp * 6;
        let mut x = Matrix::zeros(inp, t);
        for r in 0..inp {
            let energy = if r % 5 == 0 { 5.0 } else { 0.4 };
            for c in 0..t {
                x[(r, c)] = rng.normal() as f32 * energy;
            }
        }
        let mut h = x.matmul_bt(&x);
        h.scale_inplace(1.0 / t as f32);
        (w, h)
    }

    #[test]
    fn permutation_sorts_diagonal() {
        let (_, h) = skewed_problem(4, 32, 1);
        let perm = act_order_permutation(&h);
        for win in perm.windows(2) {
            assert!(h[(win[0], win[0])] >= h[(win[1], win[1])]);
        }
        // valid permutation
        let mut sorted = perm.clone();
        sorted.sort();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn unpermuted_dequant_restores_column_order() {
        let (w, h) = skewed_problem(8, 32, 2);
        let spec = QuantSpec::new(4, 16);
        let pq = gptq_quantize_actorder(&w, &h, &spec, ScaleMetric::L2, &GptqConfig::default())
            .unwrap();
        let deq = pq.dequantize_unpermuted();
        // at 4 bits the dequantized weights should be close to W columnwise
        // in ORIGINAL order — a shuffled result would show huge error.
        let mse = crate::quant::metrics::weight_mse(&w, &deq);
        assert!(mse < 0.05, "mse={mse} (column order likely wrong)");
    }

    #[test]
    fn conversion_to_quantized_linear_is_lossless() {
        let (w, h) = skewed_problem(8, 32, 7);
        let spec = QuantSpec::new(4, 16);
        let pq = gptq_quantize_actorder(&w, &h, &spec, ScaleMetric::L2, &GptqConfig::default())
            .unwrap();
        let reference = pq.dequantize_unpermuted();
        let unified = pq.into_quantized_linear();
        assert!(unified.perm.is_some());
        assert_eq!(unified.dequantize().max_abs_diff(&reference), 0.0);
    }

    #[test]
    fn actorder_competitive_with_natural_order_at_low_bits() {
        let (w, h) = skewed_problem(24, 64, 3);
        let spec = QuantSpec::new(2, 16);
        let mut wd = w.clone();
        let hd = prepare_hessian(&h, &mut wd, 0.01);

        let natural = {
            let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
            let q = gptq_quantize(&w, &h, &scales, &spec, &GptqConfig::default()).unwrap();
            layer_loss(&w, &q.dequantize(), &hd)
        };
        let actord = {
            let pq = gptq_quantize_actorder(&w, &h, &spec, ScaleMetric::L2, &GptqConfig::default())
                .unwrap();
            layer_loss(&w, &pq.dequantize_unpermuted(), &hd)
        };
        // On strongly skewed H act-order should not be dramatically worse
        // and is typically better; assert within 1.2x either way plus print
        // the direction for the ablation bench to pick up.
        println!("natural={natural:.4e} actorder={actord:.4e}");
        assert!(actord < natural * 1.2, "act-order catastrophically worse");
    }

    #[test]
    fn actorder_composes_with_stage2() {
        // stage2 refinement applies unchanged in permuted space.
        let (w, h) = skewed_problem(8, 32, 4);
        let spec = QuantSpec::new(2, 16);
        let mut wd = w.clone();
        let hd = prepare_hessian(&h, &mut wd, 0.01);
        let perm_h = {
            let pq =
                gptq_quantize_actorder(&w, &h, &spec, ScaleMetric::HessianBlock, &GptqConfig::default())
                    .unwrap();
            // refine in permuted space against permuted W, H
            let perm = pq.perm.clone();
            let wp = super::permute_columns(&wd, &perm);
            let hp = super::permute_sym(&hd, &perm);
            let mut q = pq.quantized.clone();
            let before = layer_loss(&wp, &q.dequantize(), &hp);
            crate::quant::stage2::refine_quantized_linear(
                &wp,
                &mut q,
                &hp,
                None,
                &crate::quant::stage2::Stage2Config::default(),
            );
            let after = layer_loss(&wp, &q.dequantize(), &hp);
            assert!(after <= before * 1.0001, "stage2 broke in permuted space");
            after
        };
        assert!(perm_h.is_finite());
    }
}
