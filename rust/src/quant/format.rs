//! Grouped, bit-packed integer weight storage.
//!
//! Weight-only quantization's deployment story (the paper §2.2: "supported
//! by major LLM inference frameworks such as vLLM and TensorRT-LLM") needs a
//! real packed format: integers are packed along the input dimension into
//! `u32` words (little-endian bit order, values may straddle word
//! boundaries for 3-bit), with one `(scale, zero)` pair per `(row, group)`.
//! The same packed layout is what the L1 Pallas dequant-matmul kernel
//! unpacks in VMEM.

use crate::tensor::Matrix;

/// Bit-packed unsigned integers (2/3/4/8 bits per value).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedInts {
    pub bits: u8,
    pub len: usize,
    pub words: Vec<u32>,
}

impl PackedInts {
    /// Pack `vals` (each < 2^bits) into a little-endian bit stream.
    pub fn pack(vals: &[u8], bits: u8) -> PackedInts {
        assert!(matches!(bits, 1..=8), "bits must be 1..=8");
        let total_bits = vals.len() * bits as usize;
        let mut words = vec![0u32; total_bits.div_ceil(32)];
        for (i, &v) in vals.iter().enumerate() {
            debug_assert!((v as u32) < (1u32 << bits), "value {v} out of range for {bits} bits");
            let bit = i * bits as usize;
            let word = bit / 32;
            let off = bit % 32;
            words[word] |= (v as u32) << off;
            let spill = off + bits as usize;
            if spill > 32 {
                words[word + 1] |= (v as u32) >> (32 - off);
            }
        }
        PackedInts { bits, len: vals.len(), words }
    }

    /// Unpack back to bytes.
    pub fn unpack(&self) -> Vec<u8> {
        let bits = self.bits as usize;
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        (0..self.len)
            .map(|i| {
                let bit = i * bits;
                let word = bit / 32;
                let off = bit % 32;
                let mut v = self.words[word] >> off;
                if off + bits > 32 {
                    v |= self.words[word + 1] << (32 - off);
                }
                (v & mask) as u8
            })
            .collect()
    }

    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        let bits = self.bits as usize;
        let mask = (1u32 << bits) - 1;
        let bit = i * bits;
        let word = bit / 32;
        let off = bit % 32;
        let mut v = self.words[word] >> off;
        if off + bits > 32 && word + 1 < self.words.len() {
            v |= self.words[word + 1] << (32 - off);
        }
        (v & mask) as u8
    }

    /// Size in bytes of the packed payload.
    pub fn nbytes(&self) -> usize {
        self.words.len() * 4
    }
}

/// A fully quantized linear layer: packed integers + per-(row, group)
/// scales/zero-points. Rows are output channels; grouping runs along the
/// input dimension, exactly as in the paper's Fig. 1.
///
/// Two optional pieces of deployment metadata let every registered
/// quantizer express its output losslessly in this one type (the same
/// extensions real formats carry — AutoGPTQ's `g_idx`, AWQ's folded
/// scales):
///
/// * `perm` — act-order column gather: stored column `j` is original
///   column `perm[j]`; groups run over the *stored* (permuted) order.
/// * `channel_scales` — AWQ per-input-channel divisors applied after the
///   grid dequant (`W ≈ dequant(Q) / s` column-wise), indexed by stored
///   column.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub rows: usize,
    pub cols: usize,
    pub bits: u8,
    pub group_size: usize,
    /// Packed per row: `qweight[r]` holds the row's `cols` integers.
    pub qweight: Vec<PackedInts>,
    /// `[rows, n_groups]` scale factors.
    pub scales: Matrix,
    /// `[rows, n_groups]` integer zero-points (stored as f32).
    pub zeros: Matrix,
    /// Act-order gather: original column of stored column `j` (None =
    /// natural order).
    pub perm: Option<Vec<u32>>,
    /// AWQ channel divisors (None = no channel scaling).
    pub channel_scales: Option<Vec<f32>>,
}

impl QuantizedLinear {
    pub fn n_groups(&self) -> usize {
        self.cols.div_ceil(self.group_size)
    }

    /// Build from an integer matrix (`[rows, cols]`, values in [0, 2^bits))
    /// plus scales/zeros.
    pub fn from_ints(
        ints: &[Vec<u8>],
        bits: u8,
        group_size: usize,
        scales: Matrix,
        zeros: Matrix,
    ) -> QuantizedLinear {
        let rows = ints.len();
        let cols = ints[0].len();
        assert_eq!(scales.rows, rows);
        assert_eq!(scales.cols, cols.div_ceil(group_size));
        assert_eq!((zeros.rows, zeros.cols), (scales.rows, scales.cols));
        let qweight = ints.iter().map(|row| PackedInts::pack(row, bits)).collect();
        QuantizedLinear {
            rows,
            cols,
            bits,
            group_size,
            qweight,
            scales,
            zeros,
            perm: None,
            channel_scales: None,
        }
    }

    /// Dequantize one row into `out` (original column order: the act-order
    /// gather and AWQ channel divisors, when present, are applied here).
    pub fn dequant_row_into(&self, r: usize, out: &mut [f32]) {
        let g = self.group_size;
        let srow = self.scales.row(r);
        let zrow = self.zeros.row(r);
        let q = &self.qweight[r];
        for j in 0..self.cols {
            let gi = j / g;
            let mut v = srow[gi] * (q.get(j) as f32 - zrow[gi]);
            if let Some(cs) = &self.channel_scales {
                v /= cs[j];
            }
            let dst = match &self.perm {
                Some(p) => p[j] as usize,
                None => j,
            };
            out[dst] = v;
        }
    }

    /// Dequantize the whole layer to a dense matrix.
    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            // split borrow: copy row out then write
            let mut row = vec![0.0f32; self.cols];
            self.dequant_row_into(r, &mut row);
            m.row_mut(r).copy_from_slice(&row);
        }
        m
    }

    /// Total payload bytes (packed ints + scales + zeros + optional
    /// permutation / channel scales), for the compression-ratio report.
    pub fn nbytes(&self) -> usize {
        self.qweight.iter().map(|p| p.nbytes()).sum::<usize>()
            + (self.scales.data.len() + self.zeros.data.len()) * 4
            + self.perm.as_ref().map_or(0, |p| p.len() * 4)
            + self.channel_scales.as_ref().map_or(0, |c| c.len() * 4)
    }

    /// Effective bits per weight including scale/zero overhead.
    pub fn bits_per_weight(&self) -> f64 {
        self.nbytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn pack_roundtrip_all_widths() {
        for bits in [1u8, 2, 3, 4, 5, 8] {
            let max = 1u32 << bits;
            let vals: Vec<u8> = (0..1000u32).map(|i| ((i * 7 + 3) % max) as u8).collect();
            let p = PackedInts::pack(&vals, bits);
            assert_eq!(p.unpack(), vals, "bits={bits}");
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(p.get(i), v, "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn pack_density() {
        // 3-bit: 1000 values -> 3000 bits -> 94 words.
        let p = PackedInts::pack(&vec![5u8; 1000], 3);
        assert_eq!(p.words.len(), 94);
        assert_eq!(p.nbytes(), 376);
    }

    #[test]
    fn prop_pack_roundtrip() {
        check("pack/unpack roundtrip", 60, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let n = g.usize_in(1, 300);
            let vals: Vec<u8> =
                (0..n).map(|_| g.usize_in(0, (1usize << bits) - 1) as u8).collect();
            let p = PackedInts::pack(&vals, bits);
            prop_assert(p.unpack() == vals, "roundtrip")
        });
    }

    #[test]
    fn quantized_linear_dequant() {
        // 2 rows, 4 cols, group=2, 2 bits.
        let ints = vec![vec![0u8, 1, 2, 3], vec![3, 2, 1, 0]];
        let scales = Matrix::from_vec(2, 2, vec![0.5, 1.0, 2.0, 0.25]);
        let zeros = Matrix::from_vec(2, 2, vec![1.0, 2.0, 0.0, 1.0]);
        let q = QuantizedLinear::from_ints(&ints, 2, 2, scales, zeros);
        let d = q.dequantize();
        // row0: s=0.5,z=1 -> (0-1)*0.5, (1-1)*0.5 ; s=1,z=2 -> (2-2), (3-2)
        assert_eq!(d.row(0), &[-0.5, 0.0, 0.0, 1.0]);
        // row1: s=2,z=0 -> 6,4 ; s=0.25,z=1 -> 0, -0.25
        assert_eq!(d.row(1), &[6.0, 4.0, 0.0, -0.25]);
    }

    #[test]
    fn perm_and_channel_scales_change_dequant() {
        // 1 row, 4 cols, group=2, 2 bits; s=1, z=0 → dequant == ints.
        let ints = vec![vec![0u8, 1, 2, 3]];
        let scales = Matrix::from_vec(1, 2, vec![1.0, 1.0]);
        let zeros = Matrix::from_vec(1, 2, vec![0.0, 0.0]);
        let mut q = QuantizedLinear::from_ints(&ints, 2, 2, scales, zeros);
        assert_eq!(q.dequantize().row(0), &[0.0, 1.0, 2.0, 3.0]);
        let plain_bytes = q.nbytes();

        // reversal gather: stored column j goes to original column 3-j
        q.perm = Some(vec![3, 2, 1, 0]);
        assert_eq!(q.dequantize().row(0), &[3.0, 2.0, 1.0, 0.0]);

        // channel divisors apply per stored column, before the gather
        q.channel_scales = Some(vec![1.0, 1.0, 2.0, 4.0]);
        assert_eq!(q.dequantize().row(0), &[0.75, 1.0, 1.0, 0.0]);
        assert_eq!(q.nbytes(), plain_bytes + 4 * 4 + 4 * 4);
    }

    #[test]
    fn bits_per_weight_sane() {
        let rows = 8;
        let cols = 128;
        let ints: Vec<Vec<u8>> = (0..rows).map(|_| vec![1u8; cols]).collect();
        let scales = Matrix::zeros(rows, 2);
        let zeros = Matrix::zeros(rows, 2);
        let q = QuantizedLinear::from_ints(&ints, 2, 64, scales, zeros);
        let bpw = q.bits_per_weight();
        // 2 bits + (2 groups * 8 bytes) / 128 weights = 2 + 1 = 3 bits.
        assert!((bpw - 3.0).abs() < 0.01, "bpw={bpw}");
    }
}
