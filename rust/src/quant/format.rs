//! Grouped, bit-packed quantized-linear format — storage *and* execution.
//!
//! Weight-only quantization's deployment story (the paper §2.2: "supported
//! by major LLM inference frameworks such as vLLM and TensorRT-LLM") needs a
//! real packed format: integers are packed along the input dimension into
//! `u32` words ([`PackedInts`], little-endian bit order, values may straddle
//! word boundaries for 3-bit), with one `(scale, zero)` pair per
//! `(row, group)`. The same packed layout is what the L1 Pallas
//! dequant-matmul kernel unpacks in VMEM; [`QuantizedLinear::forward`] is
//! its CPU mirror — a fused group-wise dequant GEMV/GEMM over the packed
//! words (`tensor::packed`), so serve/eval execute quantized checkpoints
//! without ever materializing a dense weight matrix.

use crate::tensor::packed::{group_sums, packed_row_dot};
use crate::tensor::Matrix;
use anyhow::{bail, Result};

pub use crate::tensor::packed::PackedInts;

/// Output-weight rows per packed-GEMM work item. One tile's packed rows +
/// scales/zeros fit comfortably in L2 at every supported width.
const ROW_TILE: usize = 64;
/// Activation rows per packed-GEMM work item: how many times each fetched
/// packed weight row is reused before moving on.
const ACT_BLOCK: usize = 8;
/// Below this many weight elements, a single-token GEMV runs serially on
/// the calling thread: the scoped spawn/join of a parallel region (tens of
/// µs) costs more than the dot products it would split. Above it, decode
/// parallelizes across row tiles.
const PAR_GEMV_MIN_ELEMS: usize = 1 << 20;

/// A fully quantized linear layer: packed integers + per-(row, group)
/// scales/zero-points. Rows are output channels; grouping runs along the
/// input dimension, exactly as in the paper's Fig. 1.
///
/// Two optional pieces of deployment metadata let every registered
/// quantizer express its output losslessly in this one type (the same
/// extensions real formats carry — AutoGPTQ's `g_idx`, AWQ's folded
/// scales):
///
/// * `perm` — act-order column gather: stored column `j` is original
///   column `perm[j]`; groups run over the *stored* (permuted) order.
/// * `channel_scales` — AWQ per-input-channel divisors applied after the
///   grid dequant (`W ≈ dequant(Q) / s` column-wise), indexed by stored
///   column.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub rows: usize,
    pub cols: usize,
    pub bits: u8,
    pub group_size: usize,
    /// Packed per row: `qweight[r]` holds the row's `cols` integers.
    pub qweight: Vec<PackedInts>,
    /// `[rows, n_groups]` scale factors.
    pub scales: Matrix,
    /// `[rows, n_groups]` integer zero-points (stored as f32).
    pub zeros: Matrix,
    /// Act-order gather: original column of stored column `j` (None =
    /// natural order).
    pub perm: Option<Vec<u32>>,
    /// AWQ channel divisors (None = no channel scaling).
    pub channel_scales: Option<Vec<f32>>,
}

impl QuantizedLinear {
    pub fn n_groups(&self) -> usize {
        self.cols.div_ceil(self.group_size)
    }

    /// Build from an integer matrix (`[rows, cols]`, values in [0, 2^bits))
    /// plus scales/zeros.
    pub fn from_ints(
        ints: &[Vec<u8>],
        bits: u8,
        group_size: usize,
        scales: Matrix,
        zeros: Matrix,
    ) -> QuantizedLinear {
        let rows = ints.len();
        let cols = ints[0].len();
        assert_eq!(scales.rows, rows);
        assert_eq!(scales.cols, cols.div_ceil(group_size));
        assert_eq!((zeros.rows, zeros.cols), (scales.rows, scales.cols));
        let qweight = ints.iter().map(|row| PackedInts::pack(row, bits)).collect();
        QuantizedLinear {
            rows,
            cols,
            bits,
            group_size,
            qweight,
            scales,
            zeros,
            perm: None,
            channel_scales: None,
        }
    }

    /// Structural integrity check — the one gate every deserialized linear
    /// must pass before any decode path touches it. Rejects truncated packed
    /// payloads (where `get`/`unpack` would otherwise panic), shape-mismatched
    /// scales/zeros, non-bijective `perm`, and zero / non-finite
    /// `channel_scales` (which would turn `dequant_row_into`'s division into
    /// inf/NaN weights).
    pub fn validate(&self) -> Result<()> {
        if !matches!(self.bits, 1..=8) {
            bail!("bits {} out of range 1..=8", self.bits);
        }
        if self.group_size == 0 {
            bail!("group_size must be positive");
        }
        let n_g = self.n_groups();
        if self.qweight.len() != self.rows {
            bail!("{} packed rows != {} rows", self.qweight.len(), self.rows);
        }
        let need = PackedInts::words_needed(self.cols, self.bits);
        for (r, q) in self.qweight.iter().enumerate() {
            if q.bits != self.bits || q.len != self.cols {
                bail!("row {r}: packed layout ({} bits, {} vals) != ({}, {})",
                    q.bits, q.len, self.bits, self.cols);
            }
            if q.words.len() < need {
                bail!("row {r}: packed payload truncated ({} words < {need} needed)",
                    q.words.len());
            }
        }
        if (self.scales.rows, self.scales.cols) != (self.rows, n_g) {
            bail!("scales shape [{}, {}] != [{}, {n_g}]",
                self.scales.rows, self.scales.cols, self.rows);
        }
        if (self.zeros.rows, self.zeros.cols) != (self.rows, n_g) {
            bail!("zeros shape [{}, {}] != [{}, {n_g}]",
                self.zeros.rows, self.zeros.cols, self.rows);
        }
        if self.scales.data.iter().any(|v| !v.is_finite()) {
            bail!("non-finite scale");
        }
        if self.zeros.data.iter().any(|v| !v.is_finite()) {
            bail!("non-finite zero-point");
        }
        if let Some(p) = &self.perm {
            if p.len() != self.cols {
                bail!("perm length {} != {} cols", p.len(), self.cols);
            }
            // must be a bijection: a repeated destination would leave some
            // original column silently unwritten at dequantization
            let mut seen = vec![false; self.cols];
            for &v in p {
                if v as usize >= self.cols {
                    bail!("perm entry out of range (cols = {})", self.cols);
                }
                if std::mem::replace(&mut seen[v as usize], true) {
                    bail!("perm entry {v} duplicated (not a permutation)");
                }
            }
        }
        if let Some(cs) = &self.channel_scales {
            if cs.len() != self.cols {
                bail!("channel_scales length {} != {} cols", cs.len(), self.cols);
            }
            if cs.iter().any(|v| !v.is_finite() || *v == 0.0) {
                bail!("non-finite or zero channel scale");
            }
        }
        Ok(())
    }

    /// Dequantize one row into `out` (original column order: the act-order
    /// gather and AWQ channel divisors, when present, are applied here).
    pub fn dequant_row_into(&self, r: usize, out: &mut [f32]) {
        let g = self.group_size;
        let srow = self.scales.row(r);
        let zrow = self.zeros.row(r);
        let q = &self.qweight[r];
        for j in 0..self.cols {
            let gi = j / g;
            let mut v = srow[gi] * (q.get(j) as f32 - zrow[gi]);
            if let Some(cs) = &self.channel_scales {
                v /= cs[j];
            }
            let dst = match &self.perm {
                Some(p) => p[j] as usize,
                None => j,
            };
            out[dst] = v;
        }
    }

    /// Dequantize the whole layer to a dense matrix.
    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            // split borrow: copy row out then write
            let mut row = vec![0.0f32; self.cols];
            self.dequant_row_into(r, &mut row);
            m.row_mut(r).copy_from_slice(&row);
        }
        m
    }

    /// Fold one activation row (original column order) into *stored* order
    /// with the AWQ channel divisors applied, and fill the per-group sums
    /// the fused kernel shares across output rows:
    /// `xf[j] = x[perm[j]] / cs[j]`, `gsum[g] = Σ_{j∈g} xf[j]`.
    pub fn fold_activation(&self, x: &[f32], xf: &mut [f32], gsum: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols);
        match (&self.perm, &self.channel_scales) {
            (None, None) => xf.copy_from_slice(x),
            (Some(p), None) => {
                for (f, &src) in xf.iter_mut().zip(p) {
                    *f = x[src as usize];
                }
            }
            (None, Some(cs)) => {
                for ((f, &xv), &c) in xf.iter_mut().zip(x).zip(cs) {
                    *f = xv / c;
                }
            }
            (Some(p), Some(cs)) => {
                for ((f, &src), &c) in xf.iter_mut().zip(p).zip(cs) {
                    *f = x[src as usize] / c;
                }
            }
        }
        group_sums(xf, self.group_size, gsum);
    }

    /// Fused GEMV: `out[r] = Σ_c W[r, c] · x[c]` computed directly from the
    /// packed words. `xf`/`gsum` come from [`QuantizedLinear::fold_activation`].
    pub fn gemv_into(&self, xf: &[f32], gsum: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.rows);
        for (r, o) in out.iter_mut().enumerate() {
            *o = packed_row_dot(
                &self.qweight[r].words,
                self.bits,
                self.cols,
                self.group_size,
                self.scales.row(r),
                self.zeros.row(r),
                xf,
                gsum,
            );
        }
    }

    /// Fused GEMV from a raw activation row (original column order): fold +
    /// group sums + dot, with the working buffers checked out of the shared
    /// scratch pool — steady-state decode allocates nothing per token.
    pub fn gemv(&self, x: &[f32], out: &mut [f32]) {
        let mut xf = crate::util::scratch::take_f32(self.cols);
        let mut gsum = crate::util::scratch::take_f32(self.n_groups());
        self.fold_activation(x, &mut xf, &mut gsum);
        self.gemv_into(&xf, &gsum, out);
    }

    /// Fused dequant GEMM: `x @ Wᵀ` (`[T, cols] → [T, rows]`) straight from
    /// the packed words — numerically the dequantized matmul, reading
    /// `bits/32` of its weight bytes.
    ///
    /// Two-level blocking instead of the old rows-only split: activations
    /// are folded **once** per row up front (shared by every output-row
    /// tile), then work items are output-row tiles × activation blocks. A
    /// tile's packed weight rows stay cache-hot across its `ACT_BLOCK`
    /// activation rows, and the item count is
    /// `⌈rows/ROW_TILE⌉ · ⌈T/ACT_BLOCK⌉`, so prefill batches keep every
    /// core busy well past the activation row count — and single-token
    /// decode (`T = 1`) parallelizes across row tiles instead of running on
    /// one thread. Working buffers come from the scratch pool; nothing is
    /// allocated per call except the output.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols, "packed gemm shape mismatch");
        let t_rows = x.rows;
        let n_g = self.n_groups();
        let mut out = Matrix::zeros(t_rows, self.rows);
        if t_rows == 0 {
            return out;
        }
        if t_rows == 1
            && (self.rows <= ROW_TILE || self.rows * self.cols < PAR_GEMV_MIN_ELEMS)
        {
            // Single-token decode on a single tile (the tiled path would be
            // serial anyway) or on a linear too small to amortize a thread
            // spawn: go straight through the pooled GEMV on the calling
            // thread (same kernels, same fold — minus the staging).
            self.gemv(x.row(0), out.row_mut(0));
            return out;
        }
        let mut xf_all = crate::util::scratch::take_f32(t_rows * self.cols);
        let mut gs_all = crate::util::scratch::take_f32(t_rows * n_g);
        // Stage 1: fold every activation row once (act-order gather, AWQ
        // divisors, per-group sums) — computed once per tile column and
        // reused by every output-row tile.
        {
            let xf_ptr = crate::util::SendPtr(xf_all.as_mut_ptr());
            let gs_ptr = crate::util::SendPtr(gs_all.as_mut_ptr());
            crate::util::threadpool::parallel_for_auto(t_rows, |ti| {
                // SAFETY: disjoint per-activation-row slices.
                let (xf, gs) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(
                            xf_ptr.get().add(ti * self.cols),
                            self.cols,
                        ),
                        std::slice::from_raw_parts_mut(gs_ptr.get().add(ti * n_g), n_g),
                    )
                };
                self.fold_activation(x.row(ti), xf, gs);
            });
        }
        // Stage 2: output-row tiles × activation blocks.
        let n_rt = self.rows.div_ceil(ROW_TILE);
        let n_tb = t_rows.div_ceil(ACT_BLOCK);
        let out_ptr = crate::util::SendPtr(out.data.as_mut_ptr());
        let (xf_all, gs_all) = (&*xf_all, &*gs_all);
        crate::util::threadpool::parallel_for_auto(n_rt * n_tb, |item| {
            // row tile varies slowest so consecutive steals by one worker
            // revisit the same packed rows while they are still hot.
            let (rt, tb) = (item / n_tb, item % n_tb);
            let (r0, r1) = (rt * ROW_TILE, (rt * ROW_TILE + ROW_TILE).min(self.rows));
            let (t0, t1) = (tb * ACT_BLOCK, (tb * ACT_BLOCK + ACT_BLOCK).min(t_rows));
            for r in r0..r1 {
                let words = &self.qweight[r].words;
                let srow = self.scales.row(r);
                let zrow = self.zeros.row(r);
                for ti in t0..t1 {
                    let xf = &xf_all[ti * self.cols..(ti + 1) * self.cols];
                    let gs = &gs_all[ti * n_g..(ti + 1) * n_g];
                    let y = packed_row_dot(
                        words,
                        self.bits,
                        self.cols,
                        self.group_size,
                        srow,
                        zrow,
                        xf,
                        gs,
                    );
                    // SAFETY: each work item owns the disjoint output
                    // rectangle [t0,t1) × [r0,r1).
                    unsafe { *out_ptr.get().add(ti * self.rows + r) = y };
                }
            }
        });
        out
    }

    /// Total payload bytes (packed ints + scales + zeros + optional
    /// permutation / channel scales), for the compression-ratio report and
    /// the bytes-touched-per-token column of the packed-GEMV bench.
    pub fn nbytes(&self) -> usize {
        self.qweight.iter().map(|p| p.nbytes()).sum::<usize>()
            + (self.scales.data.len() + self.zeros.data.len()) * 4
            + self.perm.as_ref().map_or(0, |p| p.len() * 4)
            + self.channel_scales.as_ref().map_or(0, |c| c.len() * 4)
    }

    /// Effective bits per weight including scale/zero overhead.
    pub fn bits_per_weight(&self) -> f64 {
        self.nbytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn quantized_linear_dequant() {
        // 2 rows, 4 cols, group=2, 2 bits.
        let ints = vec![vec![0u8, 1, 2, 3], vec![3, 2, 1, 0]];
        let scales = Matrix::from_vec(2, 2, vec![0.5, 1.0, 2.0, 0.25]);
        let zeros = Matrix::from_vec(2, 2, vec![1.0, 2.0, 0.0, 1.0]);
        let q = QuantizedLinear::from_ints(&ints, 2, 2, scales, zeros);
        let d = q.dequantize();
        // row0: s=0.5,z=1 -> (0-1)*0.5, (1-1)*0.5 ; s=1,z=2 -> (2-2), (3-2)
        assert_eq!(d.row(0), &[-0.5, 0.0, 0.0, 1.0]);
        // row1: s=2,z=0 -> 6,4 ; s=0.25,z=1 -> 0, -0.25
        assert_eq!(d.row(1), &[6.0, 4.0, 0.0, -0.25]);
    }

    #[test]
    fn perm_and_channel_scales_change_dequant() {
        // 1 row, 4 cols, group=2, 2 bits; s=1, z=0 → dequant == ints.
        let ints = vec![vec![0u8, 1, 2, 3]];
        let scales = Matrix::from_vec(1, 2, vec![1.0, 1.0]);
        let zeros = Matrix::from_vec(1, 2, vec![0.0, 0.0]);
        let mut q = QuantizedLinear::from_ints(&ints, 2, 2, scales, zeros);
        assert_eq!(q.dequantize().row(0), &[0.0, 1.0, 2.0, 3.0]);
        let plain_bytes = q.nbytes();

        // reversal gather: stored column j goes to original column 3-j
        q.perm = Some(vec![3, 2, 1, 0]);
        assert_eq!(q.dequantize().row(0), &[3.0, 2.0, 1.0, 0.0]);

        // channel divisors apply per stored column, before the gather
        q.channel_scales = Some(vec![1.0, 1.0, 2.0, 4.0]);
        assert_eq!(q.dequantize().row(0), &[0.75, 1.0, 1.0, 0.0]);
        assert_eq!(q.nbytes(), plain_bytes + 4 * 4 + 4 * 4);
    }

    #[test]
    fn bits_per_weight_sane() {
        let rows = 8;
        let cols = 128;
        let ints: Vec<Vec<u8>> = (0..rows).map(|_| vec![1u8; cols]).collect();
        let scales = Matrix::zeros(rows, 2);
        let zeros = Matrix::zeros(rows, 2);
        let q = QuantizedLinear::from_ints(&ints, 2, 64, scales, zeros);
        let bpw = q.bits_per_weight();
        // 2 bits + (2 groups * 8 bytes) / 128 weights = 2 + 1 = 3 bits.
        assert!((bpw - 3.0).abs() < 0.01, "bpw={bpw}");
    }

    /// Random quantized linear over the full metadata space: any bit width,
    /// ragged tail group, optional act-order perm, optional channel scales.
    fn random_linear(g: &mut crate::util::proptest::Gen) -> QuantizedLinear {
        let bits = [2u8, 3, 4, 8][g.usize_in(0, 3)];
        let group = [8usize, 16, 32][g.usize_in(0, 2)];
        let rows = g.usize_in(1, 6);
        let cols = g.usize_in(1, 3) * group + g.usize_in(0, group - 1);
        let n_g = cols.div_ceil(group);
        let max = 1usize << bits;
        let mut rng = g.rng.fork(17);
        let ints: Vec<Vec<u8>> = (0..rows)
            .map(|_| (0..cols).map(|_| (rng.next_u64() as usize % max) as u8).collect())
            .collect();
        let scales = Matrix::from_vec(
            rows,
            n_g,
            (0..rows * n_g).map(|_| 0.01 + rng.normal().abs() as f32).collect(),
        );
        let zeros = Matrix::from_vec(
            rows,
            n_g,
            (0..rows * n_g).map(|_| (rng.next_u64() % max as u64) as f32).collect(),
        );
        let mut q = QuantizedLinear::from_ints(&ints, bits, group, scales, zeros);
        if g.bool() {
            let mut p: Vec<u32> = (0..cols as u32).collect();
            rng.shuffle(&mut p);
            q.perm = Some(p);
        }
        if g.bool() {
            q.channel_scales =
                Some((0..cols).map(|_| 0.5 + rng.normal().abs() as f32).collect());
        }
        q
    }

    #[test]
    fn prop_fused_forward_matches_dense_dequant_matmul() {
        // The tentpole equivalence: packed execution ≡ dequantize-then-GEMM,
        // across bit widths (incl. 3-bit word straddling), ragged tail
        // groups, act-order perms and AWQ channel scales.
        check("packed forward == dequant + matmul_bt", 50, |g| {
            let q = random_linear(g);
            let t = g.usize_in(1, 5);
            let mut rng = g.rng.fork(23);
            let x = Matrix::randn(t, q.cols, 1.0, &mut rng);
            let fused = q.forward(&x);
            let dense = x.matmul_bt(&q.dequantize());
            let scale = dense.data.iter().fold(1.0f32, |m, v| m.max(v.abs()));
            prop_assert(
                fused.max_abs_diff(&dense) <= 2e-4 * scale,
                &format!(
                    "bits={} group={} cols={} perm={} cs={}: diff {}",
                    q.bits,
                    q.group_size,
                    q.cols,
                    q.perm.is_some(),
                    q.channel_scales.is_some(),
                    fused.max_abs_diff(&dense)
                ),
            )
        });
    }

    #[test]
    fn tiled_forward_crosses_tile_boundaries() {
        // Shapes that exercise ragged edges of BOTH blocking levels: more
        // output rows than ROW_TILE (plus a ragged tail tile) and more
        // activation rows than ACT_BLOCK (plus a ragged tail block).
        let mut rng = crate::util::rng::Rng::new(77);
        let rows = ROW_TILE * 2 + 3;
        let cols = 96;
        let ints: Vec<Vec<u8>> = (0..rows)
            .map(|_| (0..cols).map(|_| (rng.next_u64() % 16) as u8).collect())
            .collect();
        let n_g = cols / 32;
        let scales = Matrix::from_vec(
            rows,
            n_g,
            (0..rows * n_g).map(|_| 0.01 + rng.normal().abs() as f32).collect(),
        );
        let zeros = Matrix::from_vec(
            rows,
            n_g,
            (0..rows * n_g).map(|_| (rng.next_u64() % 16) as f32).collect(),
        );
        let q = QuantizedLinear::from_ints(&ints, 4, 32, scales, zeros);
        let x = Matrix::randn(ACT_BLOCK * 2 + 5, cols, 1.0, &mut rng);
        let fused = q.forward(&x);
        let dense = x.matmul_bt(&q.dequantize());
        let scale = dense.data.iter().fold(1.0f32, |m, v| m.max(v.abs()));
        assert!(
            fused.max_abs_diff(&dense) <= 2e-4 * scale,
            "diff {}",
            fused.max_abs_diff(&dense)
        );
    }

    #[test]
    fn pooled_gemv_matches_dense_reference() {
        // `gemv` is the T = 1 fast path `forward` routes through for
        // single-tile linears — check it against the independent
        // dequantize-then-matmul reference, not against forward itself.
        check("gemv == dequant + matmul", 25, |g| {
            let q = random_linear(g);
            let mut rng = g.rng.fork(41);
            let x = Matrix::randn(1, q.cols, 1.0, &mut rng);
            let mut out = vec![0.0f32; q.rows];
            q.gemv(x.row(0), &mut out);
            let want = x.matmul_bt(&q.dequantize());
            let scale = want.row(0).iter().fold(1.0f32, |m, v| m.max(v.abs()));
            let diff = out
                .iter()
                .zip(want.row(0))
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            prop_assert(
                diff <= 2e-4 * scale,
                &format!("gemv diverged from dense reference: {diff}"),
            )
        });
    }

    #[test]
    fn validate_accepts_good_and_rejects_corrupt() {
        let ints = vec![vec![1u8, 2, 3, 0], vec![0, 1, 2, 3]];
        let scales = Matrix::from_vec(2, 2, vec![1.0; 4]);
        let zeros = Matrix::zeros(2, 2);
        let good = QuantizedLinear::from_ints(&ints, 2, 2, scales, zeros);
        good.validate().unwrap();

        let mut truncated = good.clone();
        truncated.qweight[1].words.clear();
        assert!(truncated.validate().unwrap_err().to_string().contains("truncated"));

        let mut bad_perm = good.clone();
        bad_perm.perm = Some(vec![4, 0, 1, 2]);
        assert!(bad_perm.validate().unwrap_err().to_string().contains("out of range"));

        let mut dup_perm = good.clone();
        dup_perm.perm = Some(vec![0, 0, 1, 2]);
        assert!(dup_perm.validate().unwrap_err().to_string().contains("duplicated"));

        let mut bad_cs = good.clone();
        bad_cs.channel_scales = Some(vec![1.0, 0.0, 1.0, 1.0]);
        assert!(bad_cs.validate().unwrap_err().to_string().contains("channel scale"));

        let mut bad_scale = good.clone();
        bad_scale.scales[(1, 0)] = f32::NAN;
        assert!(bad_scale.validate().unwrap_err().to_string().contains("non-finite scale"));

        let mut bad_shape = good;
        bad_shape.scales = Matrix::zeros(2, 3);
        assert!(bad_shape.validate().is_err());
    }
}
