//! Reconstruction-loss metrics — the objectives the paper optimizes and the
//! quantities our benches report.

use crate::tensor::Matrix;

/// Layer-wise reconstruction loss `tr(ΔW H ΔWᵀ) = Σ_r Δw_rᵀ H Δw_r`
/// (Eq. 1/3 summed over output channels), with ΔW = Q − W.
pub fn layer_loss(w: &Matrix, q: &Matrix, h: &Matrix) -> f64 {
    assert_eq!((w.rows, w.cols), (q.rows, q.cols));
    assert_eq!(h.rows, w.cols);
    let d = q.sub(w);
    let dh = d.matmul(h); // [rows, cols]
    d.data
        .iter()
        .zip(&dh.data)
        .map(|(a, b)| *a as f64 * *b as f64)
        .sum()
}

/// Error-aware loss of Eq. 7 (up to the constant c):
/// `tr(ΔW H ΔWᵀ) + 2 Σ_r w_rᵀ R Δw_r`, capturing upstream quantization
/// error through `R = E[ΔX Xᵀ]`.
pub fn layer_loss_with_deviation(w: &Matrix, q: &Matrix, h: &Matrix, r: &Matrix) -> f64 {
    let base = layer_loss(w, q, h);
    let d = q.sub(w);
    let wr = w.matmul(r); // [rows, cols] ; rows of W times R
    let cross: f64 = wr
        .data
        .iter()
        .zip(&d.data)
        .map(|(a, b)| *a as f64 * *b as f64)
        .sum();
    base + 2.0 * cross
}

/// Mean squared weight error `‖Q − W‖² / numel` — the proxy stock GPTQ's
/// grid search actually optimizes.
pub fn weight_mse(w: &Matrix, q: &Matrix) -> f64 {
    w.sub(q).frob2() / (w.rows * w.cols) as f64
}

/// Relative layer loss: `layer_loss / tr(W H Wᵀ)` — a scale-free number
/// comparable across layers and presets.
pub fn relative_layer_loss(w: &Matrix, q: &Matrix, h: &Matrix) -> f64 {
    let denom = layer_loss(&Matrix::zeros(w.rows, w.cols), w, h);
    if denom <= 0.0 {
        return 0.0;
    }
    layer_loss(w, q, h) / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn zero_error_zero_loss() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(4, 8, 1.0, &mut rng);
        let h = Matrix::eye(8);
        assert_eq!(layer_loss(&w, &w, &h), 0.0);
        assert_eq!(weight_mse(&w, &w), 0.0);
    }

    #[test]
    fn identity_hessian_reduces_to_frobenius() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(5, 7, 1.0, &mut rng);
        let q = Matrix::randn(5, 7, 1.0, &mut rng);
        let h = Matrix::eye(7);
        let ll = layer_loss(&w, &q, &h);
        let fr = w.sub(&q).frob2();
        assert!((ll - fr).abs() < 1e-3 * fr.max(1.0));
    }

    #[test]
    fn loss_positive_for_spd_h() {
        let mut rng = Rng::new(3);
        let x = Matrix::randn(16, 64, 1.0, &mut rng);
        let h = x.matmul_bt(&x);
        let w = Matrix::randn(4, 16, 1.0, &mut rng);
        let q = Matrix::randn(4, 16, 1.0, &mut rng);
        assert!(layer_loss(&w, &q, &h) > 0.0);
    }

    #[test]
    fn deviation_term_matches_expansion() {
        // Check Eq. 7 against a brute-force expectation over explicit X, X̃.
        let mut rng = Rng::new(4);
        let (din, t) = (6, 200);
        let xt = Matrix::randn(din, t, 1.0, &mut rng); // FP input X̃
        let mut x = xt.clone();
        let noise = Matrix::randn(din, t, 0.1, &mut rng);
        x.add_inplace(&noise); // deviated input X
        let w = Matrix::randn(3, din, 1.0, &mut rng);
        let q = Matrix::randn(3, din, 1.0, &mut rng);

        // direct: E ||qᵀX − wᵀX̃||² (sum over tokens, not averaged)
        let qy = q.matmul(&x);
        let wy = w.matmul(&xt);
        let direct = qy.sub(&wy).frob2();

        // via Eq. 7: ΔW H ΔWᵀ + 2 wᵀR(q−w) + c, c = tr(W ΔXΔXᵀ Wᵀ)
        let h = x.matmul_bt(&x);
        let dx = noise;
        let r = dx.matmul_bt(&x);
        let main = layer_loss_with_deviation(&w, &q, &h, &r);
        let c = {
            let wd = w.matmul(&dx);
            wd.frob2()
        };
        assert!(
            (direct - (main + c)).abs() < 1e-2 * direct.max(1.0),
            "direct={direct} decomposed={}",
            main + c
        );
    }

    #[test]
    fn relative_loss_scale_free() {
        let mut rng = Rng::new(5);
        let w = Matrix::randn(4, 8, 1.0, &mut rng);
        let mut q = w.clone();
        q.scale_inplace(0.9);
        let x = Matrix::randn(8, 32, 1.0, &mut rng);
        let h = x.matmul_bt(&x);
        let rel = relative_layer_loss(&w, &q, &h);
        // (0.9 - 1)² = 0.01 exactly, since Q = 0.9 W.
        assert!((rel - 0.01).abs() < 1e-4, "rel={rel}");
    }
}
