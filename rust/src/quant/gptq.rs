//! The GPTQ inner loop: sequential per-column quantization with
//! Hessian-based error compensation (Frantar et al., 2023 — paper ref [1]).
//!
//! Given fixed group scales, GPTQ quantizes one column at a time and spreads
//! the induced error over the remaining unquantized columns using rows of
//! `U = chol(H⁻¹, upper)`. Columns are processed in blocks; compensation
//! within the block is immediate and the tail is updated once per block
//! (the "lazy batch" scheme of the original implementation).
//!
//! Rows (output channels) are fully independent given `U`, so the sweep is
//! parallelized across row chunks.

use super::format::QuantizedLinear;
use super::scale::{GroupScales, QuantSpec};
use crate::tensor::{cholesky_inverse_upper, Matrix};
use crate::util::threadpool::parallel_for_auto;
use anyhow::Result;

/// Tunables for the GPTQ sweep.
#[derive(Clone, Copy, Debug)]
pub struct GptqConfig {
    /// Relative dampening added to diag(H): λ = percdamp · mean(diag H).
    pub percdamp: f64,
    /// Lazy-batch block size.
    pub block_size: usize,
}

impl Default for GptqConfig {
    fn default() -> Self {
        GptqConfig { percdamp: 0.01, block_size: 128 }
    }
}

/// Dampen H in place and zero dead columns (GPTQ's preprocessing):
/// columns whose diagonal is 0 carry no signal; their weights are forced
/// to the grid's zero so they contribute nothing.
pub fn prepare_hessian(h: &Matrix, w: &mut Matrix, percdamp: f64) -> Matrix {
    let n = h.rows;
    let mut hd = h.clone();
    let mut diag_mean = 0.0f64;
    for i in 0..n {
        diag_mean += hd[(i, i)] as f64;
    }
    diag_mean /= n as f64;
    let damp = (percdamp * diag_mean).max(1e-8) as f32;
    for i in 0..n {
        if hd[(i, i)] == 0.0 {
            hd[(i, i)] = 1.0;
            for r in 0..w.rows {
                w[(r, i)] = 0.0;
            }
        }
        hd[(i, i)] += damp;
    }
    hd
}

/// Run the GPTQ sweep with **fixed** group scales.
///
/// Returns the quantized layer. `w` is the FP weight matrix `[out, in]`;
/// `h` the (undamped) Hessian `[in, in]`.
pub fn gptq_quantize(
    w: &Matrix,
    h: &Matrix,
    scales: &GroupScales,
    spec: &QuantSpec,
    cfg: &GptqConfig,
) -> Result<QuantizedLinear> {
    assert_eq!(h.rows, w.cols, "hessian/layer shape mismatch");
    let mut wwork = w.clone();
    let hd = prepare_hessian(h, &mut wwork, cfg.percdamp);
    let u = cholesky_inverse_upper(&hd)?; // H⁻¹ = UᵀU, U upper
    Ok(gptq_sweep(&wwork, &u, scales, spec, cfg))
}

/// The sweep itself, factored out so tests can inject a custom `U`.
pub fn gptq_sweep(
    w: &Matrix,
    u: &Matrix,
    scales: &GroupScales,
    spec: &QuantSpec,
    cfg: &GptqConfig,
) -> QuantizedLinear {
    let (rows, cols) = (w.rows, w.cols);
    let qmax = spec.qmax() as f32;
    let bs = cfg.block_size.max(1);

    let mut ints: Vec<Vec<u8>> = vec![vec![0u8; cols]; rows];
    let ints_ptr = crate::util::SendPtr(ints.as_mut_ptr());

    // Rows are independent: each worker owns a chunk of rows end-to-end.
    parallel_for_auto(rows, |r| {
        // SAFETY: each row index is visited exactly once.
        let int_row: &mut Vec<u8> = unsafe { &mut *ints_ptr.get().add(r) };
        let mut wrow = w.row(r).to_vec();
        let srow = scales.scales.row(r);
        let zrow = scales.zeros.row(r);
        let g = scales.group_size;
        let mut err = vec![0.0f32; bs];

        let mut b0 = 0;
        while b0 < cols {
            let b1 = (b0 + bs).min(cols);
            for j in b0..b1 {
                let s = srow[j / g];
                let z = zrow[j / g];
                let wj = wrow[j];
                let q = ((wj / s).round() + z).clamp(0.0, qmax);
                int_row[j] = q as u8;
                let dq = s * (q - z);
                let ujj = u[(j, j)];
                let e = (wj - dq) / ujj;
                err[j - b0] = e;
                // immediate compensation inside the block
                let urow = &u.row(j)[j + 1..b1];
                let wtail = &mut wrow[j + 1..b1];
                for (wt, uk) in wtail.iter_mut().zip(urow) {
                    *wt -= e * *uk;
                }
            }
            // lazy compensation of the tail: w[b1..] -= err_blk · U[b0..b1, b1..]
            if b1 < cols {
                for j in b0..b1 {
                    let e = err[j - b0];
                    if e == 0.0 {
                        continue;
                    }
                    let urow = &u.row(j)[b1..];
                    let wtail = &mut wrow[b1..];
                    for (wt, uk) in wtail.iter_mut().zip(urow) {
                        *wt -= e * *uk;
                    }
                }
            }
            b0 = b1;
        }
    });

    QuantizedLinear::from_ints(
        &ints,
        spec.bits,
        scales.group_size,
        scales.scales.clone(),
        scales.zeros.clone(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::layer_loss;
    use crate::quant::rtn::rtn_quantize;
    use crate::quant::scale::{compute_group_scales, ScaleMetric};
    use crate::util::rng::Rng;

    fn correlated_hessian(cols: usize, t: usize, rng: &mut Rng) -> Matrix {
        // AR(1)-style correlated activations -> realistic non-diagonal H.
        let mut x = Matrix::zeros(cols, t);
        for c in 0..t {
            let mut prev = 0.0f32;
            for r in 0..cols {
                let v = 0.7 * prev + rng.normal() as f32;
                x[(r, c)] = v;
                prev = v;
            }
        }
        let mut h = x.matmul_bt(&x);
        h.scale_inplace(1.0 / t as f32);
        h
    }

    #[test]
    fn gptq_beats_rtn_on_layer_loss() {
        let mut rng = Rng::new(1);
        let (out, inp) = (16, 64);
        let w = Matrix::randn(out, inp, 1.0, &mut rng);
        let h = correlated_hessian(inp, 256, &mut rng);
        let spec = QuantSpec::new(2, 32);
        let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);

        let rtn = rtn_quantize(&w, &scales, &spec);
        let gptq = gptq_quantize(&w, &h, &scales, &spec, &GptqConfig::default()).unwrap();

        let mut wdamp = w.clone();
        let hd = prepare_hessian(&h, &mut wdamp, 0.01);
        let l_rtn = layer_loss(&w, &rtn.dequantize(), &hd);
        let l_gptq = layer_loss(&w, &gptq.dequantize(), &hd);
        assert!(
            l_gptq < l_rtn * 0.9,
            "gptq {l_gptq} should beat rtn {l_rtn} clearly"
        );
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        // With H = I there is nothing to compensate: GPTQ == RTN exactly.
        let mut rng = Rng::new(2);
        let w = Matrix::randn(4, 32, 1.0, &mut rng);
        let h = Matrix::eye(32);
        let spec = QuantSpec::new(3, 16);
        let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
        let a = gptq_quantize(&w, &h, &scales, &spec, &GptqConfig::default()).unwrap();
        let b = rtn_quantize(&w, &scales, &spec);
        // damping perturbs U ~ uniformly; integers must match
        for r in 0..w.rows {
            assert_eq!(a.qweight[r].unpack(), b.qweight[r].unpack());
        }
    }

    #[test]
    fn block_size_does_not_change_result() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(6, 48, 1.0, &mut rng);
        let h = correlated_hessian(48, 128, &mut rng);
        let spec = QuantSpec::new(2, 16);
        let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
        let a = gptq_quantize(&w, &h, &scales, &spec, &GptqConfig { percdamp: 0.01, block_size: 8 }).unwrap();
        let b = gptq_quantize(&w, &h, &scales, &spec, &GptqConfig { percdamp: 0.01, block_size: 48 }).unwrap();
        for r in 0..w.rows {
            assert_eq!(a.qweight[r].unpack(), b.qweight[r].unpack(), "row {r}");
        }
    }

    #[test]
    fn dead_columns_are_zeroed() {
        let mut rng = Rng::new(4);
        let mut w = Matrix::randn(3, 16, 1.0, &mut rng);
        let mut h = correlated_hessian(16, 64, &mut rng);
        // kill column 5
        for i in 0..16 {
            h[(5, i)] = 0.0;
            h[(i, 5)] = 0.0;
        }
        let hd = prepare_hessian(&h, &mut w, 0.01);
        assert!(hd[(5, 5)] > 0.0);
        for r in 0..3 {
            assert_eq!(w[(r, 5)], 0.0);
        }
    }

    #[test]
    fn quantized_ints_in_range() {
        let mut rng = Rng::new(5);
        let w = Matrix::randn(8, 64, 2.0, &mut rng);
        let h = correlated_hessian(64, 128, &mut rng);
        for bits in [2u8, 3, 4] {
            let spec = QuantSpec::new(bits, 32);
            let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
            let q = gptq_quantize(&w, &h, &scales, &spec, &GptqConfig::default()).unwrap();
            let qmax = (1u16 << bits) as u8 - 1;
            for r in 0..w.rows {
                assert!(q.qweight[r].unpack().iter().all(|&v| v <= qmax));
            }
        }
    }
}
