//! AWQ-style activation-aware scaling baseline (Lin et al. — the paper's
//! ref [8]), for the extended baseline comparison.
//!
//! AWQ's observation: protecting the ~1% of weight channels with the
//! largest activation magnitudes preserves most of the quantized model's
//! quality. Mechanism: scale input channel `c` of `W` up by
//! `s_c = E[|x_c|]^α` before quantization (and fold `1/s_c` into the
//! producer layer — simulated here by dividing after dequantization), so
//! the uniform grid spends more resolution on salient channels. `α` is
//! grid-searched per layer against the true layer-wise loss, like the
//! paper's AWQ setup.

use super::format::QuantizedLinear;
use super::rtn::rtn_quantize;
use super::scale::{compute_group_scales, QuantSpec, ScaleMetric};
use crate::quant::metrics::layer_loss;
use crate::tensor::Matrix;

/// Per-input-channel activation magnitudes from the Hessian diagonal
/// (`diag H = E[x_c²]`, so `E[|x_c|] ≈ sqrt(diag H)` up to distribution
/// shape — the standard proxy when only H is stored).
pub fn activation_magnitudes(h: &Matrix) -> Vec<f32> {
    (0..h.rows).map(|i| h[(i, i)].max(0.0).sqrt()).collect()
}

/// Result: quantized layer in scaled space plus the channel scales needed
/// at dequantization (`W ≈ dequant(Q) / s` column-wise).
#[derive(Clone, Debug)]
pub struct AwqQuant {
    pub quantized: QuantizedLinear,
    pub channel_scales: Vec<f32>,
    pub alpha: f32,
}

impl AwqQuant {
    /// Dequantize back to the original weight space.
    pub fn dequantize_unscaled(&self) -> Matrix {
        let mut m = self.quantized.dequantize();
        for r in 0..m.rows {
            let row = m.row_mut(r);
            for (v, s) in row.iter_mut().zip(&self.channel_scales) {
                *v /= *s;
            }
        }
        m
    }

    /// Lossless conversion into the unified [`QuantizedLinear`]: the channel
    /// scales become the layer's `channel_scales` divisors, so
    /// `.dequantize()` lands bit-for-bit on [`Self::dequantize_unscaled`].
    pub fn into_quantized_linear(self) -> QuantizedLinear {
        let mut q = self.quantized;
        q.channel_scales = Some(self.channel_scales);
        q
    }
}

fn scale_columns(w: &Matrix, s: &[f32]) -> Matrix {
    let mut out = w.clone();
    for r in 0..out.rows {
        for (v, sc) in out.row_mut(r).iter_mut().zip(s) {
            *v *= *sc;
        }
    }
    out
}

/// AWQ-lite: grid-search α ∈ {0, 0.25, 0.5, 0.75, 1.0}, scale, RTN-quantize
/// on the (L2) group grid, score by true layer loss, keep the best.
pub fn awq_quantize(w: &Matrix, h: &Matrix, spec: &QuantSpec) -> AwqQuant {
    let mags = activation_magnitudes(h);
    let mean_mag =
        (mags.iter().map(|&m| m as f64).sum::<f64>() / mags.len() as f64).max(1e-12) as f32;
    let mut best: Option<(f64, AwqQuant)> = None;
    for &alpha in &[0.0f32, 0.25, 0.5, 0.75, 1.0] {
        // normalized so the average channel scale is ~1 (keeps grids sane)
        let s: Vec<f32> = mags
            .iter()
            .map(|&m| ((m.max(1e-6) / mean_mag).powf(alpha)).clamp(1e-3, 1e3))
            .collect();
        let ws = scale_columns(w, &s);
        let gs = compute_group_scales(&ws, spec, ScaleMetric::L2, None);
        let q = rtn_quantize(&ws, &gs, spec);
        let candidate = AwqQuant { quantized: q, channel_scales: s, alpha };
        let loss = layer_loss(w, &candidate.dequantize_unscaled(), h);
        if best.as_ref().map(|(l, _)| loss < *l).unwrap_or(true) {
            best = Some((loss, candidate));
        }
    }
    best.unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::stage1::baseline_init;
    use crate::util::rng::Rng;

    fn skewed(out: usize, inp: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(out, inp, 1.0, &mut rng);
        let t = inp * 8;
        let mut x = Matrix::zeros(inp, t);
        for r in 0..inp {
            let energy = if r % 8 == 0 { 8.0 } else { 0.3 };
            for c in 0..t {
                x[(r, c)] = rng.normal() as f32 * energy;
            }
        }
        let mut h = x.matmul_bt(&x);
        h.scale_inplace(1.0 / t as f32);
        (w, h)
    }

    #[test]
    fn magnitudes_track_energy() {
        let (_, h) = skewed(4, 32, 1);
        let m = activation_magnitudes(&h);
        // hot channels (every 8th) must dominate
        assert!(m[0] > 4.0 * m[1], "m0={} m1={}", m[0], m[1]);
    }

    #[test]
    fn awq_beats_plain_rtn_on_skewed_inputs() {
        let (w, h) = skewed(16, 64, 2);
        let spec = QuantSpec::new(2, 32);
        let awq = awq_quantize(&w, &h, &spec);
        let plain = {
            let gs = baseline_init(&w, &spec);
            rtn_quantize(&w, &gs, &spec).dequantize()
        };
        let l_awq = layer_loss(&w, &awq.dequantize_unscaled(), &h);
        let l_rtn = layer_loss(&w, &plain, &h);
        assert!(
            l_awq < l_rtn,
            "awq {l_awq} should beat rtn {l_rtn} under skewed activations"
        );
        assert!(awq.alpha > 0.0, "grid search should pick a nonzero α here");
    }

    #[test]
    fn alpha_zero_recovers_plain_rtn() {
        let (w, h) = skewed(8, 32, 3);
        let mags = activation_magnitudes(&h);
        let mean =
            (mags.iter().map(|&m| m as f64).sum::<f64>() / mags.len() as f64) as f32;
        let s: Vec<f32> = mags.iter().map(|_| 1.0f32).collect();
        let ws = scale_columns(&w, &s);
        assert!(ws.max_abs_diff(&w) < 1e-6);
        let _ = mean; // α = 0 ⇒ all scales 1 regardless of normalization
    }

    #[test]
    fn conversion_to_quantized_linear_is_lossless() {
        let (w, h) = skewed(8, 32, 5);
        let spec = QuantSpec::new(2, 16);
        let awq = awq_quantize(&w, &h, &spec);
        let reference = awq.dequantize_unscaled();
        let unified = awq.into_quantized_linear();
        assert!(unified.channel_scales.is_some());
        assert_eq!(unified.dequantize().max_abs_diff(&reference), 0.0);
    }

    #[test]
    fn dequantize_unscaled_roundtrip_shape() {
        let (w, h) = skewed(8, 32, 4);
        let spec = QuantSpec::new(8, 16);
        let awq = awq_quantize(&w, &h, &spec);
        let d = awq.dequantize_unscaled();
        assert_eq!((d.rows, d.cols), (8, 32));
        // 8-bit AWQ should be near-lossless in original space
        let mse = crate::quant::metrics::weight_mse(&w, &d);
        assert!(mse < 1e-3, "mse={mse}");
    }
}
