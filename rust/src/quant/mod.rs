//! The paper's algorithm and its baselines.
//!
//! * [`format`] — grouped, bit-packed integer weight storage (INT2/3/4/8).
//! * [`scale`] — uniform affine quantization primitives + β-grid search
//!   under either the L2 metric (stock GPTQ) or the `H_ii` metric
//!   (the paper's Stage 1).
//! * [`rtn`] — round-to-nearest baseline.
//! * [`gptq`] — the GPTQ inner loop (Hessian-compensated sequential
//!   quantization) shared by the baseline and the proposed method.
//! * [`stage1`] — input-aware group-scale initialization (Eq. 4).
//! * [`stage2`] — coordinate-descent scale refinement with the closed-form
//!   update, first-layer (Eq. 5) and error-aware (Eq. 9) variants.
//! * [`metrics`] — layer-wise reconstruction losses used as objectives and
//!   reported by benches.

pub mod actorder;
pub mod awq;
pub mod format;
pub mod gptq;
pub mod metrics;
pub mod rtn;
pub mod scale;
pub mod stage1;
pub mod stage2;

pub use format::{PackedInts, QuantizedLinear};
pub use gptq::{gptq_quantize, GptqConfig};
pub use scale::{GroupScales, ScaleMetric, QuantSpec};

/// Which scale strategy to use around the GPTQ inner loop — selects between
/// the stock baseline and the paper's method (and the ablation cells of
/// Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MethodConfig {
    /// Stage 1: input-aware (H_ii-weighted) grid init instead of L2 grid.
    pub stage1: bool,
    /// Stage 2: CD refinement of scales after the GPTQ sweep.
    pub stage2: bool,
}

/// Everything measured while quantizing one linear layer.
#[derive(Clone, Debug)]
pub struct LayerQuantResult {
    pub quantized: QuantizedLinear,
    /// Layer-wise reconstruction loss (Eq. 3) on the damped Hessian.
    pub layer_loss: f64,
    /// Same, before stage 2 ran (equal to `layer_loss` if stage2 is off).
    pub loss_before_stage2: f64,
    /// Wall-clock per phase.
    pub time_scales: std::time::Duration,
    pub time_gptq: std::time::Duration,
    pub time_stage2: std::time::Duration,
}

/// Quantize one linear layer end-to-end per the paper:
///
/// 1. group scales — stock L2 grid (baseline) or Stage-1 input-aware grid;
/// 2. the GPTQ compensated sweep with those scales frozen;
/// 3. optional Stage-2 CD refinement of the scales (error-aware via `r`).
///
/// `h` is the raw accumulated Hessian `E[XXᵀ]`; damping is applied here so
/// both the sweep and the refinement use the same damped matrix (as in the
/// paper, where stage 2 reuses GPTQ's Hessian).
pub fn quantize_layer(
    w: &crate::tensor::Matrix,
    h: &crate::tensor::Matrix,
    r: Option<&crate::tensor::Matrix>,
    spec: &QuantSpec,
    method: MethodConfig,
    gptq_cfg: &GptqConfig,
    stage2_cfg: &stage2::Stage2Config,
) -> crate::Result<LayerQuantResult> {
    use std::time::Instant;
    let mut wwork = w.clone();
    let hd = gptq::prepare_hessian(h, &mut wwork, gptq_cfg.percdamp);

    let t0 = Instant::now();
    let scales = if method.stage1 {
        stage1::stage1_init(&wwork, &hd, spec)
    } else {
        stage1::baseline_init(&wwork, spec)
    };
    let time_scales = t0.elapsed();

    let t1 = Instant::now();
    let u = crate::tensor::cholesky_inverse_upper(&hd)?;
    let mut quantized = gptq::gptq_sweep(&wwork, &u, &scales, spec, gptq_cfg);
    let time_gptq = t1.elapsed();

    let loss_before_stage2 = metrics::layer_loss(w, &quantized.dequantize(), &hd);

    let t2 = Instant::now();
    if method.stage2 {
        stage2::refine_quantized_linear(w, &mut quantized, &hd, r, stage2_cfg);
    }
    let time_stage2 = t2.elapsed();

    let layer_loss = if method.stage2 {
        metrics::layer_loss(w, &quantized.dequantize(), &hd)
    } else {
        loss_before_stage2
    };

    Ok(LayerQuantResult {
        quantized,
        layer_loss,
        loss_before_stage2,
        time_scales,
        time_gptq,
        time_stage2,
    })
}

impl MethodConfig {
    /// Stock GPTQ baseline.
    pub const GPTQ: MethodConfig = MethodConfig { stage1: false, stage2: false };
    /// The paper's full method.
    pub const OURS: MethodConfig = MethodConfig { stage1: true, stage2: true };
    /// Ablation rows of Table 3.
    pub const STAGE1_ONLY: MethodConfig = MethodConfig { stage1: true, stage2: false };
    pub const STAGE2_ONLY: MethodConfig = MethodConfig { stage1: false, stage2: true };

    pub fn label(&self) -> &'static str {
        match (self.stage1, self.stage2) {
            (false, false) => "GPTQ",
            (true, false) => "ours(s1)",
            (false, true) => "ours(s2)",
            (true, true) => "ours",
        }
    }
}
