//! The paper's algorithm and its baselines, behind one unified API.
//!
//! Every algorithm implements the [`LayerQuantizer`] trait (weight matrix +
//! Hessian + optional upstream-error matrix + [`QuantSpec`] in, a
//! [`LayerQuantResult`] carrying a [`QuantizedLinear`] and phase timings
//! out) and is registered by name — `rtn`, `awq`, `actorder`, `gptq`,
//! `stage1`, `stage2`, `ours` — via [`resolve_quantizer`]. A [`QuantPlan`]
//! maps `(layer, kind)` patterns to a quantizer + spec, making
//! mixed-precision and mixed-method runs first-class: the string
//! `ours:bits=2,group=64;wv,wo=bits4;l0=awq` (or the equivalent
//! [`PlanRule`] builder calls) quantizes everything 2-bit with the paper's
//! method except 4-bit `wv`/`wo` and AWQ for layer 0.
//!
//! Module map:
//!
//! * [`api`] — the [`LayerQuantizer`] trait, its implementations
//!   ([`Rtn`], [`Awq`], [`ActOrderGptq`], [`TwoStage`]) and the registry.
//! * [`plan`] — [`QuantPlan`]: per-layer quantizer/spec rules + the plan
//!   string grammar.
//! * [`format`] — grouped, bit-packed integer weight storage (INT2/3/4/8),
//!   with optional act-order permutation and AWQ channel divisors so every
//!   method's output round-trips losslessly through one type.
//! * [`scale`] — uniform affine quantization primitives + β-grid search
//!   under either the L2 metric (stock GPTQ) or the `H_ii` metric
//!   (the paper's Stage 1).
//! * [`rtn`] — round-to-nearest inner loop.
//! * [`awq`] — activation-aware channel scaling (AWQ-lite) inner loop.
//! * [`actorder`] — act-order (`desc_act`) permutation around the sweep.
//! * [`gptq`] — the GPTQ inner loop (Hessian-compensated sequential
//!   quantization) shared by the baseline and the proposed method.
//! * [`stage1`] — input-aware group-scale initialization (Eq. 4).
//! * [`stage2`] — coordinate-descent scale refinement with the closed-form
//!   update, first-layer (Eq. 5) and error-aware (Eq. 9) variants.
//! * [`metrics`] — layer-wise reconstruction losses used as objectives and
//!   reported by benches.

pub mod actorder;
pub mod api;
pub mod awq;
pub mod format;
pub mod gptq;
pub mod metrics;
pub mod plan;
pub mod rtn;
pub mod scale;
pub mod stage1;
pub mod stage2;

pub use api::{
    quantizer_names, resolve_quantizer, ActOrderGptq, Awq, LayerQuantResult, LayerQuantizer,
    QuantContext, Rtn, TwoStage, QUANTIZER_NAMES,
};
pub use format::{PackedInts, QuantizedLinear};
pub use gptq::{gptq_quantize, GptqConfig};
pub use plan::{PlanRule, QuantPlan, SpecPatch};
pub use scale::{GroupScales, QuantSpec, ScaleMetric};
