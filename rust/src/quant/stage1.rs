//! **Stage 1 — input-aware group-scale initialization** (paper §3.1, Eq. 4).
//!
//! Conducted *before* GPTQ's iterative sweep: each group scale `s_i` is
//! grid-searched to minimize the group-local reconstruction loss
//!
//! ```text
//! min_{s_i>0} (s_i w_int,i − w_i)ᵀ H_{i,i} (s_i w_int,i − w_i)
//! ```
//!
//! instead of GPTQ's `‖s_i w_int,i − w_i‖²` (which assumes `H_ii = I`).
//! The problem is separable across groups, so groups (and rows) run in
//! parallel, and `H_ii` is sliced from the Hessian the GPTQ pipeline has
//! already accumulated — no extra statistics pass (Fig. 1).

use super::scale::{compute_group_scales, GroupScales, QuantSpec, ScaleMetric};
use crate::tensor::Matrix;

/// Stage-1 initialization: input-aware grid search per group.
pub fn stage1_init(w: &Matrix, h: &Matrix, spec: &QuantSpec) -> GroupScales {
    assert_eq!(h.rows, w.cols, "hessian/layer shape mismatch");
    compute_group_scales(w, spec, ScaleMetric::HessianBlock, Some(h))
}

/// The stock GPTQ initialization the paper compares against: same grid, but
/// the metric ignores input statistics (`H = I`).
pub fn baseline_init(w: &Matrix, spec: &QuantSpec) -> GroupScales {
    compute_group_scales(w, spec, ScaleMetric::L2, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::layer_loss;
    use crate::quant::rtn::rtn_quantize;
    use crate::util::rng::Rng;

    fn skewed_hessian(cols: usize, rng: &mut Rng) -> Matrix {
        // Activations with strongly non-uniform per-channel energy: the case
        // where input statistics matter most (paper §2.3).
        let t = cols * 8;
        let mut x = Matrix::zeros(cols, t);
        for r in 0..cols {
            let energy = if r % 7 == 0 { 6.0 } else { 0.3 };
            for c in 0..t {
                x[(r, c)] = rng.normal() as f32 * energy;
            }
        }
        let mut h = x.matmul_bt(&x);
        h.scale_inplace(1.0 / t as f32);
        h
    }

    #[test]
    fn stage1_improves_group_local_loss() {
        // Under the true layer-wise metric, stage-1 scales (then RTN) must be
        // at least as good as L2 scales on the *block-diagonal* part of H —
        // and in skewed-input regimes, strictly better overall.
        let mut rng = Rng::new(1);
        let (out, inp, g) = (24, 128, 32);
        let w = Matrix::randn(out, inp, 1.0, &mut rng);
        let h = skewed_hessian(inp, &mut rng);
        let spec = QuantSpec::new(2, g);

        let s_base = baseline_init(&w, &spec);
        let s_ours = stage1_init(&w, &h, &spec);

        // Evaluate on the block-diagonal metric both were derived under.
        let mut hblk = Matrix::zeros(inp, inp);
        for gi in 0..inp / g {
            let b = h.slice(gi * g, (gi + 1) * g, gi * g, (gi + 1) * g);
            hblk.set_slice(gi * g, gi * g, &b);
        }
        let q_base = rtn_quantize(&w, &s_base, &spec).dequantize();
        let q_ours = rtn_quantize(&w, &s_ours, &spec).dequantize();
        let l_base = layer_loss(&w, &q_base, &hblk);
        let l_ours = layer_loss(&w, &q_ours, &hblk);
        assert!(
            l_ours <= l_base * 1.0 + 1e-9,
            "stage1 {l_ours} must not exceed baseline {l_base} on block-diag metric"
        );
        assert!(
            l_ours < l_base * 0.97,
            "expected a strict improvement in the skewed regime: {l_ours} vs {l_base}"
        );
    }

    #[test]
    fn stage1_equals_baseline_when_h_is_identity() {
        // If H_ii = I the two metrics coincide, so the grid picks the same β.
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 64, 1.0, &mut rng);
        let spec = QuantSpec::new(3, 32);
        let h = Matrix::eye(64);
        let a = stage1_init(&w, &h, &spec);
        let b = baseline_init(&w, &spec);
        assert!(a.scales.max_abs_diff(&b.scales) < 1e-7);
        assert!(a.zeros.max_abs_diff(&b.zeros) < 1e-7);
    }

    #[test]
    fn stage1_shapes_and_positivity() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(5, 96, 1.0, &mut rng);
        let h = skewed_hessian(96, &mut rng);
        let spec = QuantSpec::new(2, 64);
        let gs = stage1_init(&w, &h, &spec);
        assert_eq!((gs.scales.rows, gs.scales.cols), (5, 2)); // ceil(96/64)
        assert!(gs.scales.data.iter().all(|&s| s > 0.0));
    }
}
