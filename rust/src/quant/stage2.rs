//! **Stage 2 — coordinate-descent group-scale refinement**
//! (paper §3.2–3.3, Algorithm 1).
//!
//! After GPTQ's sweep, the integer weights `w_int` are **frozen** and the
//! group scales are refined to minimize the *full* layer-wise loss (Eq. 3),
//! which — unlike Stage 1 — accounts for inter-group correlations `H_{i,j}`.
//! The objective is quadratic in each `s_i`, giving the closed-form CD step
//!
//! ```text
//! s_i ← s_i + ( v_iᵀ H_{i,:} (w − q) − wᵀ R_i v_i ) / ( v_iᵀ H_{i,i} v_i )
//! ```
//!
//! where `v_i = w_int,i − z_i` (the paper's zero-offset form generalized to
//! the asymmetric grid: `q_i = s_i · v_i`, and `z` stays frozen along with
//! `w_int`, so the derivation is unchanged), and the `R = E[ΔX Xᵀ]` term
//! (Eq. 8/9) corrects for quantization error accumulated in preceding
//! layers. For the first layer `R = 0` and the update reduces to Eq. 5; for
//! `n_g = 1` it reduces to the COMQ channel-wise rule (Eq. 6).
//!
//! Rows (output channels) are independent; within a row the groups are
//! swept sequentially (true coordinate descent), which makes every step an
//! exact 1-D minimization — the total loss is monotonically non-increasing
//! (property-tested below).

use super::format::QuantizedLinear;
use super::scale::GroupScales;
use crate::tensor::kernels::scalar::dot_span_f64;
use crate::tensor::Matrix;
use crate::util::threadpool::parallel_for_auto;

/// Stage-2 tunables.
#[derive(Clone, Copy, Debug)]
pub struct Stage2Config {
    /// Number of full CD sweeps over all groups.
    pub n_sweeps: usize,
    /// Denominator guard: groups with `v_iᵀ H_ii v_i < eps` are skipped.
    pub denom_eps: f64,
}

impl Default for Stage2Config {
    fn default() -> Self {
        Stage2Config { n_sweeps: 4, denom_eps: 1e-10 }
    }
}

/// Outcome of a refinement run.
#[derive(Clone, Debug)]
pub struct Stage2Report {
    pub sweeps: usize,
    pub updated_groups: usize,
    pub skipped_groups: usize,
}

/// Refine `scales` in place given frozen integers.
///
/// * `w` — FP weights `[out, in]`.
/// * `vint` — frozen `w_int − z` as f32 `[out, in]` (so `q = s ⊙_g vint`).
/// * `h` — layer Hessian `[in, in]` (damped, same one GPTQ used).
/// * `r` — deviation correlation `R = E[ΔX Xᵀ]` for layers after the first
///   (Eq. 9); `None` for the first layer (Eq. 5).
pub fn refine_scales(
    w: &Matrix,
    vint: &Matrix,
    h: &Matrix,
    r: Option<&Matrix>,
    scales: &mut GroupScales,
    cfg: &Stage2Config,
) -> Stage2Report {
    let (rows, cols) = (w.rows, w.cols);
    assert_eq!((vint.rows, vint.cols), (rows, cols));
    assert_eq!(h.rows, cols);
    let g = scales.group_size;
    let n_g = scales.scales.cols;

    // denom[r][gi] = v_iᵀ H_ii v_i — constant while integers are frozen.
    // (The packed entry point computes the same quantity straight from the
    // packed words via the dispatched kernels — see `packed_group_denoms`.)
    let mut denom = Matrix::zeros(rows, n_g);
    for gi in 0..n_g {
        let c0 = gi * g;
        let c1 = ((gi + 1) * g).min(cols);
        let hii = h.slice(c0, c1, c0, c1);
        for rr in 0..rows {
            let v = &vint.row(rr)[c0..c1];
            denom[(rr, gi)] = crate::tensor::linalg::quad_form(v, &hii, v) as f32;
        }
    }
    refine_scales_with_denom(w, vint, h, r, scales, cfg, denom)
}

/// Core CD sweep given a precomputed denominator matrix (`vᵀ H_ii v` per
/// `(row, group)`), so the packed path can supply kernel-computed denoms.
#[allow(clippy::too_many_arguments)]
fn refine_scales_with_denom(
    w: &Matrix,
    vint: &Matrix,
    h: &Matrix,
    r: Option<&Matrix>,
    scales: &mut GroupScales,
    cfg: &Stage2Config,
    denom: Matrix,
) -> Stage2Report {
    let (rows, cols) = (w.rows, w.cols);
    let g = scales.group_size;
    let n_g = scales.scales.cols;
    assert_eq!((denom.rows, denom.cols), (rows, n_g));

    // wr = W · R  (wᵀ R_i per row is a column slice of this) — Eq. 8 term.
    let wr = r.map(|rm| {
        assert_eq!((rm.rows, rm.cols), (cols, cols));
        w.matmul(rm)
    });

    // Current quantized weights and residual D = W − Q.
    let mut dmat = Matrix::zeros(rows, cols);
    for rr in 0..rows {
        let srow = scales.scales.row(rr);
        let drow = dmat.row_mut(rr);
        let vrow = vint.row(rr);
        let wrow = w.row(rr);
        for c in 0..cols {
            drow[c] = wrow[c] - srow[c / g] * vrow[c];
        }
    }

    let mut updated = 0usize;
    let mut skipped = 0usize;
    for _sweep in 0..cfg.n_sweeps {
        for gi in 0..n_g {
            let c0 = gi * g;
            let c1 = ((gi + 1) * g).min(cols);
            // T = D · H[:, c0..c1]  — H symmetric, so row block H_{i,:} of the
            // paper acting on d equals this column-sliced product (per row).
            let hcols = h.slice(0, cols, c0, c1);
            let t = dmat.matmul(&hcols); // [rows, c1-c0]

            // Per-row closed-form update + local D refresh (rows independent).
            let counts = std::sync::Mutex::new((0usize, 0usize));
            let scales_ptr = crate::util::SendPtr(scales.scales.data.as_mut_ptr());
            let d_ptr = crate::util::SendPtr(dmat.data.as_mut_ptr());
            let n_scale_cols = scales.scales.cols;
            parallel_for_auto(rows, |rr| {
                let v = &vint.row(rr)[c0..c1];
                let den = denom[(rr, gi)] as f64;
                if den < cfg.denom_eps {
                    counts.lock().unwrap().1 += 1;
                    return;
                }
                let mut num = 0.0f64;
                for (vi, ti) in v.iter().zip(t.row(rr)) {
                    num += *vi as f64 * *ti as f64;
                }
                if let Some(wr) = &wr {
                    let wrrow = &wr.row(rr)[c0..c1];
                    for (vi, wi) in v.iter().zip(wrrow) {
                        num -= *vi as f64 * *wi as f64;
                    }
                }
                let delta = (num / den) as f32;
                // SAFETY: disjoint rows per worker.
                unsafe {
                    let s = scales_ptr.get().add(rr * n_scale_cols + gi);
                    *s += delta;
                    // refresh residual for this group: d -= delta * v
                    let drow = std::slice::from_raw_parts_mut(d_ptr.get().add(rr * cols + c0), c1 - c0);
                    for (dv, vi) in drow.iter_mut().zip(v) {
                        *dv -= delta * *vi;
                    }
                }
                counts.lock().unwrap().0 += 1;
            });
            let (u, s) = *counts.lock().unwrap();
            updated += u;
            skipped += s;
        }
    }
    Stage2Report { sweeps: cfg.n_sweeps, updated_groups: updated, skipped_groups: skipped }
}

/// Convenience wrapper operating on a [`QuantizedLinear`]: extracts the
/// frozen `v = w_int − z`, refines, and writes the new scales back.
///
/// `vint` lives in *stored* column order (that is what the packed integers
/// are), while `w`/`h`/`r` arrive in *original* order. When the linear
/// carries an act-order `perm` or AWQ `channel_scales`, the whole problem
/// is therefore transformed into stored coordinates before the CD sweep —
/// refining against the original-order `w`/`h` would produce plausibly-wrong
/// scales (each group's update would be computed against the wrong columns).
///
/// With `P` the stored→original gather and `C = diag(channel_scales)`, the
/// dequantized weights are `Q̂ = (S∘V) C⁻¹ Pᵀ`, so the loss
/// `tr(ΔW H ΔWᵀ) + 2 tr(W R ΔWᵀ)` becomes, in stored coordinates,
///
/// ```text
/// W″ = W P C          w″[r,j] = w[r, perm[j]] · cs[j]
/// H″ = C⁻¹ Pᵀ H P C⁻¹  h″[i,j] = h[perm[i], perm[j]] / (cs[i]·cs[j])
/// R″ = C⁻¹ Pᵀ R P C⁻¹  (same gather/scaling as H)
/// ```
///
/// and `refine_scales(W″, V, H″, R″)` is exactly the original objective.
pub fn refine_quantized_linear(
    w: &Matrix,
    q: &mut QuantizedLinear,
    h: &Matrix,
    r: Option<&Matrix>,
    cfg: &Stage2Config,
) -> Stage2Report {
    let mut vint = Matrix::zeros(q.rows, q.cols);
    let g = q.group_size;
    for rr in 0..q.rows {
        // one streaming unpack per row instead of `get(c)` per element
        // (which re-validates the words vec on every access)
        let vals = q.qweight[rr].unpack();
        let zrow = q.zeros.row(rr).to_vec();
        let vrow = vint.row_mut(rr);
        for (c, (v, &qc)) in vrow.iter_mut().zip(&vals).enumerate() {
            *v = qc as f32 - zrow[c / g];
        }
    }
    let mut gs = GroupScales {
        scales: q.scales.clone(),
        zeros: q.zeros.clone(),
        group_size: g,
        bits: q.bits,
    };
    let report = if q.perm.is_none() && q.channel_scales.is_none() {
        let denom = packed_group_denoms(q, h, &vint);
        refine_scales_with_denom(w, &vint, h, r, &mut gs, cfg, denom)
    } else {
        let (wg, hg, rg) = to_stored_coords(w, h, r, q);
        let denom = packed_group_denoms(q, &hg, &vint);
        refine_scales_with_denom(&wg, &vint, &hg, rg.as_ref(), &mut gs, cfg, denom)
    };
    q.scales = gs.scales;
    report
}

/// `denom[r, gi] = vᵀ H_ii v` for `v = q_r − z_g`, computed straight from
/// the packed words: each Hessian row contributes one `H v` product
/// `(H_ii v)_i = Σ_{j∈g} q_j H_ij − z_g Σ_{j∈g} H_ij`, with the integer
/// unpack-dot reusing the kernel layer ([`dot_span_f64`]) — the same
/// decomposition the serving GEMV dispatches, so the CD sweep stays cheap
/// at quantization time for the same reason decode is fast at serve time.
///
/// The f64-accumulating variant (not the dispatched f32 kernels) is
/// deliberate: when a group's ints sit near the zero-point, `Σ q_j H_ij`
/// and `z Σ H_ij` are each ~`z/|v|` times the centered difference, and this
/// quantity is a *denominator* — f32 rounding of the uncentered sums would
/// be amplified by the cancellation straight into the CD step size.
///
/// `vint` is the caller's already-materialized `q − z` in stored order
/// (exact in f32: both operands are small integers), supplying the outer
/// `v_i` factor without re-unpacking every row.
fn packed_group_denoms(q: &QuantizedLinear, h: &Matrix, vint: &Matrix) -> Matrix {
    let g = q.group_size;
    let n_g = q.n_groups();
    let cols = q.cols;
    debug_assert_eq!(h.rows, cols);
    debug_assert_eq!((vint.rows, vint.cols), (q.rows, cols));
    // Σ_{j∈group(i)} H[i, j] per column i — the zero-point term of each
    // H v product; row-independent, computed once.
    let mut hgsum = vec![0.0f64; cols];
    for (i, hg) in hgsum.iter_mut().enumerate() {
        let c0 = (i / g) * g;
        let c1 = (c0 + g).min(cols);
        *hg = h.row(i)[c0..c1].iter().map(|v| *v as f64).sum();
    }
    let mut denom = Matrix::zeros(q.rows, n_g);
    let d_ptr = crate::util::SendPtr(denom.data.as_mut_ptr());
    parallel_for_auto(q.rows, |rr| {
        let words = &q.qweight[rr].words;
        let vrow = vint.row(rr);
        let zrow = q.zeros.row(rr);
        // SAFETY: disjoint denom rows per worker.
        let drow: &mut [f32] =
            unsafe { std::slice::from_raw_parts_mut(d_ptr.get().add(rr * n_g), n_g) };
        for (gi, d) in drow.iter_mut().enumerate() {
            let c0 = gi * g;
            let c1 = (c0 + g).min(cols);
            let z = zrow[gi] as f64;
            let mut acc = 0.0f64;
            for i in c0..c1 {
                let hq = dot_span_f64(words, q.bits, c0, c1, h.row(i));
                acc += vrow[i] as f64 * (hq - z * hgsum[i]);
            }
            *d = acc as f32;
        }
    });
    denom
}

/// Gather `w`/`h`/`r` into stored column order with the AWQ channel
/// divisors folded in (see [`refine_quantized_linear`]).
fn to_stored_coords(
    w: &Matrix,
    h: &Matrix,
    r: Option<&Matrix>,
    q: &QuantizedLinear,
) -> (Matrix, Matrix, Option<Matrix>) {
    let cols = q.cols;
    let orig = |j: usize| -> usize {
        match &q.perm {
            Some(p) => p[j] as usize,
            None => j,
        }
    };
    let cs = |j: usize| -> f32 {
        match &q.channel_scales {
            Some(c) => c[j],
            None => 1.0,
        }
    };
    let mut wg = Matrix::zeros(w.rows, cols);
    for rr in 0..w.rows {
        let src = w.row(rr);
        let dst = wg.row_mut(rr);
        for (j, d) in dst.iter_mut().enumerate() {
            *d = src[orig(j)] * cs(j);
        }
    }
    let gather_sym = |m: &Matrix| -> Matrix {
        let mut out = Matrix::zeros(cols, cols);
        for i in 0..cols {
            let oi = orig(i);
            let ci = cs(i);
            let src = m.row(oi);
            let dst = out.row_mut(i);
            for (j, d) in dst.iter_mut().enumerate() {
                *d = src[orig(j)] / (ci * cs(j));
            }
        }
        out
    };
    (wg, gather_sym(h), r.map(gather_sym))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::{gptq_quantize, prepare_hessian, GptqConfig};
    use crate::quant::metrics::{layer_loss, layer_loss_with_deviation};
    use crate::quant::scale::{compute_group_scales, QuantSpec, ScaleMetric};
    use crate::util::proptest::{check, prop_assert};
    use crate::util::rng::Rng;

    fn correlated_hessian(cols: usize, t: usize, rng: &mut Rng) -> Matrix {
        let mut x = Matrix::zeros(cols, t);
        for c in 0..t {
            let mut prev = 0.0f32;
            for r in 0..cols {
                let v = 0.6 * prev + rng.normal() as f32;
                x[(r, c)] = v;
                prev = v;
            }
        }
        let mut h = x.matmul_bt(&x);
        h.scale_inplace(1.0 / t as f32);
        h
    }

    fn setup(
        out: usize,
        inp: usize,
        g: usize,
        bits: u8,
        seed: u64,
    ) -> (Matrix, Matrix, QuantizedLinear, QuantSpec) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(out, inp, 1.0, &mut rng);
        let h = correlated_hessian(inp, inp * 4, &mut rng);
        let spec = QuantSpec::new(bits, g);
        let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
        let mut wd = w.clone();
        let hd = prepare_hessian(&h, &mut wd, 0.01);
        let q = gptq_quantize(&w, &h, &scales, &spec, &GptqConfig::default()).unwrap();
        (w, hd, q, spec)
    }

    #[test]
    fn stage2_reduces_layer_loss() {
        let (w, hd, mut q, _) = setup(16, 64, 16, 2, 1);
        let before = layer_loss(&w, &q.dequantize(), &hd);
        let rep =
            refine_quantized_linear(&w, &mut q, &hd, None, &Stage2Config::default());
        let after = layer_loss(&w, &q.dequantize(), &hd);
        assert!(rep.updated_groups > 0);
        assert!(
            after < before * 0.999,
            "stage2 should strictly reduce loss: {before} -> {after}"
        );
    }

    #[test]
    fn stage2_monotone_per_sweep() {
        let (w, hd, mut q, _) = setup(8, 48, 16, 2, 2);
        let mut last = layer_loss(&w, &q.dequantize(), &hd);
        for _ in 0..5 {
            refine_quantized_linear(
                &w,
                &mut q,
                &hd,
                None,
                &Stage2Config { n_sweeps: 1, ..Default::default() },
            );
            let cur = layer_loss(&w, &q.dequantize(), &hd);
            assert!(cur <= last + last.abs() * 1e-5, "not monotone: {last} -> {cur}");
            last = cur;
        }
    }

    #[test]
    fn channelwise_reduces_to_comq_rule() {
        // n_g = 1: the update must land exactly on s* = vᵀHw / vᵀHv (Eq. 6).
        let mut rng = Rng::new(3);
        let inp = 32;
        let w = Matrix::randn(1, inp, 1.0, &mut rng);
        let h = correlated_hessian(inp, 128, &mut rng);
        let spec = QuantSpec::new(3, inp); // one group
        let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
        let q = crate::quant::rtn::rtn_quantize(&w, &scales, &spec);

        let mut vint = Matrix::zeros(1, inp);
        for c in 0..inp {
            vint[(0, c)] = q.qweight[0].get(c) as f32 - q.zeros[(0, 0)];
        }
        let mut gs = GroupScales {
            scales: q.scales.clone(),
            zeros: q.zeros.clone(),
            group_size: inp,
            bits: 3,
        };
        refine_scales(&w, &vint, &h, None, &mut gs, &Stage2Config { n_sweeps: 1, ..Default::default() });

        let v = vint.row(0);
        let hw = h.matvec(w.row(0));
        let hv = h.matvec(v);
        let num: f64 = v.iter().zip(&hw).map(|(a, b)| *a as f64 * *b as f64).sum();
        let den: f64 = v.iter().zip(&hv).map(|(a, b)| *a as f64 * *b as f64).sum();
        let expected = (num / den) as f32;
        assert!(
            (gs.scales[(0, 0)] - expected).abs() < 1e-4 * expected.abs().max(1.0),
            "got {} want {expected}",
            gs.scales[(0, 0)]
        );
    }

    #[test]
    fn single_group_single_sweep_is_exact_minimizer() {
        // After one update of the only group, a second sweep must be a no-op.
        let (w, hd, mut q, _) = setup(4, 16, 16, 2, 4);
        refine_quantized_linear(&w, &mut q, &hd, None, &Stage2Config { n_sweeps: 1, ..Default::default() });
        let s1 = q.scales.clone();
        refine_quantized_linear(&w, &mut q, &hd, None, &Stage2Config { n_sweeps: 1, ..Default::default() });
        assert!(q.scales.max_abs_diff(&s1) < 1e-5);
    }

    #[test]
    fn deviation_term_shifts_optimum() {
        // With a non-zero R the refined scales must differ from the R = None
        // run, and must reduce the deviation-aware loss (Eq. 7).
        let (w, hd, q0, _) = setup(8, 48, 16, 2, 5);
        let mut rng = Rng::new(99);
        let dx = Matrix::randn(48, 96, 0.3, &mut rng);
        let x = Matrix::randn(48, 96, 1.0, &mut rng);
        let mut r = dx.matmul_bt(&x);
        r.scale_inplace(1.0 / 96.0);

        let mut q_plain = q0.clone();
        let mut q_dev = q0.clone();
        refine_quantized_linear(&w, &mut q_plain, &hd, None, &Stage2Config::default());
        refine_quantized_linear(&w, &mut q_dev, &hd, Some(&r), &Stage2Config::default());
        assert!(q_plain.scales.max_abs_diff(&q_dev.scales) > 1e-6);

        let before = layer_loss_with_deviation(&w, &q0.dequantize(), &hd, &r);
        let after = layer_loss_with_deviation(&w, &q_dev.dequantize(), &hd, &r);
        assert!(after < before, "deviation-aware loss: {before} -> {after}");
    }

    #[test]
    fn prop_stage2_never_increases_loss() {
        check("stage2 monotone on random problems", 15, |gen| {
            let out = gen.usize_in(1, 6);
            let n_g = gen.usize_in(1, 4);
            let g = 8 * gen.usize_in(1, 2);
            let inp = n_g * g;
            let bits = gen.usize_in(2, 4) as u8;
            let seed = gen.rng.next_u64();
            let mut rng = Rng::new(seed);
            let w = Matrix::randn(out, inp, 1.0, &mut rng);
            let h = correlated_hessian(inp, inp * 4 + 8, &mut rng);
            let spec = QuantSpec::new(bits, g);
            let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
            let mut wd = w.clone();
            let hd = prepare_hessian(&h, &mut wd, 0.01);
            let mut q = gptq_quantize(&w, &h, &scales, &spec, &GptqConfig::default()).unwrap();
            let before = layer_loss(&w, &q.dequantize(), &hd);
            refine_quantized_linear(&w, &mut q, &hd, None, &Stage2Config::default());
            let after = layer_loss(&w, &q.dequantize(), &hd);
            prop_assert(
                after <= before + before.abs() * 1e-4 + 1e-7,
                &format!("loss increased {before} -> {after} (seed {seed})"),
            )
        });
    }

    #[test]
    fn identity_perm_and_unit_channel_scales_match_plain_refine() {
        // The stored-coordinate transform must be exactly a no-op for
        // trivial metadata.
        let (w, hd, q0, _) = setup(8, 48, 16, 2, 11);
        let mut q_plain = q0.clone();
        let mut q_meta = q0.clone();
        q_meta.perm = Some((0..q_meta.cols as u32).collect());
        q_meta.channel_scales = Some(vec![1.0; q_meta.cols]);
        refine_quantized_linear(&w, &mut q_plain, &hd, None, &Stage2Config::default());
        refine_quantized_linear(&w, &mut q_meta, &hd, None, &Stage2Config::default());
        assert!(q_plain.scales.max_abs_diff(&q_meta.scales) < 1e-5);
    }

    #[test]
    fn refines_actorder_output_in_correct_column_order() {
        // Regression: refining an act-order linear used to build `vint` in
        // stored order against `w`/`h` in original order, producing
        // plausibly-wrong scales. The gathered transform must strictly
        // reduce the *original-order* layer loss.
        let mut rng = Rng::new(31);
        let w = Matrix::randn(12, 64, 1.0, &mut rng);
        let h = correlated_hessian(64, 256, &mut rng);
        let spec = QuantSpec::new(2, 16);
        let mut wd = w.clone();
        let hd = prepare_hessian(&h, &mut wd, 0.01);
        let mut q = crate::quant::actorder::gptq_quantize_actorder(
            &w,
            &h,
            &spec,
            crate::quant::scale::ScaleMetric::L2,
            &GptqConfig::default(),
        )
        .unwrap()
        .into_quantized_linear();
        assert!(q.perm.is_some(), "actorder must set perm");
        let before = layer_loss(&w, &q.dequantize(), &hd);
        let rep = refine_quantized_linear(&w, &mut q, &hd, None, &Stage2Config::default());
        let after = layer_loss(&w, &q.dequantize(), &hd);
        assert!(rep.updated_groups > 0);
        assert!(
            after < before * 0.9999,
            "stage2 on act-order output must reduce loss: {before} -> {after}"
        );
    }

    #[test]
    fn refines_awq_output_through_channel_scales() {
        let mut rng = Rng::new(32);
        let w = Matrix::randn(10, 64, 1.0, &mut rng);
        let h = correlated_hessian(64, 256, &mut rng);
        let spec = QuantSpec::new(3, 16);
        let mut wd = w.clone();
        let hd = prepare_hessian(&h, &mut wd, 0.01);
        let mut q = crate::quant::awq::awq_quantize(&w, &hd, &spec).into_quantized_linear();
        assert!(q.channel_scales.is_some(), "awq must set channel_scales");
        let before = layer_loss(&w, &q.dequantize(), &hd);
        refine_quantized_linear(&w, &mut q, &hd, None, &Stage2Config::default());
        let after = layer_loss(&w, &q.dequantize(), &hd);
        assert!(
            after <= before * (1.0 + 1e-6),
            "stage2 on AWQ output must not increase loss: {before} -> {after}"
        );
    }

    #[test]
    fn packed_denoms_match_quad_form_reference() {
        // The kernel-computed H v denominators must agree with the dense
        // quad-form path refine_scales uses, across a straddling bit width.
        for (bits, seed) in [(2u8, 21), (3, 22), (4, 23), (8, 24)] {
            let (_, hd, q, _) = setup(6, 64, 16, bits, seed);
            let mut vint = Matrix::zeros(q.rows, q.cols);
            for rr in 0..q.rows {
                let vals = q.qweight[rr].unpack();
                let zrow = q.zeros.row(rr).to_vec();
                let vrow = vint.row_mut(rr);
                for (c, (v, &qc)) in vrow.iter_mut().zip(&vals).enumerate() {
                    *v = qc as f32 - zrow[c / q.group_size];
                }
            }
            let denom_p = packed_group_denoms(&q, &hd, &vint);
            let g = q.group_size;
            for rr in 0..q.rows {
                let vals = q.qweight[rr].unpack();
                for gi in 0..q.n_groups() {
                    let c0 = gi * g;
                    let c1 = ((gi + 1) * g).min(q.cols);
                    let z = q.zeros[(rr, gi)];
                    let v: Vec<f32> =
                        vals[c0..c1].iter().map(|&qc| qc as f32 - z).collect();
                    let hii = hd.slice(c0, c1, c0, c1);
                    let want = crate::tensor::linalg::quad_form(&v, &hii, &v) as f32;
                    let got = denom_p[(rr, gi)];
                    assert!(
                        (got - want).abs() <= 1e-3 * want.abs().max(1e-6),
                        "bits={bits} r={rr} g={gi}: packed {got} vs quad_form {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_denoms_survive_near_zero_point_cancellation() {
        // 8-bit ints clustered at the zero-point (v ∈ {−1,0,1}) against a
        // large-magnitude Hessian: the uncentered sums Σ q_j·H_ij are ~128×
        // the centered signal, so an f32 inner dot would leak its rounding
        // into the denominator through the cancellation. The f64 unpack-dot
        // must track the all-f64 centered quad form tightly.
        let inp = 32;
        let g = 16;
        let rows = 2;
        let mut rng = Rng::new(55);
        let mut h = correlated_hessian(inp, 128, &mut rng);
        h.scale_inplace(1e3);
        let ints: Vec<Vec<u8>> = (0..rows)
            .map(|_| (0..inp).map(|_| 127 + (rng.next_u64() % 3) as u8).collect())
            .collect();
        let scales = Matrix::from_vec(rows, 2, vec![0.01; rows * 2]);
        let zeros = Matrix::from_vec(rows, 2, vec![128.0; rows * 2]);
        let q = QuantizedLinear::from_ints(&ints, 8, g, scales, zeros);
        let mut vint = Matrix::zeros(rows, inp);
        for rr in 0..rows {
            for c in 0..inp {
                vint[(rr, c)] = ints[rr][c] as f32 - 128.0;
            }
        }
        let denoms = packed_group_denoms(&q, &h, &vint);
        for rr in 0..rows {
            for gi in 0..2 {
                let c0 = gi * g;
                let c1 = c0 + g;
                let v = &vint.row(rr)[c0..c1];
                let hii = h.slice(c0, c1, c0, c1);
                let want = crate::tensor::linalg::quad_form(v, &hii, v) as f32;
                let got = denoms[(rr, gi)];
                assert!(
                    (got - want).abs() <= 1e-5 * want.abs().max(1e-12),
                    "r={rr} g={gi}: packed denom {got} vs centered quad form {want}"
                );
            }
        }
    }

    #[test]
    fn skips_degenerate_groups() {
        // A group whose integers are all equal to the zero-point (v = 0) has
        // denominator 0 and must be skipped, not NaN'd.
        let inp = 16;
        let w = Matrix::zeros(2, inp);
        let h = Matrix::eye(inp);
        let vint = Matrix::zeros(2, inp);
        let mut gs = GroupScales {
            scales: Matrix::from_vec(2, 2, vec![0.1; 4]),
            zeros: Matrix::zeros(2, 2),
            group_size: 8,
            bits: 2,
        };
        let rep = refine_scales(&w, &vint, &h, None, &mut gs, &Stage2Config::default());
        assert_eq!(rep.updated_groups, 0);
        assert_eq!(rep.skipped_groups, 16); // 2 rows × 2 groups × 4 sweeps
        assert!(gs.scales.data.iter().all(|s| s.is_finite()));
    }
}
