//! `bench_check` — compare a fresh `BENCH_packed_gemv.json` against the
//! committed baseline and fail on tokens/s regressions (`make bench-check`).
//!
//! Usage: `bench_check <baseline.json> <fresh.json>`
//!
//! Per bit width (GEMV dispatched tokens/s), per decode row, and per
//! prefill-TTFT row (ms, inverted to prefills/s so every comparison is
//! higher-is-better), a drop of more than `TSGO_BENCH_TOLERANCE` (default
//! 0.15 = 15%) against the baseline is a regression → exit 1. Two
//! deliberate soft edges:
//!
//! * a missing baseline is a bootstrap, not a failure — the tool says how to
//!   create one and exits 0;
//! * only a baseline whose `provenance` field is exactly `"measured"` (what
//!   `make bench-json` stamps) arms the hard gate; anything else — including
//!   the repo-seeded `"seeded-unmeasured"` placeholder and baselines with no
//!   provenance at all — is compared and reported but never fails the build.
//!
//! Absolute tokens/s are machine-specific, so cross-machine comparisons are
//! advisory by nature — CI runs this as a non-blocking job; the hard gate is
//! meant for a stable perf box comparing against its own committed numbers.

use std::process::exit;
use tsgo::util::json::Json;

fn load(path: &str, what: &str) -> Option<Json> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => return None,
    };
    match Json::parse(&text) {
        Ok(j) => Some(j),
        Err(e) => {
            eprintln!("bench-check: {what} {path} is not valid JSON: {e}");
            exit(2);
        }
    }
}

/// Pull `(key, tokens/s)` comparison rows shared by both reports.
fn rows(j: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    if let Some(arr) = j.get("gemv").as_arr() {
        for e in arr {
            if let (Some(bits), Some(tps)) =
                (e.get("bits").as_f64(), e.get("dispatched_tokens_per_s").as_f64())
            {
                out.push((format!("gemv INT{bits}"), tps));
            }
        }
    }
    let decode = j.get("decode");
    for key in [
        "dense_tokens_per_s",
        "packed_int2_tokens_per_s",
        "packed_int2_sampled_tokens_per_s",
        "packed_int2_fault_unarmed_tokens_per_s",
        "packed_int2_fault_armed_tokens_per_s",
        "packed_int2_metrics_tokens_per_s",
        "packed_int2_kv8_tokens_per_s",
        "packed_int2_kv4_tokens_per_s",
        "packed_int2_paged_tokens_per_s",
        "packed_int2_shards1_tokens_per_s",
        "packed_int2_shards2_tokens_per_s",
        "packed_int2_shards4_tokens_per_s",
    ] {
        if let Some(tps) = decode.get(key).as_f64() {
            out.push((format!("decode {key}"), tps));
        }
    }
    // Prefill rows are milliseconds (lower is better); invert into prefills/s
    // so the shared higher-is-better ratio logic covers them too.
    let prefill = j.get("prefill");
    if let Some(ms) = prefill.get("ttft_ms_int2_prompt512").as_f64() {
        if ms > 0.0 {
            out.push(("prefill ttft_ms_int2_prompt512".to_string(), 1e3 / ms));
        }
    }
    if let Some(sweep) = prefill.get("chunk_sweep").as_arr() {
        for e in sweep {
            if let (Some(chunk), Some(ms)) =
                (e.get("chunk").as_f64(), e.get("ttft_ms").as_f64())
            {
                if ms > 0.0 {
                    out.push((format!("prefill ttft chunk {chunk}"), 1e3 / ms));
                }
            }
        }
    }
    out
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let [baseline_path, fresh_path] = match args.as_slice() {
        [b, f] => [b.clone(), f.clone()],
        _ => {
            eprintln!("usage: bench_check <baseline.json> <fresh.json>");
            exit(2);
        }
    };
    let tolerance: f64 = std::env::var("TSGO_BENCH_TOLERANCE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.15);

    let Some(baseline) = load(&baseline_path, "baseline") else {
        println!(
            "bench-check: no baseline at {baseline_path} — bootstrap: run \
             `make bench-json` and commit {baseline_path} to arm the regression guard."
        );
        exit(0);
    };
    let Some(fresh) = load(&fresh_path, "fresh results") else {
        eprintln!("bench-check: cannot read fresh results at {fresh_path} (run `make bench-json` first)");
        exit(2);
    };

    // Only a baseline `make bench-json` actually measured arms the gate;
    // seeded placeholders and un-tagged files report but never fail.
    let armed = baseline.get("provenance").as_str() == Some("measured");

    let base_rows = rows(&baseline);
    let fresh_rows = rows(&fresh);
    let mut regressions = Vec::new();
    println!(
        "bench-check vs {baseline_path} (tolerance {:.0}%{})",
        tolerance * 100.0,
        if armed { "" } else { ", baseline not yet measured — advisory" }
    );
    println!("  {:<36} {:>12} {:>12} {:>8}", "row", "baseline", "fresh", "ratio");
    for (key, base_tps) in &base_rows {
        let Some((_, fresh_tps)) = fresh_rows.iter().find(|(k, _)| k == key) else {
            regressions.push(format!("{key}: missing from fresh results"));
            continue;
        };
        let ratio = if *base_tps > 0.0 { fresh_tps / base_tps } else { f64::INFINITY };
        let flag = if ratio < 1.0 - tolerance { "  << REGRESSION" } else { "" };
        println!(
            "  {key:<36} {base_tps:>12.1} {fresh_tps:>12.1} {:>7.2}x{flag}",
            ratio
        );
        if ratio < 1.0 - tolerance {
            regressions.push(format!(
                "{key}: {fresh_tps:.1} tok/s is {:.1}% below baseline {base_tps:.1}",
                (1.0 - ratio) * 100.0
            ));
        }
    }
    if regressions.is_empty() {
        println!("bench-check: OK — no row regressed more than {:.0}%", tolerance * 100.0);
        return;
    }
    println!("bench-check: {} regression(s):", regressions.len());
    for r in &regressions {
        println!("  - {r}");
    }
    if armed {
        exit(1);
    }
    println!(
        "bench-check: baseline is seeded, not measured — not failing. \
         Regenerate it with `make bench-json` and commit to arm the guard."
    );
}
