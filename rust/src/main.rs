//! `tsgo` — the command-line launcher for the whole system.
//!
//! Subcommands:
//! * `info`      — presets, artifact status, thread counts
//! * `gen-data`  — write the synthetic corpora to disk
//! * `train`     — train a Llamette from scratch (AOT train_step artifact)
//! * `quantize`  — run the PTQ pipeline (GPTQ baseline or the paper's method)
//! * `eval`      — perplexity + 0-shot suite for a checkpoint
//! * `serve`     — batched generation server over a checkpoint
//! * `stats`     — fetch + pretty-print a running server's telemetry snapshot
//! * `kernels`   — the runtime-selected dequant kernel dispatch table
//! * `warmup`    — pre-compile all HLO artifacts

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::eval::tasks::{build_suite, task_suite};
use tsgo::kvpool::{KvPool, PoolCfg};
use tsgo::model::{store, KvSpec, ModelExec, ModelWeights, Preset};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantPlan;
use tsgo::runtime::Engine;
use tsgo::shard::ShardedModel;
use tsgo::util::cli::{usage, Args, OptSpec};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "info" => cmd_info(),
        "gen-data" => cmd_gen_data(rest),
        "train" => cmd_train(rest),
        "quantize" => cmd_quantize(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "stats" => cmd_stats(rest),
        "kernels" => cmd_kernels(),
        "warmup" => cmd_warmup(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `tsgo help`)"),
    }
}

fn print_help() {
    println!(
        "tsgo — Two-Stage Grid Optimization for Group-wise Quantization of LLMs\n\n\
         commands:\n\
         \x20 info       environment / artifact status\n\
         \x20 gen-data   write synthetic corpora (--out dir)\n\
         \x20 train      train a model (--preset small --steps 300 --out model.tsr)\n\
         \x20 quantize   PTQ pipeline (--model m.tsr --method ours --bits 2 --group 64);\n\
         \x20            --method takes any registered quantizer (rtn|awq|actorder|gptq|\n\
         \x20            stage1|stage2|ours) or a per-layer plan string such as\n\
         \x20            'ours:bits=2,group=64;wv,wo=bits4;l0=awq'\n\
         \x20 eval       PPL + 0-shot (--model m.tsr [--quantized | --packed]);\n\
         \x20            --kv-bits 8 --kv-group 64 additionally reports the\n\
         \x20            decode-path ppl delta of a group-wise quantized KV cache;\n\
         \x20            --kv-pool-mb M pages the decode KV out of a bounded pool;\n\
         \x20            --shards N evaluates through the layer-sharded model\n\
         \x20            (prints the shard plan; numerics identical to unsharded)\n\
         \x20 serve      generation server (--model m.tsr --addr 127.0.0.1:7433\n\
         \x20            [--quantized | --packed]); --packed executes the packed\n\
         \x20            ints through the fused dequant kernels, never\n\
         \x20            materializing dense weights; --kv-bits 8|4 --kv-group 64\n\
         \x20            quantizes the decode KV cache group-wise per head;\n\
         \x20            --shards N splits layers over N pipeline shard threads\n\
         \x20            (bit-identical tokens; banner shows per-shard ranges,\n\
         \x20            weight bytes and KV bytes/token); --kv-pool-mb M\n\
         \x20            --kv-page-tokens T bound total KV memory with a paged\n\
         \x20            pool (budget-aware admission, youngest-first preemption\n\
         \x20            with deterministic re-prefill — tokens unchanged);\n\
         \x20            --prefill-chunk C feeds prompts C tokens per step\n\
         \x20            (batched GEMM prefill; C=1 is the one-token path,\n\
         \x20            tokens bit-identical for every C);\n\
         \x20            --request-timeout MS bounds total per-request latency\n\
         \x20            (expired requests return partial tokens, timed_out=true),\n\
         \x20            --step-timeout MS bounds one decode step, --conn-timeout MS\n\
         \x20            disconnects silent clients; panicked decode workers are\n\
         \x20            respawned and dead shard chains rebuilt automatically\n\
         \x20            (TSGO_FAULT=point[=v][@hit=N] injects test faults);\n\
         \x20            --temperature T --top-k K --top-p P --repetition-penalty R\n\
         \x20            --seed S set server-default sampling (T=0 is greedy,\n\
         \x20            bit-identical to the pre-sampler path; T>0 is seeded\n\
         \x20            multinomial with deterministic replay), --stop \"a,b\"\n\
         \x20            sets default stop strings; per-request JSON fields\n\
         \x20            override, incl. \"stream\": true for per-token events\n\
         \x20            (see docs/SERVE_API.md);\n\
         \x20            --metrics-addr HOST:PORT serves Prometheus text\n\
         \x20            exposition of the telemetry plane on a dedicated\n\
         \x20            listener (counters, gauges, latency histograms)\n\
         \x20 stats      fetch + pretty-print a running server's telemetry\n\
         \x20            snapshot (--addr 127.0.0.1:7433; the {{\"stats\": true}}\n\
         \x20            control line on the serve protocol)\n\
         \x20 kernels    print the dequant kernel dispatch table (CPU features,\n\
         \x20            per-bit-width kernel selection, forcing state)\n\
         \x20 warmup     pre-compile all artifacts"
    );
}

fn cmd_info() -> Result<()> {
    println!("tsgo build info");
    println!("  threads: {}", tsgo::util::threadpool::num_threads());
    for p in [Preset::Tiny, Preset::Small, Preset::Base] {
        let c = p.config();
        println!(
            "  preset {:<6} d={} L={} heads={} ffn={} params={:.2}M",
            p.label(),
            c.d_model,
            c.n_layers,
            c.n_heads,
            c.ffn,
            c.n_params() as f64 / 1e6
        );
    }
    match Engine::open_default() {
        Some(e) => {
            println!(
                "  artifacts: {} entries for d_model={} (dir {})",
                e.manifest.entries.len(),
                e.manifest.config.d_model,
                e.manifest.dir.display()
            );
        }
        None => println!("  artifacts: none (run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_gen_data(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "out", help: "output directory", default: Some("data"), is_flag: false },
        OptSpec { name: "bytes", help: "corpus size in bytes", default: Some("400000"), is_flag: false },
        OptSpec { name: "seed", help: "generation seed", default: Some("1"), is_flag: false },
    ];
    let a = parse(argv, "tsgo gen-data", "write synthetic corpora", &specs)?;
    let dir = PathBuf::from(a.str("out"));
    std::fs::create_dir_all(&dir)?;
    let n = a.usize("bytes").map_err(anyhow::Error::msg)?;
    let seed = a.u64("seed").map_err(anyhow::Error::msg)?;
    for kind in [CorpusKind::SynthWiki, CorpusKind::SynthC4] {
        let c = Corpus::generate(kind, n, seed);
        let path = dir.join(format!("{}.txt", kind.label()));
        std::fs::write(&path, &c.bytes)?;
        println!("wrote {} ({} bytes)", path.display(), c.bytes.len());
    }
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "steps", help: "training steps", default: Some("300"), is_flag: false },
        OptSpec { name: "seed", help: "init/data seed", default: Some("7"), is_flag: false },
        OptSpec { name: "out", help: "checkpoint path", default: Some("model.tsr"), is_flag: false },
        OptSpec { name: "corpus-bytes", help: "training corpus size", default: Some("400000"), is_flag: false },
    ];
    let a = parse(argv, "tsgo train", "train a Llamette from scratch", &specs)?;
    let engine = Engine::open_default()
        .context("training needs artifacts — run `make artifacts` first")?;
    let corpus = Corpus::generate(
        CorpusKind::SynthWiki,
        a.usize("corpus-bytes").map_err(anyhow::Error::msg)?,
        1,
    );
    let (train_split, _) = corpus.split(0.1);
    let cfg = tsgo::runtime::TrainConfig {
        steps: a.usize("steps").map_err(anyhow::Error::msg)?,
        seed: a.u64("seed").map_err(anyhow::Error::msg)?,
        log_every: 25,
    };
    println!(
        "training preset matching artifacts ({:.2}M params) for {} steps…",
        engine.manifest.config.n_params() as f64 / 1e6,
        cfg.steps
    );
    let t0 = std::time::Instant::now();
    let out = tsgo::runtime::train(&engine, train_split, &cfg)?;
    println!(
        "trained in {} — loss {:.4} → {:.4}",
        tsgo::util::fmt_duration(t0.elapsed()),
        out.losses.first().copied().unwrap_or(0.0),
        out.losses.last().copied().unwrap_or(0.0)
    );
    let path = PathBuf::from(a.str("out"));
    store::save_model(&path, &out.weights)?;
    println!("saved {}", path.display());
    Ok(())
}

fn cmd_quantize(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "model", help: "FP checkpoint", default: Some("model.tsr"), is_flag: false },
        OptSpec { name: "out", help: "quantized checkpoint", default: Some("model.q.tsr"), is_flag: false },
        OptSpec {
            name: "method",
            help: "quantizer (rtn|awq|actorder|gptq|stage1|stage2|ours) or plan string, \
                   e.g. 'ours:bits=2,group=64;wv,wo=bits4;l0=awq'",
            default: Some("ours"),
            is_flag: false,
        },
        OptSpec { name: "bits", help: "default bit width (1-8)", default: Some("2"), is_flag: false },
        OptSpec { name: "group", help: "default group size", default: Some("64"), is_flag: false },
        OptSpec { name: "calib-seqs", help: "calibration sequences", default: Some("32"), is_flag: false },
        OptSpec { name: "seed", help: "calibration seed", default: Some("3"), is_flag: false },
    ];
    let a = parse(argv, "tsgo quantize", "run the PTQ pipeline", &specs)?;
    let w = store::load_model(Path::new(&a.str("model")))?;
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 400_000, 1);
    let (train_split, _) = corpus.split(0.1);
    let calib = calibration_batches(
        train_split,
        a.usize("calib-seqs").map_err(anyhow::Error::msg)?,
        w.config.seq_len,
        4,
        a.u64("seed").map_err(anyhow::Error::msg)?,
    );
    let plan = QuantPlan::parse_with_defaults(
        &a.str("method"),
        a.usize("bits").map_err(anyhow::Error::msg)? as u8,
        a.usize("group").map_err(anyhow::Error::msg)?,
    )
    .context("bad --method")?;
    println!("quantizing {} linears with plan {plan}…", 7 * w.config.n_layers);
    let (qm, report) = quantize_model(&w, &calib, &PipelineConfig::from_plan(plan))?;
    println!(
        "done in {} — total layer loss {:.4e} (stats {} | scales {} | gptq {} | stage2 {})",
        tsgo::util::fmt_duration(report.total_time),
        report.total_loss(),
        tsgo::util::fmt_duration(report.time_stats),
        tsgo::util::fmt_duration(report.time_scales),
        tsgo::util::fmt_duration(report.time_gptq),
        tsgo::util::fmt_duration(report.time_stage2),
    );
    for (label, n, loss) in report.method_summary() {
        println!("  {label:<20} {n:>3} linears  Σ layer loss {loss:.4e}");
    }
    let out = PathBuf::from(a.str("out"));
    store::save_quantized(&out, &qm)?;
    // Element-weighted effective bit width: a uniform average over linears
    // would let small layers skew the number under mixed-precision plans.
    let total_elems: usize = qm.linears.values().map(|q| q.rows * q.cols).sum();
    let total_bits: f64 = qm.linears.values().map(|q| q.nbytes() as f64 * 8.0).sum();
    println!(
        "saved {} ({:.2} bits/weight effective)",
        out.display(),
        total_bits / total_elems.max(1) as f64
    );
    Ok(())
}

fn load_any_model(path: &Path, quantized: bool) -> Result<ModelWeights> {
    if quantized {
        Ok(store::load_quantized(path)?.weights)
    } else {
        store::load_model(path)
    }
}

/// PPL + 0-shot report, generic over the execution representation (dense
/// f32 or packed fused-dequant) with a pluggable per-corpus PPL backend
/// (native forward vs AOT artifact) — one copy of the reporting code for
/// every eval mode.
fn run_eval_report<M: ModelExec>(
    m: &M,
    windows: usize,
    n_tasks: usize,
    ppl_fn: &mut dyn FnMut(&M, &[u8], usize) -> Result<f64>,
) -> Result<()> {
    for kind in [CorpusKind::SynthWiki, CorpusKind::SynthC4] {
        let corpus = Corpus::generate(kind, 400_000, 1);
        let (_, test) = corpus.split(0.1);
        let ppl = ppl_fn(m, test, windows)?;
        println!("ppl[{}] = {ppl:.3}", kind.label());
    }
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 400_000, 1);
    let items = build_suite(&corpus, n_tasks, 17);
    let rep = task_suite(m, &items);
    for (family, acc, n) in &rep.per_family {
        println!("0-shot {family:<8} {acc:5.1}%  (n={n})");
    }
    println!("0-shot avg = {:.2}%", rep.average);
    Ok(())
}

fn native_ppl<M: ModelExec>(m: &M, test: &[u8], windows: usize) -> Result<f64> {
    Ok(tsgo::eval::perplexity(m, test, m.config().seq_len, windows))
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "model", help: "checkpoint path", default: Some("model.tsr"), is_flag: false },
        OptSpec { name: "quantized", help: "checkpoint is quantized (dequantize at load)", default: None, is_flag: true },
        OptSpec { name: "packed", help: "execute the packed ints directly (fused dequant kernels)", default: None, is_flag: true },
        OptSpec { name: "windows", help: "eval windows per corpus", default: Some("32"), is_flag: false },
        OptSpec { name: "tasks", help: "items per 0-shot family", default: Some("25"), is_flag: false },
        OptSpec { name: "native", help: "force native forward (skip artifacts)", default: None, is_flag: true },
        OptSpec { name: "kv-bits", help: "also report decode ppl with an N-bit KV cache (0 = off)", default: Some("0"), is_flag: false },
        OptSpec { name: "kv-group", help: "KV group size (per-head groups, clamped to head_dim)", default: Some("64"), is_flag: false },
        OptSpec { name: "kv-pool-mb", help: "page the decode-ppl KV caches out of an N MB pool (0 = contiguous)", default: Some("0"), is_flag: false },
        OptSpec { name: "kv-page-tokens", help: "token rows per KV page", default: Some("16"), is_flag: false },
        OptSpec { name: "shards", help: "evaluate through a layer-sharded model (banner reports the plan; forces native forward)", default: Some("1"), is_flag: false },
    ];
    let a = parse(argv, "tsgo eval", "PPL + 0-shot evaluation", &specs)?;
    let windows = a.usize("windows").map_err(anyhow::Error::msg)?;
    let n_tasks = a.usize("tasks").map_err(anyhow::Error::msg)?;
    let shards = a.usize("shards").map_err(anyhow::Error::msg)?;
    let kv = KvSpec::from_flags(
        a.usize("kv-bits").map_err(anyhow::Error::msg)?,
        a.usize("kv-group").map_err(anyhow::Error::msg)?,
    )?;
    let pool = PoolCfg::from_flags(
        a.usize("kv-pool-mb").map_err(anyhow::Error::msg)?,
        a.usize("kv-page-tokens").map_err(anyhow::Error::msg)?,
    )?;
    if a.flag("packed") {
        let em = store::load_quantized_packed(Path::new(&a.str("model")))?;
        println!(
            "packed execution: {}/{} linears packed ({:.2} MB linear weights)",
            em.packed_linears(),
            em.total_linears(),
            em.linear_weight_bytes() as f64 / 1e6
        );
        println!("kernels: {}", em.kernel_dispatch());
        if shards > 1 {
            return run_eval_sharded(em, shards, kv, pool, windows, n_tasks);
        }
        run_eval_report(&em, windows, n_tasks, &mut native_ppl)?;
        return run_kv_ppl_report(&em, windows, kv, pool);
    }
    let w = load_any_model(Path::new(&a.str("model")), a.flag("quantized"))?;
    if shards > 1 {
        return run_eval_sharded(w, shards, kv, pool, windows, n_tasks);
    }
    let engine = if a.flag("native") { None } else { Engine::open_default() };
    match &engine {
        Some(e) if e.manifest.config == w.config => {
            run_eval_report(&w, windows, n_tasks, &mut |m, test, wnd| {
                tsgo::runtime::perplexity_artifact(e, m, test, m.config().seq_len, wnd)
            })?
        }
        _ => run_eval_report(&w, windows, n_tasks, &mut native_ppl)?,
    }
    run_kv_ppl_report(&w, windows, kv, pool)
}

/// The end-to-end accuracy accounting of KV-cache quantization: decode-path
/// ppl with the f32 cache vs the requested packed cache, and the delta. A
/// no-op when `--kv-bits` was 0/absent. With `--kv-pool-mb` the quantized
/// run pages its caches out of a bounded pool (the banner says so) — the
/// numbers must not move, only the memory ceiling does.
fn run_kv_ppl_report<M: ModelExec>(
    m: &M,
    windows: usize,
    kv: KvSpec,
    pool: Option<PoolCfg>,
) -> Result<()> {
    if !kv.is_packed() {
        return Ok(());
    }
    let cfg = m.config();
    print_kv_banner(&kv, cfg, pool.is_some());
    if let Some(pc) = pool {
        print_pool_banner(&pc, &kv, cfg);
    }
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 400_000, 1);
    let (_, test) = corpus.split(0.1);
    let base = tsgo::eval::decode_perplexity(m, test, cfg.seq_len, windows, KvSpec::DenseF32);
    let quant = match pool {
        Some(pc) => {
            tsgo::eval::decode_perplexity_pooled(m, test, cfg.seq_len, windows, kv, pc)?
        }
        None => tsgo::eval::decode_perplexity(m, test, cfg.seq_len, windows, kv),
    };
    println!(
        "decode ppl[{}]: f32-KV = {base:.3}, {}-KV = {quant:.3} ({:+.3}%)",
        CorpusKind::SynthWiki.label(),
        kv.effective(cfg).label(),
        (quant / base - 1.0) * 100.0
    );
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "model", help: "checkpoint path", default: Some("model.tsr"), is_flag: false },
        OptSpec { name: "quantized", help: "checkpoint is quantized (dequantize at load)", default: None, is_flag: true },
        OptSpec { name: "packed", help: "execute the packed ints directly (fused dequant kernels)", default: None, is_flag: true },
        OptSpec { name: "addr", help: "bind address", default: Some("127.0.0.1:7433"), is_flag: false },
        OptSpec { name: "max-batch", help: "dynamic batch cap", default: Some("8"), is_flag: false },
        OptSpec { name: "kv-bits", help: "quantize the decode KV cache to N bits (0 = f32)", default: Some("0"), is_flag: false },
        OptSpec { name: "kv-group", help: "KV group size (per-head groups, clamped to head_dim)", default: Some("64"), is_flag: false },
        OptSpec { name: "kv-pool-mb", help: "page all KV caches out of an N MB pool with budget-aware admission and preemption (0 = unbounded contiguous)", default: Some("0"), is_flag: false },
        OptSpec { name: "kv-page-tokens", help: "token rows per KV page", default: Some("16"), is_flag: false },
        OptSpec { name: "shards", help: "pipeline-parallel shard count (layers split over N worker threads; clamped to the layer count)", default: Some("1"), is_flag: false },
        OptSpec { name: "prefill-chunk", help: "prompt tokens per prefill step (1 = one-token steps; tokens identical for any value; 0 = default 64 / TSGO_PREFILL_CHUNK)", default: Some("0"), is_flag: false },
        OptSpec { name: "request-timeout", help: "total per-request deadline in ms, queue wait included; expired requests return partial tokens with timed_out=true (0 = none)", default: Some("0"), is_flag: false },
        OptSpec { name: "step-timeout", help: "per-decode-step deadline in ms before a worker is declared lost and its sequence errored (0 = default 60000)", default: Some("0"), is_flag: false },
        OptSpec { name: "conn-timeout", help: "per-connection socket read/write timeout in ms; disconnects silent/half-open clients (0 = default 120000)", default: Some("0"), is_flag: false },
        OptSpec { name: "temperature", help: "default sampling temperature (0 = greedy, bit-identical to the pre-sampler path; >0 = seeded multinomial)", default: Some("0"), is_flag: false },
        OptSpec { name: "top-k", help: "default top-k truncation before sampling (0 = off)", default: Some("0"), is_flag: false },
        OptSpec { name: "top-p", help: "default nucleus (top-p) truncation before sampling (1.0 = off)", default: Some("1.0"), is_flag: false },
        OptSpec { name: "repetition-penalty", help: "default repetition penalty over prompt+output tokens (1.0 = off)", default: Some("1.0"), is_flag: false },
        OptSpec { name: "seed", help: "default sampling seed (per-request \"seed\" overrides; same seed replays token-identically)", default: Some("0"), is_flag: false },
        OptSpec { name: "stop", help: "default stop strings, comma-separated; generation ends when the decoded tail matches one (per-request \"stop\" overrides)", default: Some(""), is_flag: false },
        OptSpec { name: "metrics-addr", help: "serve Prometheus text metrics on HOST:PORT via a dedicated listener thread (empty = off; the {\"stats\": true} control line works either way)", default: Some(""), is_flag: false },
    ];
    let a = parse(argv, "tsgo serve", "batched generation server", &specs)?;
    let kv = KvSpec::from_flags(
        a.usize("kv-bits").map_err(anyhow::Error::msg)?,
        a.usize("kv-group").map_err(anyhow::Error::msg)?,
    )?;
    let pool = PoolCfg::from_flags(
        a.usize("kv-pool-mb").map_err(anyhow::Error::msg)?,
        a.usize("kv-page-tokens").map_err(anyhow::Error::msg)?,
    )?;
    let shards = a.usize("shards").map_err(anyhow::Error::msg)?;
    let prefill_chunk = match a.usize("prefill-chunk").map_err(anyhow::Error::msg)? {
        0 => tsgo::serve::default_prefill_chunk(),
        c => c,
    };
    let request_timeout = match a.usize("request-timeout").map_err(anyhow::Error::msg)? {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms as u64)),
    };
    let step_timeout = match a.usize("step-timeout").map_err(anyhow::Error::msg)? {
        0 => tsgo::serve::BatcherConfig::default().step_timeout,
        ms => std::time::Duration::from_millis(ms as u64),
    };
    let conn_timeout = match a.usize("conn-timeout").map_err(anyhow::Error::msg)? {
        0 => tsgo::serve::ServerConfig::default().conn_timeout,
        ms => Some(std::time::Duration::from_millis(ms as u64)),
    };
    let default_sampling = tsgo::serve::SamplingParams {
        temperature: a.f64("temperature").map_err(anyhow::Error::msg)? as f32,
        top_k: a.usize("top-k").map_err(anyhow::Error::msg)?,
        top_p: a.f64("top-p").map_err(anyhow::Error::msg)? as f32,
        repetition_penalty: a.f64("repetition-penalty").map_err(anyhow::Error::msg)? as f32,
        seed: a.u64("seed").map_err(anyhow::Error::msg)?,
    };
    default_sampling.validate().map_err(anyhow::Error::msg)?;
    let default_stop: Vec<Vec<u8>> = a
        .str("stop")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.as_bytes().to_vec())
        .collect();
    // Validate --metrics-addr at the door: a typo'd address should fail
    // here with a clean message, not after the model is loaded and the
    // worker threads are up.
    let metrics_addr = match a.str("metrics-addr") {
        s if s.is_empty() => None,
        s => {
            use std::net::ToSocketAddrs;
            s.to_socket_addrs()
                .with_context(|| format!("bad --metrics-addr '{s}' (expected HOST:PORT)"))?;
            Some(s)
        }
    };
    let cfg = tsgo::serve::ServerConfig {
        addr: a.str("addr"),
        batcher: tsgo::serve::BatcherConfig {
            max_batch: a.usize("max-batch").map_err(anyhow::Error::msg)?,
            kv,
            shards,
            pool,
            prefill_chunk,
            request_timeout,
            step_timeout,
            default_sampling,
            ..Default::default()
        },
        max_connections: None,
        conn_timeout,
        default_stop,
        metrics_addr,
    };
    println!(
        "prefill: chunked, {prefill_chunk} tokens/step (--prefill-chunk; \
         1 reproduces one-token prefill, tokens identical either way)"
    );
    println!(
        "fault tolerance: step deadline {}, request deadline {}, conn timeout {} \
         (workers respawn after panics, shard chains rebuild after deaths; \
         TSGO_FAULT injects deterministic faults — see util::fault)",
        tsgo::util::fmt_duration(step_timeout),
        request_timeout.map_or("none".to_string(), tsgo::util::fmt_duration),
        conn_timeout.map_or("none".to_string(), tsgo::util::fmt_duration),
    );
    if default_sampling.is_greedy() {
        println!(
            "sampling: greedy default (bit-identical to argmax decode); per-request \
             temperature/top_k/top_p/repetition_penalty/seed/stop/stream override \
             (docs/SERVE_API.md)"
        );
    } else {
        println!(
            "sampling: default temperature {} top_k {} top_p {} repetition_penalty {} \
             seed {} ({} stop seqs); seeded multinomial replays deterministically",
            default_sampling.temperature,
            default_sampling.top_k,
            default_sampling.top_p,
            default_sampling.repetition_penalty,
            default_sampling.seed,
            cfg.default_stop.len(),
        );
    }
    if a.flag("packed") {
        let em = store::load_quantized_packed(Path::new(&a.str("model")))?;
        println!(
            "packed execution: {}/{} linears packed ({:.2} MB linear weights vs {:.2} MB dense)",
            em.packed_linears(),
            em.total_linears(),
            em.linear_weight_bytes() as f64 / 1e6,
            em.dense_linear_bytes() as f64 / 1e6
        );
        println!("kernels: {}", em.kernel_dispatch());
        print_kv_banner(&kv, em.config(), pool.is_some());
        if let Some(pc) = pool {
            print_pool_banner(&pc, &kv, em.config());
        }
        if shards > 1 {
            return serve_sharded(Arc::new(em), shards, kv, cfg);
        }
        return tsgo::serve::serve(Arc::new(em), cfg);
    }
    let w = Arc::new(load_any_model(Path::new(&a.str("model")), a.flag("quantized"))?);
    print_kv_banner(&kv, w.config(), pool.is_some());
    if let Some(pc) = pool {
        print_pool_banner(&pc, &kv, w.config());
    }
    if shards > 1 {
        return serve_sharded(w, shards, kv, cfg);
    }
    tsgo::serve::serve(w, cfg)
}

/// The `--shards N` eval path, shared by the packed and dense branches:
/// wrap, print the plan banner, run the native-forward report. (The AOT
/// artifact engine runs whole-model graphs, so sharded eval is always
/// native.)
fn run_eval_sharded<M: ModelExec>(
    m: M,
    shards: usize,
    kv: KvSpec,
    pool: Option<PoolCfg>,
    windows: usize,
    n_tasks: usize,
) -> Result<()> {
    let sm = ShardedModel::new(Arc::new(m), shards);
    print_shard_banner(&sm, &kv);
    run_eval_report(&sm, windows, n_tasks, &mut native_ppl)?;
    run_kv_ppl_report(&sm, windows, kv, pool)
}

/// The `--shards N` serve path, shared by the packed and dense branches:
/// print the plan banner, then serve the *inner* model — the batcher
/// shards it itself (`cfg.batcher.shards`) through the same
/// `ShardedModel::new` recipe the banner used, so wrapping here too would
/// only nest a second delegation layer onto the decode hot path.
fn serve_sharded<M: ModelExec + Send + Sync + 'static>(
    m: Arc<M>,
    shards: usize,
    kv: KvSpec,
    cfg: tsgo::serve::ServerConfig,
) -> Result<()> {
    let sm = ShardedModel::new(m.clone(), shards);
    print_shard_banner(&sm, &kv);
    tsgo::serve::serve(m, cfg)
}

/// The `--shards` banner: the plan's per-shard layer ranges, weight bytes
/// and KV bytes/token — what a deployment log needs to spot the pipeline
/// bottleneck shard (the batcher derives the identical plan internally).
fn print_shard_banner<M: ModelExec>(sm: &ShardedModel<M>, kv: &KvSpec) {
    for line in sm.banner_lines(*kv) {
        println!("{line}");
    }
}

/// One banner line describing the decode KV-cache representation, with the
/// per-token byte accounting that motivates quantizing it. `paged` marks
/// the cache as pool-backed (`--kv-pool-mb`) — same bytes, bounded ceiling.
fn print_kv_banner(kv: &KvSpec, cfg: &tsgo::model::ModelConfig, paged: bool) {
    let dense = KvSpec::DenseF32.bytes_per_token(cfg) * cfg.n_layers;
    let tag = if paged { ", paged" } else { "" };
    // Label the *effective* spec: a requested group wider than head_dim is
    // stored clamped, and the banner must describe what actually runs.
    match kv.effective(cfg) {
        KvSpec::DenseF32 => {
            println!("kv cache: f32{tag} ({dense} B/token across {} layers)", cfg.n_layers)
        }
        spec => {
            let b = spec.bytes_per_token(cfg) * cfg.n_layers;
            println!(
                "kv cache: {}{tag} ({} B/token across {} layers vs {} f32, {:.1}x smaller)",
                spec.label(),
                b,
                cfg.n_layers,
                dense,
                dense as f64 / b as f64
            );
        }
    }
}

/// The `--kv-pool-mb` banner: page geometry and pool capacity, plus the
/// policy one line of log should remind an operator of. Occupancy and
/// preemption counts surface at runtime (scheduler pressure lines, and
/// `kv_pages_used` / `preemptions` on every response).
fn print_pool_banner(pc: &PoolCfg, kv: &KvSpec, cfg: &tsgo::model::ModelConfig) {
    let probe = KvPool::new(*pc, *kv, cfg);
    println!(
        "kv pool: {:.1} MB budget = {} pages x {} tokens ({} B/page); \
         admission by free pages, youngest-first preemption with re-prefill",
        pc.budget_bytes as f64 / (1 << 20) as f64,
        probe.total_pages(),
        probe.page_tokens(),
        probe.page_bytes(),
    );
}

/// `tsgo stats HOST:PORT`-style client for the telemetry plane: send the
/// `{"stats": true}` control line, pretty-print the snapshot. Works against
/// any serving mode (the registry is process-wide); the raw JSON is the
/// same object a monitoring script would read.
fn cmd_stats(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "addr", help: "running server's serve address", default: Some("127.0.0.1:7433"), is_flag: false },
        OptSpec { name: "json", help: "print the raw snapshot JSON line instead of the table", default: None, is_flag: true },
    ];
    let a = parse(argv, "tsgo stats", "fetch a running server's telemetry snapshot", &specs)?;
    let addr = a.str("addr");
    let snap = tsgo::serve::request_stats(&addr)?;
    if a.flag("json") {
        println!("{snap}");
        return Ok(());
    }
    println!("telemetry snapshot from {addr}");
    for section in ["counters", "gauges"] {
        let Some(obj) = snap.get(section).as_obj() else { continue };
        println!("{section}:");
        for (k, v) in obj {
            println!("  {k:<24} {v}");
        }
    }
    if let Some(hists) = snap.get("hist").as_obj() {
        println!("latency histograms (ms):");
        println!(
            "  {:<24} {:>8} {:>9} {:>9} {:>9} {:>9}",
            "name", "count", "mean", "p50", "p95", "p99"
        );
        for (k, h) in hists {
            println!(
                "  {k:<24} {:>8} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
                h.get("count").as_usize().unwrap_or(0),
                h.get("mean_ms").as_f64().unwrap_or(0.0),
                h.get("p50_ms").as_f64().unwrap_or(0.0),
                h.get("p95_ms").as_f64().unwrap_or(0.0),
                h.get("p99_ms").as_f64().unwrap_or(0.0),
            );
        }
    }
    if let Some(trace) = snap.get("trace").as_arr() {
        if !trace.is_empty() {
            println!("recent steps (newest first):");
        }
        for ev in trace {
            println!(
                "  #{:<6} {:<8} batch {:<3} prefill {:<4} decode {:<3} {:>8} us  preempted {} restarts {}",
                ev.get("seq").as_usize().unwrap_or(0),
                ev.get("source").as_str().unwrap_or("?"),
                ev.get("batch").as_usize().unwrap_or(0),
                ev.get("prefill_tokens").as_usize().unwrap_or(0),
                ev.get("decode_tokens").as_usize().unwrap_or(0),
                ev.get("dur_us").as_usize().unwrap_or(0),
                ev.get("preempted").as_usize().unwrap_or(0),
                ev.get("restarts").as_usize().unwrap_or(0),
            );
        }
    }
    Ok(())
}

fn cmd_kernels() -> Result<()> {
    let info = tsgo::tensor::kernels::dispatch_info();
    println!("dequant kernel dispatch");
    println!("  arch: {}", std::env::consts::ARCH);
    for (feat, have) in &info.cpu_features {
        println!("  cpu {feat}: {}", if *have { "yes" } else { "no" });
    }
    println!("  threads: {}", tsgo::util::threadpool::num_threads());
    println!(
        "  best table: {} | active: {}{}",
        info.best,
        info.active,
        if info.forced_scalar { " (TSGO_FORCE_SCALAR / forced)" } else { "" }
    );
    println!(
        "  {:<6} {:<16} {:<16} {:<16}",
        "bits", "scalar dot", "active dot", "active kv-axpy"
    );
    for (bits, scalar, active, axpy) in &info.rows {
        println!("  {:<6} {:<16} {:<16} {:<16}", bits, scalar, active, axpy);
    }
    println!("\nforce the portable path with TSGO_FORCE_SCALAR=1 (bit-identical\nto the SIMD kernels by construction; see ROADMAP.md).");
    Ok(())
}

fn cmd_warmup() -> Result<()> {
    let engine = Engine::open_default().context("no artifacts — run `make artifacts`")?;
    let t0 = std::time::Instant::now();
    let loaded = engine.warmup()?;
    println!(
        "compiled {} artifacts in {}: {}",
        loaded.len(),
        tsgo::util::fmt_duration(t0.elapsed()),
        loaded.join(", ")
    );
    Ok(())
}

fn parse(argv: &[String], cmd: &str, about: &str, specs: &[OptSpec]) -> Result<Args> {
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        println!("{}", usage(cmd, about, specs));
        std::process::exit(0);
    }
    Args::parse(argv, specs).map_err(anyhow::Error::msg)
}
