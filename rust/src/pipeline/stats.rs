//! Streaming Hessian / deviation-correlation estimation.
//!
//! `H = E[X Xᵀ]` (Eq. 1) and `R = E[ΔX Xᵀ]` (Eq. 7) are accumulated over
//! calibration batches in f64 (activations are f32 and token counts reach
//! 10⁵; f32 accumulation visibly biases the Cholesky). X is presented as
//! `[T, in]` capture matrices straight from the forward pass.

use crate::tensor::Matrix;
use crate::util::threadpool::parallel_for_auto;

/// f64-accumulating symmetric second-moment estimator.
#[derive(Clone, Debug)]
pub struct MomentAccum {
    pub dim: usize,
    /// Row-major `[dim, dim]` running sum (not yet normalized).
    acc: Vec<f64>,
    /// Total samples (tokens) seen.
    pub count: usize,
}

impl MomentAccum {
    pub fn new(dim: usize) -> MomentAccum {
        MomentAccum { dim, acc: vec![0.0; dim * dim], count: 0 }
    }

    /// Add a batch of activations `x: [T, dim]`, accumulating `Σ_t x_t x_tᵀ`.
    pub fn add(&mut self, x: &Matrix) {
        assert_eq!(x.cols, self.dim, "activation dim mismatch");
        let dim = self.dim;
        let acc_ptr = crate::util::SendPtr(self.acc.as_mut_ptr());
        // Parallel over output rows i: acc[i][j] += Σ_t x[t][i]·x[t][j].
        parallel_for_auto(dim, |i| {
            // SAFETY: each worker owns disjoint rows of the accumulator.
            let row: &mut [f64] =
                unsafe { std::slice::from_raw_parts_mut(acc_ptr.get().add(i * dim), dim) };
            for t in 0..x.rows {
                let xrow = x.row(t);
                let xi = xrow[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                for (r, xj) in row.iter_mut().zip(xrow) {
                    *r += xi * *xj as f64;
                }
            }
        });
        self.count += x.rows;
    }

    /// Add a cross-moment batch: `Σ_t a_t b_tᵀ` (for `R = E[ΔX Xᵀ]`,
    /// pass `a = ΔX` rows, `b = X` rows).
    pub fn add_cross(&mut self, a: &Matrix, b: &Matrix) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        assert_eq!(a.cols, self.dim);
        let dim = self.dim;
        let acc_ptr = crate::util::SendPtr(self.acc.as_mut_ptr());
        parallel_for_auto(dim, |i| {
            let row: &mut [f64] =
                unsafe { std::slice::from_raw_parts_mut(acc_ptr.get().add(i * dim), dim) };
            for t in 0..a.rows {
                let ai = a.row(t)[i] as f64;
                if ai == 0.0 {
                    continue;
                }
                for (r, bj) in row.iter_mut().zip(b.row(t)) {
                    *r += ai * *bj as f64;
                }
            }
        });
        self.count += a.rows;
    }

    /// The normalized moment `Σ / count` as f32.
    pub fn finalize(&self) -> Matrix {
        let n = self.count.max(1) as f64;
        Matrix::from_vec(
            self.dim,
            self.dim,
            self.acc.iter().map(|v| (v / n) as f32).collect(),
        )
    }
}

/// All statistics needed to quantize one linear layer.
#[derive(Clone, Debug)]
pub struct LinearStats {
    pub hessian: MomentAccum,
    /// `R = E[ΔX Xᵀ]`; None for the first block when error-aware refinement
    /// is disabled or there is no upstream error yet.
    pub deviation: Option<MomentAccum>,
}

impl LinearStats {
    pub fn new(dim: usize, with_deviation: bool) -> LinearStats {
        LinearStats {
            hessian: MomentAccum::new(dim),
            deviation: with_deviation.then(|| MomentAccum::new(dim)),
        }
    }

    /// Feed one batch: `x_q` is the capture under the quantized prefix,
    /// `x_fp` under the FP model (same tokens).
    pub fn add_batch(&mut self, x_q: &Matrix, x_fp: Option<&Matrix>) {
        self.hessian.add(x_q);
        if let (Some(dev), Some(fp)) = (&mut self.deviation, x_fp) {
            let dx = x_q.sub(fp);
            dev.add_cross(&dx, x_q);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn hessian_matches_direct_computation() {
        let mut rng = Rng::new(1);
        let x = Matrix::randn(50, 8, 1.0, &mut rng);
        let mut acc = MomentAccum::new(8);
        acc.add(&x);
        let h = acc.finalize();
        // direct: Xᵀ X / T with X [T, dim]
        let direct = {
            let xt = x.transpose();
            let mut m = xt.matmul(&x);
            m.scale_inplace(1.0 / 50.0);
            m
        };
        assert!(h.max_abs_diff(&direct) < 1e-4);
    }

    #[test]
    fn batching_is_associative() {
        let mut rng = Rng::new(2);
        let x1 = Matrix::randn(30, 6, 1.0, &mut rng);
        let x2 = Matrix::randn(20, 6, 1.0, &mut rng);
        let mut a = MomentAccum::new(6);
        a.add(&x1);
        a.add(&x2);
        let mut joint = Matrix::zeros(50, 6);
        joint.set_slice(0, 0, &x1);
        joint.set_slice(30, 0, &x2);
        let mut b = MomentAccum::new(6);
        b.add(&joint);
        assert!(a.finalize().max_abs_diff(&b.finalize()) < 1e-5);
    }

    #[test]
    fn hessian_is_symmetric_psd() {
        let mut rng = Rng::new(3);
        let x = Matrix::randn(100, 12, 1.0, &mut rng);
        let mut acc = MomentAccum::new(12);
        acc.add(&x);
        let h = acc.finalize();
        for i in 0..12 {
            assert!(h[(i, i)] >= 0.0);
            for j in 0..12 {
                assert!((h[(i, j)] - h[(j, i)]).abs() < 1e-5);
            }
        }
        // PSD via Cholesky after small damping
        let mut hd = h.clone();
        for i in 0..12 {
            hd[(i, i)] += 1e-3;
        }
        assert!(crate::tensor::cholesky_lower(&hd).is_ok());
    }

    #[test]
    fn cross_moment_matches_direct() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(40, 5, 1.0, &mut rng);
        let b = Matrix::randn(40, 5, 1.0, &mut rng);
        let mut acc = MomentAccum::new(5);
        acc.add_cross(&a, &b);
        let direct = {
            let at = a.transpose();
            let mut m = at.matmul(&b);
            m.scale_inplace(1.0 / 40.0);
            m
        };
        assert!(acc.finalize().max_abs_diff(&direct) < 1e-4);
    }

    #[test]
    fn linear_stats_deviation_zero_when_inputs_equal() {
        let mut rng = Rng::new(5);
        let x = Matrix::randn(25, 4, 1.0, &mut rng);
        let mut st = LinearStats::new(4, true);
        st.add_batch(&x, Some(&x));
        let r = st.deviation.unwrap().finalize();
        assert!(r.frob2() < 1e-12);
    }
}
