//! Whole-model quantization orchestration.

use super::stats::LinearStats;
use crate::calib::Batch;
use crate::model::store::QuantizedModel;
use crate::model::{LinearKind, ModelWeights};
use crate::quant::{LayerQuantizer, QuantContext, QuantPlan, QuantSpec};
use crate::tensor::Matrix;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Pipeline-level configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Which quantizer + spec handles each `(layer, kind)`.
    pub plan: QuantPlan,
    /// Shared algorithm tunables (GPTQ damping/block size, stage-2 sweeps).
    pub ctx: QuantContext,
    /// Use the error-aware update (Eq. 9) for blocks after the first.
    pub error_aware: bool,
    /// Quantize the block's 7 projections concurrently.
    pub parallel_projections: bool,
}

impl PipelineConfig {
    /// Uniform run: every linear through the named quantizer at `spec`.
    pub fn new(spec: QuantSpec, quantizer: &str) -> PipelineConfig {
        PipelineConfig::from_plan(QuantPlan::uniform(quantizer, spec))
    }

    pub fn from_plan(plan: QuantPlan) -> PipelineConfig {
        PipelineConfig {
            plan,
            ctx: QuantContext::default(),
            error_aware: true,
            parallel_projections: true,
        }
    }
}

fn empty_caps() -> crate::model::forward::LayerCaptures {
    use crate::tensor::Matrix;
    crate::model::forward::LayerCaptures {
        x_attn: Matrix::zeros(0, 0),
        x_wo: Matrix::zeros(0, 0),
        x_mlp: Matrix::zeros(0, 0),
        x_w2: Matrix::zeros(0, 0),
    }
}

/// Per-linear outcome recorded for reports/benches: which quantizer + spec
/// handled the linear, and the losses it achieved.
#[derive(Clone, Debug)]
pub struct LinearReport {
    pub layer: usize,
    pub kind: LinearKind,
    /// Registered name of the quantizer that produced this linear.
    pub quantizer: &'static str,
    pub bits: u8,
    pub group_size: usize,
    pub layer_loss: f64,
    pub loss_before_stage2: f64,
}

/// Whole-run outcome.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub linears: Vec<LinearReport>,
    pub total_time: Duration,
    pub time_stats: Duration,
    pub time_scales: Duration,
    pub time_gptq: Duration,
    pub time_stage2: Duration,
}

impl PipelineReport {
    /// Sum of final layer losses — the scalar the paper's method minimizes.
    pub fn total_loss(&self) -> f64 {
        self.linears.iter().map(|l| l.layer_loss).sum()
    }

    /// Roll-up per `(quantizer, bits, group)` cell, in first-seen order:
    /// `(label, n linears, Σ layer loss)` — the per-rule summary the CLI
    /// prints and benches use for per-method columns.
    pub fn method_summary(&self) -> Vec<(String, usize, f64)> {
        let mut out: Vec<(String, usize, f64)> = Vec::new();
        for l in &self.linears {
            let label = format!("{} INT{} g{}", l.quantizer, l.bits, l.group_size);
            match out.iter_mut().find(|(s, _, _)| *s == label) {
                Some(e) => {
                    e.1 += 1;
                    e.2 += l.layer_loss;
                }
                None => out.push((label, 1, l.layer_loss)),
            }
        }
        out
    }
}

/// Quantize every linear in the model, sequentially over blocks, routing
/// each `(layer, kind)` through the quantizer + spec its [`QuantPlan`] rule
/// selects.
///
/// `calib` supplies token batches; captures are taken with the native
/// forward (identical math to the AOT'd JAX model — asserted by the
/// runtime equivalence tests).
pub fn quantize_model(
    fp: &ModelWeights,
    calib: &[Batch],
    cfg: &PipelineConfig,
) -> Result<(QuantizedModel, PipelineReport)> {
    use crate::model::forward::{block_forward, embed_tokens, LayerCaptures};

    let t_start = Instant::now();
    let n_layers = fp.config.n_layers;
    let n_heads = fp.config.n_heads;
    cfg.plan.validate()?;
    // Resolve the full assignment table up front so plan errors surface
    // before any work, and `with_dev` reflects what will actually run.
    let assignments: Vec<Vec<(Arc<dyn LayerQuantizer>, QuantSpec)>> = (0..n_layers)
        .map(|li| {
            LinearKind::ALL
                .iter()
                .map(|&k| cfg.plan.resolve(li, k))
                .collect::<Result<Vec<_>>>()
        })
        .collect::<Result<Vec<_>>>()?;

    let mut prefix = fp.clone(); // quantized-prefix model, updated in place
    let mut linears: BTreeMap<(usize, &'static str), crate::quant::QuantizedLinear> =
        BTreeMap::new();
    let mut quantizers: BTreeMap<(usize, &'static str), String> = BTreeMap::new();
    let mut reports = Vec::new();
    let mut time_stats = Duration::ZERO;
    let mut time_scales = Duration::ZERO;
    let mut time_gptq = Duration::ZERO;
    let mut time_stage2 = Duration::ZERO;

    let with_dev =
        cfg.error_aware && assignments.iter().flatten().any(|(q, _)| q.wants_deviation());

    // Running hidden states per calibration sequence: `h_q` flows through
    // the quantized prefix, `h_fp` through the FP model. Advancing them one
    // block per pipeline step makes the whole-run capture cost O(L) blocks
    // per sequence instead of O(L²) full forwards (§Perf L3 #4).
    let t_init = Instant::now();
    let seqs: Vec<&[u8]> =
        calib.iter().flat_map(|b| (0..b.batch).map(move |i| b.seq(i))).collect();
    let mut h_q: Vec<Matrix> =
        crate::util::threadpool::parallel_map_items(&seqs, |tokens| embed_tokens(fp, tokens));
    let mut h_fp: Vec<Matrix> = if with_dev { h_q.clone() } else { Vec::new() };
    time_stats += t_init.elapsed();

    for layer in 0..n_layers {
        // -- 1+2. capture + accumulate statistics for this block ------------
        let t0 = Instant::now();
        let d = fp.config.d_model;
        let ffn = fp.config.ffn;
        let mut st_attn = LinearStats::new(d, with_dev);
        let mut st_wo = LinearStats::new(d, with_dev);
        let mut st_mlp = LinearStats::new(d, with_dev);
        let mut st_w2 = LinearStats::new(ffn, with_dev);

        // Captures for every sequence, in parallel. The block itself still
        // uses the *FP weights of this layer* (they are quantized below),
        // fed with the quantized-prefix hidden state — standard GPTQ.
        let caps: Vec<(LayerCaptures, Option<LayerCaptures>)> =
            crate::util::threadpool::parallel_map(seqs.len(), |i| {
                let mut cq = empty_caps();
                block_forward(&prefix.layers[layer], &h_q[i], n_heads, Some(&mut cq));
                let cf = with_dev.then(|| {
                    let mut c = empty_caps();
                    block_forward(&fp.layers[layer], &h_fp[i], n_heads, Some(&mut c));
                    c
                });
                (cq, cf)
            });
        for (cq, cf) in &caps {
            st_attn.add_batch(&cq.x_attn, cf.as_ref().map(|c| &c.x_attn));
            st_wo.add_batch(&cq.x_wo, cf.as_ref().map(|c| &c.x_wo));
            st_mlp.add_batch(&cq.x_mlp, cf.as_ref().map(|c| &c.x_mlp));
            st_w2.add_batch(&cq.x_w2, cf.as_ref().map(|c| &c.x_w2));
        }
        time_stats += t0.elapsed();

        let finalize = |st: &LinearStats| -> (Matrix, Option<Matrix>) {
            (st.hessian.finalize(), st.deviation.as_ref().map(|d| d.finalize()))
        };
        let (h_attn, r_attn) = finalize(&st_attn);
        let (h_wo, r_wo) = finalize(&st_wo);
        let (h_mlp, r_mlp) = finalize(&st_mlp);
        let (h_w2, r_w2) = finalize(&st_w2);

        // -- 3. quantize the seven projections ------------------------------
        // The first block sees FP inputs exactly (R = 0 → Eq. 5).
        let use_r = layer > 0;
        let jobs: Vec<(
            LinearKind,
            &Matrix,
            &Matrix,
            Option<&Matrix>,
            Arc<dyn LayerQuantizer>,
            QuantSpec,
        )> = LinearKind::ALL
            .iter()
            .enumerate()
            .map(|(i, &kind)| {
                let (w, h, r): (&Matrix, &Matrix, Option<&Matrix>) = match kind {
                    LinearKind::Wq => (&prefix.layers[layer].wq, &h_attn, r_attn.as_ref()),
                    LinearKind::Wk => (&prefix.layers[layer].wk, &h_attn, r_attn.as_ref()),
                    LinearKind::Wv => (&prefix.layers[layer].wv, &h_attn, r_attn.as_ref()),
                    LinearKind::Wo => (&prefix.layers[layer].wo, &h_wo, r_wo.as_ref()),
                    LinearKind::W1 => (&prefix.layers[layer].w1, &h_mlp, r_mlp.as_ref()),
                    LinearKind::W3 => (&prefix.layers[layer].w3, &h_mlp, r_mlp.as_ref()),
                    LinearKind::W2 => (&prefix.layers[layer].w2, &h_w2, r_w2.as_ref()),
                };
                let (q, spec) = &assignments[layer][i];
                (kind, w, h, r, q.clone(), *spec)
            })
            .collect();

        let run_job = |(kind, w, h, r, q, spec): &(
            LinearKind,
            &Matrix,
            &Matrix,
            Option<&Matrix>,
            Arc<dyn LayerQuantizer>,
            QuantSpec,
        )| {
            let r_eff = if use_r { *r } else { None };
            q.quantize(w, h, r_eff, spec, &cfg.ctx)
                .map(|res| (*kind, q.name(), *spec, res))
        };
        let results: Vec<_> = if cfg.parallel_projections {
            crate::util::threadpool::parallel_map_items(&jobs, run_job)
        } else {
            jobs.iter().map(run_job).collect()
        };

        for res in results {
            let (kind, qname, spec, r) = res?;
            time_scales += r.time_scales;
            time_gptq += r.time_gptq;
            time_stage2 += r.time_stage2;
            reports.push(LinearReport {
                layer,
                kind,
                quantizer: qname,
                bits: spec.bits,
                group_size: spec.group_size,
                layer_loss: r.layer_loss,
                loss_before_stage2: r.loss_before_stage2,
            });
            // -- 4. splice dequantized weights into the prefix model --------
            *prefix.layers[layer].linear_mut(kind) = r.quantized.dequantize();
            quantizers.insert((layer, kind.label()), qname.to_string());
            linears.insert((layer, kind.label()), r.quantized);
        }

        // -- 5. advance the running hidden states past this (now quantized)
        //       block so the next layer sees real upstream error.
        let t1 = Instant::now();
        h_q = crate::util::threadpool::parallel_map(seqs.len(), |i| {
            block_forward(&prefix.layers[layer], &h_q[i], n_heads, None)
        });
        if with_dev {
            h_fp = crate::util::threadpool::parallel_map(seqs.len(), |i| {
                block_forward(&fp.layers[layer], &h_fp[i], n_heads, None)
            });
        }
        time_stats += t1.elapsed();
    }

    let report = PipelineReport {
        linears: reports,
        total_time: t_start.elapsed(),
        time_stats,
        time_scales,
        time_gptq,
        time_stage2,
    };
    Ok((
        QuantizedModel { config: fp.config, weights: prefix, linears, quantizers },
        report,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::{calibration_batches, Corpus, CorpusKind};
    use crate::model::Preset;
    use crate::util::rng::Rng;

    fn setup() -> (ModelWeights, Vec<Batch>) {
        let cfg = Preset::Tiny.config();
        let mut rng = Rng::new(42);
        let w = ModelWeights::init(cfg, &mut rng);
        let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 1);
        let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
        (w, calib)
    }

    #[test]
    fn pipeline_quantizes_all_linears() {
        let (w, calib) = setup();
        let cfg = PipelineConfig::new(QuantSpec::new(3, 32), "gptq");
        let (qm, report) = quantize_model(&w, &calib, &cfg).unwrap();
        assert_eq!(qm.linears.len(), 7 * w.config.n_layers);
        assert_eq!(report.linears.len(), 7 * w.config.n_layers);
        assert!(report.linears.iter().all(|l| l.quantizer == "gptq" && l.bits == 3));
        assert!(report.total_loss().is_finite());
        // spliced weights differ from FP but are close at 3 bits
        for li in 0..w.config.n_layers {
            for kind in LinearKind::ALL {
                let a = w.layers[li].linear(kind);
                let b = qm.weights.layers[li].linear(kind);
                assert!(a.max_abs_diff(b) > 0.0, "layer {li} {kind:?} unchanged");
            }
        }
    }

    #[test]
    fn ours_beats_gptq_on_total_loss() {
        let (w, calib) = setup();
        let spec = QuantSpec::new(2, 32);
        let (_, rep_gptq) =
            quantize_model(&w, &calib, &PipelineConfig::new(spec, "gptq")).unwrap();
        let (_, rep_ours) =
            quantize_model(&w, &calib, &PipelineConfig::new(spec, "ours")).unwrap();
        assert!(
            rep_ours.total_loss() < rep_gptq.total_loss(),
            "ours {} should beat gptq {}",
            rep_ours.total_loss(),
            rep_gptq.total_loss()
        );
    }

    #[test]
    fn parallel_and_serial_agree() {
        let (w, calib) = setup();
        let spec = QuantSpec::new(2, 32);
        let mut cfg = PipelineConfig::new(spec, "ours");
        cfg.parallel_projections = true;
        let (qa, _) = quantize_model(&w, &calib, &cfg).unwrap();
        cfg.parallel_projections = false;
        let (qb, _) = quantize_model(&w, &calib, &cfg).unwrap();
        for (k, a) in &qa.linears {
            let b = &qb.linears[k];
            assert!(a.scales.max_abs_diff(&b.scales) < 1e-6, "{k:?}");
        }
    }

    #[test]
    fn plan_routes_quantizer_and_bits_per_linear() {
        let (w, calib) = setup();
        let plan = QuantPlan::parse_with_defaults("gptq:bits=4,group=32;wv,wo=bits2;l0=rtn", 4, 32)
            .unwrap();
        let (qm, report) =
            quantize_model(&w, &calib, &PipelineConfig::from_plan(plan)).unwrap();
        for ((layer, kind), q) in &qm.linears {
            let want_bits = if *kind == "wv" || *kind == "wo" { 2 } else { 4 };
            assert_eq!(q.bits, want_bits, "layer {layer} {kind}");
            let want_q = if *layer == 0 { "rtn" } else { "gptq" };
            assert_eq!(qm.quantizers[&(*layer, *kind)], want_q, "layer {layer} {kind}");
        }
        // report carries the same routing, and the rollup sees every cell
        assert!(report
            .linears
            .iter()
            .all(|l| (l.quantizer == "rtn") == (l.layer == 0)));
        let summary = report.method_summary();
        assert!(summary.len() >= 3, "expected ≥3 method cells, got {summary:?}");
        let n: usize = summary.iter().map(|(_, c, _)| c).sum();
        assert_eq!(n, 7 * w.config.n_layers);
    }

    #[test]
    fn bad_plan_fails_before_any_work() {
        let (w, calib) = setup();
        let mut plan = QuantPlan::uniform("ours", QuantSpec::new(2, 32));
        plan.quantizer = "bogus".into();
        let err = quantize_model(&w, &calib, &PipelineConfig::from_plan(plan))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown quantizer"), "{err}");
    }
}
