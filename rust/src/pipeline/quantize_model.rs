//! Whole-model quantization orchestration.

use super::stats::LinearStats;
use crate::calib::Batch;
use crate::model::store::QuantizedModel;
use crate::model::{LinearKind, ModelWeights};
use crate::quant::stage2::Stage2Config;
use crate::quant::{quantize_layer, GptqConfig, MethodConfig, QuantSpec};
use crate::tensor::Matrix;
use anyhow::Result;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Pipeline-level configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub spec: QuantSpec,
    pub method: MethodConfig,
    pub gptq: GptqConfig,
    pub stage2: Stage2Config,
    /// Use the error-aware update (Eq. 9) for blocks after the first.
    pub error_aware: bool,
    /// Quantize the block's 7 projections concurrently.
    pub parallel_projections: bool,
}

impl PipelineConfig {
    pub fn new(spec: QuantSpec, method: MethodConfig) -> PipelineConfig {
        PipelineConfig {
            spec,
            method,
            gptq: GptqConfig::default(),
            stage2: Stage2Config::default(),
            error_aware: true,
            parallel_projections: true,
        }
    }
}

fn empty_caps() -> crate::model::forward::LayerCaptures {
    use crate::tensor::Matrix;
    crate::model::forward::LayerCaptures {
        x_attn: Matrix::zeros(0, 0),
        x_wo: Matrix::zeros(0, 0),
        x_mlp: Matrix::zeros(0, 0),
        x_w2: Matrix::zeros(0, 0),
    }
}

/// Per-linear outcome recorded for reports/benches.
#[derive(Clone, Debug)]
pub struct LinearReport {
    pub layer: usize,
    pub kind: LinearKind,
    pub layer_loss: f64,
    pub loss_before_stage2: f64,
}

/// Whole-run outcome.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub linears: Vec<LinearReport>,
    pub total_time: Duration,
    pub time_stats: Duration,
    pub time_scales: Duration,
    pub time_gptq: Duration,
    pub time_stage2: Duration,
}

impl PipelineReport {
    /// Sum of final layer losses — the scalar the paper's method minimizes.
    pub fn total_loss(&self) -> f64 {
        self.linears.iter().map(|l| l.layer_loss).sum()
    }
}

/// Quantize every linear in the model, sequentially over blocks.
///
/// `calib` supplies token batches; captures are taken with the native
/// forward (identical math to the AOT'd JAX model — asserted by the
/// runtime equivalence tests).
pub fn quantize_model(
    fp: &ModelWeights,
    calib: &[Batch],
    cfg: &PipelineConfig,
) -> Result<(QuantizedModel, PipelineReport)> {
    use crate::model::forward::{block_forward, embed_tokens, LayerCaptures};

    let t_start = Instant::now();
    let n_layers = fp.config.n_layers;
    let n_heads = fp.config.n_heads;
    let mut prefix = fp.clone(); // quantized-prefix model, updated in place
    let mut linears: BTreeMap<(usize, &'static str), crate::quant::QuantizedLinear> =
        BTreeMap::new();
    let mut reports = Vec::new();
    let mut time_stats = Duration::ZERO;
    let mut time_scales = Duration::ZERO;
    let mut time_gptq = Duration::ZERO;
    let mut time_stage2 = Duration::ZERO;

    let with_dev = cfg.error_aware && cfg.method.stage2;

    // Running hidden states per calibration sequence: `h_q` flows through
    // the quantized prefix, `h_fp` through the FP model. Advancing them one
    // block per pipeline step makes the whole-run capture cost O(L) blocks
    // per sequence instead of O(L²) full forwards (§Perf L3 #4).
    let t_init = Instant::now();
    let seqs: Vec<&[u8]> =
        calib.iter().flat_map(|b| (0..b.batch).map(move |i| b.seq(i))).collect();
    let mut h_q: Vec<Matrix> =
        crate::util::threadpool::parallel_map_items(&seqs, |tokens| embed_tokens(fp, tokens));
    let mut h_fp: Vec<Matrix> = if with_dev { h_q.clone() } else { Vec::new() };
    time_stats += t_init.elapsed();

    for layer in 0..n_layers {
        // -- 1+2. capture + accumulate statistics for this block ------------
        let t0 = Instant::now();
        let d = fp.config.d_model;
        let ffn = fp.config.ffn;
        let mut st_attn = LinearStats::new(d, with_dev);
        let mut st_wo = LinearStats::new(d, with_dev);
        let mut st_mlp = LinearStats::new(d, with_dev);
        let mut st_w2 = LinearStats::new(ffn, with_dev);

        // Captures for every sequence, in parallel. The block itself still
        // uses the *FP weights of this layer* (they are quantized below),
        // fed with the quantized-prefix hidden state — standard GPTQ.
        let caps: Vec<(LayerCaptures, Option<LayerCaptures>)> =
            crate::util::threadpool::parallel_map(seqs.len(), |i| {
                let mut cq = empty_caps();
                block_forward(&prefix.layers[layer], &h_q[i], n_heads, Some(&mut cq));
                let cf = with_dev.then(|| {
                    let mut c = empty_caps();
                    block_forward(&fp.layers[layer], &h_fp[i], n_heads, Some(&mut c));
                    c
                });
                (cq, cf)
            });
        for (cq, cf) in &caps {
            st_attn.add_batch(&cq.x_attn, cf.as_ref().map(|c| &c.x_attn));
            st_wo.add_batch(&cq.x_wo, cf.as_ref().map(|c| &c.x_wo));
            st_mlp.add_batch(&cq.x_mlp, cf.as_ref().map(|c| &c.x_mlp));
            st_w2.add_batch(&cq.x_w2, cf.as_ref().map(|c| &c.x_w2));
        }
        time_stats += t0.elapsed();

        let finalize = |st: &LinearStats| -> (Matrix, Option<Matrix>) {
            (st.hessian.finalize(), st.deviation.as_ref().map(|d| d.finalize()))
        };
        let (h_attn, r_attn) = finalize(&st_attn);
        let (h_wo, r_wo) = finalize(&st_wo);
        let (h_mlp, r_mlp) = finalize(&st_mlp);
        let (h_w2, r_w2) = finalize(&st_w2);

        // -- 3. quantize the seven projections ------------------------------
        // The first block sees FP inputs exactly (R = 0 → Eq. 5).
        let use_r = layer > 0;
        let jobs: Vec<(LinearKind, &Matrix, &Matrix, Option<&Matrix>)> = vec![
            (LinearKind::Wq, &prefix.layers[layer].wq, &h_attn, r_attn.as_ref()),
            (LinearKind::Wk, &prefix.layers[layer].wk, &h_attn, r_attn.as_ref()),
            (LinearKind::Wv, &prefix.layers[layer].wv, &h_attn, r_attn.as_ref()),
            (LinearKind::Wo, &prefix.layers[layer].wo, &h_wo, r_wo.as_ref()),
            (LinearKind::W1, &prefix.layers[layer].w1, &h_mlp, r_mlp.as_ref()),
            (LinearKind::W3, &prefix.layers[layer].w3, &h_mlp, r_mlp.as_ref()),
            (LinearKind::W2, &prefix.layers[layer].w2, &h_w2, r_w2.as_ref()),
        ];

        let run_job = |(kind, w, h, r): &(LinearKind, &Matrix, &Matrix, Option<&Matrix>)| {
            let r_eff = if use_r { *r } else { None };
            quantize_layer(w, h, r_eff, &cfg.spec, cfg.method, &cfg.gptq, &cfg.stage2)
                .map(|res| (*kind, res))
        };
        let results: Vec<_> = if cfg.parallel_projections {
            crate::util::threadpool::parallel_map_items(&jobs, run_job)
        } else {
            jobs.iter().map(run_job).collect()
        };

        for res in results {
            let (kind, r) = res?;
            time_scales += r.time_scales;
            time_gptq += r.time_gptq;
            time_stage2 += r.time_stage2;
            reports.push(LinearReport {
                layer,
                kind,
                layer_loss: r.layer_loss,
                loss_before_stage2: r.loss_before_stage2,
            });
            // -- 4. splice dequantized weights into the prefix model --------
            *prefix.layers[layer].linear_mut(kind) = r.quantized.dequantize();
            linears.insert((layer, kind.label()), r.quantized);
        }

        // -- 5. advance the running hidden states past this (now quantized)
        //       block so the next layer sees real upstream error.
        let t1 = Instant::now();
        h_q = crate::util::threadpool::parallel_map(seqs.len(), |i| {
            block_forward(&prefix.layers[layer], &h_q[i], n_heads, None)
        });
        if with_dev {
            h_fp = crate::util::threadpool::parallel_map(seqs.len(), |i| {
                block_forward(&fp.layers[layer], &h_fp[i], n_heads, None)
            });
        }
        time_stats += t1.elapsed();
    }

    let report = PipelineReport {
        linears: reports,
        total_time: t_start.elapsed(),
        time_stats,
        time_scales,
        time_gptq,
        time_stage2,
    };
    Ok((QuantizedModel { config: fp.config, weights: prefix, linears }, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::{calibration_batches, Corpus, CorpusKind};
    use crate::model::Preset;
    use crate::util::rng::Rng;

    fn setup() -> (ModelWeights, Vec<Batch>) {
        let cfg = Preset::Tiny.config();
        let mut rng = Rng::new(42);
        let w = ModelWeights::init(cfg, &mut rng);
        let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 1);
        let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
        (w, calib)
    }

    #[test]
    fn pipeline_quantizes_all_linears() {
        let (w, calib) = setup();
        let cfg = PipelineConfig::new(QuantSpec::new(3, 32), MethodConfig::GPTQ);
        let (qm, report) = quantize_model(&w, &calib, &cfg).unwrap();
        assert_eq!(qm.linears.len(), 7 * w.config.n_layers);
        assert_eq!(report.linears.len(), 7 * w.config.n_layers);
        assert!(report.total_loss().is_finite());
        // spliced weights differ from FP but are close at 3 bits
        for li in 0..w.config.n_layers {
            for kind in LinearKind::ALL {
                let a = w.layers[li].linear(kind);
                let b = qm.weights.layers[li].linear(kind);
                assert!(a.max_abs_diff(b) > 0.0, "layer {li} {kind:?} unchanged");
            }
        }
    }

    #[test]
    fn ours_beats_gptq_on_total_loss() {
        let (w, calib) = setup();
        let spec = QuantSpec::new(2, 32);
        let (_, rep_gptq) = quantize_model(
            &w,
            &calib,
            &PipelineConfig::new(spec, MethodConfig::GPTQ),
        )
        .unwrap();
        let (_, rep_ours) = quantize_model(
            &w,
            &calib,
            &PipelineConfig::new(spec, MethodConfig::OURS),
        )
        .unwrap();
        assert!(
            rep_ours.total_loss() < rep_gptq.total_loss(),
            "ours {} should beat gptq {}",
            rep_ours.total_loss(),
            rep_gptq.total_loss()
        );
    }

    #[test]
    fn parallel_and_serial_agree() {
        let (w, calib) = setup();
        let spec = QuantSpec::new(2, 32);
        let mut cfg = PipelineConfig::new(spec, MethodConfig::OURS);
        cfg.parallel_projections = true;
        let (qa, _) = quantize_model(&w, &calib, &cfg).unwrap();
        cfg.parallel_projections = false;
        let (qb, _) = quantize_model(&w, &calib, &cfg).unwrap();
        for (k, a) in &qa.linears {
            let b = &qb.linears[k];
            assert!(a.scales.max_abs_diff(&b.scales) < 1e-6, "{k:?}");
        }
    }
}
