//! The layer-by-layer post-training-quantization pipeline (L3 coordinator).
//!
//! Mirrors the GPTQ workflow the paper plugs into:
//!
//! 1. stream calibration batches through the **FP** model and the
//!    **quantized-prefix** model, capturing every linear projection's inputs
//!    in block `l` ([`crate::model::forward_captures`]);
//! 2. accumulate `H = E[XXᵀ]` (from the quantized-prefix captures) and
//!    `R = E[ΔX Xᵀ]` (from their deviation against the FP captures —
//!    Eq. 7) per linear ([`stats`]);
//! 3. quantize the block's seven projections in parallel, each routed
//!    through the [`crate::quant::LayerQuantizer`] + spec its
//!    [`crate::quant::QuantPlan`] rule selects (uniform plans reproduce the
//!    paper's Stage 1 → GPTQ sweep → Stage 2; mixed plans give
//!    per-layer methods and mixed precision);
//! 4. splice the dequantized weights into the prefix model and move to
//!    block `l + 1`, so later layers see (and compensate for) upstream
//!    quantization error, exactly the effect Eq. 9 models.

pub mod quantize_model;
pub mod stats;

pub use quantize_model::{quantize_model, LinearReport, PipelineConfig, PipelineReport};
pub use stats::{LinearStats, MomentAccum};
