//! Sharded pipeline-parallel execution — the second execution topology.
//!
//! PR 2–4 made per-layer compute cheap (fused packed GEMV, dispatched SIMD
//! kernels, quantized KV); the next scaling axis is structural: split the
//! model's layers across workers and overlap them with in-flight
//! microbatches. Three pieces, layered:
//!
//! * [`ShardPlan`] — contiguous layer ranges balanced by per-layer deployed
//!   weight bytes, with the embedding pinned to the first shard and the
//!   final norm + LM head to the last.
//! * [`ShardedModel`] — a model plus its plan; implements
//!   [`crate::model::ModelExec`] by delegation so serve, eval and
//!   `decode_perplexity` accept it anywhere a model goes, and renders the
//!   per-shard deployment banner.
//! * [`ShardedDecoder`] — the pipeline executor: one OS thread per shard,
//!   channel-based activation handoff, shard-local per-sequence KV caches,
//!   microbatches kept in flight so every shard computes during
//!   steady-state batched decode. Driven by the step-level scheduler in
//!   [`crate::serve::sched`].
//!
//! Every shard runs the same [`crate::model::decode_layer_step`] /
//! [`crate::model::decode_head`] primitives as unsharded
//! [`crate::model::DecodeState`], so sharded decode is **bit-identical** to
//! single-worker decode by construction — the property
//! `tests/sharded_exec.rs` locks in across dense, mixed-precision packed
//! and quantized-KV configurations under both kernel tables.
//!
//! This module is also the plug point for the ROADMAP's future
//! tensor-parallel mode: a tensor-parallel worker would implement the same
//! admit/retire/step surface the scheduler already drives.

pub mod model;
pub mod pipeline;
pub mod plan;

pub use model::ShardedModel;
pub use pipeline::ShardedDecoder;
pub use plan::ShardPlan;
