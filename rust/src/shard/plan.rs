//! [`ShardPlan`] — how a model's layers are split into contiguous
//! pipeline-shard ranges.
//!
//! The partition is balanced by per-layer **deployed weight bytes**
//! ([`crate::model::BlockLinears::weight_bytes`]), not layer count: a
//! mixed-precision checkpoint (`wv,wo=bits4;…`) has unequal layers, and in
//! steady-state pipeline decode the throughput ceiling is the *slowest*
//! shard, which on a memory-bound decode is the shard touching the most
//! weight bytes per token. The embedding table is charged to shard 0 (it
//! owns token lookup) and the final-norm + LM head to the last shard (it
//! produces logits), so the planner shifts interior cuts to compensate.
//!
//! Exact minimization (not a greedy sweep): layer counts are small, so an
//! O(shards · layers²) dynamic program over contiguous partitions finds a
//! split minimizing the max per-shard bytes. Ties break toward the earliest
//! cut, making the plan deterministic for a given byte profile — the serve
//! banner, the batcher's internally derived plan, and tests all agree.

use crate::model::{BlockLinears, KvSpec, ModelConfig, ModelExec};

/// Contiguous layer ranges, one per pipeline shard, with the per-shard
/// weight-byte accounting the banner reports.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Per shard: `[start, end)` layer indices. Concatenated they cover
    /// `0..n_layers` exactly; every shard holds at least one layer.
    ranges: Vec<(usize, usize)>,
    /// Per shard: deployed weight bytes (its layers, plus the embedding on
    /// shard 0 and final-norm+head on the last shard).
    weight_bytes: Vec<usize>,
}

impl ShardPlan {
    /// Balance `layer_bytes.len()` layers over `n_shards` contiguous ranges
    /// minimizing the max per-shard bytes, with `embed_bytes` pinned to the
    /// first range and `head_bytes` to the last. `n_shards` is clamped to
    /// `1..=n_layers` (every shard must own at least one layer).
    pub fn balance(
        layer_bytes: &[usize],
        embed_bytes: usize,
        head_bytes: usize,
        n_shards: usize,
    ) -> ShardPlan {
        let n_layers = layer_bytes.len();
        assert!(n_layers > 0, "cannot shard a model with no layers");
        let s = n_shards.clamp(1, n_layers);
        let mut prefix = vec![0usize; n_layers + 1];
        for (i, &b) in layer_bytes.iter().enumerate() {
            prefix[i + 1] = prefix[i] + b;
        }
        let seg = |i: usize, j: usize| prefix[j] - prefix[i];

        if s == 1 {
            return ShardPlan {
                ranges: vec![(0, n_layers)],
                weight_bytes: vec![seg(0, n_layers) + embed_bytes + head_bytes],
            };
        }

        // dp[k][j]: minimal achievable max-shard-bytes splitting the first
        // `j` layers into `k` shards (shard 0 carrying the embedding; the
        // head is folded in at the final selection below, where the last
        // segment is known). cut[k][j] records the split producing it.
        const INF: usize = usize::MAX;
        let mut dp = vec![vec![INF; n_layers + 1]; s + 1];
        let mut cut = vec![vec![0usize; n_layers + 1]; s + 1];
        for j in 1..=n_layers {
            dp[1][j] = seg(0, j) + embed_bytes;
        }
        for k in 2..=s {
            for j in k..=n_layers {
                for i in (k - 1)..j {
                    if dp[k - 1][i] == INF {
                        continue;
                    }
                    let cost = dp[k - 1][i].max(seg(i, j));
                    if cost < dp[k][j] {
                        dp[k][j] = cost;
                        cut[k][j] = i;
                    }
                }
            }
        }
        let (mut best_cost, mut best_i) = (INF, s - 1);
        for i in (s - 1)..n_layers {
            if dp[s - 1][i] == INF {
                continue;
            }
            let cost = dp[s - 1][i].max(seg(i, n_layers) + head_bytes);
            if cost < best_cost {
                best_cost = cost;
                best_i = i;
            }
        }
        // Reconstruct the cut positions right-to-left.
        let mut bounds = vec![n_layers, best_i];
        let mut j = best_i;
        for k in (2..s).rev() {
            j = cut[k][j];
            bounds.push(j);
        }
        bounds.push(0);
        bounds.reverse();
        let ranges: Vec<(usize, usize)> =
            bounds.windows(2).map(|w| (w[0], w[1])).collect();
        let weight_bytes = ranges
            .iter()
            .enumerate()
            .map(|(k, &(lo, hi))| {
                let mut b = seg(lo, hi);
                if k == 0 {
                    b += embed_bytes;
                }
                if k + 1 == ranges.len() {
                    b += head_bytes;
                }
                b
            })
            .collect();
        ShardPlan { ranges, weight_bytes }
    }

    /// Balance a model's layers directly from its deployed representation.
    pub fn for_model<M: ModelExec>(m: &M, n_shards: usize) -> ShardPlan {
        let layer_bytes: Vec<usize> =
            m.layers().iter().map(|l| l.weight_bytes()).collect();
        ShardPlan::balance(&layer_bytes, m.embed_bytes(), m.head_bytes(), n_shards)
    }

    pub fn n_shards(&self) -> usize {
        self.ranges.len()
    }

    pub fn n_layers(&self) -> usize {
        self.ranges.last().map(|&(_, hi)| hi).unwrap_or(0)
    }

    /// `[start, end)` layer range of shard `s`.
    pub fn range(&self, s: usize) -> (usize, usize) {
        self.ranges[s]
    }

    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// Deployed weight bytes held by shard `s` (incl. embed/head extras).
    pub fn weight_bytes(&self, s: usize) -> usize {
        self.weight_bytes[s]
    }

    /// The steady-state pipeline bottleneck: the heaviest shard's bytes.
    pub fn max_shard_bytes(&self) -> usize {
        self.weight_bytes.iter().copied().max().unwrap_or(0)
    }

    /// KV-cache bytes appended per decoded token by shard `s` (K+V for each
    /// of its layers, in the effective representation) — each shard owns the
    /// shard-local slice of every sequence's cache, so this is *its* growth
    /// rate, not the model's.
    pub fn kv_bytes_per_token(&self, s: usize, cfg: &ModelConfig, kv: KvSpec) -> usize {
        let (lo, hi) = self.ranges[s];
        (hi - lo) * kv.effective(cfg).bytes_per_token(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cover_invariants(p: &ShardPlan, n_layers: usize) {
        assert_eq!(p.n_layers(), n_layers);
        let mut expect = 0;
        for s in 0..p.n_shards() {
            let (lo, hi) = p.range(s);
            assert_eq!(lo, expect, "ranges not contiguous");
            assert!(hi > lo, "empty shard");
            expect = hi;
        }
        assert_eq!(expect, n_layers);
    }

    #[test]
    fn uniform_layers_split_evenly() {
        let p = ShardPlan::balance(&[100; 6], 0, 0, 3);
        cover_invariants(&p, 6);
        assert_eq!(p.ranges(), &[(0, 2), (2, 4), (4, 6)]);
        assert_eq!(p.max_shard_bytes(), 200);
    }

    #[test]
    fn shard_count_clamps_to_layer_count() {
        let p = ShardPlan::balance(&[10, 20], 0, 0, 8);
        assert_eq!(p.n_shards(), 2);
        cover_invariants(&p, 2);
        let p1 = ShardPlan::balance(&[10, 20, 30], 5, 7, 0);
        assert_eq!(p1.n_shards(), 1);
        assert_eq!(p1.weight_bytes(0), 60 + 5 + 7);
    }

    #[test]
    fn embed_and_head_shift_the_cuts() {
        // Without extras, 4×100 over 2 shards splits 2+2. A heavy embedding
        // must push the first cut earlier so shard 0 isn't the bottleneck.
        let even = ShardPlan::balance(&[100; 4], 0, 0, 2);
        assert_eq!(even.ranges(), &[(0, 2), (2, 4)]);
        let heavy_embed = ShardPlan::balance(&[100; 4], 150, 0, 2);
        assert_eq!(heavy_embed.ranges(), &[(0, 1), (1, 4)]);
        assert_eq!(heavy_embed.weight_bytes(0), 250);
        let heavy_head = ShardPlan::balance(&[100; 4], 0, 150, 2);
        assert_eq!(heavy_head.ranges(), &[(0, 3), (3, 4)]);
    }

    #[test]
    fn minimizes_max_shard_bytes_exactly() {
        // Greedy front-loading would split [90,10,10,90] as (0,1)(1,4)=110;
        // the DP must find (0,2)(2,4)=100.
        let p = ShardPlan::balance(&[90, 10, 10, 90], 0, 0, 2);
        assert_eq!(p.ranges(), &[(0, 2), (2, 4)]);
        assert_eq!(p.max_shard_bytes(), 100);
        // and a 3-way case: the heavy layer gets isolated on its own shard
        let p3 = ShardPlan::balance(&[10, 200, 10, 10, 10], 0, 0, 3);
        cover_invariants(&p3, 5);
        assert_eq!(p3.max_shard_bytes(), 200);
        assert!(p3.ranges().contains(&(1, 2)), "{:?}", p3.ranges());
    }

    #[test]
    fn kv_accounting_is_per_shard_layers() {
        use crate::model::Preset;
        let cfg = Preset::Tiny.config(); // 2 layers
        let p = ShardPlan::balance(&[100, 100], 0, 0, 2);
        let kv = KvSpec::DenseF32;
        let per_layer = kv.bytes_per_token(&cfg);
        assert_eq!(p.kv_bytes_per_token(0, &cfg, kv), per_layer);
        assert_eq!(p.kv_bytes_per_token(1, &cfg, kv), per_layer);
    }

    #[test]
    fn deterministic_for_equal_profiles() {
        let a = ShardPlan::balance(&[64, 64, 64, 64, 64], 10, 10, 2);
        let b = ShardPlan::balance(&[64, 64, 64, 64, 64], 10, 10, 2);
        assert_eq!(a, b);
    }
}
