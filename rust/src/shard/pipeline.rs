//! The pipeline executor: one OS thread per shard, channel-based activation
//! handoff, shard-local KV caches.
//!
//! Topology (for a 3-shard plan):
//!
//! ```text
//! scheduler ──Span{slot,pos,toks}──▶ shard 0 ──Act{slot,pos,h}──▶ shard 1
//!     ▲        (embed + layers 0..a,  (layers a..b, its KV slice)   │
//!     │         its KV slice)                                       ▼
//!     └────────────(slot, logits)◀── shard 2 (layers b.., ln_f + head)
//! ```
//!
//! Each shard thread owns, for every admitted sequence slot, the
//! [`LayerKv`] pair of each layer in its range — the shard-local half of
//! that sequence's KV cache. Nothing is shared between shards but the
//! immutable model (`Arc`) and the channels, so there are no locks on the
//! decode path. Under `--kv-pool-mb` that stays true: each shard pages its
//! caches out of its **own** sub-pool (a layer-proportional slice of the
//! global budget, see [`PoolCfg::shard_slice`]), so the only lock a shard
//! ever takes is on an allocator no other shard touches.
//!
//! **Microbatching / overlap.** A microbatch is one sequence's token-span
//! activation — a `T×d` block, where `T` is 1 in steady-state decode and up
//! to `--prefill-chunk` during prefill ([`crate::serve::StepJob`]).
//! [`ShardedDecoder::step`] writes *every* job of the current scheduler
//! step into the pipe before reading any logits back, so while sequence `k`
//! runs on shard 0, sequence `k−1` is already on shard 1 — up to
//! `min(batch, n_shards)` shards compute simultaneously and all shards stay
//! busy in steady-state decode once the running batch is at least as deep
//! as the pipeline. Per-channel FIFO plus one thread per stage makes result
//! order deterministic (= submission order).
//!
//! **Bit-identity.** Every shard runs
//! [`decode_layer_span`]/[`decode_head`] — the *same* functions
//! [`DecodeState::step_span`](crate::model::DecodeState) is built from —
//! over the same layer objects in the same order, so a span stepped through
//! the pipeline produces bit-identical logits to unsharded decode, for
//! dense, packed, and quantized-KV configurations alike (tested in
//! `tests/sharded_exec.rs` under both kernel tables).
//!
//! **Shutdown.** Dropping the [`ShardedDecoder`] closes shard 0's input
//! channel; each worker drains, drops its downstream sender (cascading the
//! close), and exits; `Drop` then joins every thread — no leaked shard
//! threads, mirroring `DynamicBatcher`'s own `Drop` contract.
//!
//! **Supervised recovery (PR 8).** A dead shard thread is detected fast —
//! its unwind drops its channels, the close cascades to both ends, and the
//! next send/recv fails — and marks the decoder `dead`: every remaining and
//! subsequent step job fails with a structured error (the in-flight
//! sequences' KV banks died with the chain, so they are unrecoverable), and
//! the same goes for the first slot-mismatched reply, which means the
//! result FIFO can no longer be trusted to label logits. Once the serve
//! scheduler has errored and retired every sequence that referenced the
//! dead chain, the next [`ShardedDecoder::admit`] *rebuilds* the entire
//! thread chain from the respawn recipe captured at construction (model,
//! plan, KV spec, pool budget — rebuilt sub-pools mint fresh pages) and
//! serving resumes; [`ShardedDecoder::rebuilds`] counts the recoveries.

use super::plan::ShardPlan;
use crate::kvpool::{KvPool, PoolCfg};
use crate::model::{decode_head, decode_layer_span, embed_tokens, KvSpec, LayerKv, ModelExec};
use crate::serve::StepJob;
use crate::tensor::Matrix;
use crate::util::fault::{self, FaultPoint};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// What flows down the pipe. Control packets (`Admit`/`Retire`) travel the
/// same FIFO as activations, so a shard never sees a `Span`/`Act` for a
/// slot it hasn't admitted or has already retired.
enum Packet {
    /// Allocate fresh shard-local KV caches for `slot`.
    Admit { slot: usize },
    /// Free `slot`'s caches (the slot id may be reused by a later `Admit`).
    Retire { slot: usize },
    /// A span of new tokens for `slot` starting at position `pos` —
    /// consumed by shard 0, which embeds them and emits an `Act`.
    Span { slot: usize, pos: usize, tokens: Vec<u8> },
    /// A `T×d` hidden-state block handed from the previous shard (`T` = the
    /// span length; 1 in steady-state decode).
    Act { slot: usize, pos: usize, h: Matrix },
}

/// Where a shard sends its output: the next shard, or (for the last shard)
/// the logits channel back to the scheduler.
enum Downstream {
    Next(Sender<Packet>),
    Logits(Sender<(usize, Vec<f32>)>),
}

/// One spawned thread chain: the channels into/out of it plus its worker
/// handles. Dropping a chain closes the input, cascades the close down the
/// stages, and joins every thread — dead workers join instantly.
struct Chain {
    input: Option<Sender<Packet>>,
    results: Receiver<(usize, Vec<f32>)>,
    workers: Vec<JoinHandle<()>>,
}

impl Drop for Chain {
    fn drop(&mut self) {
        // Closing the input cascades: each worker's recv loop ends, its
        // downstream sender drops, and the next stage drains in turn.
        drop(self.input.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Handle to a running shard pipeline; owned by the serve scheduler (one
/// per `DynamicBatcher` worker when `--shards N > 1`).
pub struct ShardedDecoder {
    chain: Chain,
    /// Rebuild recipe: respawns a fresh thread chain (and fresh shard
    /// sub-pools) identical to the original construction.
    respawn: Box<dyn Fn() -> Chain + Send>,
    free: Vec<usize>,
    n_slots: usize,
    n_shards: usize,
    /// Slots currently admitted and not retired; `live` counts them. A
    /// dead chain only rebuilds once every live slot has been retired —
    /// a rebuilt chain must never see a slot it didn't admit.
    admitted: Vec<bool>,
    live: usize,
    /// The chain can no longer be trusted: a worker died (send/recv on a
    /// closed channel) or the result FIFO mislabeled a reply.
    dead: bool,
    /// Completed chain rebuilds (surfaced as `pipeline_rebuilds`).
    rebuilds: usize,
    /// Upper bound for one result recv — normally death is detected by the
    /// cascading channel close long before this fires; the timeout only
    /// catches a *wedged* (not dead) shard.
    step_timeout: Duration,
}

impl ShardedDecoder {
    /// Spawn one worker thread per shard of `plan` over `model`. `kv` is
    /// the per-sequence KV representation (each shard quantizes its own
    /// slice on append, exactly as `DecodeState::with_kv` would).
    pub fn new<M: ModelExec + Send + Sync + 'static>(
        model: Arc<M>,
        plan: &ShardPlan,
        kv: KvSpec,
    ) -> ShardedDecoder {
        ShardedDecoder::new_pooled(model, plan, kv, None)
    }

    /// Like [`ShardedDecoder::new`], but with an optional paged-KV budget:
    /// each shard gets a **shard-local sub-pool** sized by
    /// [`PoolCfg::shard_slice`] (bytes proportional to its layer count), so
    /// shards never contend on one allocator lock and a shard's occupancy
    /// is exactly predictable from its layer count. Admission/preemption
    /// policy stays upstream in the serve scheduler, which mirrors these
    /// sub-pools' accounting deterministically.
    pub fn new_pooled<M: ModelExec + Send + Sync + 'static>(
        model: Arc<M>,
        plan: &ShardPlan,
        kv: KvSpec,
        pool: Option<PoolCfg>,
    ) -> ShardedDecoder {
        assert_eq!(
            plan.n_layers(),
            model.layers().len(),
            "shard plan does not match the model's layer count"
        );
        let n = plan.n_shards();
        let respawn: Box<dyn Fn() -> Chain + Send> = {
            let plan = plan.clone();
            Box::new(move || spawn_chain(&model, &plan, kv, pool))
        };
        let chain = respawn();
        ShardedDecoder {
            chain,
            respawn,
            free: Vec::new(),
            n_slots: 0,
            n_shards: n,
            admitted: Vec::new(),
            live: 0,
            dead: false,
            rebuilds: 0,
            step_timeout: Duration::from_secs(60),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The chain is down; steps fail until it drains and rebuilds.
    pub fn dead(&self) -> bool {
        self.dead
    }

    /// Admitted-but-not-retired slots (they reference the current chain).
    pub fn live_slots(&self) -> usize {
        self.live
    }

    /// Completed chain rebuilds after a death.
    pub fn rebuilds(&self) -> usize {
        self.rebuilds
    }

    /// Bound one result wait (`--step-timeout`); see the field docs.
    pub fn set_step_timeout(&mut self, timeout: Duration) {
        self.step_timeout = timeout.max(Duration::from_millis(1));
    }

    fn send(&mut self, p: Packet) -> Result<(), String> {
        let sent = self
            .chain
            .input
            .as_ref()
            .expect("chain input open until drop")
            .send(p)
            .is_ok();
        if !sent {
            self.dead = true;
        }
        sent.then_some(())
            .ok_or_else(|| "shard pipeline unavailable (a shard worker exited)".to_string())
    }

    /// Tear down the dead chain and spawn a fresh one. Only legal with no
    /// live slots (their shard-local KV lives in the old chain's threads).
    fn rebuild(&mut self) {
        assert_eq!(self.live, 0, "rebuilding a shard chain with live slots");
        // Replacing the chain drops the old one: input closes, the close
        // cascades, and every old worker (dead or drained) is joined.
        self.chain = (self.respawn)();
        self.free.clear();
        self.n_slots = 0;
        self.admitted.clear();
        self.dead = false;
        self.rebuilds += 1;
        crate::obs::registry().pipeline_rebuilds.inc();
        println!(
            "serve: shard pipeline died — rebuilt the {}-shard chain (rebuild #{}); \
             in-flight sequences on the old chain were errored",
            self.n_shards, self.rebuilds
        );
    }

    /// Allocate a sequence slot: every shard creates the KV caches for its
    /// layer range. Slot ids are recycled after [`Self::retire`]. On a
    /// dead chain this is the rebuild point — once the last live slot has
    /// retired, the next admit respawns the whole chain and serving
    /// resumes.
    pub fn admit(&mut self) -> Result<usize, String> {
        if self.dead {
            if self.live > 0 {
                return Err(
                    "shard pipeline is down; draining in-flight sequences before rebuild"
                        .to_string(),
                );
            }
            self.rebuild();
        }
        let slot = self.free.pop().unwrap_or_else(|| {
            let s = self.n_slots;
            self.n_slots += 1;
            s
        });
        match self.send(Packet::Admit { slot }) {
            Ok(()) => {
                if self.admitted.len() <= slot {
                    self.admitted.resize(slot + 1, false);
                }
                self.admitted[slot] = true;
                self.live += 1;
                Ok(slot)
            }
            Err(e) => {
                self.free.push(slot);
                Err(e)
            }
        }
    }

    /// Free a sequence slot on every shard. The id returns to the free
    /// list even if the pipe is already dead — a dead pipeline fails every
    /// later step anyway and a rebuild resets the slot space, so keeping
    /// the accounting symmetric with [`Self::admit`] means slot ids never
    /// leak; the live count reaching zero is what unlocks the rebuild.
    pub fn retire(&mut self, slot: usize) {
        if !self.dead {
            let _ = self.send(Packet::Retire { slot });
        }
        if self.admitted.get(slot).copied().unwrap_or(false) {
            self.admitted[slot] = false;
            self.live -= 1;
        }
        self.free.push(slot);
    }

    /// One span step for every [`StepJob`]: all jobs are fed into the pipe
    /// before any logits are read back (the microbatch overlap described in
    /// the module docs); returns each job's last-row logits in submission
    /// order.
    ///
    /// Any failure — a send into a closed chain, a closed or timed-out
    /// result channel, or a reply labeled with the wrong slot — marks the
    /// decoder dead and fails **all** remaining jobs: after a mismatch the
    /// FIFO's labeling is untrusted, so reading on would risk handing one
    /// sequence another's logits.
    pub fn step(&mut self, jobs: &[StepJob]) -> Vec<Result<Vec<f32>, String>> {
        let downed = || {
            "shard pipeline unavailable (a shard worker died); \
             sequence state lost, will rebuild"
                .to_string()
        };
        let mut out: Vec<Result<Vec<f32>, String>> = Vec::with_capacity(jobs.len());
        if self.dead {
            return jobs.iter().map(|_| Err(downed())).collect();
        }
        let mut sent = 0usize;
        for job in jobs {
            let pkt = Packet::Span {
                slot: job.slot,
                pos: job.pos,
                tokens: job.tokens.clone(),
            };
            if self.send(pkt).is_err() {
                break;
            }
            sent += 1;
        }
        for want_slot in jobs.iter().take(sent).map(|j| j.slot) {
            match self.chain.results.recv_timeout(self.step_timeout) {
                // FIFO channels + one thread per stage make result order
                // deterministic; a mismatch means the pipe is corrupt.
                Ok((slot, logits)) if slot == want_slot => out.push(Ok(logits)),
                Ok((slot, _)) => {
                    self.dead = true;
                    out.push(Err(format!(
                        "pipeline returned logits for slot {slot} where slot \
                         {want_slot} was expected; FIFO corrupt, will rebuild"
                    )));
                    break;
                }
                Err(RecvTimeoutError::Timeout) => {
                    self.dead = true;
                    out.push(Err(format!(
                        "shard pipeline wedged: no result within {}; will rebuild",
                        crate::util::fmt_duration(self.step_timeout)
                    )));
                    break;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    self.dead = true;
                    break;
                }
            }
        }
        while out.len() < jobs.len() {
            out.push(Err(downed()));
        }
        out
    }
}

/// Spawn one complete thread chain for `plan` — the construction recipe
/// shared by first spawn and post-death rebuild. Each call mints fresh
/// shard sub-pools, so a rebuilt chain starts with its full page budget
/// (the dead chain's pages died with its threads).
fn spawn_chain<M: ModelExec + Send + Sync + 'static>(
    model: &Arc<M>,
    plan: &ShardPlan,
    kv: KvSpec,
    pool: Option<PoolCfg>,
) -> Chain {
    let n = plan.n_shards();
    let (input_tx, first_rx) = channel::<Packet>();
    let (res_tx, res_rx) = channel::<(usize, Vec<f32>)>();
    let mut workers = Vec::with_capacity(n);
    let mut rx_opt = Some(first_rx);
    for s in 0..n {
        let this_rx = rx_opt.take().expect("one receiver per shard");
        let down = if s + 1 == n {
            Downstream::Logits(res_tx.clone())
        } else {
            let (tx, next_rx) = channel::<Packet>();
            rx_opt = Some(next_rx);
            Downstream::Next(tx)
        };
        let (lo, hi) = plan.range(s);
        let sub_pool = pool.map(|pc| {
            let sub = pc.shard_slice(hi - lo, plan.n_layers());
            KvPool::new(sub, kv, model.config())
        });
        let m = model.clone();
        let worker = std::thread::Builder::new()
            .name(format!("tsgo-shard-{s}"))
            .spawn(move || run_shard(m, s, lo..hi, kv, sub_pool, this_rx, down))
            .expect("spawn shard worker thread");
        workers.push(worker);
    }
    drop(res_tx);
    Chain { input: Some(input_tx), results: res_rx, workers }
}

/// One shard's worker loop: layers `layers.start..layers.end`, plus
/// embedding when the range starts at 0 and the final norm + head when it
/// ends at `n_layers`. `idx` is the shard's position in the chain, used to
/// label its telemetry (stage-time histogram + trace events).
fn run_shard<M: ModelExec>(
    model: Arc<M>,
    idx: usize,
    layers: std::ops::Range<usize>,
    kv: KvSpec,
    pool: Option<KvPool>,
    rx: Receiver<Packet>,
    down: Downstream,
) {
    let (lo, hi) = (layers.start, layers.end);
    let cfg = *model.config();
    // slot → the shard-local half of that sequence's KV cache (one LayerKv
    // per layer in `lo..hi`).
    let mut slots: Vec<Option<Vec<LayerKv>>> = Vec::new();
    while let Ok(pkt) = rx.recv() {
        let (slot, pos, mut h) = match pkt {
            Packet::Admit { slot } => {
                if slots.len() <= slot {
                    slots.resize_with(slot + 1, || None);
                }
                slots[slot] =
                    Some((lo..hi).map(|_| LayerKv::new_in(kv, &cfg, pool.as_ref())).collect());
                if let Downstream::Next(tx) = &down {
                    if tx.send(Packet::Admit { slot }).is_err() {
                        return;
                    }
                }
                continue;
            }
            Packet::Retire { slot } => {
                if let Some(s) = slots.get_mut(slot) {
                    *s = None;
                }
                if let Downstream::Next(tx) = &down {
                    if tx.send(Packet::Retire { slot }).is_err() {
                        return;
                    }
                }
                continue;
            }
            Packet::Span { slot, pos, tokens } => {
                debug_assert_eq!(lo, 0, "Span packet reached a non-first shard");
                (slot, pos, embed_tokens(model.as_ref(), &tokens))
            }
            Packet::Act { slot, pos, h } => (slot, pos, h),
        };
        // Deterministic kill point for the recovery tests: evaluated once
        // per compute packet per shard (a single relaxed load unarmed).
        // The unwind drops this shard's channels; the close cascades both
        // ways and the decoder marks itself dead on the next send/recv.
        fault::maybe_panic(FaultPoint::ShardWorkerPanic);
        let stage_start = std::time::Instant::now();
        let span_rows = h.rows;
        let Some(kvs) = slots.get_mut(slot).and_then(|s| s.as_mut()) else {
            // A step for an unadmitted/retired slot is a scheduler protocol
            // bug. Dying loudly tears the channel chain down, so the
            // scheduler sees "pipeline unavailable" errors instead of a
            // silently dropped packet deadlocking `step()`'s recv.
            panic!("shard {lo}..{hi}: step for unadmitted slot {slot}");
        };
        for (j, li) in (lo..hi).enumerate() {
            decode_layer_span(&model.layers()[li], &cfg, pos, &mut h, &mut kvs[j]);
        }
        let sent = match &down {
            Downstream::Next(tx) => tx.send(Packet::Act { slot, pos, h }).is_ok(),
            Downstream::Logits(tx) => {
                // Only the span's last row is sampled; its logits are the
                // step's result (matches `DecodeState::step_span`).
                let last = h.row(h.rows - 1).to_vec();
                tx.send((slot, decode_head(model.as_ref(), last))).is_ok()
            }
        };
        // Per-shard stage time: relaxed atomics only, negligible next to
        // the layer GEMVs it measures. The trace event is labeled with the
        // shard index so `{"stats": true}` shows where a step's time went.
        let stage = stage_start.elapsed();
        let reg = crate::obs::registry();
        reg.shard_stage_ms.observe(stage);
        reg.trace.record(&crate::obs::StepEvent {
            seq: 0,
            source: idx as u32,
            batch: 1,
            prefill_tokens: if span_rows > 1 { span_rows as u32 } else { 0 },
            decode_tokens: (span_rows == 1) as u32,
            dur_us: stage.as_micros() as u64,
            preempted: 0,
            restarts: 0,
        });
        if !sent {
            return; // downstream hung up: the pipeline is shutting down
        }
    }
}
