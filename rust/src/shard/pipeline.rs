//! The pipeline executor: one OS thread per shard, channel-based activation
//! handoff, shard-local KV caches.
//!
//! Topology (for a 3-shard plan):
//!
//! ```text
//! scheduler ──Span{slot,pos,toks}──▶ shard 0 ──Act{slot,pos,h}──▶ shard 1
//!     ▲        (embed + layers 0..a,  (layers a..b, its KV slice)   │
//!     │         its KV slice)                                       ▼
//!     └────────────(slot, logits)◀── shard 2 (layers b.., ln_f + head)
//! ```
//!
//! Each shard thread owns, for every admitted sequence slot, the
//! [`LayerKv`] pair of each layer in its range — the shard-local half of
//! that sequence's KV cache. Nothing is shared between shards but the
//! immutable model (`Arc`) and the channels, so there are no locks on the
//! decode path. Under `--kv-pool-mb` that stays true: each shard pages its
//! caches out of its **own** sub-pool (a layer-proportional slice of the
//! global budget, see [`PoolCfg::shard_slice`]), so the only lock a shard
//! ever takes is on an allocator no other shard touches.
//!
//! **Microbatching / overlap.** A microbatch is one sequence's token-span
//! activation — a `T×d` block, where `T` is 1 in steady-state decode and up
//! to `--prefill-chunk` during prefill ([`crate::serve::StepJob`]).
//! [`ShardedDecoder::step`] writes *every* job of the current scheduler
//! step into the pipe before reading any logits back, so while sequence `k`
//! runs on shard 0, sequence `k−1` is already on shard 1 — up to
//! `min(batch, n_shards)` shards compute simultaneously and all shards stay
//! busy in steady-state decode once the running batch is at least as deep
//! as the pipeline. Per-channel FIFO plus one thread per stage makes result
//! order deterministic (= submission order).
//!
//! **Bit-identity.** Every shard runs
//! [`decode_layer_span`]/[`decode_head`] — the *same* functions
//! [`DecodeState::step_span`](crate::model::DecodeState) is built from —
//! over the same layer objects in the same order, so a span stepped through
//! the pipeline produces bit-identical logits to unsharded decode, for
//! dense, packed, and quantized-KV configurations alike (tested in
//! `tests/sharded_exec.rs` under both kernel tables).
//!
//! **Shutdown.** Dropping the [`ShardedDecoder`] closes shard 0's input
//! channel; each worker drains, drops its downstream sender (cascading the
//! close), and exits; `Drop` then joins every thread — no leaked shard
//! threads, mirroring `DynamicBatcher`'s own `Drop` contract.

use super::plan::ShardPlan;
use crate::kvpool::{KvPool, PoolCfg};
use crate::model::{decode_head, decode_layer_span, embed_tokens, KvSpec, LayerKv, ModelExec};
use crate::serve::StepJob;
use crate::tensor::Matrix;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// What flows down the pipe. Control packets (`Admit`/`Retire`) travel the
/// same FIFO as activations, so a shard never sees a `Span`/`Act` for a
/// slot it hasn't admitted or has already retired.
enum Packet {
    /// Allocate fresh shard-local KV caches for `slot`.
    Admit { slot: usize },
    /// Free `slot`'s caches (the slot id may be reused by a later `Admit`).
    Retire { slot: usize },
    /// A span of new tokens for `slot` starting at position `pos` —
    /// consumed by shard 0, which embeds them and emits an `Act`.
    Span { slot: usize, pos: usize, tokens: Vec<u8> },
    /// A `T×d` hidden-state block handed from the previous shard (`T` = the
    /// span length; 1 in steady-state decode).
    Act { slot: usize, pos: usize, h: Matrix },
}

/// Where a shard sends its output: the next shard, or (for the last shard)
/// the logits channel back to the scheduler.
enum Downstream {
    Next(Sender<Packet>),
    Logits(Sender<(usize, Vec<f32>)>),
}

/// Handle to a running shard pipeline; owned by the serve scheduler (one
/// per `DynamicBatcher` worker when `--shards N > 1`).
pub struct ShardedDecoder {
    input: Option<Sender<Packet>>,
    results: Receiver<(usize, Vec<f32>)>,
    workers: Vec<JoinHandle<()>>,
    free: Vec<usize>,
    n_slots: usize,
    n_shards: usize,
}

impl ShardedDecoder {
    /// Spawn one worker thread per shard of `plan` over `model`. `kv` is
    /// the per-sequence KV representation (each shard quantizes its own
    /// slice on append, exactly as `DecodeState::with_kv` would).
    pub fn new<M: ModelExec + Send + Sync + 'static>(
        model: Arc<M>,
        plan: &ShardPlan,
        kv: KvSpec,
    ) -> ShardedDecoder {
        ShardedDecoder::new_pooled(model, plan, kv, None)
    }

    /// Like [`ShardedDecoder::new`], but with an optional paged-KV budget:
    /// each shard gets a **shard-local sub-pool** sized by
    /// [`PoolCfg::shard_slice`] (bytes proportional to its layer count), so
    /// shards never contend on one allocator lock and a shard's occupancy
    /// is exactly predictable from its layer count. Admission/preemption
    /// policy stays upstream in the serve scheduler, which mirrors these
    /// sub-pools' accounting deterministically.
    pub fn new_pooled<M: ModelExec + Send + Sync + 'static>(
        model: Arc<M>,
        plan: &ShardPlan,
        kv: KvSpec,
        pool: Option<PoolCfg>,
    ) -> ShardedDecoder {
        assert_eq!(
            plan.n_layers(),
            model.layers().len(),
            "shard plan does not match the model's layer count"
        );
        let n = plan.n_shards();
        let (input_tx, first_rx) = channel::<Packet>();
        let (res_tx, res_rx) = channel::<(usize, Vec<f32>)>();
        let mut workers = Vec::with_capacity(n);
        let mut rx_opt = Some(first_rx);
        for s in 0..n {
            let this_rx = rx_opt.take().expect("one receiver per shard");
            let down = if s + 1 == n {
                Downstream::Logits(res_tx.clone())
            } else {
                let (tx, next_rx) = channel::<Packet>();
                rx_opt = Some(next_rx);
                Downstream::Next(tx)
            };
            let (lo, hi) = plan.range(s);
            let sub_pool = pool.map(|pc| {
                let sub = pc.shard_slice(hi - lo, plan.n_layers());
                KvPool::new(sub, kv, model.config())
            });
            let m = model.clone();
            let worker = std::thread::Builder::new()
                .name(format!("tsgo-shard-{s}"))
                .spawn(move || run_shard(m, lo, hi, kv, sub_pool, this_rx, down))
                .expect("spawn shard worker thread");
            workers.push(worker);
        }
        drop(res_tx);
        ShardedDecoder {
            input: Some(input_tx),
            results: res_rx,
            workers,
            free: Vec::new(),
            n_slots: 0,
            n_shards: n,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    fn send(&self, p: Packet) -> Result<(), String> {
        self.input
            .as_ref()
            .expect("pipeline input open until drop")
            .send(p)
            .map_err(|_| "shard pipeline unavailable (a shard worker exited)".to_string())
    }

    /// Allocate a sequence slot: every shard creates the KV caches for its
    /// layer range. Slot ids are recycled after [`Self::retire`].
    pub fn admit(&mut self) -> Result<usize, String> {
        let slot = self.free.pop().unwrap_or_else(|| {
            let s = self.n_slots;
            self.n_slots += 1;
            s
        });
        match self.send(Packet::Admit { slot }) {
            Ok(()) => Ok(slot),
            Err(e) => {
                self.free.push(slot);
                Err(e)
            }
        }
    }

    /// Free a sequence slot on every shard. The id returns to the free
    /// list even if the pipe is already dead — a dead pipeline fails every
    /// later admit/step anyway, and keeping the accounting symmetric with
    /// [`Self::admit`] means slot ids never leak.
    pub fn retire(&mut self, slot: usize) {
        let _ = self.send(Packet::Retire { slot });
        self.free.push(slot);
    }

    /// One span step for every [`StepJob`]: all jobs are fed into the pipe
    /// before any logits are read back (the microbatch overlap described in
    /// the module docs); returns each job's last-row logits in submission
    /// order.
    pub fn step(&mut self, jobs: &[StepJob]) -> Vec<Result<Vec<f32>, String>> {
        let mut out: Vec<Result<Vec<f32>, String>> = Vec::with_capacity(jobs.len());
        let mut sent = 0usize;
        for job in jobs {
            let pkt = Packet::Span {
                slot: job.slot,
                pos: job.pos,
                tokens: job.tokens.clone(),
            };
            if self.send(pkt).is_err() {
                break;
            }
            sent += 1;
        }
        for want_slot in jobs.iter().take(sent).map(|j| j.slot) {
            match self.results.recv() {
                // FIFO channels + one thread per stage make result order
                // deterministic; a mismatch means the pipe is corrupt, so
                // surface it as an error rather than mislabeling logits.
                Ok((slot, logits)) if slot == want_slot => out.push(Ok(logits)),
                Ok((slot, _)) => out.push(Err(format!(
                    "pipeline returned logits for slot {slot} where \
                     slot {want_slot} was expected"
                ))),
                Err(_) => break,
            }
        }
        while out.len() < jobs.len() {
            out.push(Err("shard pipeline unavailable (a shard worker exited)".into()));
        }
        out
    }
}

impl Drop for ShardedDecoder {
    fn drop(&mut self) {
        // Closing the input cascades: each worker's recv loop ends, its
        // downstream sender drops, and the next stage drains in turn.
        drop(self.input.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One shard's worker loop: layers `lo..hi`, plus embedding when `lo == 0`
/// and the final norm + head when `hi == n_layers`.
fn run_shard<M: ModelExec>(
    model: Arc<M>,
    lo: usize,
    hi: usize,
    kv: KvSpec,
    pool: Option<KvPool>,
    rx: Receiver<Packet>,
    down: Downstream,
) {
    let cfg = *model.config();
    // slot → the shard-local half of that sequence's KV cache (one LayerKv
    // per layer in `lo..hi`).
    let mut slots: Vec<Option<Vec<LayerKv>>> = Vec::new();
    while let Ok(pkt) = rx.recv() {
        let (slot, pos, mut h) = match pkt {
            Packet::Admit { slot } => {
                if slots.len() <= slot {
                    slots.resize_with(slot + 1, || None);
                }
                slots[slot] =
                    Some((lo..hi).map(|_| LayerKv::new_in(kv, &cfg, pool.as_ref())).collect());
                if let Downstream::Next(tx) = &down {
                    if tx.send(Packet::Admit { slot }).is_err() {
                        return;
                    }
                }
                continue;
            }
            Packet::Retire { slot } => {
                if let Some(s) = slots.get_mut(slot) {
                    *s = None;
                }
                if let Downstream::Next(tx) = &down {
                    if tx.send(Packet::Retire { slot }).is_err() {
                        return;
                    }
                }
                continue;
            }
            Packet::Span { slot, pos, tokens } => {
                debug_assert_eq!(lo, 0, "Span packet reached a non-first shard");
                (slot, pos, embed_tokens(model.as_ref(), &tokens))
            }
            Packet::Act { slot, pos, h } => (slot, pos, h),
        };
        let Some(kvs) = slots.get_mut(slot).and_then(|s| s.as_mut()) else {
            // A step for an unadmitted/retired slot is a scheduler protocol
            // bug. Dying loudly tears the channel chain down, so the
            // scheduler sees "pipeline unavailable" errors instead of a
            // silently dropped packet deadlocking `step()`'s recv.
            panic!("shard {lo}..{hi}: step for unadmitted slot {slot}");
        };
        for (j, li) in (lo..hi).enumerate() {
            decode_layer_span(&model.layers()[li], &cfg, pos, &mut h, &mut kvs[j]);
        }
        let sent = match &down {
            Downstream::Next(tx) => tx.send(Packet::Act { slot, pos, h }).is_ok(),
            Downstream::Logits(tx) => {
                // Only the span's last row is sampled; its logits are the
                // step's result (matches `DecodeState::step_span`).
                let last = h.row(h.rows - 1).to_vec();
                tx.send((slot, decode_head(model.as_ref(), last))).is_ok()
            }
        };
        if !sent {
            return; // downstream hung up: the pipeline is shutting down
        }
    }
}
