//! [`ShardedModel`] — a model plus its shard plan, usable anywhere a
//! [`ModelExec`] is.
//!
//! The wrapper serves two roles:
//!
//! * **Drop-in execution.** It implements [`ModelExec`] by delegating to
//!   the inner model, so `tsgo eval --shards N`, `decode_perplexity`, the
//!   0-shot suite and every other `ModelExec` consumer run unchanged (and
//!   trivially bit-identical — same layers, same code path). The pipeline
//!   topology is only engaged where it pays: steady-state batched decode.
//! * **Deployment accounting.** It owns the [`ShardPlan`] and renders the
//!   per-shard banner (layer ranges, weight bytes, KV bytes/token) that
//!   `tsgo serve|eval --shards N` print, and it mints the
//!   [`ShardedDecoder`] the serve scheduler drives.

use super::pipeline::ShardedDecoder;
use super::plan::ShardPlan;
use crate::kvpool::PoolCfg;
use crate::model::{KvSpec, ModelConfig, ModelExec};
use crate::tensor::Matrix;
use std::sync::Arc;

/// A model split into contiguous layer ranges (see module docs).
pub struct ShardedModel<M: ModelExec> {
    inner: Arc<M>,
    plan: ShardPlan,
}

impl<M: ModelExec> ShardedModel<M> {
    /// Plan `n_shards` ranges over `inner` balanced by per-layer weight
    /// bytes (`n_shards` clamps to the layer count).
    pub fn new(inner: Arc<M>, n_shards: usize) -> ShardedModel<M> {
        let plan = ShardPlan::for_model(inner.as_ref(), n_shards);
        ShardedModel { inner, plan }
    }

    /// Use a pre-built plan (must cover the model's layers exactly).
    pub fn with_plan(inner: Arc<M>, plan: ShardPlan) -> ShardedModel<M> {
        assert_eq!(plan.n_layers(), inner.layers().len(), "plan/model layer mismatch");
        ShardedModel { inner, plan }
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn inner(&self) -> &Arc<M> {
        &self.inner
    }

    /// The serve/eval banner: one header plus one line per shard with its
    /// layer range, pinned extras, deployed weight bytes and KV growth per
    /// decoded token — the numbers a deployment log needs to see which
    /// shard is the pipeline bottleneck.
    pub fn banner_lines(&self, kv: KvSpec) -> Vec<String> {
        let cfg = self.inner.config();
        let n = self.plan.n_shards();
        let mut lines = vec![format!(
            "sharded execution: {} shard{} over {} layers (pipeline decode, 1 thread/shard, {} KV)",
            n,
            if n == 1 { "" } else { "s" },
            self.plan.n_layers(),
            kv.effective(cfg).label(),
        )];
        for s in 0..n {
            let (lo, hi) = self.plan.range(s);
            let extras = match (s == 0, s + 1 == n) {
                (true, true) => " +embed +head",
                (true, false) => " +embed",
                (false, true) => " +head",
                (false, false) => "",
            };
            lines.push(format!(
                "  shard {s}/{n}: layers {lo}..{hi}{extras}  {:.2} MB weights  {} B/token KV",
                self.plan.weight_bytes(s) as f64 / 1e6,
                self.plan.kv_bytes_per_token(s, cfg, kv),
            ));
        }
        lines
    }
}

impl<M: ModelExec + Send + Sync + 'static> ShardedModel<M> {
    /// Spawn the pipeline executor for this plan (one thread per shard).
    pub fn decoder(&self, kv: KvSpec) -> ShardedDecoder {
        self.decoder_pooled(kv, None)
    }

    /// Like [`ShardedModel::decoder`], but with an optional paged-KV
    /// budget: the global [`PoolCfg`] splits into shard-local sub-pools
    /// proportional to each shard's layer count (`tsgo serve --shards N
    /// --kv-pool-mb M`).
    pub fn decoder_pooled(&self, kv: KvSpec, pool: Option<PoolCfg>) -> ShardedDecoder {
        ShardedDecoder::new_pooled(self.inner.clone(), &self.plan, kv, pool)
    }
}

impl<M: ModelExec> ModelExec for ShardedModel<M> {
    type Layer = M::Layer;

    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn embed_row(&self, token: u8) -> &[f32] {
        self.inner.embed_row(token)
    }

    fn layers(&self) -> &[M::Layer] {
        self.inner.layers()
    }

    fn ln_f(&self) -> &[f32] {
        self.inner.ln_f()
    }

    fn apply_head(&self, x: &Matrix) -> Matrix {
        self.inner.apply_head(x)
    }

    fn embed_bytes(&self) -> usize {
        self.inner.embed_bytes()
    }

    fn head_bytes(&self) -> usize {
        self.inner.head_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{forward_logits, ModelWeights, Preset};
    use crate::util::rng::Rng;

    #[test]
    fn delegation_preserves_logits_and_stats() {
        let mut rng = Rng::new(9);
        let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
        let tokens: Vec<u8> = (0..10).map(|i| i * 11).collect();
        let want = forward_logits(&w, &tokens);
        let sm = ShardedModel::new(Arc::new(w), 2);
        assert_eq!(sm.plan().n_shards(), 2);
        let got = forward_logits(&sm, &tokens);
        for (a, b) in want.data.iter().zip(&got.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // accounting: shard bytes sum to the whole deployed model
        let total: usize =
            (0..sm.plan().n_shards()).map(|s| sm.plan().weight_bytes(s)).sum();
        use crate::model::BlockLinears;
        let expect: usize = sm.layers().iter().map(|l| l.weight_bytes()).sum::<usize>()
            + sm.embed_bytes()
            + sm.head_bytes();
        assert_eq!(total, expect);
    }

    #[test]
    fn banner_names_every_shard_and_extras() {
        let mut rng = Rng::new(10);
        let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
        let sm = ShardedModel::new(Arc::new(w), 2);
        let lines = sm.banner_lines(KvSpec::PackedGroupwise { bits: 8, group: 64 });
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("2 shards"), "{}", lines[0]);
        assert!(lines[0].contains("int8"), "{}", lines[0]);
        assert!(lines[1].contains("+embed") && !lines[1].contains("+head"), "{}", lines[1]);
        assert!(lines[2].contains("+head") && !lines[2].contains("+embed"), "{}", lines[2]);
    }
}
