//! The process-wide metric registry: every counter, gauge, histogram, and
//! the trace ring, as one `static` struct of atomics.
//!
//! Design rules, in order:
//!
//! 1. **No locks, no allocation on the hot path.** Every mutation is a
//!    relaxed atomic RMW on a field that exists at compile time. The
//!    scheduler's `step()` loop, the KV pool's `alloc`/`release`, and the
//!    shard workers all record through this registry, so the unarmed cost
//!    must stay at "a handful of uncontended `fetch_add`s" — priced by the
//!    `decode.packed_int2_metrics_tokens_per_s` bench row the same way the
//!    fault plane's unarmed cost is priced.
//! 2. **Process scope, delta discipline.** The registry is global (one per
//!    process, like [`crate::util::fault`]'s plane), so components that are
//!    created many times per process — KV pools, batchers, test servers —
//!    must update gauges by *delta* (`add`/`sub`), never by absolute
//!    `set`, or concurrent instances would clobber each other.
//! 3. **Snapshots are per-metric monotonic, not cross-metric atomic** —
//!    the same contract a Prometheus scrape of a live process has.

use super::hist::Hist;
use super::trace::Ring;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering::Relaxed};

/// Monotonic event counter.
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }
    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }
    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

/// Signed instantaneous-level gauge. Multi-instance components update by
/// delta so concurrent instances compose instead of clobbering.
pub struct Gauge(AtomicI64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicI64::new(0))
    }
    /// Add `d`, returning the new value (used to feed peak gauges).
    #[inline]
    pub fn add(&self, d: i64) -> i64 {
        self.0.fetch_add(d, Relaxed) + d
    }
    /// Subtract `d`.
    #[inline]
    pub fn sub(&self, d: i64) {
        self.0.fetch_sub(d, Relaxed);
    }
    /// Overwrite the level. Only for single-writer gauges (e.g. the
    /// scheduler loop publishing its own batch size).
    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Relaxed);
    }
    /// Ratchet the gauge up to at least `v` (peak tracking).
    #[inline]
    pub fn ratchet(&self, v: i64) {
        self.0.fetch_max(v, Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge::new()
    }
}

/// Every metric the serving stack records, as one flat struct. Field names
/// are the wire names: `snake_case` here becomes `tsgo_<name>[_total]` in
/// the Prometheus exposition and the key under `"counters"`/`"gauges"`/
/// `"hist"` in the `{"stats": true}` snapshot.
pub struct Registry {
    // --- scheduler ---
    /// Batch steps executed by the scheduler loop.
    pub steps: Counter,
    /// Prompt tokens fed through prefill spans.
    pub prefill_tokens: Counter,
    /// Generated-token positions fed through decode steps.
    pub decode_tokens: Counter,
    /// Admission verdicts: request seated into a slot.
    pub admit_slot: Counter,
    /// Admission verdicts: request deferred (no slot / no pool headroom).
    pub admit_defer: Counter,
    /// Admission verdicts: request rejected outright.
    pub admit_reject: Counter,
    /// Sequences preempted by pool pressure (replayed later).
    pub preemptions: Counter,
    /// Decode workers respawned after a panic (process lifetime).
    pub worker_restarts: Counter,
    /// Shard chains torn down and rebuilt (process lifetime).
    pub pipeline_rebuilds: Counter,
    /// Requests finished with `finish_reason: "length"`.
    pub finish_length: Counter,
    /// Requests finished with `finish_reason: "stop"`.
    pub finish_stop: Counter,
    /// Requests finished with `finish_reason: "timeout"`.
    pub finish_timeout: Counter,
    /// Requests finished with `finish_reason: "error"`.
    pub finish_error: Counter,

    // --- KV pool ---
    /// Pages newly minted (vs. recycled from the free list).
    pub kv_pages_minted: Counter,

    // --- server ---
    /// Connections accepted over the process lifetime.
    pub connections_total: Counter,
    /// Requests answered with a normal generation response.
    pub requests_ok: Counter,
    /// Requests answered with an error line.
    pub requests_error: Counter,
    /// Requests bounced at enqueue because the queue was full.
    pub overload_rejected: Counter,

    // --- gauges ---
    /// Requests waiting in the admission queue.
    pub queue_depth: Gauge,
    /// Sequences currently holding a scheduler slot.
    pub running_sequences: Gauge,
    /// Live client connections.
    pub active_connections: Gauge,
    /// KV pages currently allocated across all pools.
    pub kv_pages_used: Gauge,
    /// High-water mark of [`Registry::kv_pages_used`].
    pub kv_pages_peak: Gauge,
    /// Page budget of the serving pool (published by the scheduler loop).
    pub kv_pages_total: Gauge,

    // --- histograms (milliseconds) ---
    /// Wall time of one scheduler batch step.
    pub step_ms: Hist,
    /// Per-request prefill time (admission to first generated token).
    pub request_prefill_ms: Hist,
    /// Per-request decode time (first generated token to finish).
    pub request_decode_ms: Hist,
    /// Wall time of one shard worker's span stage.
    pub shard_stage_ms: Hist,

    /// Flight recorder of recent step / shard-stage events.
    pub trace: Ring,
}

impl Registry {
    pub const fn new() -> Self {
        Registry {
            steps: Counter::new(),
            prefill_tokens: Counter::new(),
            decode_tokens: Counter::new(),
            admit_slot: Counter::new(),
            admit_defer: Counter::new(),
            admit_reject: Counter::new(),
            preemptions: Counter::new(),
            worker_restarts: Counter::new(),
            pipeline_rebuilds: Counter::new(),
            finish_length: Counter::new(),
            finish_stop: Counter::new(),
            finish_timeout: Counter::new(),
            finish_error: Counter::new(),
            kv_pages_minted: Counter::new(),
            connections_total: Counter::new(),
            requests_ok: Counter::new(),
            requests_error: Counter::new(),
            overload_rejected: Counter::new(),
            queue_depth: Gauge::new(),
            running_sequences: Gauge::new(),
            active_connections: Gauge::new(),
            kv_pages_used: Gauge::new(),
            kv_pages_peak: Gauge::new(),
            kv_pages_total: Gauge::new(),
            step_ms: Hist::new(),
            request_prefill_ms: Hist::new(),
            request_decode_ms: Hist::new(),
            shard_stage_ms: Hist::new(),
            trace: Ring::new(),
        }
    }

    /// Count one finished request under its [`FinishReason`] label.
    ///
    /// [`FinishReason`]: crate::serve::FinishReason
    pub fn count_finish(&self, reason: crate::serve::FinishReason) {
        use crate::serve::FinishReason::*;
        match reason {
            Length => self.finish_length.inc(),
            Stop => self.finish_stop.inc(),
            Timeout => self.finish_timeout.inc(),
            Error => self.finish_error.inc(),
        }
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

/// The process-wide registry. Like the fault plane, there is exactly one
/// per process: tests that assert on its counters must either take deltas
/// around the work they provoke or assert `>=`.
pub fn registry() -> &'static Registry {
    static REGISTRY: Registry = Registry::new();
    &REGISTRY
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_do_arithmetic() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);

        let g = Gauge::new();
        assert_eq!(g.add(3), 3);
        g.sub(1);
        assert_eq!(g.get(), 2);
        g.ratchet(10);
        g.ratchet(7); // no-op: ratchet never lowers
        assert_eq!(g.get(), 10);
        g.set(-1);
        assert_eq!(g.get(), -1);
    }

    #[test]
    fn registry_is_a_process_singleton() {
        assert!(std::ptr::eq(registry(), registry()));
    }

    #[test]
    fn snapshots_are_monotone_under_concurrent_writers() {
        // A local registry so the test owns every write to it.
        let reg = Box::leak(Box::new(Registry::new()));
        let writers: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || {
                    for i in 0..2_000u64 {
                        reg.steps.inc();
                        reg.decode_tokens.add(3);
                        reg.step_ms.observe_us(i % 700);
                    }
                })
            })
            .collect();
        let (mut steps, mut toks, mut hist_count) = (0u64, 0u64, 0u64);
        for _ in 0..500 {
            let s = reg.steps.get();
            let t = reg.decode_tokens.get();
            let h = reg.step_ms.snapshot();
            assert!(s >= steps, "steps went backwards: {s} < {steps}");
            assert!(t >= toks, "tokens went backwards");
            assert!(h.count >= hist_count, "hist count went backwards");
            assert!(
                h.buckets.iter().sum::<u64>() >= hist_count,
                "bucket sum fell behind a previously seen count"
            );
            steps = s;
            toks = t;
            hist_count = h.count;
        }
        for w in writers {
            w.join().unwrap();
        }
        assert_eq!(reg.steps.get(), 8_000);
        assert_eq!(reg.decode_tokens.get(), 24_000);
        assert_eq!(reg.step_ms.snapshot().count, 8_000);
    }
}
