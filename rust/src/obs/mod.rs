//! Serving telemetry plane: a lock-free metrics registry, a step-trace
//! flight recorder, and two readout surfaces.
//!
//! The serving stack computes queue depths, span splits, stage timings,
//! pool occupancy, and recovery counts at every step — and, before this
//! module, threw all of it away after stamping a few fields onto each
//! [`GenResponse`]. The telemetry plane keeps those numbers, under the
//! same discipline the fault plane ([`crate::util::fault`]) set for
//! process-global infrastructure touching the hot path:
//!
//! * **Atomics only where the scheduler steps.** Recording a counter, a
//!   gauge delta, a histogram observation, or a trace event is a handful
//!   of relaxed `fetch_add`s on `static` storage — no locks, no
//!   allocation, no syscalls. The cost is priced by the
//!   `decode.packed_int2_metrics_tokens_per_s` bench row next to the
//!   fault plane's `fault_{unarmed,armed}` rows.
//! * **One registry per process.** [`registry()`] returns the singleton
//!   every layer records into: the scheduler (steps, admissions,
//!   preemptions, latency histograms), the KV pool (page gauges), the
//!   shard workers (stage times, rebuilds), and the server front door
//!   (connections, request outcomes).
//! * **Reads are scrape-consistent.** Snapshots are relaxed loads:
//!   per-metric monotonic, not cross-metric atomic — exactly what a
//!   Prometheus scrape of a live process gives you.
//!
//! Readout surfaces:
//!
//! * `{"stats": true}` on the serve protocol → [`snapshot_json`] (see
//!   `docs/SERVE_API.md` for the schema and the metric reference table);
//!   `tsgo stats HOST:PORT` pretty-prints it client-side.
//! * `tsgo serve --metrics-addr HOST:PORT` → [`serve_metrics`], Prometheus
//!   text exposition on a dedicated listener thread.
//!
//! [`GenResponse`]: crate::serve::GenResponse

pub mod export;
pub mod hist;
pub mod registry;
pub mod trace;

pub use export::{
    prometheus_text, registry_snapshot_json, render_prometheus, serve_metrics, snapshot_json,
};
pub use hist::{Hist, HistSnapshot, BUCKET_BOUNDS_US, NUM_BUCKETS};
pub use registry::{registry, Counter, Gauge, Registry};
pub use trace::{Ring, StepEvent, RING_CAPACITY, SOURCE_SCHED};
