//! Fixed-bucket latency histograms with lock-free recording.
//!
//! The bucket layout is frozen at compile time (geometric-ish bounds from
//! 50 µs to 5 s, plus a +Inf overflow bucket) so [`Hist::observe_us`] is a
//! short integer scan plus three relaxed `fetch_add`s — no locks, no
//! allocation, no floating point on the hot path. Quantiles are computed at
//! *read* time by walking the bucket counts and linearly interpolating
//! inside the bucket that crosses the target rank, the same estimate a
//! Prometheus `histogram_quantile` would produce from the exported
//! `_bucket` series.
//!
//! Readers and writers never synchronize: a [`HistSnapshot`] is a relaxed
//! copy of the counts, which is exactly as consistent as a Prometheus
//! scrape of a live process (per-counter monotonic, not cross-counter
//! atomic).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Upper bounds of the finite buckets, in microseconds. The last implicit
/// bucket is +Inf. Bounds are chosen to resolve both sub-millisecond decode
/// steps and multi-second chunked prefills.
pub const BUCKET_BOUNDS_US: [u64; 16] = [
    50,
    100,
    250,
    500,
    1_000,
    2_500,
    5_000,
    10_000,
    25_000,
    50_000,
    100_000,
    250_000,
    500_000,
    1_000_000,
    2_500_000,
    5_000_000,
];

/// Total bucket count including the +Inf overflow bucket.
pub const NUM_BUCKETS: usize = BUCKET_BOUNDS_US.len() + 1;

/// Lock-free fixed-bucket histogram. `const`-constructible so it can live in
/// a `static` registry; every mutation is a relaxed atomic add.
pub struct Hist {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Hist {
    /// A zeroed histogram, usable in `static` position.
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Hist {
            buckets: [Z; NUM_BUCKETS],
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one observation of `us` microseconds. Hot-path safe: integer
    /// compares + three relaxed atomic adds.
    #[inline]
    pub fn observe_us(&self, us: u64) {
        let mut idx = BUCKET_BOUNDS_US.len();
        for (i, &bound) in BUCKET_BOUNDS_US.iter().enumerate() {
            if us <= bound {
                idx = i;
                break;
            }
        }
        self.buckets[idx].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_us.fetch_add(us, Relaxed);
    }

    /// Record a [`std::time::Duration`] observation.
    #[inline]
    pub fn observe(&self, d: std::time::Duration) {
        self.observe_us(d.as_micros() as u64);
    }

    /// Relaxed point-in-time copy of the counts.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; NUM_BUCKETS];
        for (b, a) in buckets.iter_mut().zip(self.buckets.iter()) {
            *b = a.load(Relaxed);
        }
        HistSnapshot {
            buckets,
            count: self.count.load(Relaxed),
            sum_us: self.sum_us.load(Relaxed),
        }
    }
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

/// Plain-integer copy of a [`Hist`], the unit all readout works on.
#[derive(Clone, Copy, Debug)]
pub struct HistSnapshot {
    /// Per-bucket (non-cumulative) observation counts; the last entry is the
    /// +Inf overflow bucket.
    pub buckets: [u64; NUM_BUCKETS],
    /// Total observations.
    pub count: u64,
    /// Sum of all observed values, microseconds.
    pub sum_us: u64,
}

impl HistSnapshot {
    /// Estimated `q`-quantile (`0.0 ..= 1.0`) in **milliseconds**, by linear
    /// interpolation inside the bucket that crosses the target rank.
    /// Observations landing in the +Inf bucket clamp to the largest finite
    /// bound (the Prometheus convention). Returns 0.0 when empty.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            let prev = cum;
            cum += n;
            if (cum as f64) >= target && n > 0 {
                let hi = if i < BUCKET_BOUNDS_US.len() {
                    BUCKET_BOUNDS_US[i]
                } else {
                    // +Inf bucket: clamp the estimate to the largest finite
                    // bound rather than extrapolating.
                    return BUCKET_BOUNDS_US[BUCKET_BOUNDS_US.len() - 1] as f64 / 1_000.0;
                };
                let lo = if i == 0 { 0 } else { BUCKET_BOUNDS_US[i - 1] };
                let frac = ((target - prev as f64) / n as f64).clamp(0.0, 1.0);
                return (lo as f64 + frac * (hi - lo) as f64) / 1_000.0;
            }
        }
        BUCKET_BOUNDS_US[BUCKET_BOUNDS_US.len() - 1] as f64 / 1_000.0
    }

    /// Mean observation in milliseconds (0.0 when empty).
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64 / 1_000.0
        }
    }

    /// Sum of observations in milliseconds.
    pub fn sum_ms(&self) -> f64 {
        self.sum_us as f64 / 1_000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Hist::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.quantile_ms(0.5), 0.0);
        assert_eq!(s.mean_ms(), 0.0);
    }

    #[test]
    fn observations_land_in_the_right_bucket() {
        let h = Hist::new();
        h.observe_us(50); // boundary: le=50 bucket
        h.observe_us(51); // next bucket
        h.observe_us(7_000_000); // beyond the last bound: +Inf
        let s = h.snapshot();
        assert_eq!(s.buckets[0], 1);
        assert_eq!(s.buckets[1], 1);
        assert_eq!(s.buckets[NUM_BUCKETS - 1], 1);
        assert_eq!(s.count, 3);
        assert_eq!(s.sum_us, 50 + 51 + 7_000_000);
    }

    #[test]
    fn quantiles_bracket_a_uniform_stream() {
        let h = Hist::new();
        // 1..=1000 µs uniformly: p50 ≈ 0.5 ms, p99 ≈ 0.99 ms.
        for us in 1..=1000u64 {
            h.observe_us(us);
        }
        let s = h.snapshot();
        let p50 = s.quantile_ms(0.50);
        let p99 = s.quantile_ms(0.99);
        assert!((0.25..=0.75).contains(&p50), "p50 = {p50}");
        assert!((0.75..=1.0).contains(&p99), "p99 = {p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn overflow_quantile_clamps_to_last_finite_bound() {
        let h = Hist::new();
        for _ in 0..10 {
            h.observe_us(100_000_000);
        }
        let s = h.snapshot();
        assert_eq!(s.quantile_ms(0.99), 5_000.0);
    }

    #[test]
    fn prop_quantile_is_monotone_and_bounded() {
        check("hist quantile monotone/bounded", 200, |g| {
            let h = Hist::new();
            let n = g.usize_in(1, 200);
            let mut max_us = 0u64;
            for _ in 0..n {
                // span several decades so every bucket region gets hit
                let us = g.usize_in(1, 8_000_000) as u64;
                max_us = max_us.max(us);
                h.observe_us(us);
            }
            let s = h.snapshot();
            prop_assert(s.count == n as u64, "count matches observations")?;
            let mut prev = 0.0f64;
            for &q in &[0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0] {
                let v = s.quantile_ms(q);
                prop_assert(v >= prev, "quantile is monotone in q")?;
                prop_assert(v >= 0.0, "quantile non-negative")?;
                prop_assert(
                    v <= BUCKET_BOUNDS_US[BUCKET_BOUNDS_US.len() - 1] as f64 / 1_000.0,
                    "quantile clamped to largest bound",
                )?;
                prev = v;
            }
            // the q=1.0 estimate must not undershoot the bucket holding the max
            let max_ms_bucket_lo = BUCKET_BOUNDS_US
                .iter()
                .rev()
                .find(|&&b| b < max_us)
                .copied()
                .unwrap_or(0) as f64
                / 1_000.0;
            prop_assert(
                s.quantile_ms(1.0) >= max_ms_bucket_lo.min(5_000.0) - 1e-9,
                "q=1.0 reaches the max's bucket",
            )
        });
    }

    #[test]
    fn prop_bucket_counts_partition_the_stream() {
        check("hist buckets partition", 100, |g| {
            let h = Hist::new();
            let n = g.usize_in(0, 100);
            for _ in 0..n {
                h.observe_us(g.usize_in(0, 6_000_000) as u64);
            }
            let s = h.snapshot();
            let total: u64 = s.buckets.iter().sum();
            prop_assert(total == s.count, "bucket counts sum to count")
        });
    }
}
