//! Readout for the registry: structured JSON snapshots (the
//! `{"stats": true}` control line), Prometheus text exposition, and the
//! dedicated scrape listener behind `tsgo serve --metrics-addr`.
//!
//! Everything here is read-path only — rendering loads the same relaxed
//! atomics the hot paths write, allocates freely, and never blocks a
//! writer. The exposition format is Prometheus text format 0.0.4
//! (`# HELP` / `# TYPE` preambles, cumulative `_bucket{le="..."}` series
//! per histogram), served over a minimal hand-rolled HTTP/1.0 responder so
//! the crate stays dependency-free.

use super::hist::{HistSnapshot, BUCKET_BOUNDS_US};
use super::registry::{registry, Registry};
use super::trace::SOURCE_SCHED;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};

/// How many trace events a snapshot includes.
const SNAPSHOT_TRACE_EVENTS: usize = 16;

/// Structured snapshot of the whole registry as a [`Json`] object with
/// `"counters"`, `"gauges"`, `"hist"`, and `"trace"` sections. This is
/// the entire `{"stats": true}` reply line and the input `tsgo stats`
/// pretty-prints.
pub fn snapshot_json() -> Json {
    registry_snapshot_json(registry())
}

/// [`snapshot_json`] over an explicit registry (unit tests use locals).
pub fn registry_snapshot_json(r: &Registry) -> Json {
    let counters = Json::obj(vec![
        ("steps", Json::num(r.steps.get() as f64)),
        ("prefill_tokens", Json::num(r.prefill_tokens.get() as f64)),
        ("decode_tokens", Json::num(r.decode_tokens.get() as f64)),
        ("admit_slot", Json::num(r.admit_slot.get() as f64)),
        ("admit_defer", Json::num(r.admit_defer.get() as f64)),
        ("admit_reject", Json::num(r.admit_reject.get() as f64)),
        ("preemptions", Json::num(r.preemptions.get() as f64)),
        ("worker_restarts", Json::num(r.worker_restarts.get() as f64)),
        (
            "pipeline_rebuilds",
            Json::num(r.pipeline_rebuilds.get() as f64),
        ),
        ("finish_length", Json::num(r.finish_length.get() as f64)),
        ("finish_stop", Json::num(r.finish_stop.get() as f64)),
        ("finish_timeout", Json::num(r.finish_timeout.get() as f64)),
        ("finish_error", Json::num(r.finish_error.get() as f64)),
        ("kv_pages_minted", Json::num(r.kv_pages_minted.get() as f64)),
        (
            "connections_total",
            Json::num(r.connections_total.get() as f64),
        ),
        ("requests_ok", Json::num(r.requests_ok.get() as f64)),
        ("requests_error", Json::num(r.requests_error.get() as f64)),
        (
            "overload_rejected",
            Json::num(r.overload_rejected.get() as f64),
        ),
    ]);
    let gauges = Json::obj(vec![
        ("queue_depth", Json::num(r.queue_depth.get() as f64)),
        (
            "running_sequences",
            Json::num(r.running_sequences.get() as f64),
        ),
        (
            "active_connections",
            Json::num(r.active_connections.get() as f64),
        ),
        ("kv_pages_used", Json::num(r.kv_pages_used.get() as f64)),
        ("kv_pages_peak", Json::num(r.kv_pages_peak.get() as f64)),
        ("kv_pages_total", Json::num(r.kv_pages_total.get() as f64)),
    ]);
    let hist = Json::obj(vec![
        ("step_ms", hist_json(&r.step_ms.snapshot())),
        (
            "request_prefill_ms",
            hist_json(&r.request_prefill_ms.snapshot()),
        ),
        (
            "request_decode_ms",
            hist_json(&r.request_decode_ms.snapshot()),
        ),
        ("shard_stage_ms", hist_json(&r.shard_stage_ms.snapshot())),
    ]);
    let trace = Json::arr(r.trace.recent(SNAPSHOT_TRACE_EVENTS).into_iter().map(|e| {
        let source = if e.source == SOURCE_SCHED {
            "sched".to_string()
        } else {
            format!("shard:{}", e.source)
        };
        Json::obj(vec![
            ("seq", Json::num(e.seq as f64)),
            ("source", Json::str(&source)),
            ("batch", Json::num(e.batch)),
            ("prefill_tokens", Json::num(e.prefill_tokens)),
            ("decode_tokens", Json::num(e.decode_tokens)),
            ("dur_us", Json::num(e.dur_us as f64)),
            ("preempted", Json::num(e.preempted)),
            ("restarts", Json::num(e.restarts)),
        ])
    }));
    Json::obj(vec![
        ("counters", counters),
        ("gauges", gauges),
        ("hist", hist),
        ("trace", trace),
    ])
}

fn hist_json(s: &HistSnapshot) -> Json {
    Json::obj(vec![
        ("count", Json::num(s.count as f64)),
        ("sum_ms", Json::num(s.sum_ms())),
        ("mean_ms", Json::num(s.mean_ms())),
        ("p50_ms", Json::num(s.quantile_ms(0.50))),
        ("p95_ms", Json::num(s.quantile_ms(0.95))),
        ("p99_ms", Json::num(s.quantile_ms(0.99))),
    ])
}

/// Render the global registry in Prometheus text exposition format 0.0.4.
pub fn prometheus_text() -> String {
    render_prometheus(registry())
}

/// [`prometheus_text`] over an explicit registry.
pub fn render_prometheus(r: &Registry) -> String {
    let mut s = String::with_capacity(4096);
    counter(&mut s, "tsgo_steps_total", "Scheduler batch steps executed.", r.steps.get());
    counter(
        &mut s,
        "tsgo_prefill_tokens_total",
        "Prompt tokens fed through prefill spans.",
        r.prefill_tokens.get(),
    );
    counter(
        &mut s,
        "tsgo_decode_tokens_total",
        "Generated-token positions fed through decode steps.",
        r.decode_tokens.get(),
    );
    labeled(
        &mut s,
        "tsgo_admit_verdicts_total",
        "Admission verdicts by outcome.",
        "verdict",
        &[
            ("slot", r.admit_slot.get()),
            ("defer", r.admit_defer.get()),
            ("reject", r.admit_reject.get()),
        ],
    );
    counter(
        &mut s,
        "tsgo_preemptions_total",
        "Sequences preempted by pool pressure.",
        r.preemptions.get(),
    );
    counter(
        &mut s,
        "tsgo_worker_restarts_total",
        "Decode workers respawned after a panic.",
        r.worker_restarts.get(),
    );
    counter(
        &mut s,
        "tsgo_pipeline_rebuilds_total",
        "Shard chains torn down and rebuilt.",
        r.pipeline_rebuilds.get(),
    );
    labeled(
        &mut s,
        "tsgo_requests_finished_total",
        "Finished requests by finish_reason.",
        "reason",
        &[
            ("length", r.finish_length.get()),
            ("stop", r.finish_stop.get()),
            ("timeout", r.finish_timeout.get()),
            ("error", r.finish_error.get()),
        ],
    );
    counter(
        &mut s,
        "tsgo_kv_pages_minted_total",
        "KV pages newly minted (not recycled).",
        r.kv_pages_minted.get(),
    );
    counter(
        &mut s,
        "tsgo_connections_total",
        "Client connections accepted.",
        r.connections_total.get(),
    );
    labeled(
        &mut s,
        "tsgo_requests_total",
        "Requests answered, by outcome.",
        "outcome",
        &[
            ("ok", r.requests_ok.get()),
            ("error", r.requests_error.get()),
        ],
    );
    counter(
        &mut s,
        "tsgo_overload_rejected_total",
        "Requests bounced at enqueue because the queue was full.",
        r.overload_rejected.get(),
    );
    gauge(&mut s, "tsgo_queue_depth", "Requests waiting in the admission queue.", r.queue_depth.get());
    gauge(
        &mut s,
        "tsgo_running_sequences",
        "Sequences currently holding a scheduler slot.",
        r.running_sequences.get(),
    );
    gauge(
        &mut s,
        "tsgo_active_connections",
        "Live client connections.",
        r.active_connections.get(),
    );
    gauge(
        &mut s,
        "tsgo_kv_pages_used",
        "KV pages currently allocated across all pools.",
        r.kv_pages_used.get(),
    );
    gauge(
        &mut s,
        "tsgo_kv_pages_peak",
        "High-water mark of tsgo_kv_pages_used.",
        r.kv_pages_peak.get(),
    );
    gauge(
        &mut s,
        "tsgo_kv_pages_total",
        "Page budget of the serving pool.",
        r.kv_pages_total.get(),
    );
    histogram(
        &mut s,
        "tsgo_step_latency_ms",
        "Wall time of one scheduler batch step (ms).",
        &r.step_ms.snapshot(),
    );
    histogram(
        &mut s,
        "tsgo_request_prefill_ms",
        "Per-request prefill time (ms).",
        &r.request_prefill_ms.snapshot(),
    );
    histogram(
        &mut s,
        "tsgo_request_decode_ms",
        "Per-request decode time (ms).",
        &r.request_decode_ms.snapshot(),
    );
    histogram(
        &mut s,
        "tsgo_shard_stage_ms",
        "Wall time of one shard worker's span stage (ms).",
        &r.shard_stage_ms.snapshot(),
    );
    s
}

fn counter(s: &mut String, name: &str, help: &str, v: u64) {
    use std::fmt::Write as _;
    let _ = writeln!(s, "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}");
}

fn gauge(s: &mut String, name: &str, help: &str, v: i64) {
    use std::fmt::Write as _;
    let _ = writeln!(s, "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}");
}

fn labeled(s: &mut String, name: &str, help: &str, label: &str, series: &[(&str, u64)]) {
    use std::fmt::Write as _;
    let _ = writeln!(s, "# HELP {name} {help}\n# TYPE {name} counter");
    for (value, v) in series {
        let _ = writeln!(s, "{name}{{{label}=\"{value}\"}} {v}");
    }
}

fn histogram(s: &mut String, name: &str, help: &str, snap: &HistSnapshot) {
    use std::fmt::Write as _;
    let _ = writeln!(s, "# HELP {name} {help}\n# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, &n) in snap.buckets.iter().enumerate() {
        cum += n;
        if i < BUCKET_BOUNDS_US.len() {
            let le = BUCKET_BOUNDS_US[i] as f64 / 1_000.0;
            let _ = writeln!(s, "{name}_bucket{{le=\"{le}\"}} {cum}");
        } else {
            let _ = writeln!(s, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        }
    }
    let _ = writeln!(s, "{name}_sum {}", snap.sum_ms());
    let _ = writeln!(s, "{name}_count {}", snap.count);
}

/// Bind `addr` and serve Prometheus scrapes of the global registry on a
/// dedicated `tsgo-metrics` thread. Returns the bound address (so
/// `HOST:0` callers — tests — learn the real port). The thread runs for
/// the life of the process; scrapes are handled serially, which is how
/// Prometheus polls anyway.
pub fn serve_metrics(addr: &str) -> std::io::Result<SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    std::thread::Builder::new()
        .name("tsgo-metrics".into())
        .spawn(move || {
            for mut stream in listener.incoming().flatten() {
                let _ = handle_scrape(&mut stream);
            }
        })
        .expect("spawn tsgo-metrics listener thread");
    Ok(local)
}

fn handle_scrape(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(5)))?;
    let mut request_line = String::new();
    BufReader::new(&mut *stream).read_line(&mut request_line)?;
    let path = request_line.split_whitespace().nth(1).unwrap_or("/");
    let (status, content_type, body) = if path == "/metrics" || path == "/" {
        (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            prometheus_text(),
        )
    } else {
        (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found; scrape /metrics\n".to_string(),
        )
    };
    write!(
        stream,
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_json_has_all_sections_and_parses_back() {
        let r = Registry::new();
        r.steps.add(7);
        r.queue_depth.set(3);
        r.step_ms.observe_us(1_234);
        r.trace.record(&crate::obs::StepEvent {
            seq: 0,
            source: SOURCE_SCHED,
            batch: 2,
            prefill_tokens: 6,
            decode_tokens: 2,
            dur_us: 1_234,
            preempted: 0,
            restarts: 0,
        });
        let j = registry_snapshot_json(&r);
        let round = Json::parse(&j.to_string()).expect("snapshot is valid JSON");
        assert_eq!(round.get("counters").get("steps").as_f64(), Some(7.0));
        assert_eq!(round.get("gauges").get("queue_depth").as_f64(), Some(3.0));
        let h = round.get("hist").get("step_ms");
        assert_eq!(h.get("count").as_f64(), Some(1.0));
        assert!(h.get("p50_ms").as_f64().unwrap() > 0.0);
        let trace = round.get("trace").as_arr().expect("trace array");
        assert_eq!(trace.len(), 1);
        assert_eq!(trace[0].get("source").as_str(), Some("sched"));
        assert_eq!(trace[0].get("batch").as_f64(), Some(2.0));
    }

    #[test]
    fn prometheus_rendering_is_well_formed() {
        let r = Registry::new();
        r.decode_tokens.add(42);
        r.finish_stop.add(2);
        r.step_ms.observe_us(900);
        r.step_ms.observe_us(90_000);
        let text = render_prometheus(&r);
        assert!(text.contains("# TYPE tsgo_decode_tokens_total counter"));
        assert!(text.contains("tsgo_decode_tokens_total 42"));
        assert!(text.contains("tsgo_requests_finished_total{reason=\"stop\"} 2"));
        assert!(text.contains("# TYPE tsgo_step_latency_ms histogram"));
        assert!(text.contains("tsgo_step_latency_ms_bucket{le=\"1\"} 1"));
        assert!(text.contains("tsgo_step_latency_ms_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("tsgo_step_latency_ms_count 2"));
        // every HELP has a TYPE and cumulative buckets never decrease
        let helps = text.matches("# HELP").count();
        let types = text.matches("# TYPE").count();
        assert_eq!(helps, types);
        let mut prev = 0u64;
        for line in text.lines().filter(|l| l.starts_with("tsgo_step_latency_ms_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "cumulative bucket decreased: {line}");
            prev = v;
        }
    }

    #[test]
    fn scrape_listener_answers_http() {
        let addr = serve_metrics("127.0.0.1:0").expect("bind scrape listener");
        let mut conn = TcpStream::connect(addr).expect("connect");
        write!(conn, "GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut body = String::new();
        use std::io::Read as _;
        conn.read_to_string(&mut body).unwrap();
        assert!(body.starts_with("HTTP/1.0 200 OK\r\n"), "got: {body}");
        assert!(body.contains("Content-Type: text/plain; version=0.0.4"));
        assert!(body.contains("tsgo_steps_total"));

        let mut conn = TcpStream::connect(addr).expect("connect");
        write!(conn, "GET /nope HTTP/1.0\r\n\r\n").unwrap();
        let mut body = String::new();
        conn.read_to_string(&mut body).unwrap();
        assert!(body.starts_with("HTTP/1.0 404"), "got: {body}");
    }
}
