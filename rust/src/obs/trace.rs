//! Step-level tracing: a fixed-capacity, lock-free ring of recent events.
//!
//! Every scheduler step and every shard stage records one [`StepEvent`].
//! The ring is a per-slot seqlock: the writer claims a slot with one
//! `fetch_add` on the cursor, tags the slot odd while the fields are being
//! stored, then tags it even with the sequence number encoded. Readers
//! ([`Ring::recent`]) re-check the tag around the field loads and simply
//! drop torn or overwritten slots — a reader can never block a writer, and
//! the writer never allocates or spins.
//!
//! Capacity is deliberately small ([`RING_CAPACITY`]): this is a flight
//! recorder for "what were the last few steps shaped like", not an event
//! log. Long-horizon aggregates belong to the counters and histograms in
//! [`super::registry`].

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Number of events retained; older events are overwritten.
pub const RING_CAPACITY: usize = 64;

/// Sentinel `source` value for events recorded by the scheduler step loop
/// (shard workers record their shard index instead).
pub const SOURCE_SCHED: u32 = u32::MAX;

/// One recorded event: a scheduler step or a shard stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepEvent {
    /// Monotonic sequence number (process-wide, shared by all sources).
    pub seq: u64,
    /// [`SOURCE_SCHED`] for scheduler steps, else the shard index.
    pub source: u32,
    /// Sequences in the batch (scheduler) or spans in the stage (shard).
    pub batch: u32,
    /// Prompt tokens fed this step (prefill side of the span split).
    pub prefill_tokens: u32,
    /// Generated-token positions fed this step (decode side).
    pub decode_tokens: u32,
    /// Wall time of the step / stage, microseconds.
    pub dur_us: u64,
    /// Sequences preempted by pool pressure immediately before this step.
    pub preempted: u32,
    /// Worker restarts + pipeline rebuilds that surfaced during this step.
    pub restarts: u32,
}

/// One ring slot. `tag` is `2*seq + 1` while the writer is mid-store and
/// `2*seq + 2` once the fields are consistent; readers accept only even
/// tags that match before and after the field loads.
struct Slot {
    tag: AtomicU64,
    source: AtomicU32,
    batch: AtomicU32,
    prefill_tokens: AtomicU32,
    decode_tokens: AtomicU32,
    dur_us: AtomicU64,
    preempted: AtomicU32,
    restarts: AtomicU32,
}

impl Slot {
    const fn new() -> Self {
        Slot {
            tag: AtomicU64::new(0),
            source: AtomicU32::new(0),
            batch: AtomicU32::new(0),
            prefill_tokens: AtomicU32::new(0),
            decode_tokens: AtomicU32::new(0),
            dur_us: AtomicU64::new(0),
            preempted: AtomicU32::new(0),
            restarts: AtomicU32::new(0),
        }
    }
}

/// Lock-free flight recorder of the last [`RING_CAPACITY`] events.
pub struct Ring {
    cursor: AtomicU64,
    slots: [Slot; RING_CAPACITY],
}

impl Ring {
    /// An empty ring, usable in `static` position.
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const S: Slot = Slot::new();
        Ring {
            cursor: AtomicU64::new(0),
            slots: [S; RING_CAPACITY],
        }
    }

    /// Number of events ever recorded (not capped by capacity).
    pub fn recorded(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Record one event. The `seq` field of `ev` is ignored; the ring
    /// assigns the next sequence number. Lock-free and allocation-free.
    pub fn record(&self, ev: &StepEvent) {
        let seq = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(seq % RING_CAPACITY as u64) as usize];
        // Odd tag: readers that land mid-write will discard the slot.
        slot.tag.store(2 * seq + 1, Ordering::Release);
        slot.source.store(ev.source, Ordering::Relaxed);
        slot.batch.store(ev.batch, Ordering::Relaxed);
        slot.prefill_tokens
            .store(ev.prefill_tokens, Ordering::Relaxed);
        slot.decode_tokens.store(ev.decode_tokens, Ordering::Relaxed);
        slot.dur_us.store(ev.dur_us, Ordering::Relaxed);
        slot.preempted.store(ev.preempted, Ordering::Relaxed);
        slot.restarts.store(ev.restarts, Ordering::Relaxed);
        // Even tag encoding seq: the slot is now consistent.
        slot.tag.store(2 * seq + 2, Ordering::Release);
    }

    /// The most recent `n` events, newest first. Slots that are mid-write
    /// or already overwritten are skipped, so the result may be shorter
    /// than `n` under heavy concurrent recording.
    pub fn recent(&self, n: usize) -> Vec<StepEvent> {
        let cursor = self.cursor.load(Ordering::Acquire);
        let mut out = Vec::with_capacity(n.min(RING_CAPACITY));
        let span = (n as u64).min(RING_CAPACITY as u64).min(cursor);
        for back in 0..span {
            let seq = cursor - 1 - back;
            if let Some(ev) = self.read_slot(seq) {
                out.push(ev);
            }
        }
        out
    }

    /// Seqlock read of the slot that should hold `seq`; `None` if torn or
    /// overwritten.
    fn read_slot(&self, seq: u64) -> Option<StepEvent> {
        let slot = &self.slots[(seq % RING_CAPACITY as u64) as usize];
        let want = 2 * seq + 2;
        let before = slot.tag.load(Ordering::Acquire);
        if before != want {
            return None;
        }
        let ev = StepEvent {
            seq,
            source: slot.source.load(Ordering::Relaxed),
            batch: slot.batch.load(Ordering::Relaxed),
            prefill_tokens: slot.prefill_tokens.load(Ordering::Relaxed),
            decode_tokens: slot.decode_tokens.load(Ordering::Relaxed),
            dur_us: slot.dur_us.load(Ordering::Relaxed),
            preempted: slot.preempted.load(Ordering::Relaxed),
            restarts: slot.restarts.load(Ordering::Relaxed),
        };
        // Keep the field loads above from sinking past the tag re-check.
        std::sync::atomic::fence(Ordering::Acquire);
        let after = slot.tag.load(Ordering::Acquire);
        (after == want).then_some(ev)
    }
}

impl Default for Ring {
    fn default() -> Self {
        Ring::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(batch: u32, dur_us: u64) -> StepEvent {
        StepEvent {
            seq: 0,
            source: SOURCE_SCHED,
            batch,
            prefill_tokens: 0,
            decode_tokens: batch,
            dur_us,
            preempted: 0,
            restarts: 0,
        }
    }

    #[test]
    fn empty_ring_reads_empty() {
        let r = Ring::new();
        assert_eq!(r.recorded(), 0);
        assert!(r.recent(10).is_empty());
    }

    #[test]
    fn recent_is_newest_first_and_capped() {
        let r = Ring::new();
        for i in 0..10u32 {
            r.record(&ev(i, i as u64));
        }
        let got = r.recent(3);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].batch, 9);
        assert_eq!(got[1].batch, 8);
        assert_eq!(got[2].batch, 7);
        assert_eq!(got[0].seq, 9);
    }

    #[test]
    fn overwrite_keeps_only_the_last_capacity_events() {
        let r = Ring::new();
        let total = RING_CAPACITY as u32 + 17;
        for i in 0..total {
            r.record(&ev(i, 0));
        }
        let got = r.recent(RING_CAPACITY * 2);
        assert_eq!(got.len(), RING_CAPACITY);
        assert_eq!(got[0].batch, total - 1);
        assert_eq!(got.last().unwrap().batch, total - RING_CAPACITY as u32);
    }

    #[test]
    fn concurrent_writers_never_tear_a_reader() {
        use std::sync::Arc;
        let r = Arc::new(Ring::new());
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    for i in 0..500u32 {
                        // every writer stamps batch == dur_us so a torn
                        // read would be visible as a mismatch
                        let v = w * 1000 + i;
                        r.record(&StepEvent {
                            seq: 0,
                            source: w,
                            batch: v,
                            prefill_tokens: v,
                            decode_tokens: v,
                            dur_us: v as u64,
                            preempted: v,
                            restarts: v,
                        });
                    }
                })
            })
            .collect();
        for _ in 0..200 {
            for e in r.recent(RING_CAPACITY) {
                assert_eq!(e.batch as u64, e.dur_us, "torn event: {e:?}");
                assert_eq!(e.batch, e.prefill_tokens);
                assert_eq!(e.batch, e.preempted);
            }
        }
        for w in writers {
            w.join().unwrap();
        }
        assert_eq!(r.recorded(), 4 * 500);
    }
}
