//! Batched generation serving — the deployment story that motivates
//! weight-only quantization (paper §2.2: vLLM / TensorRT-LLM support
//! group-wise formats because decode is memory-bandwidth-bound).
//!
//! A minimal but real serving stack: a TCP line-JSON protocol, a dynamic
//! batcher that coalesces concurrent requests, and KV-cached greedy decoding
//! over either the FP or a quantized checkpoint. The serving bench compares
//! FP vs quantized token throughput and tail latency.

pub mod batcher;
pub mod client;
pub mod server;

pub use batcher::{argmax_token, BatcherConfig, DynamicBatcher, GenRequest, GenResponse};
pub use client::request_generation;
pub use server::{serve, ServerConfig};
