//! Batched generation serving — the deployment story that motivates
//! weight-only quantization (paper §2.2: vLLM / TensorRT-LLM support
//! group-wise formats because decode is memory-bandwidth-bound).
//!
//! A minimal but real serving stack: a TCP line-JSON protocol, a
//! continuous-batching scheduler that admits and retires sequences at every
//! token step (`sched`), and KV-cached greedy decoding over either the FP
//! or a quantized checkpoint — single-worker or layer-sharded
//! pipeline-parallel ([`crate::shard`], `--shards N`). The serving bench
//! compares FP vs quantized token throughput, tail latency, and shard-count
//! scaling.

pub mod batcher;
pub mod client;
pub mod sched;
pub mod server;

pub use batcher::{
    argmax_token, default_prefill_chunk, BatcherConfig, DynamicBatcher, GenRequest, GenResponse,
    Pending, RequestQueue,
};
pub use client::request_generation;
pub use sched::{
    scheduler_loop, AdmitVerdict, LocalBackend, PoolMirror, ShardBackend, StepBackend, StepJob,
};
pub use server::{serve, ServerConfig};
