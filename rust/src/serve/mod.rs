//! Batched generation serving — the deployment story that motivates
//! weight-only quantization (paper §2.2: vLLM / TensorRT-LLM support
//! group-wise formats because decode is memory-bandwidth-bound).
//!
//! A minimal but real serving stack: a TCP line-JSON protocol (documented
//! field-by-field in `docs/SERVE_API.md`), a continuous-batching scheduler
//! that admits and retires sequences at every token step (`sched`), a
//! per-request sampling chain (`sampler`: temperature / top-k / top-p /
//! repetition penalty over seeded multinomial or greedy selection, plus
//! stop sequences and token streaming), and KV-cached decoding over either
//! the FP or a quantized checkpoint — single-worker or layer-sharded
//! pipeline-parallel ([`crate::shard`], `--shards N`). The serving bench
//! compares FP vs quantized token throughput, tail latency, and shard-count
//! scaling.
//!
//! Decoding defaults to greedy, bit-identical to the pre-sampler
//! [`argmax_token`] path; a seeded request replays token-identically across
//! runs, prefill chunk sizes, shard counts, and kernel tables because the
//! logits it samples from are bit-identical by construction.

pub mod batcher;
pub mod client;
pub mod sampler;
pub mod sched;
pub mod server;

pub use batcher::{
    argmax_token, default_prefill_chunk, BatcherConfig, DynamicBatcher, FinishReason,
    GenRequest, GenResponse, Pending, RequestQueue, StreamHandle,
};
pub use client::{
    request_generation, request_generation_streaming, request_generation_with, request_stats,
    ClientOptions,
};
pub use sampler::{Sampler, SamplerChain, SamplingParams, Selector, StopSet};
pub use sched::{
    scheduler_loop, AdmitVerdict, LocalBackend, PoolMirror, ShardBackend, StepBackend, StepJob,
};
pub use server::{serve, ServerConfig};
