//! Dynamic request batching.
//!
//! Requests arrive asynchronously; the batcher coalesces up to
//! `max_batch` of them (waiting at most `max_wait` for stragglers) and
//! decodes the whole batch in lock-step, one token per step, with the
//! per-sequence KV caches advancing in parallel worker threads. This is the
//! same continuous-batching shape vLLM's router uses, reduced to its core.

use crate::model::{DecodeState, ModelWeights};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u8>,
    pub max_new: usize,
}

/// The response for one request.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub tokens: Vec<u8>,
    pub latency: Duration,
    /// How many requests shared the batch this one ran in.
    pub batch_size: usize,
}

/// Batcher tunables.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

struct Pending {
    req: GenRequest,
    enqueued: Instant,
    reply: Sender<GenResponse>,
}

/// A shared handle: submit requests, a background thread serves them.
pub struct DynamicBatcher {
    queue: Sender<Pending>,
}

impl DynamicBatcher {
    /// Spawn the batching worker over the given weights.
    pub fn spawn(weights: Arc<ModelWeights>, cfg: BatcherConfig) -> DynamicBatcher {
        let (tx, rx) = channel::<Pending>();
        std::thread::spawn(move || worker_loop(weights, cfg, rx));
        DynamicBatcher { queue: tx }
    }

    /// Submit a request; blocks until the response is ready.
    pub fn generate(&self, req: GenRequest) -> Option<GenResponse> {
        let (tx, rx) = channel();
        self.queue
            .send(Pending { req, enqueued: Instant::now(), reply: tx })
            .ok()?;
        rx.recv().ok()
    }
}

fn worker_loop(weights: Arc<ModelWeights>, cfg: BatcherConfig, rx: Receiver<Pending>) {
    loop {
        // block for the first request, then soak up stragglers
        let first = match rx.recv() {
            Ok(p) => p,
            Err(_) => return, // all senders dropped
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => batch.push(p),
                Err(_) => break,
            }
        }
        run_batch(&weights, batch);
    }
}

fn run_batch(weights: &ModelWeights, batch: Vec<Pending>) {
    let bs = batch.len();
    // Decode all sequences in lock-step; each sequence owns a KV cache and
    // advances on a worker thread per step (threads scale with batch).
    let results: Vec<(Vec<u8>, Instant, Sender<GenResponse>)> = {
        let outputs = Mutex::new(Vec::with_capacity(bs));
        crate::util::threadpool::parallel_for(bs, |i| {
            let p = &batch[i];
            let mut st = DecodeState::new(weights);
            let mut logits = Vec::new();
            for &t in &p.req.prompt {
                logits = st.step(t);
            }
            let mut out = Vec::with_capacity(p.req.max_new);
            for _ in 0..p.req.max_new {
                let next = argmax(&logits);
                out.push(next);
                logits = st.step(next);
            }
            outputs.lock().unwrap().push((i, out));
        });
        let mut v = outputs.into_inner().unwrap();
        v.sort_by_key(|(i, _)| *i);
        v.into_iter()
            .zip(batch)
            .map(|((_, out), p)| (out, p.enqueued, p.reply))
            .collect()
    };
    for (tokens, enqueued, reply) in results {
        let _ = reply.send(GenResponse {
            tokens,
            latency: enqueued.elapsed(),
            batch_size: bs,
        });
    }
}

fn argmax(v: &[f32]) -> u8 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in v.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;
    use crate::util::rng::Rng;

    fn model() -> Arc<ModelWeights> {
        let mut rng = Rng::new(1);
        Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng))
    }

    #[test]
    fn single_request_roundtrip() {
        let b = DynamicBatcher::spawn(model(), BatcherConfig::default());
        let r = b
            .generate(GenRequest { prompt: vec![10, 20, 30], max_new: 5 })
            .unwrap();
        assert_eq!(r.tokens.len(), 5);
        assert!(r.batch_size >= 1);
    }

    #[test]
    fn generation_is_deterministic_greedy() {
        let m = model();
        let b = DynamicBatcher::spawn(m.clone(), BatcherConfig::default());
        let req = GenRequest { prompt: vec![1, 2, 3, 4], max_new: 8 };
        let a = b.generate(req.clone()).unwrap();
        let c = b.generate(req).unwrap();
        assert_eq!(a.tokens, c.tokens);
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let b = Arc::new(DynamicBatcher::spawn(
            model(),
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(100) },
        ));
        let mut handles = Vec::new();
        for i in 0..4u8 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                b.generate(GenRequest { prompt: vec![i, i + 1], max_new: 3 }).unwrap()
            }));
        }
        let responses: Vec<GenResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(responses.iter().all(|r| r.tokens.len() == 3));
        // at least one pair must have shared a batch
        assert!(
            responses.iter().any(|r| r.batch_size > 1),
            "no batching happened: sizes {:?}",
            responses.iter().map(|r| r.batch_size).collect::<Vec<_>>()
        );
    }

    #[test]
    fn batched_matches_unbatched_tokens() {
        let m = model();
        // direct decode
        let mut st = DecodeState::new(&m);
        let prompt = [7u8, 9, 11];
        let mut logits = Vec::new();
        for &t in &prompt {
            logits = st.step(t);
        }
        let mut expect = Vec::new();
        for _ in 0..4 {
            let next = super::argmax(&logits);
            expect.push(next);
            logits = st.step(next);
        }
        // through the batcher
        let b = DynamicBatcher::spawn(m.clone(), BatcherConfig::default());
        let r = b.generate(GenRequest { prompt: prompt.to_vec(), max_new: 4 }).unwrap();
        assert_eq!(r.tokens, expect);
    }
}
