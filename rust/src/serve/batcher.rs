//! Dynamic request batching over the step-level scheduler.
//!
//! Requests arrive asynchronously; a single worker thread runs the
//! continuous-batching scheduler ([`super::sched`]): the unit of work is
//! one token step of the running batch, and sequences are admitted and
//! retired *between steps*, so a request arriving while other generations
//! are mid-flight joins the very next step instead of queueing behind an
//! entire batch's full generation (the seed implementation's admission
//! stall — its "vLLM-style" claim only held for requests that arrived
//! together). The split [`GenResponse::queue_wait`] / `prefill_time` /
//! `decode_time` makes the behaviour — and time to first token —
//! observable per request. Prompts prefill in chunked token spans
//! ([`BatcherConfig::prefill_chunk`]) rather than one token per step.
//!
//! The worker is generic over [`ModelExec`], so the same batcher drives
//! dense f32 weights and the packed fused-dequant execution path, and —
//! with [`BatcherConfig::shards`] > 1 — the layer-sharded pipeline executor
//! ([`crate::shard`]), where per-step scheduling is what keeps every shard
//! busy. With [`BatcherConfig::pool`] set, every sequence's KV is paged out
//! of a bounded [`crate::kvpool::KvPool`] and the scheduler adds admission
//! gating plus youngest-first preemption (see [`super::sched`]); on top of
//! that, [`BatcherConfig::max_queue`] sheds load at the door — a full queue
//! fails `generate` immediately instead of buffering unboundedly.
//!
//! [`DynamicBatcher`] owns its worker: dropping it closes the queue, drains
//! any in-flight replies with an error, joins the scheduler thread (and,
//! transitively, the shard threads) — no thread outlives its batcher.

use super::sampler::SamplingParams;
use super::sched::{scheduler_loop, LocalBackend, PoolMirror, ShardBackend};
use crate::kvpool::PoolCfg;
use crate::model::{KvSpec, ModelExec};
use crate::shard::ShardedModel;
use crate::util::fault::{self, FaultPlan};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvError, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u8>,
    pub max_new: usize,
    /// Sampling chain configuration. The default is greedy decoding, which
    /// is bit-identical to the pre-sampler [`argmax_token`] path.
    pub params: SamplingParams,
    /// Stop sequences (byte strings / token-id runs): generation ends with
    /// [`FinishReason::Stop`] as soon as the emitted output ends with any of
    /// them. The matched sequence stays in `tokens` so streamed events always
    /// concatenate to the final response.
    pub stop: Vec<Vec<u8>>,
}

impl Default for GenRequest {
    /// Empty prompt, zero budget, greedy sampling, no stop sequences —
    /// callers spread this (`..Default::default()`) to opt into new knobs
    /// without naming every field.
    fn default() -> Self {
        GenRequest {
            prompt: Vec::new(),
            max_new: 0,
            params: SamplingParams::default(),
            stop: Vec::new(),
        }
    }
}

/// Why a generation stopped. Serialized on the wire as
/// `finish_reason: "length" | "stop" | "timeout" | "error"` so clients stop
/// inferring the cause from `timed_out` + token count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit the `max_new` / `max_tokens` budget (includes `max_new == 0`).
    Length,
    /// A stop sequence matched the decoded tail.
    Stop,
    /// The request deadline expired; `tokens` holds the partial output.
    Timeout,
    /// The request failed mid-decode; the partial response carries it.
    Error,
}

impl FinishReason {
    /// The wire label (`length | stop | timeout | error`).
    pub fn label(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::Timeout => "timeout",
            FinishReason::Error => "error",
        }
    }

    /// Parse a wire label; unknown labels map to `None` so clients can
    /// degrade gracefully against newer servers.
    pub fn parse(s: &str) -> Option<FinishReason> {
        match s {
            "length" => Some(FinishReason::Length),
            "stop" => Some(FinishReason::Stop),
            "timeout" => Some(FinishReason::Timeout),
            "error" => Some(FinishReason::Error),
            _ => None,
        }
    }
}

/// The response for one request.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub tokens: Vec<u8>,
    /// Enqueue → admission into the running batch. Under continuous
    /// batching this stays near zero whenever the batch has a free lane;
    /// under the old whole-batch scheduler it absorbed entire generations.
    pub queue_wait: Duration,
    /// Admission → first generated token: the prompt-prefill cost, paid in
    /// ⌈prompt/C⌉ span steps of `C = BatcherConfig::prefill_chunk` tokens.
    /// `queue_wait + prefill_time` is this request's time to first token.
    pub prefill_time: Duration,
    /// First generated token → final token (the steady-state decode time;
    /// includes any post-preemption replay).
    pub decode_time: Duration,
    /// The largest batch this request ever shared a token step with.
    pub batch_size: usize,
    /// High-water mark of KV-pool pages this request's caches held (0
    /// without `--kv-pool-mb`).
    pub kv_pages_used: usize,
    /// Times this request was preempted for pool pressure (pages released,
    /// then deterministically re-prefilled after re-admission).
    pub preemptions: usize,
    /// The request hit `--request-timeout` before finishing: `tokens`
    /// holds whatever was generated by the deadline (possibly none).
    pub timed_out: bool,
    /// Decode-pool workers the server has respawned after a death, as of
    /// this response (process-lifetime counter, not per-request).
    pub worker_restarts: usize,
    /// Times the server has rebuilt a dead shard pipeline, as of this
    /// response (process-lifetime counter, not per-request).
    pub pipeline_rebuilds: usize,
    /// Why generation ended. `timed_out` is kept (redundantly) for wire
    /// compatibility with pre-`finish_reason` clients.
    pub finish_reason: FinishReason,
}

impl GenResponse {
    /// End-to-end latency as the client saw it.
    pub fn latency(&self) -> Duration {
        self.queue_wait + self.prefill_time + self.decode_time
    }

    /// Time to first token: queueing plus prompt prefill.
    pub fn ttft(&self) -> Duration {
        self.queue_wait + self.prefill_time
    }
}

/// Batcher tunables.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Cap on concurrently decoding sequences (the admission limit).
    pub max_batch: usize,
    /// Coalescing window applied only when the batch is idle: after the
    /// first request of a burst, wait up to this long for stragglers so
    /// they start as one batch. Once decoding, admission never waits.
    pub max_wait: Duration,
    /// KV-cache representation for every per-sequence decode state
    /// (`tsgo serve --kv-bits/--kv-group`). Default: f32.
    pub kv: KvSpec,
    /// Pipeline-parallel shard count (`tsgo serve --shards N`): 0/1 =
    /// single worker; N > 1 splits layers over N shard threads (clamped to
    /// the layer count) with channel-based activation handoff.
    pub shards: usize,
    /// Paged KV-pool budget (`tsgo serve --kv-pool-mb/--kv-page-tokens`):
    /// `None` = unbounded contiguous caches. With `shards > 1` the budget
    /// splits into shard-local sub-pools proportional to layer count.
    pub pool: Option<PoolCfg>,
    /// Requests allowed in the queue (enqueued but not yet decoding);
    /// `generate` past this limit fails immediately with a "server
    /// overloaded" error instead of queueing unboundedly.
    pub max_queue: usize,
    /// Prompt tokens fed per scheduler step while a sequence is behind its
    /// chain end (`tsgo serve --prefill-chunk C`): prefill — and
    /// post-preemption replay — runs as T×d span steps of up to this many
    /// tokens. `1` reproduces the historical one-token-per-step prefill
    /// exactly; tokens are bit-identical for every value (the span path is
    /// the one-token path's op order, batched).
    pub prefill_chunk: usize,
    /// Total per-request deadline (`tsgo serve --request-timeout`), queue
    /// wait included: an expired request answers with its partial tokens
    /// and [`GenResponse::timed_out`] set. `None` = no deadline.
    pub request_timeout: Option<Duration>,
    /// How long one batch step may block on a reply that never arrives —
    /// a dead worker or a lost message (`tsgo serve --step-timeout`).
    /// Replaces the old hardcoded 60 s bound; only the faulted sequence
    /// errors when it fires.
    pub step_timeout: Duration,
    /// Deterministic fault schedule armed at spawn (tests/chaos runs);
    /// `None` falls back to the `TSGO_FAULT` env var. See
    /// [`crate::util::fault`] for the grammar.
    pub faults: Option<FaultPlan>,
    /// Server-side sampling defaults (`tsgo serve --temperature/--top-k/
    /// --top-p/--repetition-penalty/--seed`); per-request JSON fields
    /// override individual knobs. Default: greedy.
    pub default_sampling: SamplingParams,
}

/// The `--prefill-chunk` default: the `TSGO_PREFILL_CHUNK` env knob when
/// set to a positive integer (how CI pins odd chunk sizes without touching
/// every harness), else 64 — big enough that prompt prefill is
/// GEMM-shaped, small enough that a decoding neighbour's step latency
/// stays bounded.
pub fn default_prefill_chunk() -> usize {
    std::env::var("TSGO_PREFILL_CHUNK")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&c| c > 0)
        .unwrap_or(64)
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            kv: KvSpec::DenseF32,
            shards: 1,
            pool: None,
            max_queue: 256,
            prefill_chunk: default_prefill_chunk(),
            request_timeout: None,
            step_timeout: Duration::from_secs(60),
            faults: None,
            default_sampling: SamplingParams::default(),
        }
    }
}

/// One enqueued request awaiting the scheduler. Public (like
/// [`RequestQueue`]) so integration tests can drive [`scheduler_loop`]
/// directly with instrumented backends.
pub struct Pending {
    pub req: GenRequest,
    pub enqueued: Instant,
    pub reply: Sender<Result<GenResponse, String>>,
    /// Streaming tap: when set, the scheduler sends every emitted token here
    /// as it is sampled. A closed receiver (client went away) cancels the
    /// request at its next token — the slot is retired and its KV pages are
    /// freed. `None` for plain blocking requests.
    pub events: Option<Sender<u8>>,
}

/// The scheduler's receiving end of the request queue, paired with the
/// shared depth counter behind [`BatcherConfig::max_queue`]. The counter is
/// incremented by `generate` on enqueue and decremented by
/// [`RequestQueue::settle`] exactly once per request, when the scheduler
/// *resolves* it (admitted to decode, answered directly, or drained) — a
/// pool-deferred request stays counted, so the overload gate keeps
/// back-pressuring while the KV pool is the bottleneck.
pub struct RequestQueue {
    rx: Receiver<Pending>,
    depth: Arc<AtomicUsize>,
    /// Whether this queue's depth is mirrored into the process-wide
    /// [`crate::obs`] `queue_depth` gauge. True for real batchers (enqueue
    /// adds, settle subtracts — deltas, so concurrent batchers compose);
    /// false for [`RequestQueue::for_tests`], which bypasses `generate`'s
    /// increment and would otherwise drive the global gauge negative.
    tracked: bool,
}

impl RequestQueue {
    pub(crate) fn recv(&self) -> Result<Pending, RecvError> {
        self.rx.recv()
    }

    pub(crate) fn recv_timeout(&self, timeout: Duration) -> Result<Pending, RecvTimeoutError> {
        self.rx.recv_timeout(timeout)
    }

    pub(crate) fn try_recv(&self) -> Result<Pending, TryRecvError> {
        self.rx.try_recv()
    }

    /// One request left the queue for good: reopen its `max_queue` slot.
    pub(crate) fn settle(&self) {
        self.depth.fetch_sub(1, Ordering::AcqRel);
        if self.tracked {
            crate::obs::registry().queue_depth.sub(1);
        }
    }

    /// Test-only: wrap a raw receiver so tests (in-crate and the
    /// `tests/fault_injection.rs` battery) can drive `scheduler_loop`
    /// directly with an instrumented backend. The depth counter starts
    /// huge because these tests bypass `generate`'s increment and `settle`
    /// still decrements.
    #[doc(hidden)]
    pub fn for_tests(rx: Receiver<Pending>) -> RequestQueue {
        RequestQueue {
            rx,
            depth: Arc::new(AtomicUsize::new(usize::MAX / 2)),
            tracked: false,
        }
    }
}

/// A shared handle: submit requests, a background scheduler serves them.
pub struct DynamicBatcher {
    queue: Option<Sender<Pending>>,
    worker: Option<std::thread::JoinHandle<()>>,
    /// Requests enqueued but not yet resolved by the scheduler.
    depth: Arc<AtomicUsize>,
    max_queue: usize,
    /// This batcher armed a programmatic fault plan (`cfg.faults`); its
    /// drop disarms so the process-global plane can't leak into later
    /// batchers (env-armed plans stay, matching env semantics).
    armed_faults: bool,
}

impl DynamicBatcher {
    /// Spawn the scheduling worker over the given model (dense or packed;
    /// sharded when `cfg.shards > 1`).
    pub fn spawn<M: ModelExec + Send + Sync + 'static>(
        model: Arc<M>,
        cfg: BatcherConfig,
    ) -> DynamicBatcher {
        // Arm the fault plane before the worker exists so even its first
        // step sees the schedule. A programmatic plan (tests) wins over —
        // and is disarmed by drop, unlike — the TSGO_FAULT env var (chaos
        // CI), which re-arms per spawn so every batcher sees the same
        // deterministic hit counts from zero.
        let armed_faults = match cfg.faults {
            Some(plan) => {
                fault::arm(&plan);
                true
            }
            None => {
                fault::arm_from_env();
                false
            }
        };
        let (tx, rx) = channel::<Pending>();
        let depth = Arc::new(AtomicUsize::new(0));
        let queue = RequestQueue { rx, depth: depth.clone(), tracked: true };
        let worker = std::thread::Builder::new()
            .name("tsgo-batcher".into())
            .spawn(move || {
                if cfg.shards > 1 {
                    // Same constructor path a `ShardedModel` banner uses
                    // (`new` → plan → `decoder`), so the printed plan and
                    // the executing plan can only come from one recipe.
                    let sharded = ShardedModel::new(model, cfg.shards);
                    let mirror = cfg.pool.map(|pc| {
                        PoolMirror::new(sharded.plan(), sharded.config(), cfg.kv, pc)
                    });
                    let dec = sharded.decoder_pooled(cfg.kv, cfg.pool);
                    let mut backend = ShardBackend::new(dec, mirror);
                    scheduler_loop(&mut backend, &cfg, queue);
                } else {
                    let mut backend =
                        LocalBackend::new(model, cfg.kv, cfg.max_batch, cfg.pool);
                    scheduler_loop(&mut backend, &cfg, queue);
                }
            })
            .expect("spawn batcher worker thread");
        DynamicBatcher {
            queue: Some(tx),
            worker: Some(worker),
            depth,
            max_queue: cfg.max_queue,
            armed_faults,
        }
    }

    /// Submit a request; blocks until the response is ready. Decode
    /// failures (e.g. a greedy token outside the byte range) come back as
    /// errors, never as silently-mangled tokens. A queue already at
    /// [`BatcherConfig::max_queue`] unresolved requests fails immediately —
    /// load shedding at the door instead of unbounded buffering.
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let rx = self.enqueue(req, None)?;
        rx.recv().map_err(|_| anyhow!("batcher unavailable"))?.map_err(|e| anyhow!(e))
    }

    /// Submit a request and stream its tokens as they are sampled. Returns
    /// immediately with a [`StreamHandle`]; dropping the handle before the
    /// generation finishes cancels it (the scheduler retires the slot and
    /// frees its KV pages at the next emitted token).
    pub fn generate_stream(&self, req: GenRequest) -> Result<StreamHandle> {
        let (ev_tx, ev_rx) = channel();
        let reply = self.enqueue(req, Some(ev_tx))?;
        Ok(StreamHandle { events: ev_rx, reply })
    }

    /// Shared enqueue path: overload gate, sampling-parameter validation at
    /// the door (so bad knobs never reach a scheduler slot), then hand-off.
    fn enqueue(
        &self,
        req: GenRequest,
        events: Option<Sender<u8>>,
    ) -> Result<Receiver<Result<GenResponse, String>>> {
        req.params.validate().map_err(|e| anyhow!(e))?;
        let d = self.depth.fetch_add(1, Ordering::AcqRel);
        if d >= self.max_queue {
            self.depth.fetch_sub(1, Ordering::AcqRel);
            crate::obs::registry().overload_rejected.inc();
            return Err(anyhow!(
                "server overloaded: {d} requests already queued (max_queue = {})",
                self.max_queue
            ));
        }
        // Gauge moves before the send so the scheduler's matching
        // `settle()` decrement can never land first.
        crate::obs::registry().queue_depth.add(1);
        let (tx, rx) = channel();
        if self
            .queue
            .as_ref()
            .expect("batcher queue open until drop")
            .send(Pending { req, enqueued: Instant::now(), reply: tx, events })
            .is_err()
        {
            self.depth.fetch_sub(1, Ordering::AcqRel);
            crate::obs::registry().queue_depth.sub(1);
            return Err(anyhow!("batcher unavailable"));
        }
        Ok(rx)
    }
}

/// Live tap on one streaming generation (see
/// [`DynamicBatcher::generate_stream`]).
///
/// Read sampled tokens from [`StreamHandle::events`] as they land, then call
/// [`StreamHandle::wait`] for the final [`GenResponse`] (the events channel
/// closes right before the response is sent). Dropping the handle early
/// cancels the generation server-side.
pub struct StreamHandle {
    /// Per-token events in emission order.
    pub events: Receiver<u8>,
    /// The terminal response (or error) for the request.
    pub reply: Receiver<Result<GenResponse, String>>,
}

impl StreamHandle {
    /// Block until the generation finishes and return the final response.
    /// Unread token events are left in the channel — the response's `tokens`
    /// always carries the full output.
    pub fn wait(self) -> Result<GenResponse> {
        self.reply.recv().map_err(|_| anyhow!("batcher unavailable"))?.map_err(|e| anyhow!(e))
    }
}

impl Drop for DynamicBatcher {
    /// Close the queue and join the worker. The scheduler notices the
    /// closed queue at its next admission point, answers any in-flight
    /// request with an error, and exits — which in turn drops its backend
    /// and joins the shard threads. The seed implementation had no shutdown
    /// path at all: every `spawn` (one per test, one per server) leaked its
    /// worker thread for the life of the process.
    fn drop(&mut self) {
        drop(self.queue.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        // Only after the worker is joined: no thread of this batcher can
        // still be consulting the plan it armed.
        if self.armed_faults {
            fault::disarm();
        }
    }
}

/// Greedy argmax with a checked conversion to the byte token type: empty
/// or non-finite logits and indices beyond 255 are errors, not a
/// `best as u8` truncation that would silently alias token ids for
/// vocabularies larger than 256. For vocab ≤ 256 this is byte-exact greedy
/// decode (first maximum wins). Public so tests/benches decode with the
/// exact server semantics instead of re-implementing the cast.
pub fn argmax_token(v: &[f32]) -> Result<u8, String> {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    if v.is_empty() {
        return Err("empty logits (no prompt token was decoded)".into());
    }
    for (i, &x) in v.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    // All-NaN (or all -inf) logits leave `best` at 0 — that is corrupt
    // model output, not a real greedy pick.
    if !bv.is_finite() {
        return Err("non-finite logits (model produced NaN/inf)".into());
    }
    u8::try_from(best).map_err(|_| {
        format!("greedy token id {best} exceeds the byte token range (vocab > 256)")
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DecodeState, ModelWeights, Preset};
    use crate::util::rng::Rng;

    fn model() -> Arc<ModelWeights> {
        let mut rng = Rng::new(1);
        Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng))
    }

    #[test]
    fn single_request_roundtrip() {
        let b = DynamicBatcher::spawn(model(), BatcherConfig::default());
        let r = b
            .generate(GenRequest { prompt: vec![10, 20, 30], max_new: 5, ..Default::default() })
            .unwrap();
        assert_eq!(r.tokens.len(), 5);
        assert!(r.batch_size >= 1);
        // the latency split always reconstructs the end-to-end number
        assert_eq!(r.latency(), r.queue_wait + r.prefill_time + r.decode_time);
        assert_eq!(r.ttft(), r.queue_wait + r.prefill_time);
        assert!(r.decode_time > Duration::ZERO);
    }

    #[test]
    fn generation_is_deterministic_greedy() {
        let m = model();
        let b = DynamicBatcher::spawn(m.clone(), BatcherConfig::default());
        let req = GenRequest { prompt: vec![1, 2, 3, 4], max_new: 8, ..Default::default() };
        let a = b.generate(req.clone()).unwrap();
        let c = b.generate(req).unwrap();
        assert_eq!(a.tokens, c.tokens);
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let b = Arc::new(DynamicBatcher::spawn(
            model(),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(100),
                ..Default::default()
            },
        ));
        let mut handles = Vec::new();
        for i in 0..4u8 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                b.generate(GenRequest { prompt: vec![i, i + 1], max_new: 3, ..Default::default() })
                    .unwrap()
            }));
        }
        let responses: Vec<GenResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(responses.iter().all(|r| r.tokens.len() == 3));
        // at least one pair must have shared a step
        assert!(
            responses.iter().any(|r| r.batch_size > 1),
            "no batching happened: sizes {:?}",
            responses.iter().map(|r| r.batch_size).collect::<Vec<_>>()
        );
    }

    #[test]
    fn batched_matches_unbatched_tokens() {
        let m = model();
        // direct decode
        let mut st = DecodeState::new(m.as_ref());
        let prompt = [7u8, 9, 11];
        let mut logits = Vec::new();
        for &t in &prompt {
            logits = st.step(t);
        }
        let mut expect = Vec::new();
        for _ in 0..4 {
            let next = super::argmax_token(&logits).unwrap();
            expect.push(next);
            logits = st.step(next);
        }
        // through the batcher
        let b = DynamicBatcher::spawn(m.clone(), BatcherConfig::default());
        let r = b
            .generate(GenRequest { prompt: prompt.to_vec(), max_new: 4, ..Default::default() })
            .unwrap();
        assert_eq!(r.tokens, expect);
    }

    #[test]
    fn kv_quantized_batcher_matches_direct_decode() {
        // The batcher's per-sequence states must honor the configured KV
        // representation: tokens through the batcher with int8 KV equal a
        // direct DecodeState::with_kv decode (identical numerics path).
        let m = model();
        let spec = KvSpec::PackedGroupwise { bits: 8, group: 64 };
        let prompt = [4u8, 8, 15, 16];
        let mut st = DecodeState::with_kv(m.as_ref(), spec);
        let mut logits = Vec::new();
        for &t in &prompt {
            logits = st.step(t);
        }
        let mut expect = Vec::new();
        for _ in 0..5 {
            let next = super::argmax_token(&logits).unwrap();
            expect.push(next);
            logits = st.step(next);
        }
        let b = DynamicBatcher::spawn(
            m.clone(),
            BatcherConfig { kv: spec, ..Default::default() },
        );
        let r = b
            .generate(GenRequest { prompt: prompt.to_vec(), max_new: 5, ..Default::default() })
            .unwrap();
        assert_eq!(r.tokens, expect, "batcher diverged from direct int8-KV decode");
    }

    #[test]
    fn chunked_prefill_matches_one_token_prefill() {
        // The span step contract's spine: any --prefill-chunk produces the
        // same tokens as the historical one-token-per-step prefill.
        let m = model();
        let req = GenRequest { prompt: (0..23u8).collect(), max_new: 6, ..Default::default() };
        let base = DynamicBatcher::spawn(
            m.clone(),
            BatcherConfig { prefill_chunk: 1, ..Default::default() },
        )
        .generate(req.clone())
        .unwrap();
        for chunk in [3, 8, 64] {
            let r = DynamicBatcher::spawn(
                m.clone(),
                BatcherConfig { prefill_chunk: chunk, ..Default::default() },
            )
            .generate(req.clone())
            .unwrap();
            assert_eq!(r.tokens, base.tokens, "chunk {chunk} diverged from chunk 1");
        }
    }

    #[test]
    fn drop_joins_the_worker() {
        // The seed leaked one thread per spawn. Drop must close the queue
        // and join: repeated spawn+drop cycles neither hang nor accumulate
        // workers (a hang here is the regression this test exists for).
        let m = model();
        for _ in 0..8 {
            let b = DynamicBatcher::spawn(m.clone(), BatcherConfig::default());
            let r = b
                .generate(GenRequest { prompt: vec![3, 5], max_new: 2, ..Default::default() })
                .unwrap();
            assert_eq!(r.tokens.len(), 2);
            drop(b); // joins the scheduler thread before the next iteration
        }
    }

    #[test]
    fn zero_max_new_returns_empty() {
        let b = DynamicBatcher::spawn(model(), BatcherConfig::default());
        let r = b
            .generate(GenRequest { prompt: vec![1, 2], max_new: 0, ..Default::default() })
            .unwrap();
        assert!(r.tokens.is_empty());
    }

    #[test]
    fn empty_prompt_is_an_error() {
        let b = DynamicBatcher::spawn(model(), BatcherConfig::default());
        let err = b
            .generate(GenRequest { prompt: vec![], max_new: 3, ..Default::default() })
            .unwrap_err()
            .to_string();
        assert!(err.contains("empty"), "{err}");
    }

    #[test]
    fn max_queue_overload_fails_immediately() {
        // A full queue sheds load at the door: the error is instant (no
        // enqueue, no waiting on the scheduler) and names the limit.
        let b = DynamicBatcher::spawn(
            model(),
            BatcherConfig { max_queue: 0, ..Default::default() },
        );
        let err = b
            .generate(GenRequest { prompt: vec![1, 2], max_new: 2, ..Default::default() })
            .unwrap_err()
            .to_string();
        assert!(err.contains("server overloaded"), "{err}");
        assert!(err.contains("max_queue = 0"), "{err}");
    }

    #[test]
    fn argmax_is_checked_not_truncating() {
        // Regression: `best as u8` used to alias id 300 → 44 for vocab > 256
        // and return token 0 for empty logits.
        assert!(super::argmax_token(&[]).is_err());
        let mut logits = vec![0.0f32; 300];
        logits[299] = 10.0;
        let err = super::argmax_token(&logits).unwrap_err();
        assert!(err.contains("299"), "{err}");
        logits[42] = 20.0;
        assert_eq!(super::argmax_token(&logits).unwrap(), 42);
        // all-NaN logits must be an error, not a silent token 0
        let err = super::argmax_token(&[f32::NAN, f32::NAN]).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
    }
}
