//! Dynamic request batching.
//!
//! Requests arrive asynchronously; the batcher coalesces up to
//! `max_batch` of them (waiting at most `max_wait` for stragglers) and
//! decodes the whole batch in lock-step, one token per step, with the
//! per-sequence KV caches advancing in parallel worker threads. This is the
//! same continuous-batching shape vLLM's router uses, reduced to its core.
//!
//! The worker is generic over [`ModelExec`], so the same batcher drives
//! dense f32 weights and the packed fused-dequant execution path
//! (`tsgo serve --packed`).

use crate::model::{DecodeState, KvSpec, ModelExec};
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u8>,
    pub max_new: usize,
}

/// The response for one request.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub tokens: Vec<u8>,
    pub latency: Duration,
    /// How many requests shared the batch this one ran in.
    pub batch_size: usize,
}

/// Batcher tunables.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// KV-cache representation for every per-sequence [`DecodeState`]
    /// (`tsgo serve --kv-bits/--kv-group`). Default: f32.
    pub kv: KvSpec,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            kv: KvSpec::DenseF32,
        }
    }
}

struct Pending {
    req: GenRequest,
    enqueued: Instant,
    reply: Sender<Result<GenResponse, String>>,
}

/// A shared handle: submit requests, a background thread serves them.
pub struct DynamicBatcher {
    queue: Sender<Pending>,
}

impl DynamicBatcher {
    /// Spawn the batching worker over the given model (dense or packed).
    pub fn spawn<M: ModelExec + Send + Sync + 'static>(
        model: Arc<M>,
        cfg: BatcherConfig,
    ) -> DynamicBatcher {
        let (tx, rx) = channel::<Pending>();
        std::thread::spawn(move || worker_loop(model, cfg, rx));
        DynamicBatcher { queue: tx }
    }

    /// Submit a request; blocks until the response is ready. Decode
    /// failures (e.g. a greedy token outside the byte range) come back as
    /// errors, never as silently-mangled tokens.
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let (tx, rx) = channel();
        self.queue
            .send(Pending { req, enqueued: Instant::now(), reply: tx })
            .map_err(|_| anyhow!("batcher unavailable"))?;
        rx.recv().map_err(|_| anyhow!("batcher unavailable"))?.map_err(|e| anyhow!(e))
    }
}

fn worker_loop<M: ModelExec>(model: Arc<M>, cfg: BatcherConfig, rx: Receiver<Pending>) {
    loop {
        // block for the first request, then soak up stragglers
        let first = match rx.recv() {
            Ok(p) => p,
            Err(_) => return, // all senders dropped
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => batch.push(p),
                Err(_) => break,
            }
        }
        run_batch(model.as_ref(), &cfg, batch);
    }
}

fn run_batch<M: ModelExec>(model: &M, cfg: &BatcherConfig, batch: Vec<Pending>) {
    let bs = batch.len();
    // Decode all sequences in lock-step; each sequence owns a KV cache (in
    // the configured representation) and advances on a worker thread per
    // step (threads scale with batch).
    type Decoded = (Result<Vec<u8>, String>, Instant, Sender<Result<GenResponse, String>>);
    let results: Vec<Decoded> = {
        let outputs = Mutex::new(Vec::with_capacity(bs));
        crate::util::threadpool::parallel_for(bs, |i| {
            let p = &batch[i];
            let decode = || -> Result<Vec<u8>, String> {
                let mut st = DecodeState::with_kv(model, cfg.kv);
                let mut logits = Vec::new();
                for &t in &p.req.prompt {
                    logits = st.step(t);
                }
                let mut out = Vec::with_capacity(p.req.max_new);
                for _ in 0..p.req.max_new {
                    let next = argmax_token(&logits)?;
                    out.push(next);
                    logits = st.step(next);
                }
                Ok(out)
            };
            outputs.lock().unwrap().push((i, decode()));
        });
        let mut v = outputs.into_inner().unwrap();
        v.sort_by_key(|(i, _)| *i);
        v.into_iter()
            .zip(batch)
            .map(|((_, out), p)| (out, p.enqueued, p.reply))
            .collect()
    };
    for (tokens, enqueued, reply) in results {
        let _ = reply.send(tokens.map(|tokens| GenResponse {
            tokens,
            latency: enqueued.elapsed(),
            batch_size: bs,
        }));
    }
}

/// Greedy argmax with a checked conversion to the byte token type: empty
/// or non-finite logits and indices beyond 255 are errors, not a
/// `best as u8` truncation that would silently alias token ids for
/// vocabularies larger than 256. For vocab ≤ 256 this is byte-exact greedy
/// decode (first maximum wins). Public so tests/benches decode with the
/// exact server semantics instead of re-implementing the cast.
pub fn argmax_token(v: &[f32]) -> Result<u8, String> {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    if v.is_empty() {
        return Err("empty logits (no prompt token was decoded)".into());
    }
    for (i, &x) in v.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    // All-NaN (or all -inf) logits leave `best` at 0 — that is corrupt
    // model output, not a real greedy pick.
    if !bv.is_finite() {
        return Err("non-finite logits (model produced NaN/inf)".into());
    }
    u8::try_from(best).map_err(|_| {
        format!("greedy token id {best} exceeds the byte token range (vocab > 256)")
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelWeights, Preset};
    use crate::util::rng::Rng;

    fn model() -> Arc<ModelWeights> {
        let mut rng = Rng::new(1);
        Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng))
    }

    #[test]
    fn single_request_roundtrip() {
        let b = DynamicBatcher::spawn(model(), BatcherConfig::default());
        let r = b
            .generate(GenRequest { prompt: vec![10, 20, 30], max_new: 5 })
            .unwrap();
        assert_eq!(r.tokens.len(), 5);
        assert!(r.batch_size >= 1);
    }

    #[test]
    fn generation_is_deterministic_greedy() {
        let m = model();
        let b = DynamicBatcher::spawn(m.clone(), BatcherConfig::default());
        let req = GenRequest { prompt: vec![1, 2, 3, 4], max_new: 8 };
        let a = b.generate(req.clone()).unwrap();
        let c = b.generate(req).unwrap();
        assert_eq!(a.tokens, c.tokens);
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let b = Arc::new(DynamicBatcher::spawn(
            model(),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(100),
                ..Default::default()
            },
        ));
        let mut handles = Vec::new();
        for i in 0..4u8 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                b.generate(GenRequest { prompt: vec![i, i + 1], max_new: 3 }).unwrap()
            }));
        }
        let responses: Vec<GenResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(responses.iter().all(|r| r.tokens.len() == 3));
        // at least one pair must have shared a batch
        assert!(
            responses.iter().any(|r| r.batch_size > 1),
            "no batching happened: sizes {:?}",
            responses.iter().map(|r| r.batch_size).collect::<Vec<_>>()
        );
    }

    #[test]
    fn batched_matches_unbatched_tokens() {
        let m = model();
        // direct decode
        let mut st = DecodeState::new(m.as_ref());
        let prompt = [7u8, 9, 11];
        let mut logits = Vec::new();
        for &t in &prompt {
            logits = st.step(t);
        }
        let mut expect = Vec::new();
        for _ in 0..4 {
            let next = super::argmax_token(&logits).unwrap();
            expect.push(next);
            logits = st.step(next);
        }
        // through the batcher
        let b = DynamicBatcher::spawn(m.clone(), BatcherConfig::default());
        let r = b.generate(GenRequest { prompt: prompt.to_vec(), max_new: 4 }).unwrap();
        assert_eq!(r.tokens, expect);
    }

    #[test]
    fn kv_quantized_batcher_matches_direct_decode() {
        // The batcher's per-sequence states must honor the configured KV
        // representation: tokens through the batcher with int8 KV equal a
        // direct DecodeState::with_kv decode (identical numerics path).
        let m = model();
        let spec = KvSpec::PackedGroupwise { bits: 8, group: 64 };
        let prompt = [4u8, 8, 15, 16];
        let mut st = DecodeState::with_kv(m.as_ref(), spec);
        let mut logits = Vec::new();
        for &t in &prompt {
            logits = st.step(t);
        }
        let mut expect = Vec::new();
        for _ in 0..5 {
            let next = super::argmax_token(&logits).unwrap();
            expect.push(next);
            logits = st.step(next);
        }
        let b = DynamicBatcher::spawn(
            m.clone(),
            BatcherConfig { kv: spec, ..Default::default() },
        );
        let r = b.generate(GenRequest { prompt: prompt.to_vec(), max_new: 5 }).unwrap();
        assert_eq!(r.tokens, expect, "batcher diverged from direct int8-KV decode");
    }

    #[test]
    fn argmax_is_checked_not_truncating() {
        // Regression: `best as u8` used to alias id 300 → 44 for vocab > 256
        // and return token 0 for empty logits.
        assert!(super::argmax_token(&[]).is_err());
        let mut logits = vec![0.0f32; 300];
        logits[299] = 10.0;
        let err = super::argmax_token(&logits).unwrap_err();
        assert!(err.contains("299"), "{err}");
        logits[42] = 20.0;
        assert_eq!(super::argmax_token(&logits).unwrap(), 42);
        // all-NaN logits must be an error, not a silent token 0
        let err = super::argmax_token(&[f32::NAN, f32::NAN]).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
    }
}
