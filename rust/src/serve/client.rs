//! Minimal client for the serve protocol (used by examples and benches).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Parsed generation response.
#[derive(Clone, Debug)]
pub struct ClientResponse {
    pub tokens: Vec<u8>,
    pub latency_ms: f64,
    /// Time the request sat queued before joining the running batch
    /// (near zero under continuous batching while lanes are free).
    pub queue_wait_ms: f64,
    /// Admission → first generated token: the chunked-prefill cost (0.0
    /// against a pre-PR-7 server that doesn't report the split).
    pub prefill_ms: f64,
    /// Time to first token: `queue_wait_ms + prefill_ms` (0.0 against a
    /// pre-PR-7 server).
    pub ttft_ms: f64,
    /// First generated token → final token.
    pub decode_ms: f64,
    pub batch_size: usize,
    /// Peak KV-pool pages this request held (0 when the server runs
    /// without `--kv-pool-mb`, or against a pre-pool server).
    pub kv_pages_used: usize,
    /// Times this request was preempted and re-prefilled for pool pressure.
    pub preemptions: usize,
    /// True when the request hit the server's `--request-timeout` and
    /// `tokens` holds only what was generated before the deadline (false
    /// against a pre-PR-8 server that doesn't report the flag).
    pub timed_out: bool,
    /// Process-lifetime count of decode pool workers respawned after a
    /// panic (0 against a pre-PR-8 server).
    pub worker_restarts: usize,
    /// Process-lifetime count of shard-pipeline rebuilds after a shard
    /// death (0 against a pre-PR-8 server).
    pub pipeline_rebuilds: usize,
}

/// Send one generation request and wait for the reply.
pub fn request_generation(addr: &str, prompt: &[u8], max_new: usize) -> Result<ClientResponse> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    let req = Json::obj(vec![
        ("prompt", Json::arr(prompt.iter().map(|&t| Json::num(t as f64)))),
        ("max_new", Json::num(max_new as f64)),
    ]);
    stream.write_all(req.to_string().as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
    if let Some(err) = j.get("error").as_str() {
        bail!("server error: {err}");
    }
    Ok(ClientResponse {
        tokens: j.get("tokens").usize_vec().into_iter().map(|t| t as u8).collect(),
        latency_ms: j.get("latency_ms").as_f64().unwrap_or(0.0),
        queue_wait_ms: j.get("queue_wait_ms").as_f64().unwrap_or(0.0),
        prefill_ms: j.get("prefill_ms").as_f64().unwrap_or(0.0),
        ttft_ms: j.get("ttft_ms").as_f64().unwrap_or(0.0),
        decode_ms: j.get("decode_ms").as_f64().unwrap_or(0.0),
        batch_size: j.get("batch_size").as_usize().unwrap_or(1),
        kv_pages_used: j.get("kv_pages_used").as_usize().unwrap_or(0),
        preemptions: j.get("preemptions").as_usize().unwrap_or(0),
        timed_out: j.get("timed_out").as_bool().unwrap_or(false),
        worker_restarts: j.get("worker_restarts").as_usize().unwrap_or(0),
        pipeline_rebuilds: j.get("pipeline_rebuilds").as_usize().unwrap_or(0),
    })
}
