//! Minimal client for the serve protocol (used by examples and benches).
//!
//! The wire protocol — request knobs, response metrics, and the streaming
//! event framing — is documented field-by-field in `docs/SERVE_API.md`.
//! [`request_generation`] covers the plain greedy case;
//! [`request_generation_with`] exposes sampling/stop knobs via
//! [`ClientOptions`]; [`request_generation_streaming`] adds a per-token
//! callback fed from the server's `{"token", "index"}` event lines;
//! [`request_stats`] fetches the server's telemetry snapshot via the
//! `{"stats": true}` control line (what `tsgo stats HOST:PORT` prints).

use super::sampler::SamplingParams;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Parsed generation response.
#[derive(Clone, Debug)]
pub struct ClientResponse {
    pub tokens: Vec<u8>,
    pub latency_ms: f64,
    /// Time the request sat queued before joining the running batch
    /// (near zero under continuous batching while lanes are free).
    pub queue_wait_ms: f64,
    /// Admission → first generated token: the chunked-prefill cost (0.0
    /// against a pre-PR-7 server that doesn't report the split).
    pub prefill_ms: f64,
    /// Time to first token: `queue_wait_ms + prefill_ms` (0.0 against a
    /// pre-PR-7 server).
    pub ttft_ms: f64,
    /// First generated token → final token.
    pub decode_ms: f64,
    pub batch_size: usize,
    /// Peak KV-pool pages this request held (0 when the server runs
    /// without `--kv-pool-mb`, or against a pre-pool server).
    pub kv_pages_used: usize,
    /// Times this request was preempted and re-prefilled for pool pressure.
    pub preemptions: usize,
    /// True when the request hit the server's `--request-timeout` and
    /// `tokens` holds only what was generated before the deadline (false
    /// against a pre-PR-8 server that doesn't report the flag).
    pub timed_out: bool,
    /// Process-lifetime count of decode pool workers respawned after a
    /// panic (0 against a pre-PR-8 server).
    pub worker_restarts: usize,
    /// Process-lifetime count of shard-pipeline rebuilds after a shard
    /// death (0 against a pre-PR-8 server).
    pub pipeline_rebuilds: usize,
    /// Why generation ended: `length | stop | timeout | error`. Inferred
    /// for pre-PR-9 servers that don't send the field: `timeout` when
    /// `timed_out` is set, else `length`.
    pub finish_reason: String,
}

/// Optional request knobs for [`request_generation_with`] /
/// [`request_generation_streaming`]. The default sends no sampling fields at
/// all, so the server's own defaults (its `--temperature` family of flags)
/// apply.
#[derive(Clone, Debug, Default)]
pub struct ClientOptions {
    /// Sampling knobs to send explicitly; `None` fields defer to the
    /// server's defaults.
    pub params: Option<SamplingParams>,
    /// Stop sequences: raw token-id runs, serialized as id arrays.
    pub stop: Vec<Vec<u8>>,
}

fn build_request(
    prompt: &[u8],
    max_new: usize,
    opts: &ClientOptions,
    stream: bool,
) -> Json {
    let mut fields = vec![
        ("prompt", Json::arr(prompt.iter().map(|&t| Json::num(t as f64)))),
        ("max_new", Json::num(max_new as f64)),
    ];
    if let Some(p) = &opts.params {
        fields.push(("temperature", Json::num(p.temperature as f64)));
        fields.push(("top_k", Json::num(p.top_k as f64)));
        fields.push(("top_p", Json::num(p.top_p as f64)));
        fields.push(("repetition_penalty", Json::num(p.repetition_penalty as f64)));
        fields.push(("seed", Json::num(p.seed as f64)));
    }
    if !opts.stop.is_empty() {
        fields.push((
            "stop",
            Json::arr(opts.stop.iter().map(|seq| {
                Json::arr(seq.iter().map(|&t| Json::num(t as f64)))
            })),
        ));
    }
    if stream {
        fields.push(("stream", Json::Bool(true)));
    }
    Json::obj(fields)
}

fn parse_response(j: &Json) -> Result<ClientResponse> {
    if let Some(err) = j.get("error").as_str() {
        bail!("server error: {err}");
    }
    let timed_out = j.get("timed_out").as_bool().unwrap_or(false);
    Ok(ClientResponse {
        tokens: j.get("tokens").usize_vec().into_iter().map(|t| t as u8).collect(),
        latency_ms: j.get("latency_ms").as_f64().unwrap_or(0.0),
        queue_wait_ms: j.get("queue_wait_ms").as_f64().unwrap_or(0.0),
        prefill_ms: j.get("prefill_ms").as_f64().unwrap_or(0.0),
        ttft_ms: j.get("ttft_ms").as_f64().unwrap_or(0.0),
        decode_ms: j.get("decode_ms").as_f64().unwrap_or(0.0),
        batch_size: j.get("batch_size").as_usize().unwrap_or(1),
        kv_pages_used: j.get("kv_pages_used").as_usize().unwrap_or(0),
        preemptions: j.get("preemptions").as_usize().unwrap_or(0),
        timed_out,
        worker_restarts: j.get("worker_restarts").as_usize().unwrap_or(0),
        pipeline_rebuilds: j.get("pipeline_rebuilds").as_usize().unwrap_or(0),
        finish_reason: match j.get("finish_reason").as_str() {
            Some(r) => r.to_string(),
            // Pre-PR-9 servers don't send the field: infer the old way.
            None if timed_out => "timeout".to_string(),
            None => "length".to_string(),
        },
    })
}

/// Send one generation request and wait for the reply (server-default
/// sampling, no stop sequences).
pub fn request_generation(addr: &str, prompt: &[u8], max_new: usize) -> Result<ClientResponse> {
    request_generation_with(addr, prompt, max_new, &ClientOptions::default())
}

/// Send one generation request with explicit sampling/stop knobs and wait
/// for the reply.
pub fn request_generation_with(
    addr: &str,
    prompt: &[u8],
    max_new: usize,
    opts: &ClientOptions,
) -> Result<ClientResponse> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    let req = build_request(prompt, max_new, opts, false);
    stream.write_all(req.to_string().as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
    parse_response(&j)
}

/// Fetch the server's process-wide telemetry snapshot over the serve
/// protocol's `{"stats": true}` control line. Returns the raw JSON object
/// (sections `counters` / `gauges` / `hist` / `trace` — see
/// `docs/SERVE_API.md` for the schema) so callers pick the fields they
/// care about; `tsgo stats HOST:PORT` pretty-prints it.
pub fn request_stats(addr: &str) -> Result<Json> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.write_all(b"{\"stats\": true}\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        bail!("server closed the connection before answering the stats line");
    }
    let j = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad stats line: {e}"))?;
    if let Some(err) = j.get("error").as_str() {
        bail!("server error: {err}");
    }
    Ok(j)
}

/// Streaming request: `on_token` fires for every `{"token", "index"}` event
/// line as the server samples it; the returned [`ClientResponse`] is the
/// final terminal line (its `tokens` always equals the concatenated events).
/// Degrades gracefully against a pre-PR-9 server that ignores `"stream"`:
/// the single response line is terminal, so `on_token` simply never fires.
pub fn request_generation_streaming(
    addr: &str,
    prompt: &[u8],
    max_new: usize,
    opts: &ClientOptions,
    mut on_token: impl FnMut(u8, usize),
) -> Result<ClientResponse> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    let req = build_request(prompt, max_new, opts, true);
    stream.write_all(req.to_string().as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("server closed the stream before the final response");
        }
        let j = Json::parse(line.trim())
            .map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        // Event lines carry `token`; anything else is the terminal line
        // (the full response, or an error object).
        match (j.get("token").as_usize(), j.get("index").as_usize()) {
            (Some(token), Some(index)) if token <= 255 => on_token(token as u8, index),
            _ => return parse_response(&j),
        }
    }
}
