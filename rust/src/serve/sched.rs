//! Step-level continuous-batching scheduler — one admission/retire decision
//! per token step.
//!
//! The seed batcher coalesced a batch once, then decoded every member's
//! *entire* generation before looking at the queue again: a request arriving
//! one token after a batch started waited for the whole batch to finish (the
//! admission stall). This scheduler is the vLLM-shaped fix: the unit of
//! scheduling is a single token step of the *running batch*, and between
//! steps sequences join (admission) and leave (retire) mid-flight. A
//! late-arriving short request therefore starts decoding on the very next
//! step and finishes long before an earlier long generation does — the
//! property `GenResponse::queue_wait` makes observable and
//! `tests/sharded_exec.rs` locks in.
//!
//! **The span step contract (PR 7).** The unit of work per sequence per
//! step is a [`StepJob`]: a *span* of chain tokens starting at the
//! sequence's current position, not a single token. Steady-state decode
//! feeds one-token spans; prefill — and post-preemption replay, which is
//! just prefill of a longer chain — feeds up to `--prefill-chunk` tokens
//! per step, so a 1000-token prompt costs ⌈1000/C⌉ steps of batched T×d
//! GEMMs instead of 1000 sequential batch-1 GEMVs. Backends return one
//! logits vector per job, for the span's **last** row only: earlier
//! prefill rows' logits are never sampled, which is what lets the span
//! path skip their head projections entirely. Decode interleaving is
//! structural, not scheduled: all batch members step together, so a
//! decoding sequence gets its one-token span in the *same* backend step
//! as a prefilling neighbour's C-token span and is never starved behind
//! someone else's prompt. Bit-identity with the one-token loop is also
//! structural — every backend runs `decode_layer_span`, of which the
//! one-token step is the T=1 case, and the span's causal masking replays
//! the exact per-row op order of the historical step (see
//! `model/forward.rs`).
//!
//! **Paged-KV back-pressure (PR 6).** With `--kv-pool-mb` set, every
//! sequence's KV lives in fixed-size pages drawn from a global [`KvPool`]
//! budget, and the scheduler becomes the memory arbiter:
//!
//! * *Admission* is budget-aware: [`StepBackend::admit`] returns
//!   [`AdmitVerdict::Defer`] when the pool lacks free pages for the
//!   prompt's prefill plus a one-step reservation margin (the request waits
//!   in FIFO order without blocking the batch), and `Reject` only when the
//!   prompt could never fit the whole pool.
//! * *Steps* are gated: before each token step the scheduler asks
//!   [`StepBackend::can_step`] whether every sequence crossing a page
//!   boundary can get its pages. If not, it **preempts the youngest
//!   sequence** — releases all its pages, keeps its generated tokens, and
//!   requeues it for deterministic re-prefill (greedy decode replays the
//!   prompt + generated chain to rebuild byte-identical KV state).
//!   Preempted sequences re-admit with strict priority over new work, so
//!   every request still completes; `queue_wait` keeps its original
//!   enqueue anchor across preemptions.
//!
//! The scheduler is backend-agnostic via [`StepBackend`]:
//!
//! * [`LocalBackend`] — single-worker execution: every sequence owns a full
//!   per-layer [`LayerKv`] bank; batch steps run on a **persistent step
//!   pool** (spawned lazily at the first multi-job step, joined on drop —
//!   a scoped spawn-per-step would pay thread creation once per decoded
//!   token), with a no-pool inline fast path for the batch-of-1 case. Same
//!   per-layer primitives as [`crate::model::DecodeState`], so tokens are
//!   identical to direct decode.
//! * [`ShardBackend`] — the pipeline topology: steps are fed to the
//!   [`ShardedDecoder`]'s shard threads, which is exactly what makes the
//!   step-level design matter — per-step scheduling keeps microbatches
//!   flowing so all shards stay busy, where whole-batch scheduling would
//!   drain the pipe between generations. Pool accounting runs through a
//!   scheduler-side [`PoolMirror`] of the shard-local sub-pools, because
//!   `retire` is an asynchronous packet: the mirror frees pages the moment
//!   the scheduler decides, and channel FIFO order guarantees each shard
//!   processes that release before any allocation the decision enabled.
//!
//! **Fault tolerance (PR 8).** Worker failure is contained to the failing
//! sequence, never the process or its co-batch:
//!
//! * Every decode step (inline and pooled) runs under `catch_unwind`; a
//!   panicking step worker sends a *structured* `Err` reply tagged with the
//!   job's `gen`/`idx`, so exactly that sequence errors while its
//!   neighbours' replies land normally — no 60-second stall. The pool
//!   supervisor (`StepPool::reap_and_respawn`) joins finished workers and
//!   respawns back to full width before the next step.
//! * The shard pipeline self-reports death ([`ShardedDecoder::dead`]);
//!   [`ShardBackend`] defers admission while dead sequences drain (their KV
//!   banks died with the chain, so they error terminally and retire), then
//!   the decoder rebuilds the whole thread chain on the next admit.
//! * Deadlines: `BatcherConfig::step_timeout` bounds how long one batch
//!   step may wait on a lost reply (the old hardcoded 60s), and
//!   `BatcherConfig::request_timeout` retires sequences past their total
//!   deadline with partial tokens and `GenResponse::timed_out` set.
//!
//! The failure paths are exercised deterministically via the fault points
//! in [`crate::util::fault`] (`TSGO_FAULT`, `BatcherConfig::faults`).

use super::batcher::{BatcherConfig, FinishReason, GenResponse, Pending, RequestQueue};
use super::sampler::{SamplerChain, StopSet};
use crate::kvpool::{KvPool, PoolCfg};
use crate::obs::{self, StepEvent, SOURCE_SCHED};
use crate::model::{
    decode_head, decode_layer_span, embed_tokens, KvSpec, LayerKv, ModelConfig, ModelExec,
};
use crate::shard::{ShardPlan, ShardedDecoder};
use crate::util::fault::{self, FaultPoint};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One sequence's work for one scheduler step: feed `tokens` into `slot`'s
/// KV caches starting at chain position `pos` (which always equals the rows
/// already cached for that slot). Steady-state decode carries a one-token
/// span; prefill — and post-preemption replay, which is prefill of a longer
/// chain — carries spans of up to `--prefill-chunk` tokens. Backends return
/// one result per job: the logits of the span's **last** row. Logits at
/// earlier span rows are never sampled, so backends skip their head
/// projections.
#[derive(Clone, Debug)]
pub struct StepJob {
    pub slot: usize,
    pub pos: usize,
    pub tokens: Vec<u8>,
}

impl StepJob {
    /// A one-token decode job: position `pos` feeds `token`. The spelling
    /// of the pre-span step contract, kept for tests and benches.
    pub fn single(slot: usize, pos: usize, token: u8) -> StepJob {
        StepJob { slot, pos, tokens: vec![token] }
    }

    /// Chain position just past this span: the slot's rows after the step.
    pub fn end(&self) -> usize {
        self.pos + self.tokens.len()
    }
}

/// What admission says about a sequence, given the KV budget. Public (like
/// the backends and [`scheduler_loop`]) so integration tests — and the
/// planned multi-process fleet — can drive the scheduler surface directly.
pub enum AdmitVerdict {
    /// Admitted into this slot.
    Slot(usize),
    /// No room right now — retry once pages free up (retire/preemption).
    Defer,
    /// Can never fit (e.g. the prompt alone exceeds the whole pool):
    /// answer the request with this error.
    Reject(String),
}

/// The execution surface the scheduler drives: admit a sequence slot, step
/// a batch of [`StepJob`] spans, retire a slot. Implementations own all
/// per-sequence decode state; the scheduler owns all policy. The pool
/// hooks (`can_step`/`preempt`/`slot_pages`/`pool_stats`) have pass-through
/// defaults so an unpooled backend is exactly the pre-PR-6 surface.
pub trait StepBackend {
    /// Try to start a sequence whose prompt is `prompt_len` tokens.
    fn admit(&mut self, prompt_len: usize) -> AdmitVerdict;
    fn retire(&mut self, slot: usize);
    /// One span step per job; returns each job's last-row logits in job
    /// order. An `Err` entry retires that sequence with the error.
    fn step(&mut self, jobs: &[StepJob]) -> Vec<Result<Vec<f32>, String>>;
    /// Whether every job of this step can append its full KV span without
    /// exhausting the page budget. `true` means `step(jobs)` cannot fail
    /// on page allocation.
    fn can_step(&self, _jobs: &[StepJob]) -> bool {
        true
    }
    /// Release `slot` (like [`Self::retire`]) but record it as a
    /// preemption: the sequence will be re-admitted and re-prefilled.
    fn preempt(&mut self, slot: usize) {
        self.retire(slot);
    }
    /// Pool pages currently held by `slot` (0 when unpooled).
    fn slot_pages(&self, _slot: usize) -> usize {
        0
    }
    /// `(used_pages, total_pages)` of the pool, when there is one.
    fn pool_stats(&self) -> Option<(usize, usize)> {
        None
    }
    /// Upper bound one batch step may block waiting for a reply that will
    /// never come (`--step-timeout`). No-op default for backends whose
    /// steps have no asynchronous replies.
    fn set_step_timeout(&mut self, _timeout: Duration) {}
    /// `(worker_restarts, pipeline_rebuilds)` this backend has recovered
    /// from so far. The scheduler uses the per-step *delta* for trace
    /// events; the process-lifetime values on [`GenResponse`] come from
    /// the telemetry registry ([`crate::obs::registry`]), which the
    /// recovery paths feed directly.
    fn recovery_counts(&self) -> (usize, usize) {
        (0, 0)
    }
}

/// One full-depth span step — the exact [`crate::model::DecodeState`]
/// `step_span` op sequence, shared by the inline fast path and the pool
/// workers. Only the span's last row feeds the LM head: logits at earlier
/// prefill rows are never sampled by greedy decode.
fn run_job<M: ModelExec>(m: &M, pos: usize, tokens: &[u8], bank: &mut [LayerKv]) -> Vec<f32> {
    // Both step-job fault points live here so the inline fast path and the
    // pool workers share one injection site (a single relaxed load when
    // nothing is armed — see `util::fault`).
    fault::maybe_sleep(FaultPoint::StepWorkerSlowMs);
    fault::maybe_panic(FaultPoint::StepWorkerPanic);
    let mut h = embed_tokens(m, tokens);
    for (l, kv) in m.layers().iter().zip(bank.iter_mut()) {
        decode_layer_span(l, m.config(), pos, &mut h, kv);
    }
    let last = h.row(h.rows - 1).to_vec();
    decode_head(m, last)
}

/// One batched-step job in flight to the persistent pool: the sequence's KV
/// bank travels with the job and comes back with the logits, so workers
/// need no shared mutable state. `gen` identifies the `step` call that sent
/// the job — a result surfacing after its step gave up (recv timeout) must
/// be discarded, never matched by raw index against a *later* step's jobs.
struct PoolJob {
    gen: u64,
    idx: usize,
    pos: usize,
    tokens: Vec<u8>,
    bank: Vec<LayerKv>,
}

/// A pool worker's reply: the job's generation tag and index, then either
/// the returned bank + logits, or the contained panic's message (the bank
/// was dropped worker-side, releasing its pages exactly once).
type PoolReply = (u64, usize, Result<(Vec<LayerKv>, Vec<f32>), String>);

/// Best-effort text of a caught panic payload.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

/// The persistent decode pool: workers pull [`PoolJob`]s off a shared
/// receiver and reply on `done_rx`. Dropping it closes the job channel and
/// joins every worker. A panicking worker is *supervised*: the panic is
/// caught, routed back as a structured `Err` reply for exactly its job, and
/// the worker replaced by [`StepPool::reap_and_respawn`] before the next
/// step — the pool never silently shrinks.
struct StepPool {
    job_tx: Option<Sender<PoolJob>>,
    done_rx: Receiver<PoolReply>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Monotonic `step` counter; see [`PoolJob::gen`].
    gen: u64,
    /// Target worker count — respawn restores to this.
    width: usize,
    /// Next worker thread id (monotonic across respawns, for clear names).
    next_id: usize,
    /// Workers respawned after a death; surfaced as `worker_restarts`.
    restarts: usize,
    /// Factory for one worker thread; captures the model, the shared job
    /// receiver and the reply sender so replacements join the same
    /// channels the dead worker left.
    spawn_worker: Box<dyn Fn(usize) -> std::thread::JoinHandle<()> + Send>,
}

impl StepPool {
    fn spawn<M: ModelExec + Send + Sync + 'static>(model: &Arc<M>, width: usize) -> StepPool {
        let (job_tx, job_rx) = channel::<PoolJob>();
        let (done_tx, done_rx) = channel::<PoolReply>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let spawn_worker: Box<dyn Fn(usize) -> std::thread::JoinHandle<()> + Send> = {
            let model = model.clone();
            Box::new(move |i| {
                let m = model.clone();
                let rx = job_rx.clone();
                let tx = done_tx.clone();
                std::thread::Builder::new()
                    .name(format!("tsgo-step-{i}"))
                    .spawn(move || loop {
                        // Classic shared-receiver pool: the idle worker holds
                        // the lock while blocked in recv; peers queue on the
                        // mutex. Pickup is serialized, compute is parallel. A
                        // poisoned lock (a peer panicked mid-pickup) is
                        // recovered, not propagated — one dead worker must not
                        // cascade into a dead pool.
                        let job = match rx.lock().unwrap_or_else(|p| p.into_inner()).recv() {
                            Ok(j) => j,
                            Err(_) => break, // backend dropped: pool drains
                        };
                        let mut bank = job.bank;
                        let result = catch_unwind(AssertUnwindSafe(|| {
                            run_job(m.as_ref(), job.pos, &job.tokens, &mut bank)
                        }));
                        match result {
                            Ok(logits) => {
                                // A dropped reply models a lost message: the
                                // bank (and its pool pages) is released right
                                // here; the scheduler's step deadline errors
                                // the sequence.
                                if fault::fires(FaultPoint::ChannelDrop) {
                                    continue;
                                }
                                if tx.send((job.gen, job.idx, Ok((bank, logits)))).is_err() {
                                    break;
                                }
                            }
                            Err(p) => {
                                // Contained panic: drop the (possibly torn)
                                // bank so its pages return to the pool exactly
                                // once, route the failure to precisely this
                                // job's sequence, then exit — a panicked
                                // worker's state is no longer trusted; the
                                // supervisor respawns a replacement.
                                drop(bank);
                                let _ = tx.send((job.gen, job.idx, Err(panic_msg(p.as_ref()))));
                                break;
                            }
                        }
                    })
                    .expect("spawn step-pool worker thread")
            })
        };
        let workers = (0..width).map(|i| spawn_worker(i)).collect();
        StepPool {
            job_tx: Some(job_tx),
            done_rx,
            workers,
            gen: 0,
            width,
            next_id: width,
            restarts: 0,
            spawn_worker,
        }
    }

    /// Supervision: join any worker that exited (a contained panic kills
    /// its worker after the `Err` reply) and respawn replacements back to
    /// the pool width. Returns how many were respawned. Called at the top
    /// of every pooled step, so a death in step N is healed before step
    /// N+1's jobs queue.
    fn reap_and_respawn(&mut self) -> usize {
        if !self.workers.iter().any(|w| w.is_finished()) {
            return 0;
        }
        let (dead, alive): (Vec<_>, Vec<_>) =
            self.workers.drain(..).partition(|w| w.is_finished());
        self.workers = alive;
        for w in dead {
            let _ = w.join(); // the panic was already routed as an Err reply
        }
        let mut spawned = 0usize;
        while self.workers.len() < self.width {
            let id = self.next_id;
            self.next_id += 1;
            self.workers.push((self.spawn_worker)(id));
            self.restarts += 1;
            spawned += 1;
        }
        if spawned > 0 {
            obs::registry().worker_restarts.add(spawned as u64);
        }
        spawned
    }
}

impl Drop for StepPool {
    fn drop(&mut self) {
        drop(self.job_tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Single-worker backend: per-sequence full-depth KV banks, batch steps
/// distributed over a persistent decode pool. The pool spawns lazily on
/// the first multi-job step (a server that only ever sees one request at a
/// time decodes inline and never pays for idle workers) and lives until
/// the backend drops — the scheduler calls `step` once per decoded token,
/// so a scoped spawn-per-call would pay thread creation per token.
///
/// With a [`KvPool`] configured, every bank's caches are paged out of that
/// shared budget; admission and the per-step gate are exact because decode
/// appends K and V on every layer each step, so a sequence at `rows` tokens
/// holds exactly `2 · n_layers · ⌈rows / page_tokens⌉` pages.
pub struct LocalBackend<M: ModelExec> {
    model: Arc<M>,
    kv: KvSpec,
    /// Pool width when it spawns: `min(threads, max_batch)` — never more
    /// workers than concurrently decoding sequences or the thread budget.
    pool_width: usize,
    pool: Option<StepPool>,
    /// The paged-KV page budget (`--kv-pool-mb`); `None` = contiguous
    /// growable caches, exactly the pre-PR-6 behaviour.
    kv_pool: Option<KvPool>,
    slots: Vec<Option<Vec<LayerKv>>>,
    free: Vec<usize>,
    /// How long one pooled step waits for a reply that may never come
    /// (`--step-timeout`; the old behaviour was a hardcoded 60s).
    step_timeout: Duration,
}

impl<M: ModelExec> LocalBackend<M> {
    pub fn new(
        model: Arc<M>,
        kv: KvSpec,
        max_batch: usize,
        pool_cfg: Option<PoolCfg>,
    ) -> LocalBackend<M> {
        let pool_width = crate::util::threadpool::num_threads().min(max_batch.max(1));
        let kv_pool = pool_cfg.map(|pc| KvPool::new(pc, kv, model.config()));
        LocalBackend {
            model,
            kv,
            pool_width,
            pool: None,
            kv_pool,
            slots: Vec::new(),
            free: Vec::new(),
            step_timeout: Duration::from_secs(60),
        }
    }

    /// Pages K+V of all layers allocate whenever a sequence crosses one
    /// page boundary.
    fn pages_per_boundary(&self) -> usize {
        2 * self.model.config().n_layers
    }

    /// Workers the pool supervisor has respawned after a death.
    pub fn worker_restarts(&self) -> usize {
        self.pool.as_ref().map_or(0, |p| p.restarts)
    }

    /// Drop any replies parked in the done channel. Between steps every
    /// parked reply is stale — its step already returned, its sequence was
    /// errored and retired — so the only live thing in it is a KV bank
    /// whose drop here returns the pages to the pool (the lost-bank leak:
    /// a slow worker's reply landing after its step's deadline would
    /// otherwise hold pages forever). Called by `retire` and at the top of
    /// every pooled step; public so fault tests can force reclamation.
    pub fn reclaim_stale(&mut self) {
        if let Some(pool) = &self.pool {
            while pool.done_rx.try_recv().is_ok() {}
        }
    }
}

impl<M: ModelExec + Send + Sync + 'static> StepBackend for LocalBackend<M> {
    fn admit(&mut self, prompt_len: usize) -> AdmitVerdict {
        if fault::fires(FaultPoint::AdmitExhaust) {
            return AdmitVerdict::Defer;
        }
        if let Some(pool) = &self.kv_pool {
            let per_boundary = 2 * self.model.config().n_layers;
            let need = per_boundary * pool.pages_for_rows(prompt_len);
            if need > pool.total_pages() {
                return AdmitVerdict::Reject(format!(
                    "kv pool too small for this prompt: prefill needs {need} pages \
                     ({prompt_len} tokens x {} layers x K+V at {} tokens/page) but \
                     the pool holds {} pages — raise --kv-pool-mb",
                    self.model.config().n_layers,
                    pool.page_tokens(),
                    pool.total_pages(),
                ));
            }
            // One decode step past the prompt as reservation margin, capped
            // at the whole pool so a lone maximal sequence still admits.
            if (need + per_boundary).min(pool.total_pages()) > pool.free_pages() {
                return AdmitVerdict::Defer;
            }
        }
        let cfg = self.model.config();
        let bank: Vec<LayerKv> = (0..cfg.n_layers)
            .map(|_| LayerKv::new_in(self.kv, cfg, self.kv_pool.as_ref()))
            .collect();
        let slot = match self.free.pop() {
            Some(s) => {
                self.slots[s] = Some(bank);
                s
            }
            None => {
                self.slots.push(Some(bank));
                self.slots.len() - 1
            }
        };
        AdmitVerdict::Slot(slot)
    }

    fn retire(&mut self, slot: usize) {
        // Dropping a paged bank releases its pages back to the pool. A
        // bankless slot (its bank was lost to a worker death or is parked
        // in a stale reply) has nothing to drop here — `reclaim_stale`
        // frees any parked bank, so each bank's pages release exactly once
        // whichever path it died on.
        self.slots[slot] = None;
        self.free.push(slot);
        self.reclaim_stale();
    }

    fn step(&mut self, jobs: &[StepJob]) -> Vec<Result<Vec<f32>, String>> {
        if jobs.is_empty() {
            return Vec::new();
        }
        if let [job] = jobs {
            // Batch of one: decode inline, skipping the pool's channel
            // hops. Panics are contained exactly like a pool worker's: the
            // failure becomes this job's Err, the (possibly torn) bank is
            // discarded so its pages return to the pool, and the slot
            // stays bankless until retire.
            let mut bank = self.slots[job.slot].take().expect("step on unadmitted slot");
            let result = catch_unwind(AssertUnwindSafe(|| {
                run_job(self.model.as_ref(), job.pos, &job.tokens, &mut bank)
            }));
            return vec![match result {
                Ok(logits) => {
                    self.slots[job.slot] = Some(bank);
                    Ok(logits)
                }
                Err(p) => {
                    drop(bank);
                    Err(format!("decode worker panicked: {}", panic_msg(p.as_ref())))
                }
            }];
        }
        let timeout = self.step_timeout;
        let lost = || {
            format!(
                "decode step reply lost (worker died or exceeded the {} step deadline)",
                crate::util::fmt_duration(timeout)
            )
        };
        let mut out: Vec<Result<Vec<f32>, String>> = jobs.iter().map(|_| Err(lost())).collect();
        let pool = self
            .pool
            .get_or_insert_with(|| StepPool::spawn(&self.model, self.pool_width));
        let respawned = pool.reap_and_respawn();
        if respawned > 0 {
            println!(
                "serve: step pool respawned {respawned} decode worker(s) after a death \
                 (width {}, total restarts {})",
                pool.width, pool.restarts
            );
        }
        // Anything parked in the done channel now predates this step:
        // drain it so stale banks release their pages and the recv loop
        // below mostly sees this generation.
        while pool.done_rx.try_recv().is_ok() {}
        pool.gen += 1;
        let gen = pool.gen;
        let tx = pool.job_tx.as_ref().expect("step pool open until drop");
        let mut sent = 0usize;
        for (idx, job) in jobs.iter().enumerate() {
            let bank = self.slots[job.slot].take().expect("step on unadmitted slot");
            let pj = PoolJob { gen, idx, pos: job.pos, tokens: job.tokens.clone(), bank };
            if tx.send(pj).is_err() {
                break; // every worker died mid-step; remaining entries stay Err
            }
            sent += 1;
        }
        let mut got = 0usize;
        while got < sent {
            // recv_timeout, not recv: if a reply is lost (dead worker,
            // dropped message) while idle peers keep the channel open, a
            // plain recv would wedge the scheduler. `--step-timeout`
            // bounds the wait (a healthy batch step is milliseconds).
            match pool.done_rx.recv_timeout(timeout) {
                // A stale generation is a job whose step already gave up:
                // its sequence was errored/retired back then, so both the
                // bank and the logits are dead — drop them rather than
                // matching the raw index into *this* step's jobs.
                Ok((g, _, _)) if g != gen => continue,
                Ok((_, idx, Ok((bank, logits)))) => {
                    self.slots[jobs[idx].slot] = Some(bank);
                    out[idx] = Ok(logits);
                    got += 1;
                }
                Ok((_, idx, Err(e))) => {
                    // A contained worker panic: only this job's sequence
                    // errors; its bank was dropped worker-side, so the
                    // pages are already back in the pool.
                    out[idx] = Err(format!("decode worker panicked: {e}"));
                    got += 1;
                }
                Err(_) => break, // deadline: unanswered entries keep `lost`
            }
        }
        out
    }

    fn can_step(&self, jobs: &[StepJob]) -> bool {
        let Some(pool) = &self.kv_pool else {
            return true;
        };
        // Exact span-aware gate: a job appending `tokens.len()` rows from
        // `pos` crosses `pages_for(end) - pages_for(pos)` page boundaries
        // per (layer, K|V) cache. The one-token case degenerates to the old
        // "pos is on a boundary" test.
        let new_pages: usize = jobs
            .iter()
            .map(|j| pool.pages_for_rows(j.end()) - pool.pages_for_rows(j.pos))
            .sum();
        self.pages_per_boundary() * new_pages <= pool.free_pages()
    }

    fn preempt(&mut self, slot: usize) {
        self.retire(slot);
        if let Some(pool) = &self.kv_pool {
            pool.note_preemption();
        }
    }

    fn slot_pages(&self, slot: usize) -> usize {
        self.slots
            .get(slot)
            .and_then(|b| b.as_ref())
            .map_or(0, |bank| bank.iter().map(|lk| lk.pages_used()).sum())
    }

    fn pool_stats(&self) -> Option<(usize, usize)> {
        self.kv_pool.as_ref().map(|p| (p.used_pages(), p.total_pages()))
    }

    fn set_step_timeout(&mut self, timeout: Duration) {
        self.step_timeout = timeout.max(Duration::from_millis(1));
    }

    fn recovery_counts(&self) -> (usize, usize) {
        (self.worker_restarts(), 0)
    }
}

/// Scheduler-side accounting twin of the shard-local KV sub-pools.
///
/// The pipeline's `admit`/`retire` are asynchronous packets, so the real
/// sub-pools' counters lag the scheduler's decisions; gating on them could
/// spin on stale state. The mirror instead tracks what each decision
/// *implies* — exact, because decode appends K and V on every layer each
/// step, so a slot at `rows` tokens holds `2 · layers_s · ⌈rows/pt⌉` pages
/// of shard `s`'s sub-pool. Channel FIFO order makes the mirror safe: a
/// release the mirror credits was sent down the pipe before any allocation
/// it enabled, so each shard frees first and allocates second.
pub struct PoolMirror {
    page_tokens: usize,
    /// Per shard: (layers in its range, its sub-pool's page budget).
    shards: Vec<(usize, usize)>,
    /// Rows cached per admitted slot (== that sequence's next position).
    slot_rows: Vec<Option<usize>>,
}

impl PoolMirror {
    pub fn new(
        plan: &ShardPlan,
        mcfg: &ModelConfig,
        kv: KvSpec,
        pc: PoolCfg,
    ) -> PoolMirror {
        let shards = (0..plan.n_shards())
            .map(|s| {
                let (lo, hi) = plan.range(s);
                let sub = pc.shard_slice(hi - lo, plan.n_layers());
                // A throwaway pool computes the page budget with the exact
                // constructor math of the shard's real sub-pool.
                (hi - lo, KvPool::new(sub, kv, mcfg).total_pages())
            })
            .collect();
        PoolMirror { page_tokens: pc.page_tokens.max(1), shards, slot_rows: Vec::new() }
    }

    fn pages_for(&self, rows: usize) -> usize {
        rows.div_ceil(self.page_tokens)
    }

    /// Σ over live slots of pages held per (layer, K|V) cache; shard `s`
    /// holds `2 · layers_s ·` this.
    fn held(&self) -> usize {
        self.slot_rows.iter().flatten().map(|&r| self.pages_for(r)).sum()
    }

    fn verdict(&self, prompt_len: usize) -> Option<AdmitVerdict> {
        let held = self.held();
        for &(layers, total) in &self.shards {
            let per_boundary = 2 * layers;
            let need = per_boundary * self.pages_for(prompt_len);
            if need > total {
                return Some(AdmitVerdict::Reject(format!(
                    "kv pool too small for this prompt: prefill needs {need} of a \
                     {layers}-layer shard's {total} pages — raise --kv-pool-mb",
                )));
            }
            let free = total.saturating_sub(per_boundary * held);
            if (need + per_boundary).min(total) > free {
                return Some(AdmitVerdict::Defer);
            }
        }
        None
    }

    fn on_admit(&mut self, slot: usize) {
        if self.slot_rows.len() <= slot {
            self.slot_rows.resize(slot + 1, None);
        }
        self.slot_rows[slot] = Some(0);
    }

    fn on_retire(&mut self, slot: usize) {
        if let Some(s) = self.slot_rows.get_mut(slot) {
            *s = None;
        }
    }

    /// Credit one job's span as cached rows. Only called for jobs whose
    /// step result was `Ok` — a failed job's KV never (reliably) appended,
    /// and its sequence is about to retire anyway, so counting it would
    /// overstate held pages until the retire lands.
    fn on_job(&mut self, j: &StepJob) {
        if let Some(Some(r)) = self.slot_rows.get_mut(j.slot) {
            *r += j.tokens.len();
        }
    }

    fn can_step(&self, jobs: &[StepJob]) -> bool {
        // Span-aware, like `LocalBackend::can_step`: each job's new pages
        // are the boundary crossings of its whole span, computed from the
        // mirror's row counts (authoritative — see the struct docs).
        let new_pages: usize = jobs
            .iter()
            .map(|j| match self.slot_rows.get(j.slot) {
                Some(Some(r)) => self.pages_for(r + j.tokens.len()) - self.pages_for(*r),
                _ => 0,
            })
            .sum();
        let held = self.held();
        self.shards
            .iter()
            .all(|&(layers, total)| 2 * layers * (held + new_pages) <= total)
    }

    fn slot_pages(&self, slot: usize) -> usize {
        let rows = match self.slot_rows.get(slot) {
            Some(Some(r)) => *r,
            _ => return 0,
        };
        self.shards
            .iter()
            .map(|&(layers, _)| 2 * layers * self.pages_for(rows))
            .sum()
    }

    fn stats(&self) -> (usize, usize) {
        let held = self.held();
        let used = self.shards.iter().map(|&(layers, _)| 2 * layers * held).sum();
        let total = self.shards.iter().map(|&(_, t)| t).sum();
        (used, total)
    }
}

/// Pipeline backend: delegates execution to the shard threads and pool
/// accounting to the [`PoolMirror`] (when a pool is configured).
///
/// Failure containment: when the decoder reports itself dead (a shard
/// thread died or the result FIFO went corrupt), admission *defers* until
/// every in-flight sequence has been errored and retired — their KV banks
/// died with the chain — and only then lets [`ShardedDecoder::admit`]
/// rebuild the whole thread chain, so a rebuilt pipeline never sees a slot
/// it didn't admit.
pub struct ShardBackend {
    dec: ShardedDecoder,
    mirror: Option<PoolMirror>,
}

impl ShardBackend {
    pub fn new(dec: ShardedDecoder, mirror: Option<PoolMirror>) -> ShardBackend {
        ShardBackend { dec, mirror }
    }
}

impl StepBackend for ShardBackend {
    fn admit(&mut self, prompt_len: usize) -> AdmitVerdict {
        if fault::fires(FaultPoint::AdmitExhaust) {
            return AdmitVerdict::Defer;
        }
        if self.dec.dead() && self.dec.live_slots() > 0 {
            // The chain is down but sequences still reference its slots:
            // their next step errors them terminally and retires them;
            // rebuild (inside `dec.admit`) waits for that drain.
            return AdmitVerdict::Defer;
        }
        if let Some(v) = self.mirror.as_ref().and_then(|m| m.verdict(prompt_len)) {
            return v;
        }
        match self.dec.admit() {
            Ok(slot) => {
                if let Some(m) = &mut self.mirror {
                    m.on_admit(slot);
                }
                AdmitVerdict::Slot(slot)
            }
            Err(e) => AdmitVerdict::Reject(e),
        }
    }

    fn retire(&mut self, slot: usize) {
        if let Some(m) = &mut self.mirror {
            m.on_retire(slot);
        }
        self.dec.retire(slot);
    }

    fn step(&mut self, jobs: &[StepJob]) -> Vec<Result<Vec<f32>, String>> {
        let out = self.dec.step(jobs);
        if let Some(m) = &mut self.mirror {
            for (j, r) in jobs.iter().zip(&out) {
                if r.is_ok() {
                    m.on_job(j);
                }
            }
        }
        out
    }

    fn can_step(&self, jobs: &[StepJob]) -> bool {
        self.mirror.as_ref().is_none_or(|m| m.can_step(jobs))
    }

    fn slot_pages(&self, slot: usize) -> usize {
        self.mirror.as_ref().map_or(0, |m| m.slot_pages(slot))
    }

    fn pool_stats(&self) -> Option<(usize, usize)> {
        self.mirror.as_ref().map(|m| m.stats())
    }

    fn set_step_timeout(&mut self, timeout: Duration) {
        self.dec.set_step_timeout(timeout.max(Duration::from_millis(1)));
    }

    fn recovery_counts(&self) -> (usize, usize) {
        (0, self.dec.rebuilds())
    }
}

/// One in-flight sequence: its slot, progress, and reply line.
///
/// The feed chain is `prompt ++ out`: position `pos` always feeds
/// `chain[pos]`, which uniformly covers prefill, steady-state decode (the
/// last generated token) and post-preemption re-prefill — a preempted
/// sequence just resets `pos` to 0 and replays the whole chain (greedy
/// decode is deterministic, so the rebuilt KV state is byte-identical and
/// the continuation matches an unpreempted run).
struct Running {
    slot: usize,
    prompt: Vec<u8>,
    /// Chain positions stepped so far = this sequence's next position.
    pos: usize,
    out: Vec<u8>,
    max_new: usize,
    enqueued: Instant,
    /// When this sequence joined its first token step. Set by the
    /// scheduler right before stepping (not at admission) so the idle
    /// coalescing window counts as queue time, not decode time. Survives
    /// preemption: replay time is decode time, never queue time.
    started: Option<Instant>,
    /// Largest co-running batch this sequence ever shared a step with.
    max_cobatch: usize,
    /// When this sequence's first generated token was sampled: the boundary
    /// between prefill time and decode time. Survives preemption — replay
    /// of an already-started generation counts as decode time.
    first_token: Option<Instant>,
    /// Times this sequence was evicted for pool pressure.
    preemptions: usize,
    /// High-water mark of pool pages this sequence's KV held.
    kv_pages_peak: usize,
    /// This request's sampling pipeline. Only consulted at the chain end
    /// (one call per emitted token), so replay positions never advance the
    /// RNG — a preempted sampled sequence resumes its stream exactly where
    /// it left off.
    chain: SamplerChain,
    /// Stop sequences checked against `out`'s tail after every emitted token.
    stop: StopSet,
    /// Streaming tap (see [`Pending::events`]); a closed receiver cancels
    /// the sequence at its next emitted token.
    events: Option<Sender<u8>>,
    reply: Sender<Result<GenResponse, String>>,
}

impl Running {
    fn chain_len(&self) -> usize {
        self.prompt.len() + self.out.len()
    }

    fn chain_at(&self, i: usize) -> u8 {
        if i < self.prompt.len() {
            self.prompt[i]
        } else {
            self.out[i - self.prompt.len()]
        }
    }

    /// The span of chain tokens to feed this step: up to `chunk` tokens
    /// while behind the chain end (prefill, or post-preemption replay),
    /// which degenerates to a single token in steady-state decode where
    /// `pos == chain_len - 1`.
    fn feed_span(&self, chunk: usize) -> Vec<u8> {
        let end = (self.pos + chunk.max(1)).min(self.chain_len());
        (self.pos..end).map(|i| self.chain_at(i)).collect()
    }
}

enum Advance {
    Continue,
    /// Retire with a reply: `Ok` carries why generation ended, `Err` the
    /// decode failure.
    Done(Result<FinishReason, String>),
    /// The streaming client went away: retire the slot (freeing its KV
    /// pages) and send no reply — there is nobody left to read it.
    Cancelled,
}

/// The scheduler loop: runs on the `DynamicBatcher` worker thread until the
/// request queue closes (batcher dropped). Exits only with every in-flight
/// sequence answered — finished normally, or drained with an error on
/// shutdown — so `DynamicBatcher::drop` can join unconditionally.
pub fn scheduler_loop(backend: &mut dyn StepBackend, cfg: &BatcherConfig, queue: RequestQueue) {
    backend.set_step_timeout(cfg.step_timeout);
    let mut active: Vec<Running> = Vec::new();
    // Preempted sequences awaiting re-admission (oldest first) and requests
    // the pool deferred at admission (FIFO). Invariant: both only grow under
    // pool pressure, and pages always free up (sequences finish or error),
    // so neither starves.
    let mut paused: VecDeque<Running> = VecDeque::new();
    let mut waiting: VecDeque<Pending> = VecDeque::new();
    loop {
        // -- admission: one decision point per token step -----------------
        if active.is_empty() && paused.is_empty() && waiting.is_empty() {
            // Idle: block for the next request; a closed, drained queue
            // means the batcher was dropped — done.
            match queue.recv() {
                Ok(p) => {
                    if let Some(p) = admit_request(backend, &mut active, &queue, p) {
                        waiting.push_back(p);
                    }
                }
                Err(_) => return,
            }
            // Initial coalescing window (the legacy `max_wait` knob): soak
            // up stragglers so a burst starts as one batch. Only applies
            // from idle — once decoding, admission never waits — and only
            // when the first request actually started a sequence.
            let deadline = Instant::now() + cfg.max_wait;
            while !active.is_empty() && active.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match queue.recv_timeout(deadline - now) {
                    Ok(p) => {
                        if let Some(p) = admit_request(backend, &mut active, &queue, p) {
                            waiting.push_back(p);
                        }
                    }
                    Err(_) => break,
                }
            }
        } else {
            // Preempted sequences re-admit first, oldest first: they carry
            // generation progress, and handing freed pages to new prompts
            // instead would starve them.
            while active.len() < cfg.max_batch && !paused.is_empty() {
                let need = paused.front().expect("checked non-empty").chain_len();
                match backend.admit(need) {
                    AdmitVerdict::Slot(slot) => {
                        obs::registry().admit_slot.inc();
                        let mut r = paused.pop_front().expect("checked non-empty");
                        r.slot = slot;
                        active.push(r);
                    }
                    AdmitVerdict::Defer => {
                        obs::registry().admit_defer.inc();
                        break;
                    }
                    AdmitVerdict::Reject(e) => {
                        // The chain outgrew the whole pool while paused.
                        obs::registry().admit_reject.inc();
                        obs::registry().finish_error.inc();
                        let r = paused.pop_front().expect("checked non-empty");
                        let _ = r.reply.send(Err(e));
                    }
                }
            }
            // Deferred and fresh requests get pages only once nothing is
            // paused; within that, earlier-deferred before newly-arrived
            // (FIFO fairness — a Defer at the front holds the line).
            let mut open = paused.is_empty();
            while open && active.len() < cfg.max_batch && !waiting.is_empty() {
                let p = waiting.pop_front().expect("checked non-empty");
                if let Some(p) = admit_request(backend, &mut active, &queue, p) {
                    waiting.push_front(p);
                    open = false;
                }
            }
            // Decoding: admit whatever is queued right now, without
            // waiting — this is the continuous-batching fix. A sequence
            // admitted here joins the very next token step.
            while open && active.len() < cfg.max_batch {
                match queue.try_recv() {
                    Ok(p) => {
                        if let Some(p) = admit_request(backend, &mut active, &queue, p) {
                            waiting.push_back(p);
                            open = false;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        // Batcher dropped mid-flight: drain every reply
                        // with an error rather than leaving callers hung.
                        drain(backend, active, paused, waiting, &queue, "batcher shut down");
                        return;
                    }
                }
            }
        }

        // -- deadlines: expire requests past --request-timeout -------------
        // Checked once per step (steps are milliseconds), queue wait
        // included: an expired sequence answers with its partial tokens and
        // `timed_out` set, freeing its slot and pages for the batch.
        if let Some(limit) = cfg.request_timeout {
            let now = Instant::now();
            let expired = |enq: Instant| now.saturating_duration_since(enq) >= limit;
            let mut still = Vec::with_capacity(active.len());
            for r in active {
                if expired(r.enqueued) {
                    println!(
                        "serve: deadline exceeded ({} elapsed): retiring sequence with \
                         {} of {} tokens",
                        crate::util::fmt_duration(now.saturating_duration_since(r.enqueued)),
                        r.out.len(),
                        r.max_new
                    );
                    backend.retire(r.slot);
                    finish(r, Ok(FinishReason::Timeout));
                } else {
                    still.push(r);
                }
            }
            active = still;
            // Paused sequences hold no slot (preemption released it).
            for _ in 0..paused.len() {
                let r = paused.pop_front().expect("iterating current length");
                if expired(r.enqueued) {
                    finish(r, Ok(FinishReason::Timeout));
                } else {
                    paused.push_back(r);
                }
            }
            // Never-admitted requests expire with zero tokens: all their
            // elapsed time was queue wait.
            for _ in 0..waiting.len() {
                let p = waiting.pop_front().expect("iterating current length");
                if expired(p.enqueued) {
                    queue.settle();
                    obs::registry().finish_timeout.inc();
                    let _ = p.reply.send(Ok(GenResponse {
                        tokens: Vec::new(),
                        queue_wait: now.saturating_duration_since(p.enqueued),
                        prefill_time: Duration::ZERO,
                        decode_time: Duration::ZERO,
                        batch_size: 1,
                        kv_pages_used: 0,
                        preemptions: 0,
                        timed_out: true,
                        worker_restarts: obs::registry().worker_restarts.get() as usize,
                        pipeline_rebuilds: obs::registry().pipeline_rebuilds.get() as usize,
                        finish_reason: FinishReason::Timeout,
                    }));
                } else {
                    waiting.push_back(p);
                }
            }
        }

        // Admission can answer requests without starting a sequence (empty
        // prompt, max_new == 0, backend refusal); with nothing running, go
        // straight back to blocking on the queue instead of issuing an
        // empty step.
        if active.is_empty() {
            continue;
        }

        // -- pool pressure gate: preempt until the step fits ---------------
        let mut preempted_now = 0u32;
        let jobs = loop {
            let jobs: Vec<StepJob> = active
                .iter()
                .map(|r| StepJob {
                    slot: r.slot,
                    pos: r.pos,
                    tokens: r.feed_span(cfg.prefill_chunk),
                })
                .collect();
            if backend.can_step(&jobs) {
                break jobs;
            }
            if active.len() == 1 {
                // Alone and still short of pages: this one chain exceeds
                // the whole pool. Preempting it would just replay into the
                // same wall, so answer it with the error.
                let r = active.pop().expect("checked non-empty");
                backend.retire(r.slot);
                obs::registry().finish_error.inc();
                let _ = r.reply.send(Err(format!(
                    "kv pool exhausted: this sequence alone needs more pages than \
                     the pool holds ({} tokens cached) — raise --kv-pool-mb",
                    r.pos
                )));
                break Vec::new();
            }
            // Youngest first: the most recently (re)admitted sequence has
            // the least progress to replay.
            let mut r = active.pop().expect("len checked above");
            r.preemptions += 1;
            r.kv_pages_peak = r.kv_pages_peak.max(backend.slot_pages(r.slot));
            if let Some((used, total)) = backend.pool_stats() {
                println!(
                    "serve: kv pool pressure ({used}/{total} pages held): preempting \
                     youngest sequence ({} of {} tokens generated, will re-prefill)",
                    r.out.len(),
                    r.max_new
                );
            }
            backend.preempt(r.slot);
            obs::registry().preemptions.inc();
            preempted_now += 1;
            r.pos = 0;
            paused.push_back(r);
        };
        if active.is_empty() {
            continue;
        }

        // -- one span step for the whole running batch ---------------------
        let bs = active.len();
        let span_lens: Vec<usize> = jobs.iter().map(|j| j.tokens.len()).collect();
        // Span split for the telemetry plane: a job whose span reaches the
        // chain end samples one token (decode); every other fed position is
        // prefill (or post-preemption replay, which is prefill of a longer
        // chain).
        let (mut prefill_fed, mut decode_fed) = (0usize, 0usize);
        for (r, j) in active.iter().zip(&jobs) {
            if j.end() == r.chain_len() {
                decode_fed += 1;
                prefill_fed += j.tokens.len() - 1;
            } else {
                prefill_fed += j.tokens.len();
            }
        }
        let recovered_before = backend.recovery_counts();
        let step_start = Instant::now();
        for r in active.iter_mut() {
            r.started.get_or_insert(step_start);
        }
        let results = backend.step(&jobs);
        // Telemetry for the step just taken: relaxed atomics only — the
        // registry adds no locks and no allocation to the step hot path
        // (priced by the `packed_int2_metrics` bench row).
        let step_dur = step_start.elapsed();
        let recovered_after = backend.recovery_counts();
        let reg = obs::registry();
        reg.steps.inc();
        reg.prefill_tokens.add(prefill_fed as u64);
        reg.decode_tokens.add(decode_fed as u64);
        reg.step_ms.observe(step_dur);
        reg.running_sequences.set(bs as i64);
        if let Some((_, total)) = backend.pool_stats() {
            reg.kv_pages_total.set(total as i64);
        }
        reg.trace.record(&StepEvent {
            seq: 0,
            source: SOURCE_SCHED,
            batch: bs as u32,
            prefill_tokens: prefill_fed as u32,
            decode_tokens: decode_fed as u32,
            dur_us: step_dur.as_micros() as u64,
            preempted: preempted_now,
            restarts: (recovered_after.0.saturating_sub(recovered_before.0)
                + recovered_after.1.saturating_sub(recovered_before.1))
                as u32,
        });

        // -- retire decisions ----------------------------------------------
        let mut still = Vec::with_capacity(bs);
        for ((mut r, res), span_len) in active.into_iter().zip(results).zip(span_lens) {
            r.max_cobatch = r.max_cobatch.max(bs);
            r.kv_pages_peak = r.kv_pages_peak.max(backend.slot_pages(r.slot));
            let had_tokens = !r.out.is_empty();
            let verdict = advance(&mut r, res, span_len);
            if !had_tokens && !r.out.is_empty() {
                r.first_token = Some(Instant::now());
            }
            match verdict {
                Advance::Continue => still.push(r),
                Advance::Done(result) => {
                    backend.retire(r.slot);
                    finish(r, result);
                }
                Advance::Cancelled => {
                    println!(
                        "serve: streaming client disconnected: retiring sequence \
                         with {} of {} tokens",
                        r.out.len(),
                        r.max_new
                    );
                    backend.retire(r.slot);
                }
            }
        }
        active = still;
    }
}

/// Consume one span-step result for one sequence; decides continue vs
/// retire. `span_len` is how many chain tokens the step just cached.
fn advance(r: &mut Running, res: Result<Vec<f32>, String>, span_len: usize) -> Advance {
    let mut logits = match res {
        Ok(l) => l,
        Err(e) => return Advance::Done(Err(e)),
    };
    r.pos += span_len;
    if r.pos < r.chain_len() {
        // Mid-prefill — or mid-replay after a preemption: known chain
        // positions never consult the logits (or the sampler chain's RNG),
        // which is what makes replay cheap and trivially deterministic.
        return Advance::Continue;
    }
    // The chain's last token was just stepped: its logits pick the next
    // generated token. The default (greedy) chain is bit-identical to the
    // historical argmax path; a seeded chain consumes exactly one RNG draw
    // here, so same seed + same logits ⇒ same token.
    let next = match r.chain.next_token(&mut logits, &r.prompt, &r.out) {
        Ok(next) => next,
        Err(e) => return Advance::Done(Err(e)),
    };
    r.out.push(next);
    if let Some(events) = &r.events {
        // A dead receiver means the streaming client disconnected: stop
        // spending steps on a generation nobody is reading.
        if events.send(next).is_err() {
            return Advance::Cancelled;
        }
    }
    if r.stop.hit(&r.out) {
        Advance::Done(Ok(FinishReason::Stop))
    } else if r.out.len() >= r.max_new {
        Advance::Done(Ok(FinishReason::Length))
    } else {
        Advance::Continue
    }
}

/// Resolve one pending request: answer it directly (validation, rejection),
/// start it as a [`Running`], or hand it back for the deferred queue.
fn admit_request(
    backend: &mut dyn StepBackend,
    active: &mut Vec<Running>,
    queue: &RequestQueue,
    p: Pending,
) -> Option<Pending> {
    let queue_wait = Instant::now().saturating_duration_since(p.enqueued);
    if p.req.prompt.is_empty() {
        // Matches the historical error path (argmax over no decoded step).
        queue.settle();
        let _ = p
            .reply
            .send(Err("empty logits (no prompt token was decoded)".into()));
        return None;
    }
    if p.req.max_new == 0 {
        queue.settle();
        let reg = obs::registry();
        reg.finish_length.inc();
        let (worker_restarts, pipeline_rebuilds) = (
            reg.worker_restarts.get() as usize,
            reg.pipeline_rebuilds.get() as usize,
        );
        let _ = p.reply.send(Ok(GenResponse {
            tokens: Vec::new(),
            queue_wait,
            prefill_time: Duration::ZERO,
            decode_time: Duration::ZERO,
            batch_size: 1,
            kv_pages_used: 0,
            preemptions: 0,
            timed_out: false,
            worker_restarts,
            pipeline_rebuilds,
            finish_reason: FinishReason::Length,
        }));
        return None;
    }
    // `generate` validates at the door, but tests (and any future ingress)
    // can drive the scheduler directly — bad knobs still answer with the
    // validation error instead of poisoning a slot.
    let chain = match SamplerChain::from_params(&p.req.params) {
        Ok(chain) => chain,
        Err(e) => {
            queue.settle();
            let _ = p.reply.send(Err(e));
            return None;
        }
    };
    match backend.admit(p.req.prompt.len()) {
        AdmitVerdict::Slot(slot) => {
            obs::registry().admit_slot.inc();
            queue.settle();
            active.push(Running {
                slot,
                prompt: p.req.prompt,
                pos: 0,
                out: Vec::new(),
                max_new: p.req.max_new,
                enqueued: p.enqueued,
                started: None,
                max_cobatch: 1,
                first_token: None,
                preemptions: 0,
                kv_pages_peak: 0,
                chain,
                stop: StopSet::new(p.req.stop),
                events: p.events,
                reply: p.reply,
            });
            None
        }
        // Deferred requests stay un-settled: they keep occupying their
        // `max_queue` slot, so the front door keeps back-pressuring while
        // the pool is the bottleneck.
        AdmitVerdict::Defer => {
            obs::registry().admit_defer.inc();
            Some(p)
        }
        AdmitVerdict::Reject(e) => {
            obs::registry().admit_reject.inc();
            queue.settle();
            let _ = p.reply.send(Err(e));
            None
        }
    }
}

fn finish(r: Running, result: Result<FinishReason, String>) {
    // A sequence only finishes after at least one step, so `started` is
    // always stamped by then; the fallbacks are pure defensiveness (and
    // cover a deadline expiry before the first step).
    let started = r.started.unwrap_or_else(Instant::now);
    // Prefill ends when the first generated token is sampled; everything
    // after (including any post-preemption replay) is decode time. A
    // sequence that errored before its first token has zero decode time.
    let first = r.first_token.unwrap_or_else(Instant::now);
    // The recovery counters are *process-lifetime* values read off the
    // telemetry registry at finish time — not per-request deltas, and no
    // longer per-backend (see docs/SERVE_API.md "counter scope"). The
    // registry is also where the finish-reason tallies and the per-request
    // prefill/decode latency histograms accrue.
    let reg = obs::registry();
    let resp = result.map(|finish_reason| GenResponse {
        tokens: r.out,
        queue_wait: started.saturating_duration_since(r.enqueued),
        prefill_time: first.saturating_duration_since(started),
        decode_time: first.elapsed(),
        batch_size: r.max_cobatch,
        kv_pages_used: r.kv_pages_peak,
        preemptions: r.preemptions,
        timed_out: finish_reason == FinishReason::Timeout,
        worker_restarts: reg.worker_restarts.get() as usize,
        pipeline_rebuilds: reg.pipeline_rebuilds.get() as usize,
        finish_reason,
    });
    match &resp {
        Ok(ok) => {
            reg.count_finish(ok.finish_reason);
            reg.request_prefill_ms.observe(ok.prefill_time);
            reg.request_decode_ms.observe(ok.decode_time);
        }
        Err(_) => reg.finish_error.inc(),
    }
    let _ = r.reply.send(resp);
}

fn drain(
    backend: &mut dyn StepBackend,
    active: Vec<Running>,
    paused: VecDeque<Running>,
    waiting: VecDeque<Pending>,
    queue: &RequestQueue,
    msg: &str,
) {
    for r in active {
        backend.retire(r.slot);
        let _ = r.reply.send(Err(format!(
            "{msg} while this request was in flight ({} of {} tokens generated)",
            r.out.len(),
            r.max_new
        )));
    }
    // Paused sequences hold no slot (preemption released it) — no retire.
    for r in paused {
        let _ = r.reply.send(Err(format!(
            "{msg} while this request was in flight ({} of {} tokens generated)",
            r.out.len(),
            r.max_new
        )));
    }
    for p in waiting {
        queue.settle();
        let _ = p.reply.send(Err(format!("{msg} before this request was admitted")));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DecodeState, ModelWeights, Preset};
    use crate::serve::batcher::{argmax_token, GenRequest};
    use crate::util::rng::Rng;

    /// Wraps a backend to record every step's `(slot, pos, span_len)` jobs.
    struct Recording<B: StepBackend> {
        inner: B,
        log: Arc<Mutex<Vec<Vec<(usize, usize, usize)>>>>,
    }

    impl<B: StepBackend> StepBackend for Recording<B> {
        fn admit(&mut self, prompt_len: usize) -> AdmitVerdict {
            self.inner.admit(prompt_len)
        }
        fn retire(&mut self, slot: usize) {
            self.inner.retire(slot)
        }
        fn step(&mut self, jobs: &[StepJob]) -> Vec<Result<Vec<f32>, String>> {
            self.log
                .lock()
                .unwrap()
                .push(jobs.iter().map(|j| (j.slot, j.pos, j.tokens.len())).collect());
            self.inner.step(jobs)
        }
        fn can_step(&self, jobs: &[StepJob]) -> bool {
            self.inner.can_step(jobs)
        }
        fn preempt(&mut self, slot: usize) {
            self.inner.preempt(slot)
        }
        fn slot_pages(&self, slot: usize) -> usize {
            self.inner.slot_pages(slot)
        }
        fn pool_stats(&self) -> Option<(usize, usize)> {
            self.inner.pool_stats()
        }
    }

    /// ROADMAP item 2's closed caveat: a preempted ~200-token sequence must
    /// re-prefill through the chunked span path — ⌈chain/C⌉ replay steps of
    /// C tokens, not one step per token — and its tokens must be unchanged
    /// from an unpreempted decode.
    #[test]
    fn preemption_replay_is_chunked_and_token_identical() {
        const CHUNK: usize = 48;
        let mut rng = Rng::new(11);
        let model = Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng));
        let kv = KvSpec::DenseF32;
        // Pool of 16 "units" (a unit = one page in each of the 2·n_layers
        // caches, at 16 tokens/page). A peaks at 5 units (8 + 60 = 68
        // rows), B needs 13 for its 200-token prompt and crosses into 14
        // mid-decode — so the pool drains while both run, and the youngest
        // sequence (B) is preempted with its whole prompt cached.
        let probe = KvPool::new(
            PoolCfg { budget_bytes: 1 << 30, page_tokens: 16 },
            kv,
            model.config(),
        );
        let pc = PoolCfg {
            budget_bytes: 16 * 2 * model.config().n_layers * probe.page_bytes(),
            page_tokens: 16,
        };
        let mut backend = Recording {
            inner: LocalBackend::new(model.clone(), kv, 2, Some(pc)),
            log: Arc::new(Mutex::new(Vec::new())),
        };
        let log = backend.log.clone();

        let prompt_a: Vec<u8> = (0..8u8).collect();
        let prompt_b: Vec<u8> = (0..200u32).map(|i| (i * 7 % 251) as u8).collect();
        let (tx, rx) = channel::<Pending>();
        let (ra_tx, ra_rx) = channel();
        let (rb_tx, rb_rx) = channel();
        let now = Instant::now();
        // Both requests are queued before the loop starts, so A admits from
        // idle and B joins deterministically in the coalescing window.
        tx.send(Pending {
            req: GenRequest { prompt: prompt_a, max_new: 60, ..Default::default() },
            enqueued: now,
            reply: ra_tx,
            events: None,
        })
        .unwrap();
        tx.send(Pending {
            req: GenRequest {
                prompt: prompt_b.clone(),
                max_new: 24,
                ..Default::default()
            },
            enqueued: now,
            reply: rb_tx,
            events: None,
        })
        .unwrap();
        let cfg = BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(1),
            kv,
            shards: 1,
            pool: Some(pc),
            max_queue: 256,
            prefill_chunk: CHUNK,
            ..Default::default()
        };
        let sched = std::thread::spawn(move || {
            scheduler_loop(&mut backend, &cfg, RequestQueue::for_tests(rx));
        });
        let resp_a = ra_rx.recv().unwrap().unwrap();
        let resp_b = rb_rx.recv().unwrap().unwrap();
        drop(tx);
        sched.join().unwrap();

        assert_eq!(resp_a.tokens.len(), 60);
        assert_eq!(resp_b.tokens.len(), 24);
        assert!(resp_b.preemptions >= 1, "B was never preempted; pool sizing drifted");

        // Tokens unchanged: the preempted, replayed, co-batched generation
        // equals a solo unpooled greedy decode.
        let mut st = DecodeState::new(model.as_ref());
        let mut logits = Vec::new();
        for &t in &prompt_b {
            logits = st.step(t);
        }
        let mut expect = Vec::new();
        for _ in 0..24 {
            let next = argmax_token(&logits).unwrap();
            expect.push(next);
            logits = st.step(next);
        }
        assert_eq!(resp_b.tokens, expect, "preempted sequence's tokens changed");

        // Replay is chunked. The replay begins at the first step after the
        // initial one whose jobs restart from position 0 (only a preempted
        // sequence ever resets); by then A has finished, so every later job
        // is B's.
        let log = log.lock().unwrap();
        assert_eq!(log[0].len(), 2, "A and B must start in the same first step");
        let reset = log
            .iter()
            .skip(1)
            .position(|step| step.iter().any(|&(_, pos, _)| pos == 0))
            .map(|i| i + 1)
            .expect("no replay step found after the preemption");
        let post: Vec<(usize, usize, usize)> =
            log[reset..].iter().flatten().copied().collect();
        let n_replay =
            post.iter().position(|&(_, _, len)| len == 1).unwrap_or(post.len());
        let replay = &post[..n_replay];
        let replay_chain: usize = replay.iter().map(|&(_, _, len)| len).sum();
        assert!(
            replay_chain >= 200,
            "B should replay its whole 200-token prompt plus generated tokens, \
             got {replay_chain}"
        );
        assert_eq!(
            replay.len(),
            replay_chain.div_ceil(CHUNK),
            "replay took {} steps for {replay_chain} tokens, want ⌈chain/{CHUNK}⌉: {replay:?}",
            replay.len(),
        );
        for (i, &(_, pos, len)) in replay.iter().enumerate() {
            assert_eq!(pos, i * CHUNK, "replay spans must be contiguous from 0");
            assert_eq!(len, CHUNK.min(replay_chain - pos), "replay span {i} wrong length");
        }
        // …and decode resumes exactly past the rebuilt chain.
        if let Some(&(_, pos, _)) = post.get(n_replay) {
            assert_eq!(pos, replay_chain, "decode did not resume at the chain end");
        }
    }
}
