//! Step-level continuous-batching scheduler — one admission/retire decision
//! per token step.
//!
//! The seed batcher coalesced a batch once, then decoded every member's
//! *entire* generation before looking at the queue again: a request arriving
//! one token after a batch started waited for the whole batch to finish (the
//! admission stall). This scheduler is the vLLM-shaped fix: the unit of
//! scheduling is a single token step of the *running batch*, and between
//! steps sequences join (admission) and leave (retire) mid-flight. A
//! late-arriving short request therefore starts decoding on the very next
//! step and finishes long before an earlier long generation does — the
//! property `GenResponse::queue_wait` makes observable and
//! `tests/sharded_exec.rs` locks in.
//!
//! The scheduler is backend-agnostic via [`StepBackend`]:
//!
//! * [`LocalBackend`] — single-worker execution: every sequence owns a full
//!   per-layer [`LayerKv`] bank; batch steps run on a **persistent step
//!   pool** (spawned lazily at the first multi-job step, joined on drop —
//!   a scoped spawn-per-step would pay thread creation once per decoded
//!   token), with a no-pool inline fast path for the batch-of-1 case. Same
//!   per-layer primitives as [`crate::model::DecodeState`], so tokens are
//!   identical to direct decode.
//! * [`ShardBackend`] — the pipeline topology: steps are fed to the
//!   [`ShardedDecoder`]'s shard threads, which is exactly what makes the
//!   step-level design matter — per-step scheduling keeps microbatches
//!   flowing so all shards stay busy, where whole-batch scheduling would
//!   drain the pipe between generations.

use super::batcher::{argmax_token, BatcherConfig, GenResponse, Pending};
use crate::model::{decode_head, decode_layer_step, KvSpec, LayerKv, ModelExec};
use crate::shard::ShardedDecoder;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// The execution surface the scheduler drives: admit a sequence slot, step
/// a batch of `(slot, pos, token)` jobs, retire a slot. Implementations own
/// all per-sequence decode state; the scheduler owns all policy.
pub(crate) trait StepBackend {
    fn admit(&mut self) -> Result<usize, String>;
    fn retire(&mut self, slot: usize);
    /// One token step per job; returns each job's next-position logits in
    /// job order. An `Err` entry retires that sequence with the error.
    fn step(&mut self, jobs: &[(usize, usize, u8)]) -> Vec<Result<Vec<f32>, String>>;
}

/// One full-depth decode step — the exact [`crate::model::DecodeState`]
/// op sequence, shared by the inline fast path and the pool workers.
fn run_job<M: ModelExec>(m: &M, pos: usize, token: u8, bank: &mut [LayerKv]) -> Vec<f32> {
    let mut h = m.embed_row(token).to_vec();
    for (l, kv) in m.layers().iter().zip(bank.iter_mut()) {
        decode_layer_step(l, m.config(), pos, &mut h, kv);
    }
    decode_head(m, h)
}

/// One batched-step job in flight to the persistent pool: the sequence's KV
/// bank travels with the job and comes back with the logits, so workers
/// need no shared mutable state. `gen` identifies the `step` call that sent
/// the job — a result surfacing after its step gave up (recv timeout) must
/// be discarded, never matched by raw index against a *later* step's jobs.
struct PoolJob {
    gen: u64,
    idx: usize,
    pos: usize,
    token: u8,
    bank: Vec<LayerKv>,
}

/// The persistent decode pool: workers pull [`PoolJob`]s off a shared
/// receiver and reply on `done_rx`. Dropping it closes the job channel and
/// joins every worker.
struct StepPool {
    job_tx: Option<Sender<PoolJob>>,
    done_rx: Receiver<(u64, usize, Vec<LayerKv>, Vec<f32>)>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Monotonic `step` counter; see [`PoolJob::gen`].
    gen: u64,
}

impl StepPool {
    fn spawn<M: ModelExec + Send + Sync + 'static>(model: &Arc<M>, width: usize) -> StepPool {
        let (job_tx, job_rx) = channel::<PoolJob>();
        let (done_tx, done_rx) = channel::<(u64, usize, Vec<LayerKv>, Vec<f32>)>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let mut workers = Vec::with_capacity(width);
        for i in 0..width {
            let m = model.clone();
            let rx = job_rx.clone();
            let tx = done_tx.clone();
            let worker = std::thread::Builder::new()
                .name(format!("tsgo-step-{i}"))
                .spawn(move || loop {
                    // Classic shared-receiver pool: the idle worker holds
                    // the lock while blocked in recv; peers queue on the
                    // mutex. Pickup is serialized, compute is parallel.
                    let job = match rx.lock().unwrap().recv() {
                        Ok(j) => j,
                        Err(_) => break, // backend dropped: pool drains
                    };
                    let mut bank = job.bank;
                    let logits = run_job(m.as_ref(), job.pos, job.token, &mut bank);
                    if tx.send((job.gen, job.idx, bank, logits)).is_err() {
                        break;
                    }
                })
                .expect("spawn step-pool worker thread");
            workers.push(worker);
        }
        StepPool { job_tx: Some(job_tx), done_rx, workers, gen: 0 }
    }
}

impl Drop for StepPool {
    fn drop(&mut self) {
        drop(self.job_tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Single-worker backend: per-sequence full-depth KV banks, batch steps
/// distributed over a persistent decode pool. The pool spawns lazily on
/// the first multi-job step (a server that only ever sees one request at a
/// time decodes inline and never pays for idle workers) and lives until
/// the backend drops — the scheduler calls `step` once per decoded token,
/// so a scoped spawn-per-call would pay thread creation per token.
pub(crate) struct LocalBackend<M: ModelExec> {
    model: Arc<M>,
    kv: KvSpec,
    /// Pool width when it spawns: `min(threads, max_batch)` — never more
    /// workers than concurrently decoding sequences or the thread budget.
    pool_width: usize,
    pool: Option<StepPool>,
    slots: Vec<Option<Vec<LayerKv>>>,
    free: Vec<usize>,
}

impl<M: ModelExec> LocalBackend<M> {
    pub(crate) fn new(model: Arc<M>, kv: KvSpec, max_batch: usize) -> LocalBackend<M> {
        let pool_width = crate::util::threadpool::num_threads().min(max_batch.max(1));
        LocalBackend {
            model,
            kv,
            pool_width,
            pool: None,
            slots: Vec::new(),
            free: Vec::new(),
        }
    }
}

impl<M: ModelExec + Send + Sync + 'static> StepBackend for LocalBackend<M> {
    fn admit(&mut self) -> Result<usize, String> {
        let cfg = self.model.config();
        let bank: Vec<LayerKv> =
            (0..cfg.n_layers).map(|_| LayerKv::new(self.kv, cfg)).collect();
        match self.free.pop() {
            Some(s) => {
                self.slots[s] = Some(bank);
                Ok(s)
            }
            None => {
                self.slots.push(Some(bank));
                Ok(self.slots.len() - 1)
            }
        }
    }

    fn retire(&mut self, slot: usize) {
        self.slots[slot] = None;
        self.free.push(slot);
    }

    fn step(&mut self, jobs: &[(usize, usize, u8)]) -> Vec<Result<Vec<f32>, String>> {
        if jobs.is_empty() {
            return Vec::new();
        }
        if let [(slot, pos, token)] = *jobs {
            // Batch of one: decode inline, skipping the pool's channel hops.
            let mut bank = self.slots[slot].take().expect("step on unadmitted slot");
            let logits = run_job(self.model.as_ref(), pos, token, &mut bank);
            self.slots[slot] = Some(bank);
            return vec![Ok(logits)];
        }
        let unavailable = || "step pool unavailable (a decode worker exited)".to_string();
        let mut out: Vec<Result<Vec<f32>, String>> =
            jobs.iter().map(|_| Err(unavailable())).collect();
        let pool = self
            .pool
            .get_or_insert_with(|| StepPool::spawn(&self.model, self.pool_width));
        pool.gen += 1;
        let gen = pool.gen;
        let tx = pool.job_tx.as_ref().expect("step pool open until drop");
        let mut sent = 0usize;
        for (idx, &(slot, pos, token)) in jobs.iter().enumerate() {
            let bank = self.slots[slot].take().expect("step on unadmitted slot");
            if tx.send(PoolJob { gen, idx, pos, token, bank }).is_err() {
                break; // a worker panicked; remaining entries stay Err
            }
            sent += 1;
        }
        let mut got = 0usize;
        while got < sent {
            // recv_timeout, not recv: if a worker dies mid-job its reply
            // never comes while idle peers keep the channel open — a plain
            // recv would wedge the scheduler. The bound only fires on a
            // genuinely dead pool (a healthy batch step is milliseconds).
            match pool.done_rx.recv_timeout(Duration::from_secs(60)) {
                // A stale generation is a job whose step already gave up:
                // its sequence was errored/retired back then, so both the
                // bank and the logits are dead — drop them rather than
                // matching the raw index into *this* step's jobs.
                Ok((g, _, _, _)) if g != gen => continue,
                Ok((_, idx, bank, logits)) => {
                    self.slots[jobs[idx].0] = Some(bank);
                    out[idx] = Ok(logits);
                    got += 1;
                }
                Err(_) => break,
            }
        }
        out
    }
}

/// Pipeline backend: delegates to the shard threads.
pub(crate) struct ShardBackend {
    dec: ShardedDecoder,
}

impl ShardBackend {
    pub(crate) fn new(dec: ShardedDecoder) -> ShardBackend {
        ShardBackend { dec }
    }
}

impl StepBackend for ShardBackend {
    fn admit(&mut self) -> Result<usize, String> {
        self.dec.admit()
    }

    fn retire(&mut self, slot: usize) {
        self.dec.retire(slot)
    }

    fn step(&mut self, jobs: &[(usize, usize, u8)]) -> Vec<Result<Vec<f32>, String>> {
        self.dec.step(jobs)
    }
}

/// One in-flight sequence: its slot, progress, and reply line.
struct Running {
    slot: usize,
    prompt: Vec<u8>,
    /// Prompt tokens fed so far (prefill advances one per step, in lock
    /// step with the rest of the batch).
    fed: usize,
    /// Tokens fed in total = this sequence's next position.
    pos: usize,
    /// The generated token to feed next (valid once `out` is non-empty).
    pending: u8,
    out: Vec<u8>,
    max_new: usize,
    enqueued: Instant,
    /// When this sequence joined its first token step. Set by the
    /// scheduler right before stepping (not at admission) so the idle
    /// coalescing window counts as queue time, not decode time.
    started: Option<Instant>,
    /// Largest co-running batch this sequence ever shared a step with.
    max_cobatch: usize,
    reply: Sender<Result<GenResponse, String>>,
}

enum Advance {
    Continue,
    Done(Result<(), String>),
}

/// The scheduler loop: runs on the `DynamicBatcher` worker thread until the
/// request queue closes (batcher dropped). Exits only with every in-flight
/// sequence answered — finished normally, or drained with an error on
/// shutdown — so `DynamicBatcher::drop` can join unconditionally.
pub(crate) fn scheduler_loop(
    backend: &mut dyn StepBackend,
    cfg: &BatcherConfig,
    rx: Receiver<Pending>,
) {
    let mut active: Vec<Running> = Vec::new();
    loop {
        // -- admission: one decision point per token step -----------------
        if active.is_empty() {
            // Idle: block for the next request; a closed, drained queue
            // means the batcher was dropped — done.
            match rx.recv() {
                Ok(p) => admit_request(backend, &mut active, p),
                Err(_) => return,
            }
            // Initial coalescing window (the legacy `max_wait` knob): soak
            // up stragglers so a burst starts as one batch. Only applies
            // from idle — once decoding, admission never waits — and only
            // when the first request actually started a sequence.
            let deadline = Instant::now() + cfg.max_wait;
            while !active.is_empty() && active.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(p) => admit_request(backend, &mut active, p),
                    Err(_) => break,
                }
            }
        } else {
            // Decoding: admit whatever is queued right now, without
            // waiting — this is the continuous-batching fix. A sequence
            // admitted here joins the very next token step.
            loop {
                if active.len() >= cfg.max_batch {
                    break;
                }
                match rx.try_recv() {
                    Ok(p) => admit_request(backend, &mut active, p),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        // Batcher dropped mid-flight: drain every reply
                        // with an error rather than leaving callers hung.
                        drain(backend, active, "batcher shut down");
                        return;
                    }
                }
            }
        }

        // Admission can answer requests without starting a sequence (empty
        // prompt, max_new == 0, backend refusal); with nothing running, go
        // straight back to blocking on the queue instead of issuing an
        // empty step.
        if active.is_empty() {
            continue;
        }

        // -- one token step for the whole running batch --------------------
        let bs = active.len();
        let step_start = Instant::now();
        for r in active.iter_mut() {
            r.started.get_or_insert(step_start);
        }
        let jobs: Vec<(usize, usize, u8)> = active
            .iter()
            .map(|r| {
                let tok =
                    if r.fed < r.prompt.len() { r.prompt[r.fed] } else { r.pending };
                (r.slot, r.pos, tok)
            })
            .collect();
        let results = backend.step(&jobs);

        // -- retire decisions ----------------------------------------------
        let mut still = Vec::with_capacity(bs);
        for (mut r, res) in active.into_iter().zip(results) {
            r.max_cobatch = r.max_cobatch.max(bs);
            match advance(&mut r, res) {
                Advance::Continue => still.push(r),
                Advance::Done(result) => {
                    backend.retire(r.slot);
                    finish(r, result);
                }
            }
        }
        active = still;
    }
}

/// Consume one step result for one sequence; decides continue vs retire.
fn advance(r: &mut Running, res: Result<Vec<f32>, String>) -> Advance {
    let logits = match res {
        Ok(l) => l,
        Err(e) => return Advance::Done(Err(e)),
    };
    r.pos += 1;
    if r.fed < r.prompt.len() {
        r.fed += 1;
        if r.fed < r.prompt.len() {
            return Advance::Continue; // mid-prefill: logits unused
        }
        // fall through: the last prompt token's logits pick generated
        // token #1 — identical to the unbatched greedy-decode semantics.
    }
    match argmax_token(&logits) {
        Ok(next) => {
            r.out.push(next);
            if r.out.len() >= r.max_new {
                Advance::Done(Ok(()))
            } else {
                r.pending = next;
                Advance::Continue
            }
        }
        Err(e) => Advance::Done(Err(e)),
    }
}

fn admit_request(backend: &mut dyn StepBackend, active: &mut Vec<Running>, p: Pending) {
    let admitted = Instant::now();
    let queue_wait = admitted.saturating_duration_since(p.enqueued);
    if p.req.prompt.is_empty() {
        // Matches the historical error path (argmax over no decoded step).
        let _ = p
            .reply
            .send(Err("empty logits (no prompt token was decoded)".into()));
        return;
    }
    if p.req.max_new == 0 {
        let _ = p.reply.send(Ok(GenResponse {
            tokens: Vec::new(),
            queue_wait,
            decode_time: Duration::ZERO,
            batch_size: 1,
        }));
        return;
    }
    match backend.admit() {
        Ok(slot) => active.push(Running {
            slot,
            prompt: p.req.prompt,
            fed: 0,
            pos: 0,
            pending: 0,
            out: Vec::new(),
            max_new: p.req.max_new,
            enqueued: p.enqueued,
            started: None,
            max_cobatch: 1,
            reply: p.reply,
        }),
        Err(e) => {
            let _ = p.reply.send(Err(e));
        }
    }
}

fn finish(r: Running, result: Result<(), String>) {
    // A sequence only finishes after at least one step, so `started` is
    // always stamped by then; the fallback is pure defensiveness.
    let started = r.started.unwrap_or_else(Instant::now);
    let resp = result.map(|()| GenResponse {
        tokens: r.out,
        queue_wait: started.saturating_duration_since(r.enqueued),
        decode_time: started.elapsed(),
        batch_size: r.max_cobatch,
    });
    let _ = r.reply.send(resp);
}

fn drain(backend: &mut dyn StepBackend, active: Vec<Running>, msg: &str) {
    for r in active {
        backend.retire(r.slot);
        let _ = r.reply.send(Err(format!(
            "{msg} while this request was in flight ({} of {} tokens generated)",
            r.out.len(),
            r.max_new
        )));
    }
}
