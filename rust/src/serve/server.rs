//! TCP line-JSON serving front-end.
//!
//! Protocol: one JSON object per line; the full field-by-field reference
//! (request knobs, response metrics, streaming event framing) lives in
//! `docs/SERVE_API.md`. In brief:
//!
//! Request  : `{"prompt": [byte ids], "max_new": N, "temperature": f,
//!             "top_k": n, "top_p": f, "repetition_penalty": f, "seed": n,
//!             "stop": ["str" | [ids]], "stream": b}` — everything after
//!             `prompt` optional; `max_tokens` is accepted as an alias for
//!             `max_new`.
//! Response : `{"tokens": [...], "finish_reason": "length|stop|timeout|error",
//!             "latency_ms": f, "queue_wait_ms": f, "prefill_ms": f,
//!             "ttft_ms": f, "decode_ms": f, "batch_size": n,
//!             "kv_pages_used": n, "preemptions": n, "timed_out": b,
//!             "worker_restarts": n, "pipeline_rebuilds": n}`
//! Event    : `{"token": t, "index": i}` — only with `"stream": true`: one
//!             line per sampled token, terminated by the final response
//!             line (whose `tokens` is always the full output, so the
//!             concatenated events equal it).
//! Error    : `{"error": "...", "finish_reason": "error"}`
//! Stats    : `{"stats": true}` → one line with the process-wide telemetry
//!             snapshot ([`crate::obs::snapshot_json`]): counters, gauges,
//!             latency histograms (p50/p95/p99) and the recent step trace.
//!             A control line, not a generation — any other fields on it
//!             are ignored. `tsgo stats HOST:PORT` pretty-prints it.
//!
//! `timed_out` is true when the request hit the server's `--request-timeout`
//! and returned the tokens generated so far (kept redundantly with
//! `finish_reason` for pre-`finish_reason` clients); `worker_restarts` /
//! `pipeline_rebuilds` are process-lifetime recovery counters (see
//! [`crate::serve::sched`]) so a client can observe that a fault occurred
//! and was absorbed.
//!
//! `latency_ms` is always `queue_wait_ms + prefill_ms + decode_ms`, and
//! `ttft_ms` (time to first token) is `queue_wait_ms + prefill_ms`; the
//! split makes both the continuous-batching behaviour (a request admitted
//! mid-flight shows a near-zero queue wait even when other generations were
//! already running) and the chunked-prefill speedup (`--prefill-chunk`
//! shrinks `prefill_ms`, nothing else) observable per request.
//!
//! Sampling defaults come from [`BatcherConfig::default_sampling`] (the
//! `--temperature` family of serve flags); per-request fields override
//! individual knobs. A streaming client that disconnects mid-generation
//! cancels its request: the scheduler retires the slot and frees its KV
//! pages at the next sampled token.

use super::batcher::{BatcherConfig, DynamicBatcher, GenRequest, GenResponse};
use super::sampler::SamplingParams;
use crate::model::ModelExec;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// Server tunables.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub batcher: BatcherConfig,
    /// Stop after serving this many connections (None = forever). Used by
    /// tests and the example driver.
    pub max_connections: Option<usize>,
    /// Per-connection socket read/write timeout. A half-open client (TCP
    /// established, then silence) would otherwise pin its connection
    /// thread in a blocking read forever; with this set, the read times
    /// out and the thread exits. Generous by default — it must comfortably
    /// exceed generation latency only for *writes*; reads between requests
    /// are idle time, so this doubles as an idle-connection reaper.
    pub conn_timeout: Option<Duration>,
    /// Server-wide default stop sequences (`tsgo serve --stop`), applied
    /// when a request carries no `stop` field of its own.
    pub default_stop: Vec<Vec<u8>>,
    /// Prometheus scrape endpoint (`tsgo serve --metrics-addr HOST:PORT`):
    /// when set, a dedicated listener thread answers `GET /metrics` with
    /// the text exposition of the process-wide registry
    /// ([`crate::obs::serve_metrics`]). `None` = no metrics listener; the
    /// `{"stats": true}` control line works either way. With port 0 the
    /// kernel picks the port — the banner prints the bound address; callers
    /// that need it programmatically use [`crate::obs::serve_metrics`]
    /// directly.
    pub metrics_addr: Option<String>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7433".into(),
            batcher: BatcherConfig::default(),
            max_connections: None,
            conn_timeout: Some(Duration::from_secs(120)),
            default_stop: Vec::new(),
            metrics_addr: None,
        }
    }
}

/// Per-connection request defaults, copied out of [`ServerConfig`] when the
/// connection thread spawns.
#[derive(Clone)]
struct ReqDefaults {
    sampling: SamplingParams,
    stop: Vec<Vec<u8>>,
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![
        ("error", Json::str(msg)),
        ("finish_reason", Json::str("error")),
    ])
    .to_string()
}

/// Parse one request line into a [`GenRequest`] plus its `stream` flag.
/// Absent sampling fields fall back to the server-wide defaults; present
/// ones override knob-by-knob.
fn parse_request(line: &str, defaults: &ReqDefaults) -> Result<(GenRequest, bool), String> {
    let req = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    let Some(arr) = req.get("prompt").as_arr() else {
        return Err("prompt must be an array of token ids".into());
    };
    // Token ids are byte values; anything else is a client error, not
    // something to silently truncate.
    let mut prompt: Vec<u8> = Vec::with_capacity(arr.len());
    for (i, tok) in arr.iter().enumerate() {
        match tok.as_f64() {
            Some(v) if v.fract() == 0.0 && (0.0..=255.0).contains(&v) => prompt.push(v as u8),
            _ => {
                return Err(format!(
                    "prompt[{i}] = {tok} is out of range (token ids are integers 0-255)"
                ))
            }
        }
    }
    if prompt.is_empty() {
        return Err("empty prompt".into());
    }
    let max_new = req
        .get("max_new")
        .as_usize()
        .or_else(|| req.get("max_tokens").as_usize())
        .unwrap_or(16)
        .min(512);

    let mut params = defaults.sampling;
    if let Some(t) = req.get("temperature").as_f64() {
        params.temperature = t as f32;
    }
    if let Some(k) = req.get("top_k").as_usize() {
        params.top_k = k;
    }
    if let Some(p) = req.get("top_p").as_f64() {
        params.top_p = p as f32;
    }
    if let Some(rp) = req.get("repetition_penalty").as_f64() {
        params.repetition_penalty = rp as f32;
    }
    if let Some(s) = req.get("seed").as_f64() {
        if s.fract() != 0.0 || s < 0.0 {
            return Err(format!("seed must be a non-negative integer, got {s}"));
        }
        params.seed = s as u64;
    }
    params.validate()?;

    let stop = match req.get("stop") {
        Json::Null => defaults.stop.clone(),
        Json::Str(s) => vec![s.clone().into_bytes()],
        Json::Arr(entries) => {
            let mut seqs = Vec::with_capacity(entries.len());
            for (i, e) in entries.iter().enumerate() {
                seqs.push(parse_stop_seq(e).map_err(|why| format!("stop[{i}] {why}"))?);
            }
            seqs
        }
        other => {
            return Err(format!(
                "stop must be a string or an array of strings / token-id arrays, got {other}"
            ))
        }
    };
    let stream = req.get("stream").as_bool().unwrap_or(false);
    Ok((GenRequest { prompt, max_new, params, stop }, stream))
}

/// One `stop` entry: a UTF-8 string (matched on its bytes) or an array of
/// token ids 0-255.
fn parse_stop_seq(e: &Json) -> Result<Vec<u8>, String> {
    match e {
        Json::Str(s) => Ok(s.clone().into_bytes()),
        Json::Arr(ids) => {
            let mut seq = Vec::with_capacity(ids.len());
            for id in ids {
                match id.as_f64() {
                    Some(v) if v.fract() == 0.0 && (0.0..=255.0).contains(&v) => {
                        seq.push(v as u8)
                    }
                    _ => {
                        return Err(format!(
                            "has token id {id} out of range (integers 0-255)"
                        ))
                    }
                }
            }
            Ok(seq)
        }
        other => Err(format!(
            "must be a string or an array of token ids, got {other}"
        )),
    }
}

fn response_json(resp: &GenResponse) -> String {
    Json::obj(vec![
        (
            "tokens",
            Json::arr(resp.tokens.iter().map(|&t| Json::num(t as f64))),
        ),
        ("finish_reason", Json::str(resp.finish_reason.label())),
        ("latency_ms", Json::num(resp.latency().as_secs_f64() * 1e3)),
        ("queue_wait_ms", Json::num(resp.queue_wait.as_secs_f64() * 1e3)),
        ("prefill_ms", Json::num(resp.prefill_time.as_secs_f64() * 1e3)),
        ("ttft_ms", Json::num(resp.ttft().as_secs_f64() * 1e3)),
        ("decode_ms", Json::num(resp.decode_time.as_secs_f64() * 1e3)),
        ("batch_size", Json::num(resp.batch_size as f64)),
        ("kv_pages_used", Json::num(resp.kv_pages_used as f64)),
        ("preemptions", Json::num(resp.preemptions as f64)),
        ("timed_out", Json::Bool(resp.timed_out)),
        ("worker_restarts", Json::num(resp.worker_restarts as f64)),
        ("pipeline_rebuilds", Json::num(resp.pipeline_rebuilds as f64)),
    ])
    .to_string()
}


/// Serve one `"stream": true` request: one `{"token", "index"}` event line
/// per sampled token, then the final response line. Returns `false` when the
/// socket died — the caller should drop the connection; dropping the
/// [`super::batcher::StreamHandle`] here is what cancels the generation
/// server-side (slot retired, KV pages freed at the next sampled token).
fn handle_stream(
    batcher: &DynamicBatcher,
    writer: &mut impl Write,
    req: GenRequest,
) -> bool {
    let handle = match batcher.generate_stream(req) {
        Ok(h) => h,
        Err(e) => {
            crate::obs::registry().requests_error.inc();
            let line = err_json(&e.to_string());
            return writeln!(writer, "{line}").is_ok();
        }
    };
    let mut index = 0usize;
    while let Ok(token) = handle.events.recv() {
        let event = Json::obj(vec![
            ("token", Json::num(token as f64)),
            ("index", Json::num(index as f64)),
        ]);
        index += 1;
        if writeln!(writer, "{event}").is_err() || writer.flush().is_err() {
            // Client gone: dropping `handle` closes the events receiver and
            // the scheduler cancels the generation at its next token.
            return false;
        }
    }
    // Events channel closed: the scheduler is done with this request and
    // the final reply is (or is about to be) in flight.
    let line = match handle.wait() {
        Ok(resp) => {
            crate::obs::registry().requests_ok.inc();
            response_json(&resp)
        }
        Err(e) => {
            crate::obs::registry().requests_error.inc();
            err_json(&e.to_string())
        }
    };
    writeln!(writer, "{line}").is_ok()
}

fn handle_conn(
    batcher: Arc<DynamicBatcher>,
    defaults: ReqDefaults,
    stream: TcpStream,
    timeout: Option<Duration>,
) {
    let peer = stream.peer_addr().ok();
    // A half-open or silent client must not pin this thread: a timed-out
    // blocking read surfaces as an Err line below and the thread exits.
    // Failure to set the timeouts degrades to the old (pin-prone)
    // behaviour rather than refusing the connection.
    if let Some(t) = timeout {
        let _ = stream.set_read_timeout(Some(t));
        let _ = stream.set_write_timeout(Some(t));
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reg = crate::obs::registry();
    reg.connections_total.inc();
    reg.active_connections.add(1);
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        // `{"stats": true}` is a control line, not a generation: answer
        // with one telemetry-snapshot line and move on. Checked before
        // request parsing so it needs no `prompt`.
        if let Ok(obj) = Json::parse(&line) {
            if obj.get("stats").as_bool() == Some(true) {
                if writeln!(writer, "{}", crate::obs::snapshot_json()).is_err() {
                    break;
                }
                continue;
            }
        }
        // A streaming request takes over the connection until its final
        // response line; everything else stays strict request/response.
        match parse_request(&line, &defaults) {
            Ok((req, true)) => {
                if !handle_stream(&batcher, &mut writer, req) {
                    break;
                }
            }
            Ok((req, false)) => {
                let resp = match batcher.generate(req) {
                    Ok(r) => {
                        reg.requests_ok.inc();
                        response_json(&r)
                    }
                    Err(e) => {
                        reg.requests_error.inc();
                        err_json(&e.to_string())
                    }
                };
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            Err(e) => {
                reg.requests_error.inc();
                if writeln!(writer, "{}", err_json(&e)).is_err() {
                    break;
                }
            }
        }
    }
    reg.active_connections.sub(1);
    let _ = peer; // quiet unused in non-logging builds
}

/// Run the server (blocking). Returns the bound address (useful with
/// `addr: "127.0.0.1:0"`). Connections are handled on their own threads;
/// generation is funneled through the shared [`DynamicBatcher`]. Generic
/// over the execution representation: dense [`crate::model::ModelWeights`]
/// or the packed [`crate::model::ExecModel`] (`tsgo serve --packed`).
pub fn serve<M: ModelExec + Send + Sync + 'static>(
    model: Arc<M>,
    cfg: ServerConfig,
) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("bind {}", cfg.addr))?;
    // Metrics listener binds before the batcher spawns so a bad
    // --metrics-addr fails the whole serve at the door, not after the
    // worker threads are up.
    let metrics = match &cfg.metrics_addr {
        Some(a) => Some(
            crate::obs::serve_metrics(a)
                .with_context(|| format!("bind metrics listener {a}"))?,
        ),
        None => None,
    };
    let batcher = Arc::new(DynamicBatcher::spawn(model, cfg.batcher));
    let defaults = ReqDefaults {
        sampling: cfg.batcher.default_sampling,
        stop: cfg.default_stop.clone(),
    };
    println!("tsgo serving on {}", listener.local_addr()?);
    match metrics {
        Some(addr) => println!("  metrics: http://{addr}/metrics"),
        None => println!("  metrics: off"),
    }
    let mut served = 0usize;
    for stream in listener.incoming() {
        let stream = stream?;
        let b = batcher.clone();
        let d = defaults.clone();
        let t = cfg.conn_timeout;
        std::thread::spawn(move || handle_conn(b, d, stream, t));
        served += 1;
        if let Some(max) = cfg.max_connections {
            if served >= max {
                break;
            }
        }
    }
    Ok(())
}

/// Bind a listener first (so callers know the port), then serve on a thread.
pub fn serve_in_background<M: ModelExec + Send + Sync + 'static>(
    model: Arc<M>,
    cfg: ServerConfig,
) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    if let Some(a) = &cfg.metrics_addr {
        crate::obs::serve_metrics(a)
            .with_context(|| format!("bind metrics listener {a}"))?;
    }
    let batcher = Arc::new(DynamicBatcher::spawn(model, cfg.batcher));
    let defaults = ReqDefaults {
        sampling: cfg.batcher.default_sampling,
        stop: cfg.default_stop.clone(),
    };
    let max = cfg.max_connections;
    let conn_timeout = cfg.conn_timeout;
    let handle = std::thread::spawn(move || {
        let mut served = 0usize;
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            let b = batcher.clone();
            let d = defaults.clone();
            std::thread::spawn(move || handle_conn(b, d, stream, conn_timeout));
            served += 1;
            if let Some(m) = max {
                if served >= m {
                    break;
                }
            }
        }
    });
    Ok((addr, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelWeights, Preset};
    use crate::serve::client::request_generation;
    use crate::util::rng::Rng;

    #[test]
    fn server_roundtrip() {
        let mut rng = Rng::new(1);
        let w = Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng));
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_connections: Some(1),
            ..Default::default()
        };
        let (addr, handle) = serve_in_background(w, cfg).unwrap();
        let resp = request_generation(&addr.to_string(), &[10, 20, 30], 4).unwrap();
        assert_eq!(resp.tokens.len(), 4);
        assert!(resp.latency_ms > 0.0);
        handle.join().unwrap();
    }

    #[test]
    fn bad_requests_get_errors() {
        let mut rng = Rng::new(2);
        let w = Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng));
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_connections: Some(1),
            ..Default::default()
        };
        let (addr, handle) = serve_in_background(w, cfg).unwrap();
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        use std::io::{BufRead, BufReader, Write};
        stream.write_all(b"{not json}\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"));
        // empty prompt
        stream.write_all(b"{\"prompt\": [], \"max_new\": 2}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("empty prompt"));
        drop(stream);
        handle.join().unwrap();
    }

    #[test]
    fn silent_client_is_disconnected() {
        // A client that connects and then says nothing must not pin its
        // connection thread forever: the read timeout fires and the server
        // closes the socket (observed as EOF on our side).
        let mut rng = Rng::new(7);
        let w = Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng));
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_connections: Some(1),
            conn_timeout: Some(Duration::from_millis(200)),
            ..Default::default()
        };
        let (addr, handle) = serve_in_background(w, cfg).unwrap();
        let stream = std::net::TcpStream::connect(addr).unwrap();
        // Generous guard so a hang fails the test instead of wedging it.
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        use std::io::{BufRead, BufReader};
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        let start = std::time::Instant::now();
        let n = reader.read_line(&mut line).unwrap();
        assert_eq!(n, 0, "expected EOF from server, got: {line}");
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "server took too long to drop the silent connection"
        );
        handle.join().unwrap();
    }

    #[test]
    fn out_of_range_tokens_get_errors() {
        // Regression: ids > 255 used to be silently truncated (`t & 0xff`),
        // mangling the prompt; they must be rejected with a JSON error.
        let mut rng = Rng::new(3);
        let w = Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng));
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_connections: Some(1),
            ..Default::default()
        };
        let (addr, handle) = serve_in_background(w, cfg).unwrap();
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        use std::io::{BufRead, BufReader, Write};
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        for bad in [
            "{\"prompt\": [10, 300, 20], \"max_new\": 2}\n",
            "{\"prompt\": [1.5], \"max_new\": 2}\n",
            "{\"prompt\": [-1], \"max_new\": 2}\n",
            "{\"prompt\": \"abc\", \"max_new\": 2}\n",
        ] {
            stream.write_all(bad.as_bytes()).unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("error"), "{bad} → {line}");
            assert!(
                line.contains("out of range") || line.contains("array of token ids"),
                "{bad} → {line}"
            );
        }
        // a valid request on the same connection still works
        stream.write_all(b"{\"prompt\": [10, 255, 0], \"max_new\": 3}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("tokens"), "{line}");
        drop(stream);
        handle.join().unwrap();
    }
}
