//! Composable per-request sampling: logit transforms + a final selector.
//!
//! Decoding was hard-wired to greedy [`argmax_token`] until PR 9; this module
//! generalizes it without giving up reproducibility. A [`SamplerChain`] is a
//! list of [`Sampler`] transforms that mutate the step's last-row logits
//! in-place (repetition penalty, temperature, top-k, top-p — applied in that
//! order) followed by a [`Selector`] that picks the token: greedy argmax, or
//! seeded multinomial over the surviving probability mass.
//!
//! Invariants this module is built around:
//!
//! * **Greedy default is bit-identical to the pre-sampler path.** Default
//!   [`SamplingParams`] build an empty transform list and the greedy
//!   selector, which calls [`argmax_token`] on the untouched logits — same
//!   token, same error strings, same first-maximum tie-break.
//! * **Deterministic replay.** Each request owns its chain; the multinomial
//!   selector draws from a [`Rng`] seeded with the
//!   request's `seed` and consumes exactly one draw per *emitted* token.
//!   Since the kernel/shard/chunking planes already guarantee bit-identical
//!   logits, same seed + same prompt ⇒ same tokens — across runs, prefill
//!   chunk sizes, shard counts, and kernel tables. Preemption replay re-feeds
//!   recorded tokens without consulting logits, so the RNG stream is not
//!   perturbed by a restart.
//! * **Masked tokens are unreachable.** Top-k/top-p mask candidates to
//!   `-inf`; the selection converts those to zero weight and
//!   `Rng::weighted` never lands on a zero-weight index.
//!
//! Stop handling lives here too: a [`StopSet`] holds byte sequences (UTF-8
//! strings or raw token ids from the wire) and is checked against the decoded
//! tail after every emitted token.

use super::batcher::argmax_token;
use crate::util::rng::Rng;

/// Per-request knobs for the sampling chain. `Copy` so it can ride inside
/// `BatcherConfig` and request structs without ceremony.
///
/// The defaults mean "greedy, no transforms": `temperature == 0.0` selects
/// greedy argmax, `top_k == 0` and `top_p == 1.0` disable truncation, and
/// `repetition_penalty == 1.0` disables the penalty.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature. `0.0` (the default) means greedy decoding;
    /// values `> 0.0` enable seeded multinomial sampling.
    pub temperature: f32,
    /// Keep only the `k` highest logits before sampling. `0` disables.
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest probability-sorted prefix whose
    /// cumulative mass reaches `top_p`. `1.0` disables.
    pub top_p: f32,
    /// Divide (positive) / multiply (non-positive) logits of tokens already
    /// seen in the prompt or the output. `1.0` disables.
    pub repetition_penalty: f32,
    /// Seed for the per-request RNG stream. Same seed + same logits ⇒ same
    /// tokens. Only consulted when `temperature > 0.0`.
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            seed: 0,
        }
    }
}

impl SamplingParams {
    /// True when the selector will be greedy argmax (temperature `0.0`).
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    /// Reject values that would make sampling meaningless or non-reproducible
    /// before the request is admitted, so the error reaches the client instead
    /// of a scheduler slot.
    pub fn validate(&self) -> Result<(), String> {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            return Err(format!(
                "temperature must be finite and >= 0.0, got {}",
                self.temperature
            ));
        }
        if !self.top_p.is_finite() || self.top_p <= 0.0 || self.top_p > 1.0 {
            return Err(format!("top_p must be in (0.0, 1.0], got {}", self.top_p));
        }
        if !self.repetition_penalty.is_finite() || self.repetition_penalty <= 0.0 {
            return Err(format!(
                "repetition_penalty must be finite and > 0.0, got {}",
                self.repetition_penalty
            ));
        }
        Ok(())
    }
}

/// One in-place logit transform in a [`SamplerChain`].
///
/// `apply` sees the full step context — the mutable logit row plus the
/// request's prompt and everything emitted so far — so history-aware
/// transforms (repetition penalty) and pure row transforms (temperature,
/// truncation) share one interface.
pub trait Sampler: Send {
    /// Mutate `logits` in place. `prompt`/`out` are the request's prompt and
    /// the tokens emitted so far.
    fn apply(&mut self, logits: &mut [f32], prompt: &[u8], out: &[u8]);
}

/// Divides positive logits by `penalty` (and multiplies non-positive ones)
/// for every token id present in the prompt or the output so far.
struct RepetitionPenalty {
    penalty: f32,
}

impl Sampler for RepetitionPenalty {
    fn apply(&mut self, logits: &mut [f32], prompt: &[u8], out: &[u8]) {
        let mut seen = [false; 256];
        for &t in prompt.iter().chain(out) {
            seen[t as usize] = true;
        }
        for (i, l) in logits.iter_mut().enumerate() {
            if i < 256 && seen[i] {
                if *l > 0.0 {
                    *l /= self.penalty;
                } else {
                    *l *= self.penalty;
                }
            }
        }
    }
}

/// Scales logits by `1 / temperature`. Only constructed for `t > 0`.
struct Temperature {
    t: f32,
}

impl Sampler for Temperature {
    fn apply(&mut self, logits: &mut [f32], _prompt: &[u8], _out: &[u8]) {
        for l in logits.iter_mut() {
            *l /= self.t;
        }
    }
}

/// Masks everything below the `k`-th largest logit to `-inf`. Ties with the
/// threshold value are all kept, which can retain slightly more than `k`
/// candidates but is deterministic and order-independent.
struct TopK {
    k: usize,
}

impl Sampler for TopK {
    fn apply(&mut self, logits: &mut [f32], _prompt: &[u8], _out: &[u8]) {
        if self.k == 0 || self.k >= logits.len() {
            return;
        }
        let mut sorted: Vec<f32> = logits.to_vec();
        sorted.sort_unstable_by(|a, b| b.total_cmp(a));
        let threshold = sorted[self.k - 1];
        for l in logits.iter_mut() {
            if *l < threshold {
                *l = f32::NEG_INFINITY;
            }
        }
    }
}

/// Nucleus truncation: keeps the smallest probability-sorted prefix whose
/// cumulative softmax mass reaches `p` (always at least the top token) and
/// masks the rest to `-inf`. Sorting breaks probability ties by ascending
/// token id so the kept set is deterministic.
struct TopP {
    p: f32,
}

impl Sampler for TopP {
    fn apply(&mut self, logits: &mut [f32], _prompt: &[u8], _out: &[u8]) {
        if self.p >= 1.0 || logits.is_empty() {
            return;
        }
        let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        if !max.is_finite() {
            return;
        }
        let weights: Vec<f64> = logits
            .iter()
            .map(|&l| if l.is_finite() { ((l - max) as f64).exp() } else { 0.0 })
            .collect();
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return;
        }
        let mut order: Vec<usize> = (0..logits.len()).collect();
        order.sort_unstable_by(|&a, &b| {
            weights[b].total_cmp(&weights[a]).then(a.cmp(&b))
        });
        let mut cum = 0.0;
        let mut keep = vec![false; logits.len()];
        for &i in &order {
            keep[i] = true;
            cum += weights[i] / total;
            if cum >= self.p as f64 {
                break;
            }
        }
        for (i, l) in logits.iter_mut().enumerate() {
            if !keep[i] {
                *l = f32::NEG_INFINITY;
            }
        }
    }
}

/// Terminal stage of the chain: turns the (transformed) logit row into one
/// token id.
pub enum Selector {
    /// First-maximum argmax — byte-exact with [`argmax_token`].
    Greedy,
    /// Seeded multinomial over the softmax of the surviving candidates.
    Multinomial(Rng),
}

/// A request's sampling pipeline: in-order transforms plus the final
/// [`Selector`]. Built once per request via [`SamplerChain::from_params`] and
/// consulted by the scheduler exactly once per emitted token.
pub struct SamplerChain {
    transforms: Vec<Box<dyn Sampler>>,
    selector: Selector,
}

impl SamplerChain {
    /// Build the chain for `params` (validating them first). Greedy requests
    /// skip temperature/top-k/top-p entirely — they cannot change an argmax —
    /// so the default chain is empty and byte-exact with the pre-sampler
    /// decode path.
    pub fn from_params(params: &SamplingParams) -> Result<Self, String> {
        params.validate()?;
        let mut transforms: Vec<Box<dyn Sampler>> = Vec::new();
        if params.repetition_penalty != 1.0 {
            transforms.push(Box::new(RepetitionPenalty {
                penalty: params.repetition_penalty,
            }));
        }
        let selector = if params.is_greedy() {
            Selector::Greedy
        } else {
            transforms.push(Box::new(Temperature { t: params.temperature }));
            if params.top_k > 0 {
                transforms.push(Box::new(TopK { k: params.top_k }));
            }
            if params.top_p < 1.0 {
                transforms.push(Box::new(TopP { p: params.top_p }));
            }
            Selector::Multinomial(Rng::new(params.seed))
        };
        Ok(SamplerChain { transforms, selector })
    }

    /// True when the selector is greedy argmax.
    pub fn is_greedy(&self) -> bool {
        matches!(self.selector, Selector::Greedy)
    }

    /// Run the transforms over `logits` in place, then select the next token.
    ///
    /// Input validation mirrors [`argmax_token`]: empty or non-finite *input*
    /// logits and token ids beyond 255 are errors. (`-inf` introduced by the
    /// chain's own masking is fine — it is zero probability, not corruption.)
    /// The multinomial selector consumes exactly one RNG draw per call.
    pub fn next_token(
        &mut self,
        logits: &mut [f32],
        prompt: &[u8],
        out: &[u8],
    ) -> Result<u8, String> {
        match &mut self.selector {
            Selector::Greedy => {
                for t in &mut self.transforms {
                    t.apply(logits, prompt, out);
                }
                argmax_token(logits)
            }
            Selector::Multinomial(rng) => {
                if logits.is_empty() {
                    return Err("empty logits (no prompt token was decoded)".into());
                }
                if logits.iter().any(|v| !v.is_finite()) {
                    return Err("non-finite logits (model produced NaN/inf)".into());
                }
                for t in &mut self.transforms {
                    t.apply(logits, prompt, out);
                }
                let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
                if !max.is_finite() {
                    return Err("non-finite logits (model produced NaN/inf)".into());
                }
                let weights: Vec<f64> = logits
                    .iter()
                    .map(|&l| if l.is_finite() { ((l - max) as f64).exp() } else { 0.0 })
                    .collect();
                if weights.iter().sum::<f64>() <= 0.0 {
                    return Err("non-finite logits (model produced NaN/inf)".into());
                }
                let idx = rng.weighted(&weights);
                u8::try_from(idx).map_err(|_| {
                    format!("sampled token id {idx} exceeds the byte token range (vocab > 256)")
                })
            }
        }
    }
}

/// Stop sequences for one request: byte strings checked as suffixes of the
/// emitted output after every token. An empty set never matches.
#[derive(Clone, Debug, Default)]
pub struct StopSet {
    seqs: Vec<Vec<u8>>,
}

impl StopSet {
    /// Build from raw byte sequences; empty sequences are dropped (they would
    /// match everything, including the empty output).
    pub fn new(seqs: Vec<Vec<u8>>) -> Self {
        StopSet { seqs: seqs.into_iter().filter(|s| !s.is_empty()).collect() }
    }

    /// True when no stop sequence is registered.
    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// True when any stop sequence is a suffix of `out`.
    pub fn hit(&self, out: &[u8]) -> bool {
        self.seqs.iter().any(|s| out.ends_with(s))
    }

    /// The registered sequences (wire-format echo and tests).
    pub fn seqs(&self) -> &[Vec<u8>] {
        &self.seqs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampled(params: SamplingParams, logits: &[f32], n: usize) -> Vec<u8> {
        let mut chain = SamplerChain::from_params(&params).unwrap();
        let mut out = Vec::new();
        for _ in 0..n {
            let mut row = logits.to_vec();
            out.push(chain.next_token(&mut row, &[], &out).unwrap());
        }
        out
    }

    #[test]
    fn default_is_greedy_and_bit_identical_to_argmax() {
        let logits = [0.1f32, 2.5, -1.0, 2.5, 0.0];
        let mut chain = SamplerChain::from_params(&SamplingParams::default()).unwrap();
        assert!(chain.is_greedy());
        let mut row = logits.to_vec();
        let tok = chain.next_token(&mut row, &[], &[]).unwrap();
        assert_eq!(tok, argmax_token(&logits).unwrap());
        assert_eq!(tok, 1, "first maximum wins on ties");
        assert_eq!(row, logits, "default chain must not touch the logits");
    }

    #[test]
    fn greedy_error_contract_matches_argmax() {
        let mut chain = SamplerChain::from_params(&SamplingParams::default()).unwrap();
        assert_eq!(
            chain.next_token(&mut [], &[], &[]).unwrap_err(),
            argmax_token(&[]).unwrap_err()
        );
        let bad = [1.0f32, f32::NAN];
        let mut row = bad.to_vec();
        assert_eq!(
            chain.next_token(&mut row, &[], &[]).unwrap_err(),
            argmax_token(&bad).unwrap_err()
        );
    }

    #[test]
    fn multinomial_rejects_bad_input_logits() {
        let params = SamplingParams { temperature: 1.0, seed: 1, ..Default::default() };
        let mut chain = SamplerChain::from_params(&params).unwrap();
        assert!(chain.next_token(&mut [], &[], &[]).unwrap_err().contains("empty"));
        let mut row = vec![1.0f32, f32::INFINITY];
        assert!(chain
            .next_token(&mut row, &[], &[])
            .unwrap_err()
            .contains("non-finite"));
    }

    #[test]
    fn same_seed_replays_identically() {
        let params = SamplingParams {
            temperature: 0.9,
            top_k: 3,
            top_p: 0.95,
            repetition_penalty: 1.2,
            seed: 42,
        };
        let logits = [0.3f32, 1.1, -0.2, 0.9, 0.5, -1.5];
        assert_eq!(sampled(params, &logits, 32), sampled(params, &logits, 32));
    }

    #[test]
    fn different_seeds_diverge() {
        let base = SamplingParams { temperature: 1.5, ..Default::default() };
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let a = sampled(SamplingParams { seed: 1, ..base }, &logits, 64);
        let b = sampled(SamplingParams { seed: 2, ..base }, &logits, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn top_k_masks_everything_outside_k() {
        let params = SamplingParams { temperature: 1.0, top_k: 2, seed: 7, ..Default::default() };
        let logits = [5.0f32, 4.0, -50.0, -50.0, -50.0];
        for tok in sampled(params, &logits, 256) {
            assert!(tok <= 1, "top_k=2 sampled masked token {tok}");
        }
    }

    #[test]
    fn top_p_keeps_only_the_nucleus() {
        // Token 0 holds ~83% of the mass; top_p=0.5 must keep exactly it.
        let params = SamplingParams { temperature: 1.0, top_p: 0.5, seed: 9, ..Default::default() };
        let logits = [3.0f32, 1.0, 0.0, -1.0];
        for tok in sampled(params, &logits, 256) {
            assert_eq!(tok, 0, "top_p nucleus should be a single token here");
        }
    }

    #[test]
    fn top_p_keeps_at_least_one_token() {
        let params = SamplingParams {
            temperature: 1.0,
            top_p: 1e-6,
            seed: 3,
            ..Default::default()
        };
        let logits = [0.0f32, 0.0, 0.0];
        let mut chain = SamplerChain::from_params(&params).unwrap();
        let mut row = logits.to_vec();
        chain.next_token(&mut row, &[], &[]).unwrap();
    }

    #[test]
    fn repetition_penalty_discourages_repeats_under_greedy() {
        // Greedy with a strong penalty: once 0 is emitted, its logit is
        // divided and token 1 takes over.
        let params = SamplingParams { repetition_penalty: 10.0, ..Default::default() };
        let logits = [2.0f32, 1.9, -5.0];
        let mut chain = SamplerChain::from_params(&params).unwrap();
        assert!(chain.is_greedy());
        let mut out = Vec::new();
        for _ in 0..2 {
            let mut row = logits.to_vec();
            out.push(chain.next_token(&mut row, &[], &out).unwrap());
        }
        assert_eq!(out, vec![0, 1]);
    }

    #[test]
    fn repetition_penalty_sees_the_prompt() {
        let params = SamplingParams { repetition_penalty: 10.0, ..Default::default() };
        let logits = [2.0f32, 1.9, -5.0];
        let mut chain = SamplerChain::from_params(&params).unwrap();
        let mut row = logits.to_vec();
        // Token 0 is in the prompt, so it is penalized before the first emit.
        assert_eq!(chain.next_token(&mut row, &[0], &[]).unwrap(), 1);
    }

    #[test]
    fn validation_rejects_bad_params() {
        for p in [
            SamplingParams { temperature: -1.0, ..Default::default() },
            SamplingParams { temperature: f32::NAN, ..Default::default() },
            SamplingParams { top_p: 0.0, ..Default::default() },
            SamplingParams { top_p: 1.5, ..Default::default() },
            SamplingParams { repetition_penalty: 0.0, ..Default::default() },
            SamplingParams { repetition_penalty: -2.0, ..Default::default() },
        ] {
            assert!(p.validate().is_err(), "{p:?} should be rejected");
            assert!(SamplerChain::from_params(&p).is_err());
        }
        assert!(SamplingParams::default().validate().is_ok());
    }

    #[test]
    fn stop_set_suffix_matching() {
        let stop = StopSet::new(vec![vec![10, 11], vec![7], vec![]]);
        assert_eq!(stop.seqs().len(), 2, "empty sequences are dropped");
        assert!(!stop.hit(&[]));
        assert!(!stop.hit(&[10]));
        assert!(stop.hit(&[1, 10, 11]));
        assert!(stop.hit(&[7]));
        assert!(!stop.hit(&[11, 10]));
        assert!(StopSet::default().is_empty());
        assert!(!StopSet::default().hit(&[1, 2, 3]));
    }

    #[test]
    fn sampled_distribution_tracks_the_mass() {
        // Statistical sanity: with temperature 1 and two tokens at equal
        // logits plus one heavily negative, the two heavies split the draws.
        let params = SamplingParams { temperature: 1.0, seed: 11, ..Default::default() };
        let logits = [1.0f32, 1.0, -20.0];
        let toks = sampled(params, &logits, 2000);
        let c0 = toks.iter().filter(|&&t| t == 0).count();
        let c1 = toks.iter().filter(|&&t| t == 1).count();
        let c2 = toks.iter().filter(|&&t| t == 2).count();
        assert_eq!(c2, 0, "negligible-mass token should effectively never fire");
        assert!(c0 > 700 && c1 > 700, "even split expected, got {c0}/{c1}");
    }
}
