//! Calibration and evaluation data pipeline.
//!
//! The paper calibrates on 128 random length-2048 sequences from WikiText-2
//! and evaluates perplexity on the WikiText-2 and C4 test splits. Neither
//! dataset ships with this environment, so [`corpus`] synthesizes two
//! *distributionally distinct* byte-level corpora from seeded stochastic
//! grammars ("synthwiki" — prose-like, and "synthc4" — web-like), giving the
//! same in-domain/out-of-domain structure the Wiki2/C4 pair provides.
//! [`batcher`] mirrors the paper's sampling: random fixed-length calibration
//! sequences and contiguous evaluation windows.

pub mod batcher;
pub mod corpus;

pub use batcher::{calibration_batches, eval_windows, Batch};
pub use corpus::{Corpus, CorpusKind};
