//! Seeded synthetic corpora with distinct, learnable statistics.
//!
//! Each corpus is byte-level text generated from a stochastic grammar:
//! a syllable-composed word list sampled under a Zipfian unigram law, with
//! corpus-specific sentence structure. The grammars are deterministic in the
//! seed, so train/calibration/test splits are reproducible everywhere
//! (corpus generation, model training, quantization and evaluation all
//! consume the same bytes).
//!
//! Two kinds:
//! * [`CorpusKind::SynthWiki`] — prose-like: longer sentences, headers,
//!   a heavier function-word class (stands in for WikiText-2);
//! * [`CorpusKind::SynthC4`] — web-like: shorter fragments, digits, URLs
//!   and list markers, different syllable inventory (stands in for C4).

use crate::util::rng::Rng;

/// Which synthetic distribution to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    SynthWiki,
    SynthC4,
}

impl CorpusKind {
    pub fn label(&self) -> &'static str {
        match self {
            CorpusKind::SynthWiki => "synthwiki",
            CorpusKind::SynthC4 => "synthc4",
        }
    }
}

/// A generated corpus: raw bytes are the token stream (byte-level
/// tokenization, vocab = 256).
#[derive(Clone, Debug)]
pub struct Corpus {
    pub kind: CorpusKind,
    pub bytes: Vec<u8>,
}

struct Grammar {
    words: Vec<String>,
    /// Zipf weights per word.
    weights: Vec<f64>,
    sentence_len: (usize, usize),
    /// Probability a sentence is a "structure" line (header / url / list).
    structure_p: f64,
    kind: CorpusKind,
}

fn build_grammar(kind: CorpusKind, rng: &mut Rng) -> Grammar {
    let (syllables, n_words, zipf_s): (&[&str], usize, f64) = match kind {
        CorpusKind::SynthWiki => (
            &["ta", "ren", "vo", "lis", "mar", "ke", "dun", "sha", "pel", "or",
              "an", "tir", "ves", "lo", "cam", "bri", "sut", "hel", "ny", "qua"],
            900,
            1.05,
        ),
        CorpusKind::SynthC4 => (
            &["zak", "blo", "fi", "web", "ne", "tro", "gig", "pix", "mo", "dra",
              "ul", "spa", "cli", "ko", "ze", "ran", "pos", "vib", "ju", "wi"],
            1400,
            1.25,
        ),
    };
    let mut words = Vec::with_capacity(n_words);
    for _ in 0..n_words {
        let n_syl = 1 + rng.below(3);
        let mut w = String::new();
        for _ in 0..=n_syl {
            w.push_str(syllables[rng.below(syllables.len())]);
        }
        words.push(w);
    }
    // Zipf: weight of rank-k word is 1/k^s.
    let weights: Vec<f64> = (1..=n_words).map(|k| 1.0 / (k as f64).powf(zipf_s)).collect();
    match kind {
        CorpusKind::SynthWiki => Grammar {
            words,
            weights,
            sentence_len: (6, 22),
            structure_p: 0.05,
            kind,
        },
        CorpusKind::SynthC4 => Grammar {
            words,
            weights,
            sentence_len: (3, 12),
            structure_p: 0.18,
            kind,
        },
    }
}

fn push_sentence(g: &Grammar, rng: &mut Rng, out: &mut Vec<u8>) {
    if rng.f64() < g.structure_p {
        match (g.kind, rng.below(3)) {
            (CorpusKind::SynthWiki, _) => {
                // section header
                out.extend_from_slice(b"\n== ");
                out.extend_from_slice(g.words[rng.weighted(&g.weights)].as_bytes());
                out.extend_from_slice(b" ==\n");
            }
            (CorpusKind::SynthC4, 0) => {
                out.extend_from_slice(b"http://");
                out.extend_from_slice(g.words[rng.weighted(&g.weights)].as_bytes());
                out.extend_from_slice(b".com/");
                out.extend_from_slice(g.words[rng.weighted(&g.weights)].as_bytes());
                out.push(b'\n');
            }
            (CorpusKind::SynthC4, 1) => {
                out.extend_from_slice(b"- ");
                out.extend_from_slice(g.words[rng.weighted(&g.weights)].as_bytes());
                out.extend_from_slice(b": ");
                let n = 10 + rng.below(90);
                out.extend_from_slice(n.to_string().as_bytes());
                out.push(b'\n');
            }
            (CorpusKind::SynthC4, _) => {
                let n = rng.below(2030);
                out.extend_from_slice(n.to_string().as_bytes());
                out.push(b' ');
            }
        }
        return;
    }
    let (lo, hi) = g.sentence_len;
    let len = lo + rng.below(hi - lo + 1);
    for i in 0..len {
        let w = &g.words[rng.weighted(&g.weights)];
        if i == 0 {
            // capitalize first letter
            let mut chars = w.as_bytes().to_vec();
            chars[0] = chars[0].to_ascii_uppercase();
            out.extend_from_slice(&chars);
        } else {
            out.extend_from_slice(w.as_bytes());
        }
        if i + 1 < len {
            // occasional comma
            if rng.f64() < 0.08 {
                out.push(b',');
            }
            out.push(b' ');
        }
    }
    out.extend_from_slice(if rng.f64() < 0.1 { b"? " } else { b". " });
}

impl Corpus {
    /// Generate ~`n_bytes` of text. Same (kind, seed, n_bytes) → same bytes.
    ///
    /// The *grammar* (word inventory, Zipf weights) depends only on `kind`,
    /// so different seeds sample different text from the **same**
    /// distribution — that is what makes train/test splits and the
    /// within-corpus vs across-corpus distinction meaningful.
    pub fn generate(kind: CorpusKind, n_bytes: usize, seed: u64) -> Corpus {
        let grammar_tag = match kind {
            CorpusKind::SynthWiki => 0x5157_494B_4931_3131u64,
            CorpusKind::SynthC4 => 0x5159_4334_3434_3434u64,
        };
        let mut grammar_rng = Rng::new(grammar_tag);
        let g = build_grammar(kind, &mut grammar_rng);
        let mut rng = Rng::new(seed ^ grammar_tag);
        let mut bytes = Vec::with_capacity(n_bytes + 64);
        while bytes.len() < n_bytes {
            push_sentence(&g, &mut rng, &mut bytes);
        }
        bytes.truncate(n_bytes);
        Corpus { kind, bytes }
    }

    /// Train/test split at a byte offset (test is the tail fraction).
    pub fn split(&self, test_frac: f64) -> (&[u8], &[u8]) {
        let cut = ((1.0 - test_frac) * self.bytes.len() as f64) as usize;
        (&self.bytes[..cut], &self.bytes[cut..])
    }

    /// Empirical unigram distribution over bytes (for tests/analysis).
    pub fn unigram(&self) -> [f64; 256] {
        let mut counts = [0f64; 256];
        for &b in &self.bytes {
            counts[b as usize] += 1.0;
        }
        let total: f64 = counts.iter().sum();
        for c in counts.iter_mut() {
            *c /= total.max(1.0);
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Corpus::generate(CorpusKind::SynthWiki, 10_000, 7);
        let b = Corpus::generate(CorpusKind::SynthWiki, 10_000, 7);
        assert_eq!(a.bytes, b.bytes);
    }

    #[test]
    fn seeds_and_kinds_differ() {
        let a = Corpus::generate(CorpusKind::SynthWiki, 5_000, 1);
        let b = Corpus::generate(CorpusKind::SynthWiki, 5_000, 2);
        let c = Corpus::generate(CorpusKind::SynthC4, 5_000, 1);
        assert_ne!(a.bytes, b.bytes);
        assert_ne!(a.bytes, c.bytes);
    }

    #[test]
    fn corpora_are_distributionally_distinct() {
        // L1 distance between byte unigrams of the two kinds should be
        // clearly larger than between two seeds of the same kind.
        let wiki1 = Corpus::generate(CorpusKind::SynthWiki, 60_000, 1).unigram();
        let wiki2 = Corpus::generate(CorpusKind::SynthWiki, 60_000, 2).unigram();
        let c4 = Corpus::generate(CorpusKind::SynthC4, 60_000, 1).unigram();
        let l1 = |a: &[f64; 256], b: &[f64; 256]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
        };
        let within = l1(&wiki1, &wiki2);
        let across = l1(&wiki1, &c4);
        assert!(
            across > within * 3.0,
            "across={across:.4} within={within:.4}"
        );
    }

    #[test]
    fn exact_length_and_printable() {
        let c = Corpus::generate(CorpusKind::SynthC4, 12_345, 3);
        assert_eq!(c.bytes.len(), 12_345);
        assert!(c
            .bytes
            .iter()
            .all(|&b| b == b'\n' || (0x20..0x7f).contains(&b)));
    }

    #[test]
    fn split_partitions() {
        let c = Corpus::generate(CorpusKind::SynthWiki, 10_000, 5);
        let (train, test) = c.split(0.1);
        assert_eq!(train.len() + test.len(), 10_000);
        assert_eq!(test.len(), 1_000);
    }

    #[test]
    fn zipf_head_dominates() {
        // The most common byte (space) should be a large share — evidence the
        // word process, not uniform noise, drives the stream.
        let c = Corpus::generate(CorpusKind::SynthWiki, 50_000, 9);
        let u = c.unigram();
        assert!(u[b' ' as usize] > 0.08, "space freq {}", u[b' ' as usize]);
    }
}
