//! Sequence sampling: calibration batches and evaluation windows.

use crate::util::rng::Rng;

/// A batch of token sequences, row-major `[batch, seq_len]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    pub batch: usize,
    pub seq_len: usize,
    pub tokens: Vec<u8>,
}

impl Batch {
    pub fn seq(&self, b: usize) -> &[u8] {
        &self.tokens[b * self.seq_len..(b + 1) * self.seq_len]
    }

    /// Tokens as i32 (the dtype the HLO artifacts take).
    pub fn tokens_i32(&self) -> Vec<i32> {
        self.tokens.iter().map(|&t| t as i32).collect()
    }

    /// Next-token targets: `targets[b, t] = tokens[b, t+1]`, last column is
    /// the padding id 0 and must be masked by the loss.
    pub fn shifted_targets(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.tokens.len()];
        for b in 0..self.batch {
            let src = self.seq(b);
            let dst = &mut out[b * self.seq_len..(b + 1) * self.seq_len];
            dst[..self.seq_len - 1].copy_from_slice(&src[1..]);
        }
        out
    }
}

/// Sample `n_seqs` random sequences of `seq_len` tokens (the paper's
/// "128 random sequences of length 2048"), grouped into batches of
/// `batch_size`.
pub fn calibration_batches(
    data: &[u8],
    n_seqs: usize,
    seq_len: usize,
    batch_size: usize,
    seed: u64,
) -> Vec<Batch> {
    assert!(data.len() > seq_len, "corpus shorter than seq_len");
    let mut rng = Rng::new(seed);
    let mut batches = Vec::new();
    let mut remaining = n_seqs;
    while remaining > 0 {
        let b = batch_size.min(remaining);
        let mut tokens = Vec::with_capacity(b * seq_len);
        for _ in 0..b {
            let start = rng.below(data.len() - seq_len);
            tokens.extend_from_slice(&data[start..start + seq_len]);
        }
        batches.push(Batch { batch: b, seq_len, tokens });
        remaining -= b;
    }
    batches
}

/// Contiguous non-overlapping evaluation windows over `data` (perplexity is
/// computed over these, like lm-eval's sliding-window-free protocol).
pub fn eval_windows(data: &[u8], seq_len: usize, max_windows: usize) -> Vec<Vec<u8>> {
    data.chunks_exact(seq_len)
        .take(max_windows)
        .map(|c| c.to_vec())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::corpus::{Corpus, CorpusKind};

    fn data() -> Vec<u8> {
        Corpus::generate(CorpusKind::SynthWiki, 20_000, 1).bytes
    }

    #[test]
    fn calibration_shapes() {
        let d = data();
        let batches = calibration_batches(&d, 10, 128, 4, 7);
        assert_eq!(batches.len(), 3); // 4 + 4 + 2
        assert_eq!(batches[0].batch, 4);
        assert_eq!(batches[2].batch, 2);
        assert!(batches.iter().all(|b| b.tokens.len() == b.batch * 128));
    }

    #[test]
    fn calibration_deterministic() {
        let d = data();
        let a = calibration_batches(&d, 4, 64, 2, 9);
        let b = calibration_batches(&d, 4, 64, 2, 9);
        assert_eq!(a, b);
        let c = calibration_batches(&d, 4, 64, 2, 10);
        assert_ne!(a, c);
    }

    #[test]
    fn sequences_are_substrings() {
        let d = data();
        let batches = calibration_batches(&d, 3, 50, 3, 1);
        for b in &batches {
            for i in 0..b.batch {
                let seq = b.seq(i);
                assert!(d.windows(50).any(|w| w == seq));
            }
        }
    }

    #[test]
    fn shifted_targets_align() {
        let b = Batch { batch: 2, seq_len: 4, tokens: vec![1, 2, 3, 4, 9, 8, 7, 6] };
        assert_eq!(b.shifted_targets(), vec![2, 3, 4, 0, 8, 7, 6, 0]);
    }

    #[test]
    fn eval_windows_cover_prefix() {
        let d = data();
        let ws = eval_windows(&d, 100, 5);
        assert_eq!(ws.len(), 5);
        assert_eq!(ws[0], d[..100].to_vec());
        assert_eq!(ws[1], d[100..200].to_vec());
    }

    #[test]
    #[should_panic(expected = "corpus shorter")]
    fn short_corpus_panics() {
        calibration_batches(&[1, 2, 3], 1, 10, 1, 0);
    }
}
