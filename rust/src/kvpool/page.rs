//! Fixed-size KV pages: the allocation unit of the pool.
//!
//! A page stores a fixed number of whole token rows for **one** K-or-V cache
//! of one layer. Sizing pages in token rows (not bytes) is what keeps the
//! paged attend path trivially bit-identical: a row — and therefore every
//! (head, group) span the fused kernels read — lives entirely inside one
//! page, so the per-row slices handed to `dot_span`/`axpy_span` are
//! byte-identical to the contiguous cache's.

use crate::model::config::ModelConfig;
use crate::model::kvcache::KvSpec;
use crate::tensor::packed::PackedInts;

/// Per-row storage geometry of a page, fixed by the (effective) [`KvSpec`]
/// and model shape. All pages of one [`super::KvPool`] share one `PageSpec`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageSpec {
    /// Token rows per page.
    pub tokens: usize,
    /// Packed `u32` words per row (0 for dense pages).
    pub words_per_row: usize,
    /// f32 elements per row in `KvPage::data`: `d_model` for dense rows,
    /// `groups_per_row` scales for packed rows.
    pub data_per_row: usize,
    /// f32 zero-points per row (`groups_per_row` for packed, 0 for dense).
    pub zeros_per_row: usize,
}

impl PageSpec {
    /// Geometry for `spec` (head-clamped via [`KvSpec::effective`]) on
    /// `cfg`-shaped models, with `page_tokens` rows per page.
    pub fn new(spec: KvSpec, cfg: &ModelConfig, page_tokens: usize) -> PageSpec {
        let tokens = page_tokens.max(1);
        match spec.effective(cfg) {
            KvSpec::DenseF32 => PageSpec {
                tokens,
                words_per_row: 0,
                data_per_row: cfg.d_model,
                zeros_per_row: 0,
            },
            KvSpec::PackedGroupwise { bits, group } => {
                let gpr = cfg.n_heads * cfg.head_dim().div_ceil(group);
                PageSpec {
                    tokens,
                    words_per_row: PackedInts::words_needed(cfg.d_model, bits),
                    data_per_row: gpr,
                    zeros_per_row: gpr,
                }
            }
        }
    }

    /// Bytes one full page stores — the unit the pool's byte budget is
    /// divided by.
    pub fn page_bytes(&self) -> usize {
        self.tokens * (self.words_per_row + self.data_per_row + self.zeros_per_row) * 4
    }

    /// Mint an empty page with capacity for `tokens` rows up front (pages
    /// never reallocate: append fills them row by row, `reset` keeps the
    /// buffers for reuse).
    pub(crate) fn blank(&self) -> KvPage {
        KvPage {
            rows: 0,
            words: Vec::with_capacity(self.tokens * self.words_per_row),
            data: Vec::with_capacity(self.tokens * self.data_per_row),
            zeros: Vec::with_capacity(self.tokens * self.zeros_per_row),
        }
    }
}

/// One pool page: storage for up to `PageSpec::tokens` whole rows of one
/// K-or-V cache. Owned by exactly one page table ([`super::PagedKv`]) at a
/// time; released pages go back to the pool's free list with their buffers
/// intact.
#[derive(Debug)]
pub struct KvPage {
    /// Rows currently written (≤ `PageSpec::tokens`).
    pub(crate) rows: usize,
    /// Packed words, `rows × words_per_row` (empty for dense pages).
    pub(crate) words: Vec<u32>,
    /// Dense f32 rows, or per-group scales for packed rows.
    pub(crate) data: Vec<f32>,
    /// Per-group zero points (packed rows only).
    pub(crate) zeros: Vec<f32>,
}

impl KvPage {
    /// Rows currently written to this page.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Clear contents but keep the allocations — called on release so the
    /// free list recycles warm buffers.
    pub(crate) fn reset(&mut self) {
        self.rows = 0;
        self.words.clear();
        self.data.clear();
        self.zeros.clear();
    }
}
