//! The paged per-sequence cache: a page table over pool pages.
//!
//! `PagedKv` is the third [`crate::model::kvcache::KvCache`] representation.
//! It stores exactly what the contiguous caches store — f32 rows, or packed
//! words plus per-group scale/zero pairs — just scattered over fixed-size
//! pages instead of one flat vector. Append allocates a page every
//! `page_tokens` rows; attend walks the page table in row order and hands
//! each row's slices to the **same** `PackedLayout` helpers (or the same
//! dense `dot`/axpy loops) the contiguous caches use, so paged logits are
//! bit-identical to contiguous-cache logits under every kernel table.
//!
//! Pool exhaustion inside `append` is a panic, not an error: the scheduler
//! gates every step on free pages (`StepBackend::can_step`) and preempts
//! until the step fits, so an allocation failure here means the reservation
//! accounting is wrong — corrupting a decode silently would be worse.

use super::page::{KvPage, PageSpec};
use super::pool::KvPool;
use crate::model::config::ModelConfig;
use crate::model::kvcache::{KvSpec, PackedLayout};

/// Row representation, mirroring the contiguous `DenseKv`/`PackedKv` split.
#[derive(Clone, Copy, Debug)]
enum PagedRepr {
    Dense { d: usize, head_dim: usize },
    Packed(PackedLayout),
}

/// One K or V cache for one layer, backed by pool pages.
#[derive(Debug)]
pub struct PagedKv {
    pool: KvPool,
    repr: PagedRepr,
    rows: usize,
    /// The page table: pages in row order, all full except the last.
    pages: Vec<KvPage>,
}

impl PagedKv {
    /// An empty page table drawing from `pool` (which must have been built
    /// for the same `spec`/`cfg` — checked in debug builds).
    pub fn new(spec: KvSpec, cfg: &ModelConfig, pool: &KvPool) -> PagedKv {
        let eff = spec.effective(cfg);
        debug_assert_eq!(
            pool.page_spec(),
            PageSpec::new(eff, cfg, pool.page_tokens()),
            "KvPool was built for a different KV layout than this cache"
        );
        let repr = match eff {
            KvSpec::DenseF32 => {
                PagedRepr::Dense { d: cfg.d_model, head_dim: cfg.head_dim() }
            }
            KvSpec::PackedGroupwise { bits, group } => {
                PagedRepr::Packed(PackedLayout::new(bits, group, cfg))
            }
        };
        PagedKv { pool: pool.clone(), repr, rows: 0, pages: Vec::new() }
    }

    /// The spec this cache stores (group reported post-clamp).
    pub fn spec(&self) -> KvSpec {
        match self.repr {
            PagedRepr::Dense { .. } => KvSpec::DenseF32,
            PagedRepr::Packed(lay) => {
                KvSpec::PackedGroupwise { bits: lay.bits, group: lay.group }
            }
        }
    }

    /// Cached rows (= tokens seen so far).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Pool pages this table currently holds.
    pub fn pages_used(&self) -> usize {
        self.pages.len()
    }

    /// Bytes used by cached rows — same accounting as the contiguous caches
    /// (page-slack capacity is the pool's business, not the cache's).
    pub fn nbytes(&self) -> usize {
        match self.repr {
            PagedRepr::Dense { d, .. } => self.rows * d * 4,
            PagedRepr::Packed(lay) => {
                self.rows * (lay.words_per_row * 4 + lay.groups_per_row() * 8)
            }
        }
    }

    /// Append one `[d_model]` row, allocating a page at each page boundary.
    pub fn append(&mut self, row: &[f32]) {
        if self.rows % self.pool.page_tokens() == 0 {
            let page = self.pool.alloc().unwrap_or_else(|| {
                panic!(
                    "kv pool exhausted during append (row {}, {} pages held): \
                     the scheduler must gate steps on free pages",
                    self.rows,
                    self.pages.len()
                )
            });
            self.pages.push(page);
        }
        let page = self.pages.last_mut().expect("page allocated above");
        match self.repr {
            PagedRepr::Dense { d, .. } => {
                debug_assert_eq!(row.len(), d);
                page.data.extend_from_slice(row);
            }
            PagedRepr::Packed(lay) => {
                lay.quantize_row_into(row, &mut page.words, &mut page.data, &mut page.zeros);
            }
        }
        page.rows += 1;
        self.rows += 1;
    }

    /// Attention scores for one head against every cached row — the paged
    /// twin of the contiguous `head_scores` (same per-row math, same order).
    pub fn head_scores(&self, head: usize, q: &[f32], scale: f32, scores: &mut Vec<f32>) {
        self.head_scores_limit(head, q, scale, self.rows, scores);
    }

    /// Scores against the first `limit` rows only — the causal mask of
    /// chunked prefill, walking the page table in row order and stopping at
    /// `limit`. `limit == rows` is exactly the full attend.
    pub fn head_scores_limit(
        &self,
        head: usize,
        q: &[f32],
        scale: f32,
        limit: usize,
        scores: &mut Vec<f32>,
    ) {
        debug_assert!(limit <= self.rows);
        scores.clear();
        scores.reserve(limit);
        let mut remaining = limit;
        match self.repr {
            PagedRepr::Dense { d, head_dim } => {
                let base = head * head_dim;
                let qh = &q[base..base + head_dim];
                for page in &self.pages {
                    for r in 0..page.rows.min(remaining) {
                        let krow = &page.data[r * d + base..r * d + base + head_dim];
                        scores.push(crate::tensor::matrix::dot(qh, krow) * scale);
                    }
                    remaining -= page.rows.min(remaining);
                    if remaining == 0 {
                        break;
                    }
                }
            }
            PagedRepr::Packed(lay) => {
                let gph = lay.groups_per_head;
                let gpr = lay.groups_per_row();
                let wpr = lay.words_per_row;
                let mut gsum = crate::util::scratch::take_f32(gph);
                lay.head_gsums(q, head, &mut gsum);
                for page in &self.pages {
                    for r in 0..page.rows.min(remaining) {
                        let words = &page.words[r * wpr..(r + 1) * wpr];
                        let srow = &page.data[r * gpr + head * gph..r * gpr + (head + 1) * gph];
                        let zrow = &page.zeros[r * gpr + head * gph..r * gpr + (head + 1) * gph];
                        scores.push(lay.row_score(words, srow, zrow, head, q, &gsum) * scale);
                    }
                    remaining -= page.rows.min(remaining);
                    if remaining == 0 {
                        break;
                    }
                }
            }
        }
    }

    /// Accumulate the softmax-weighted value rows of one head into
    /// `ctx_head` — paged twin of the contiguous `head_axpy`.
    pub fn head_axpy(&self, head: usize, probs: &[f32], ctx_head: &mut [f32]) {
        self.head_axpy_limit(head, probs, self.rows, ctx_head);
    }

    /// Accumulate over the first `limit` rows only (span-prefill causal
    /// mask — see [`Self::head_scores_limit`]).
    pub fn head_axpy_limit(
        &self,
        head: usize,
        probs: &[f32],
        limit: usize,
        ctx_head: &mut [f32],
    ) {
        debug_assert!(limit <= self.rows && probs.len() >= limit);
        match self.repr {
            PagedRepr::Dense { d, head_dim } => {
                debug_assert!(ctx_head.len() >= head_dim);
                let base = head * head_dim;
                let mut t = 0usize;
                'pages: for page in &self.pages {
                    for r in 0..page.rows {
                        if t == limit {
                            break 'pages;
                        }
                        let w = probs[t];
                        let vrow = &page.data[r * d + base..r * d + base + head_dim];
                        for (o, &v) in ctx_head.iter_mut().zip(vrow) {
                            *o += w * v;
                        }
                        t += 1;
                    }
                }
            }
            PagedRepr::Packed(lay) => {
                debug_assert!(ctx_head.len() >= lay.head_dim);
                let gph = lay.groups_per_head;
                let gpr = lay.groups_per_row();
                let wpr = lay.words_per_row;
                let mut t = 0usize;
                'pages: for page in &self.pages {
                    for r in 0..page.rows {
                        if t == limit {
                            break 'pages;
                        }
                        let w = probs[t];
                        let words = &page.words[r * wpr..(r + 1) * wpr];
                        let srow = &page.data[r * gpr + head * gph..r * gpr + (head + 1) * gph];
                        let zrow = &page.zeros[r * gpr + head * gph..r * gpr + (head + 1) * gph];
                        lay.row_axpy(words, srow, zrow, head, w, ctx_head);
                        t += 1;
                    }
                }
            }
        }
    }

    /// Dequantize one cached row back to f32 (dense rows copy).
    pub fn dequant_row(&self, t: usize) -> Vec<f32> {
        let pt = self.pool.page_tokens();
        let page = &self.pages[t / pt];
        let r = t % pt;
        match self.repr {
            PagedRepr::Dense { d, .. } => page.data[r * d..(r + 1) * d].to_vec(),
            PagedRepr::Packed(lay) => {
                let wpr = lay.words_per_row;
                let gpr = lay.groups_per_row();
                lay.dequant_row_from(
                    &page.words[r * wpr..(r + 1) * wpr],
                    &page.data[r * gpr..(r + 1) * gpr],
                    &page.zeros[r * gpr..(r + 1) * gpr],
                )
            }
        }
    }
}

impl Clone for PagedKv {
    /// Clones allocate fresh pages from the same pool and copy contents —
    /// pages are uniquely owned, so a derived (shallow-vec) clone would
    /// double-release on drop and corrupt the pool's accounting.
    fn clone(&self) -> PagedKv {
        let pages = self
            .pages
            .iter()
            .map(|p| {
                let mut fresh = self.pool.alloc().unwrap_or_else(|| {
                    panic!("kv pool exhausted while cloning a page table")
                });
                fresh.rows = p.rows;
                fresh.words.extend_from_slice(&p.words);
                fresh.data.extend_from_slice(&p.data);
                fresh.zeros.extend_from_slice(&p.zeros);
                fresh
            })
            .collect();
        PagedKv { pool: self.pool.clone(), repr: self.repr, rows: self.rows, pages }
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        for page in self.pages.drain(..) {
            self.pool.release(page);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::PoolCfg;
    use crate::model::config::Preset;
    use crate::util::rng::Rng;

    fn tiny() -> ModelConfig {
        Preset::Tiny.config()
    }

    fn pool_for(spec: KvSpec, cfg: &ModelConfig, pages: usize, page_tokens: usize) -> KvPool {
        let bytes = PageSpec::new(spec, cfg, page_tokens).page_bytes();
        KvPool::new(
            PoolCfg { budget_bytes: pages * bytes, page_tokens },
            spec,
            cfg,
        )
    }

    #[test]
    fn append_allocates_one_page_per_page_tokens_rows() {
        let cfg = tiny();
        let pool = pool_for(KvSpec::DenseF32, &cfg, 4, 4);
        let mut c = PagedKv::new(KvSpec::DenseF32, &cfg, &pool);
        let mut rng = Rng::new(3);
        for t in 0..9 {
            c.append(&rng.normal_vec(cfg.d_model, 1.0));
            assert_eq!(c.rows(), t + 1);
            assert_eq!(c.pages_used(), (t + 1).div_ceil(4));
        }
        assert_eq!(pool.used_pages(), 3);
        drop(c);
        assert_eq!(pool.used_pages(), 0, "drop must release every page");
    }

    #[test]
    fn clone_owns_its_own_pages() {
        let cfg = tiny();
        let spec = KvSpec::PackedGroupwise { bits: 8, group: 16 };
        let pool = pool_for(spec, &cfg, 8, 4);
        let mut a = PagedKv::new(spec, &cfg, &pool);
        let mut rng = Rng::new(7);
        let rows: Vec<Vec<f32>> =
            (0..6).map(|_| rng.normal_vec(cfg.d_model, 1.0)).collect();
        for row in &rows {
            a.append(row);
        }
        let b = a.clone();
        assert_eq!(pool.used_pages(), 4, "clone must hold its own pages");
        for t in 0..6 {
            assert_eq!(a.dequant_row(t), b.dequant_row(t), "t={t}");
        }
        drop(a);
        assert_eq!(pool.used_pages(), 2);
        drop(b);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    #[should_panic(expected = "kv pool exhausted during append")]
    fn append_past_budget_panics() {
        // The scheduler is responsible for never letting this happen; the
        // cache fails loudly rather than decoding against missing rows.
        let cfg = tiny();
        let pool = pool_for(KvSpec::DenseF32, &cfg, 1, 2);
        let mut c = PagedKv::new(KvSpec::DenseF32, &cfg, &pool);
        let row = vec![0.5f32; cfg.d_model];
        c.append(&row);
        c.append(&row);
        c.append(&row); // third row needs a second page the pool doesn't have
    }
}
