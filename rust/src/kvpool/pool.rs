//! The free-list page allocator: a global byte budget, minted-on-demand and
//! recycled [`KvPage`]s, and the occupancy/preemption counters the serve
//! banner reports.
//!
//! `KvPool` is a cheap-`Clone` handle (shared state behind an `Arc`), so the
//! scheduler, every page table, and the banner printer all observe one
//! budget. Locks recover from poison — a panicking decode worker must not
//! wedge every other sequence's allocator (same policy as the step pool's
//! job queue).

use super::page::{KvPage, PageSpec};
use crate::model::config::ModelConfig;
use crate::model::kvcache::KvSpec;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Pool tunables — the `--kv-pool-mb` / `--kv-page-tokens` CLI pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolCfg {
    /// Global KV byte budget; the pool holds `budget_bytes / page_bytes`
    /// pages, fixed at construction.
    pub budget_bytes: usize,
    /// Token rows per page.
    pub page_tokens: usize,
}

impl PoolCfg {
    /// Default `--kv-page-tokens`.
    pub const DEFAULT_PAGE_TOKENS: usize = 16;

    /// Build from the CLI flags; `pool_mb == 0` means "no pool" (the
    /// unbounded contiguous caches, as before PR 6).
    pub fn from_flags(pool_mb: usize, page_tokens: usize) -> Result<Option<PoolCfg>> {
        if pool_mb == 0 {
            return Ok(None);
        }
        if page_tokens == 0 {
            bail!("--kv-page-tokens must be positive");
        }
        Ok(Some(PoolCfg { budget_bytes: pool_mb << 20, page_tokens }))
    }

    /// The slice of a global budget a shard owning `layers` of
    /// `total_layers` gets: bytes proportional to its layer count (KV cost
    /// is per layer), page geometry unchanged. Both the shard-local
    /// sub-pools and the scheduler's accounting mirror derive their budgets
    /// through this one function, so they can never disagree.
    pub fn shard_slice(&self, layers: usize, total_layers: usize) -> PoolCfg {
        PoolCfg {
            budget_bytes: self.budget_bytes * layers / total_layers.max(1),
            page_tokens: self.page_tokens,
        }
    }
}

struct PoolInner {
    /// Released page buffers, recycled before minting new ones.
    free: Vec<KvPage>,
    /// Pages currently held by page tables.
    used: usize,
    /// Pages ever minted: `used + free.len()`, and never above
    /// `total_pages` — the no-leak invariant the reuse test checks.
    minted: usize,
}

/// Free-list allocator over fixed-size KV pages with a global byte budget.
#[derive(Clone)]
pub struct KvPool {
    spec: PageSpec,
    total_pages: usize,
    inner: Arc<Mutex<PoolInner>>,
    peak_used: Arc<AtomicUsize>,
    preemptions: Arc<AtomicUsize>,
}

impl std::fmt::Debug for KvPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvPool")
            .field("spec", &self.spec)
            .field("total_pages", &self.total_pages)
            .field("used_pages", &self.used_pages())
            .finish()
    }
}

impl KvPool {
    /// A pool for `kv`-formatted caches of `mcfg`-shaped models. The page
    /// geometry uses the **effective** (head-clamped) spec so budget math
    /// matches what the caches actually store.
    pub fn new(cfg: PoolCfg, kv: KvSpec, mcfg: &ModelConfig) -> KvPool {
        let spec = PageSpec::new(kv, mcfg, cfg.page_tokens);
        let total_pages = cfg.budget_bytes / spec.page_bytes().max(1);
        KvPool {
            spec,
            total_pages,
            inner: Arc::new(Mutex::new(PoolInner {
                free: Vec::new(),
                used: 0,
                minted: 0,
            })),
            peak_used: Arc::new(AtomicUsize::new(0)),
            preemptions: Arc::new(AtomicUsize::new(0)),
        }
    }

    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub(crate) fn page_spec(&self) -> PageSpec {
        self.spec
    }

    /// Token rows per page.
    pub fn page_tokens(&self) -> usize {
        self.spec.tokens
    }

    /// Bytes per page for this pool's layout.
    pub fn page_bytes(&self) -> usize {
        self.spec.page_bytes()
    }

    /// The fixed page budget (`budget_bytes / page_bytes`).
    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Pages currently held by page tables.
    pub fn used_pages(&self) -> usize {
        self.lock().used
    }

    /// Pages still allocatable.
    pub fn free_pages(&self) -> usize {
        self.total_pages - self.lock().used
    }

    /// Pages ever minted (≤ `total_pages`; stays flat once the working set
    /// recycles).
    pub fn minted_pages(&self) -> usize {
        self.lock().minted
    }

    /// Pages one cache needs to hold `rows` token rows.
    pub fn pages_for_rows(&self, rows: usize) -> usize {
        rows.div_ceil(self.spec.tokens)
    }

    /// High-water mark of `used_pages`.
    pub fn peak_used(&self) -> usize {
        self.peak_used.load(Ordering::Relaxed)
    }

    /// Preemptions recorded against this pool (see [`Self::note_preemption`]).
    pub fn preemptions(&self) -> usize {
        self.preemptions.load(Ordering::Relaxed)
    }

    /// The scheduler records each mid-decode eviction here so the banner and
    /// bench rows can report a preemption rate.
    pub fn note_preemption(&self) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
    }

    /// Take one page, recycling a released buffer when one exists. `None`
    /// when the budget is exhausted — callers at the admission/step layer
    /// must treat that as back-pressure, never as an error.
    pub(crate) fn alloc(&self) -> Option<KvPage> {
        let mut inner = self.lock();
        if inner.used >= self.total_pages {
            return None;
        }
        inner.used += 1;
        let used = inner.used;
        let page = match inner.free.pop() {
            Some(p) => p,
            None => {
                inner.minted += 1;
                crate::obs::registry().kv_pages_minted.inc();
                self.spec.blank()
            }
        };
        drop(inner);
        self.peak_used.fetch_max(used, Ordering::Relaxed);
        // The process-wide occupancy gauge moves by *delta*: many pools can
        // coexist (shard sub-pools, concurrent test servers) and deltas
        // compose where absolute stores would clobber. The global peak
        // ratchets off the global level, not this pool's local `used`.
        let global_used = crate::obs::registry().kv_pages_used.add(1);
        crate::obs::registry().kv_pages_peak.ratchet(global_used);
        Some(page)
    }

    /// Return a page to the free list (contents cleared, buffers kept).
    pub(crate) fn release(&self, mut page: KvPage) {
        page.reset();
        let mut inner = self.lock();
        debug_assert!(inner.used > 0, "kv pool release with no pages out");
        inner.used = inner.used.saturating_sub(1);
        inner.free.push(page);
        drop(inner);
        crate::obs::registry().kv_pages_used.sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Preset;

    fn tiny() -> ModelConfig {
        Preset::Tiny.config() // d=64, 2 heads, head_dim=32
    }

    #[test]
    fn from_flags_parses_and_rejects() {
        assert_eq!(PoolCfg::from_flags(0, 16).unwrap(), None);
        let pc = PoolCfg::from_flags(2, 8).unwrap().unwrap();
        assert_eq!(pc.budget_bytes, 2 << 20);
        assert_eq!(pc.page_tokens, 8);
        assert!(PoolCfg::from_flags(2, 0).is_err());
    }

    #[test]
    fn budget_divides_into_pages() {
        let cfg = tiny();
        // dense rows: 64 f32 = 256 B/row, 4 rows/page → 1024 B/page
        let pool = KvPool::new(
            PoolCfg { budget_bytes: 10 * 1024 + 512, page_tokens: 4 },
            KvSpec::DenseF32,
            &cfg,
        );
        assert_eq!(pool.page_bytes(), 1024);
        assert_eq!(pool.total_pages(), 10); // remainder bytes don't mint a page
        assert_eq!(pool.pages_for_rows(0), 0);
        assert_eq!(pool.pages_for_rows(4), 1);
        assert_eq!(pool.pages_for_rows(5), 2);
    }

    #[test]
    fn packed_page_bytes_match_kvspec_accounting() {
        // One page of T packed rows must cost T × (bytes_per_token/2): the
        // pool's budget math and the serving banner's bytes/token agree.
        let cfg = tiny();
        let spec = KvSpec::PackedGroupwise { bits: 8, group: 64 };
        let page = PageSpec::new(spec, &cfg, 16);
        assert_eq!(page.page_bytes(), 16 * spec.bytes_per_token(&cfg) / 2);
    }

    #[test]
    fn alloc_release_recycles_buffers() {
        let cfg = tiny();
        let pool = KvPool::new(
            PoolCfg { budget_bytes: 3 * 1024, page_tokens: 4 },
            KvSpec::DenseF32,
            &cfg,
        );
        assert_eq!(pool.total_pages(), 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert!(pool.alloc().is_none(), "budget must be hard");
        assert_eq!(pool.used_pages(), 3);
        pool.release(a);
        pool.release(b);
        pool.release(c);
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(pool.free_pages(), 3);
        // the next round must reuse buffers, not mint new ones
        let again = pool.alloc().unwrap();
        assert_eq!(pool.minted_pages(), 3);
        assert_eq!(again.rows(), 0);
        pool.release(again);
        assert_eq!(pool.peak_used(), 3);
    }

    #[test]
    fn preemption_counter_accumulates() {
        let pool = KvPool::new(
            PoolCfg { budget_bytes: 1 << 20, page_tokens: 16 },
            KvSpec::DenseF32,
            &tiny(),
        );
        assert_eq!(pool.preemptions(), 0);
        pool.note_preemption();
        pool.note_preemption();
        assert_eq!(pool.preemptions(), 2);
    }
}
