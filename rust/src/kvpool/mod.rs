//! Paged KV memory pool — the vLLM PagedAttention design point adapted to
//! the packed-word KV layout (PR 6).
//!
//! PR 4 shrank KV *bytes per token*; this module bounds KV *bytes total*.
//! Without it every admitted sequence owns an unbounded doubling-growth
//! cache, so serving memory scales with whatever traffic shows up — the
//! first thing the "heavy traffic" north star breaks. Here all per-sequence
//! KV storage comes from one fixed-budget pool of equal-size pages:
//!
//! * [`page::PageSpec`] / [`page::KvPage`] — a page holds a fixed number of
//!   **whole token rows** (packed words + per-group scale/zero pairs for
//!   `PackedGroupwise`, f32 rows for `DenseF32`). Because group grids
//!   subdivide rows and rows never straddle pages, group boundaries are
//!   page-aligned by construction and every attend span stays whole-group.
//! * [`pool::KvPool`] — the free-list allocator: a global byte budget fixed
//!   at construction, retired page buffers recycled before new ones are
//!   minted, occupancy/preemption counters for the serve banner.
//! * [`paged::PagedKv`] — the paged [`crate::model::kvcache::KvCache`]
//!   variant: a per-sequence page table whose append/attend walk the pages
//!   but run the **same** per-row quantize/score/axpy helpers
//!   ([`crate::model::kvcache`]'s `PackedLayout`) on byte-identical row
//!   slices, so paged logits are bit-identical to contiguous-cache logits
//!   under every kernel table.
//!
//! The serving integration (admission by free pages, youngest-first
//! preemption with re-prefill when the pool runs dry) lives in
//! [`crate::serve`]; this module only owns pages and page tables.

pub mod page;
pub mod paged;
pub mod pool;

pub use page::{KvPage, PageSpec};
pub use paged::PagedKv;
pub use pool::{KvPool, PoolCfg};
