//! Weight containers and initialization.

use super::config::ModelConfig;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// The seven quantizable linear projections of one block, in the order the
/// sequential pipeline visits them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinearKind {
    Wq,
    Wk,
    Wv,
    Wo,
    W1, // gate
    W3, // up
    W2, // down
}

impl LinearKind {
    pub const ALL: [LinearKind; 7] = [
        LinearKind::Wq,
        LinearKind::Wk,
        LinearKind::Wv,
        LinearKind::Wo,
        LinearKind::W1,
        LinearKind::W3,
        LinearKind::W2,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            LinearKind::Wq => "wq",
            LinearKind::Wk => "wk",
            LinearKind::Wv => "wv",
            LinearKind::Wo => "wo",
            LinearKind::W1 => "w1",
            LinearKind::W3 => "w3",
            LinearKind::W2 => "w2",
        }
    }
}

/// One transformer block. Linear weights are `[out, in]` so `y = x Wᵀ`.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub w1: Matrix,
    pub w3: Matrix,
    pub w2: Matrix,
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
}

impl LayerWeights {
    pub fn linear(&self, kind: LinearKind) -> &Matrix {
        match kind {
            LinearKind::Wq => &self.wq,
            LinearKind::Wk => &self.wk,
            LinearKind::Wv => &self.wv,
            LinearKind::Wo => &self.wo,
            LinearKind::W1 => &self.w1,
            LinearKind::W3 => &self.w3,
            LinearKind::W2 => &self.w2,
        }
    }

    pub fn linear_mut(&mut self, kind: LinearKind) -> &mut Matrix {
        match kind {
            LinearKind::Wq => &mut self.wq,
            LinearKind::Wk => &mut self.wk,
            LinearKind::Wv => &mut self.wv,
            LinearKind::Wo => &mut self.wo,
            LinearKind::W1 => &mut self.w1,
            LinearKind::W3 => &mut self.w3,
            LinearKind::W2 => &mut self.w2,
        }
    }
}

/// Full model weights.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub config: ModelConfig,
    /// `[vocab, d_model]` token embedding.
    pub embed: Matrix,
    pub layers: Vec<LayerWeights>,
    pub ln_f: Vec<f32>,
    /// `[vocab, d_model]` untied output head.
    pub head: Matrix,
}

impl ModelWeights {
    /// Scaled-normal init (GPT-2-style: residual projections shrunk by
    /// 1/sqrt(2·n_layers)).
    pub fn init(config: ModelConfig, rng: &mut Rng) -> ModelWeights {
        let d = config.d_model;
        let ffn = config.ffn;
        let std = 0.02f32;
        let resid_std = std / (2.0 * config.n_layers as f32).sqrt();
        let layers = (0..config.n_layers)
            .map(|_| LayerWeights {
                wq: Matrix::randn(d, d, std, rng),
                wk: Matrix::randn(d, d, std, rng),
                wv: Matrix::randn(d, d, std, rng),
                wo: Matrix::randn(d, d, resid_std, rng),
                w1: Matrix::randn(ffn, d, std, rng),
                w3: Matrix::randn(ffn, d, std, rng),
                w2: Matrix::randn(d, ffn, resid_std, rng),
                ln1: vec![1.0; d],
                ln2: vec![1.0; d],
            })
            .collect();
        ModelWeights {
            config,
            embed: Matrix::randn(config.vocab, d, std, rng),
            layers,
            ln_f: vec![1.0; d],
            head: Matrix::randn(config.vocab, d, std, rng),
        }
    }

    /// Iterate `(layer_idx, kind, weight)` over every quantizable linear.
    pub fn linears(&self) -> impl Iterator<Item = (usize, LinearKind, &Matrix)> {
        self.layers.iter().enumerate().flat_map(|(i, l)| {
            LinearKind::ALL.iter().map(move |&k| (i, k, l.linear(k)))
        })
    }

    /// Flat parameter order shared with the JAX side (python/compile/model.py
    /// `PARAM_ORDER`): embed, per-layer [ln1, wq, wk, wv, wo, ln2, w1, w3,
    /// w2], ln_f, head. Returns (name, shape) pairs.
    pub fn param_manifest(config: &ModelConfig) -> Vec<(String, Vec<usize>)> {
        let d = config.d_model;
        let f = config.ffn;
        let v = config.vocab;
        let mut out = vec![("embed".to_string(), vec![v, d])];
        for i in 0..config.n_layers {
            let p = |n: &str| format!("layers.{i}.{n}");
            out.push((p("ln1"), vec![d]));
            out.push((p("wq"), vec![d, d]));
            out.push((p("wk"), vec![d, d]));
            out.push((p("wv"), vec![d, d]));
            out.push((p("wo"), vec![d, d]));
            out.push((p("ln2"), vec![d]));
            out.push((p("w1"), vec![f, d]));
            out.push((p("w3"), vec![f, d]));
            out.push((p("w2"), vec![d, f]));
        }
        out.push(("ln_f".to_string(), vec![d]));
        out.push(("head".to_string(), vec![v, d]));
        out
    }

    /// Flatten into the canonical parameter order (for artifact execution
    /// and checkpointing).
    pub fn flat_params(&self) -> Vec<(String, Vec<usize>, &[f32])> {
        let mut out: Vec<(String, Vec<usize>, &[f32])> = Vec::new();
        out.push((
            "embed".into(),
            vec![self.embed.rows, self.embed.cols],
            &self.embed.data,
        ));
        for (i, l) in self.layers.iter().enumerate() {
            let p = |n: &str| format!("layers.{i}.{n}");
            out.push((p("ln1"), vec![l.ln1.len()], &l.ln1));
            for (n, m) in [("wq", &l.wq), ("wk", &l.wk), ("wv", &l.wv), ("wo", &l.wo)] {
                out.push((p(n), vec![m.rows, m.cols], &m.data));
            }
            out.push((p("ln2"), vec![l.ln2.len()], &l.ln2));
            for (n, m) in [("w1", &l.w1), ("w3", &l.w3), ("w2", &l.w2)] {
                out.push((p(n), vec![m.rows, m.cols], &m.data));
            }
        }
        out.push(("ln_f".into(), vec![self.ln_f.len()], &self.ln_f));
        out.push((
            "head".into(),
            vec![self.head.rows, self.head.cols],
            &self.head.data,
        ));
        out
    }

    /// Rebuild from `(name → data)` in any order. Missing/ill-shaped tensors
    /// are an error.
    pub fn from_named(
        config: ModelConfig,
        mut lookup: impl FnMut(&str, &[usize]) -> crate::Result<Vec<f32>>,
    ) -> crate::Result<ModelWeights> {
        fn get_mat(
            lookup: &mut impl FnMut(&str, &[usize]) -> crate::Result<Vec<f32>>,
            name: &str,
            r: usize,
            c: usize,
        ) -> crate::Result<Matrix> {
            Ok(Matrix::from_vec(r, c, lookup(name, &[r, c])?))
        }
        let d = config.d_model;
        let f = config.ffn;
        let v = config.vocab;
        let embed = get_mat(&mut lookup, "embed", v, d)?;
        let mut layers = Vec::with_capacity(config.n_layers);
        for i in 0..config.n_layers {
            let p = |n: &str| format!("layers.{i}.{n}");
            layers.push(LayerWeights {
                ln1: lookup(&p("ln1"), &[d])?,
                wq: get_mat(&mut lookup, &p("wq"), d, d)?,
                wk: get_mat(&mut lookup, &p("wk"), d, d)?,
                wv: get_mat(&mut lookup, &p("wv"), d, d)?,
                wo: get_mat(&mut lookup, &p("wo"), d, d)?,
                ln2: lookup(&p("ln2"), &[d])?,
                w1: get_mat(&mut lookup, &p("w1"), f, d)?,
                w3: get_mat(&mut lookup, &p("w3"), f, d)?,
                w2: get_mat(&mut lookup, &p("w2"), d, f)?,
            });
        }
        let ln_f = lookup("ln_f", &[d])?;
        let head = get_mat(&mut lookup, "head", v, d)?;
        Ok(ModelWeights { config, embed, layers, ln_f, head })
    }

    pub fn n_params(&self) -> usize {
        self.flat_params().iter().map(|(_, _, d)| d.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Preset;

    #[test]
    fn init_matches_config_count() {
        let cfg = Preset::Tiny.config();
        let mut rng = Rng::new(1);
        let w = ModelWeights::init(cfg, &mut rng);
        assert_eq!(w.n_params(), cfg.n_params());
    }

    #[test]
    fn linears_iterates_7_per_layer() {
        let cfg = Preset::Tiny.config();
        let mut rng = Rng::new(2);
        let w = ModelWeights::init(cfg, &mut rng);
        assert_eq!(w.linears().count(), 7 * cfg.n_layers);
    }

    #[test]
    fn manifest_matches_flat_params() {
        let cfg = Preset::Tiny.config();
        let mut rng = Rng::new(3);
        let w = ModelWeights::init(cfg, &mut rng);
        let manifest = ModelWeights::param_manifest(&cfg);
        let flat = w.flat_params();
        assert_eq!(manifest.len(), flat.len());
        for ((mn, ms), (fname, fshape, fdata)) in manifest.iter().zip(&flat) {
            assert_eq!(mn, fname);
            assert_eq!(ms, fshape);
            assert_eq!(ms.iter().product::<usize>(), fdata.len());
        }
    }

    #[test]
    fn from_named_roundtrip() {
        let cfg = Preset::Tiny.config();
        let mut rng = Rng::new(4);
        let w = ModelWeights::init(cfg, &mut rng);
        let flat: std::collections::BTreeMap<String, Vec<f32>> = w
            .flat_params()
            .into_iter()
            .map(|(n, _, d)| (n, d.to_vec()))
            .collect();
        let w2 = ModelWeights::from_named(cfg, |name, shape| {
            let v = flat.get(name).cloned().ok_or_else(|| anyhow::anyhow!("missing {name}"))?;
            anyhow::ensure!(v.len() == shape.iter().product::<usize>());
            Ok(v)
        })
        .unwrap();
        assert_eq!(w.embed, w2.embed);
        assert_eq!(w.layers[0].w2, w2.layers[0].w2);
        assert_eq!(w.ln_f, w2.ln_f);
    }
}
