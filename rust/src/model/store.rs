//! Checkpoint I/O — a compact self-describing binary container.
//!
//! Layout: magic `TSGO`, u32 version, u32 header length, JSON header
//! (model config + tensor directory with shapes/offsets/encodings), then the
//! raw payload. FP tensors are little-endian f32; quantized tensors store
//! scales, zeros (f32) and the packed u32 words of [`PackedInts`].

use crate::model::config::ModelConfig;
use crate::model::exec::{ExecLayer, ExecModel};
use crate::model::linear::LinearOp;
use crate::model::weights::{LinearKind, ModelWeights};
use crate::quant::format::{PackedInts, QuantizedLinear};
use crate::tensor::Matrix;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"TSGO";
/// v1: fp32 + packed tensors. v2 (this code): packed tensors may carry an
/// act-order `perm` and AWQ `channel_scales` after the qweight rows. v1
/// files remain readable; v2 is written so v1-only readers reject (rather
/// than scramble) act-order/AWQ checkpoints.
const VERSION: u32 = 2;
const MIN_VERSION: u32 = 1;

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn u32s_to_bytes(v: &[u32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn bytes_to_u32s(b: &[u8]) -> Vec<u32> {
    b.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// Bounds-checked payload slice (corrupted/truncated checkpoints must fail
/// with an error, not a panic — see tests/robustness.rs).
fn payload_slice(payload: &[u8], off: usize, len: usize) -> Result<&[u8]> {
    payload
        .get(off..off + len)
        .ok_or_else(|| anyhow::anyhow!(
            "checkpoint truncated: need bytes {off}..{} but payload has {}",
            off + len,
            payload.len()
        ))
}

/// Save FP model weights.
pub fn save_model(path: &Path, w: &ModelWeights) -> Result<()> {
    let mut payload: Vec<u8> = Vec::new();
    let mut dir: Vec<Json> = Vec::new();
    for (name, shape, data) in w.flat_params() {
        dir.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("shape", Json::arr(shape.iter().map(|&s| Json::num(s as f64)))),
            ("offset", Json::num(payload.len() as f64)),
            ("encoding", Json::str("f32")),
        ]));
        payload.extend(f32s_to_bytes(data));
    }
    let header = Json::obj(vec![
        ("config", w.config.to_json()),
        ("tensors", Json::Arr(dir)),
        ("kind", Json::str("fp32")),
    ]);
    write_container(path, &header, &payload)
}

/// Load FP model weights.
pub fn load_model(path: &Path) -> Result<ModelWeights> {
    let (header, payload) = read_container(path)?;
    let config = ModelConfig::from_json(header.get("config"))
        .context("bad config in checkpoint header")?;
    let mut index: BTreeMap<String, (Vec<usize>, usize)> = BTreeMap::new();
    for t in header.get("tensors").as_arr().unwrap_or(&[]) {
        index.insert(
            t.get("name").as_str().unwrap_or("").to_string(),
            (t.get("shape").usize_vec(), t.get("offset").as_usize().unwrap_or(0)),
        );
    }
    ModelWeights::from_named(config, |name, shape| {
        let (s, off) = index
            .get(name)
            .with_context(|| format!("tensor {name} missing from checkpoint"))?;
        if s != shape {
            bail!("tensor {name}: shape {s:?} != expected {shape:?}");
        }
        let n: usize = shape.iter().product();
        Ok(bytes_to_f32s(payload_slice(&payload, *off, 4 * n)?))
    })
}

/// A quantized checkpoint: FP norms/embeddings + quantized linears.
/// Each linear carries its own bits/group (and optional act-order
/// permutation / AWQ channel scales), so heterogeneous mixed-precision
/// plans round-trip through save/load and the runtime/serve paths.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub config: ModelConfig,
    /// Base weights with the linears *dequantized* in place (ready to run).
    pub weights: ModelWeights,
    /// The packed form of every linear, keyed by `(layer, kind)`.
    pub linears: BTreeMap<(usize, &'static str), QuantizedLinear>,
    /// Provenance: registered quantizer name per linear (may be missing for
    /// checkpoints written before it was recorded).
    pub quantizers: BTreeMap<(usize, &'static str), String>,
}

impl QuantizedModel {
    pub fn get(&self, layer: usize, kind: LinearKind) -> Option<&QuantizedLinear> {
        self.linears.get(&(layer, kind.label()))
    }

    /// Total packed payload bytes across linears.
    pub fn packed_bytes(&self) -> usize {
        self.linears.values().map(|q| q.nbytes()).sum()
    }
}

/// Save a quantized model: FP tensors for norms/embed/head, packed tensors
/// for the linears.
pub fn save_quantized(path: &Path, qm: &QuantizedModel) -> Result<()> {
    let mut payload: Vec<u8> = Vec::new();
    let mut dir: Vec<Json> = Vec::new();
    for (name, shape, data) in qm.weights.flat_params() {
        // Linears that have a packed form are stored packed instead.
        let is_packed = name
            .strip_prefix("layers.")
            .and_then(|rest| rest.split_once('.'))
            .map(|(idx, kind)| {
                qm.linears.contains_key(&(
                    idx.parse::<usize>().unwrap_or(usize::MAX),
                    // leak-free static lookup
                    match kind {
                        "wq" => "wq",
                        "wk" => "wk",
                        "wv" => "wv",
                        "wo" => "wo",
                        "w1" => "w1",
                        "w3" => "w3",
                        "w2" => "w2",
                        _ => "",
                    },
                ))
            })
            .unwrap_or(false);
        if is_packed {
            continue;
        }
        dir.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("shape", Json::arr(shape.iter().map(|&s| Json::num(s as f64)))),
            ("offset", Json::num(payload.len() as f64)),
            ("encoding", Json::str("f32")),
        ]));
        payload.extend(f32s_to_bytes(data));
    }
    for ((layer, kind), q) in &qm.linears {
        let name = format!("layers.{layer}.{kind}");
        let off = payload.len();
        payload.extend(f32s_to_bytes(&q.scales.data));
        payload.extend(f32s_to_bytes(&q.zeros.data));
        for row in &q.qweight {
            payload.extend(u32s_to_bytes(&row.words));
        }
        // Optional act-order permutation / AWQ channel divisors follow the
        // packed rows; boolean header fields say whether they are present.
        if let Some(p) = &q.perm {
            payload.extend(u32s_to_bytes(p));
        }
        if let Some(cs) = &q.channel_scales {
            payload.extend(f32s_to_bytes(cs));
        }
        let quantizer = qm
            .quantizers
            .get(&(*layer, *kind))
            .cloned()
            .unwrap_or_default();
        dir.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("shape", Json::arr([q.rows, q.cols].iter().map(|&s| Json::num(s as f64)))),
            ("offset", Json::num(off as f64)),
            ("encoding", Json::str("packed")),
            ("bits", Json::num(q.bits as f64)),
            ("group_size", Json::num(q.group_size as f64)),
            (
                "words_per_row",
                Json::num(q.qweight[0].words.len() as f64),
            ),
            ("perm", Json::Bool(q.perm.is_some())),
            ("channel_scales", Json::Bool(q.channel_scales.is_some())),
            ("quantizer", Json::str(quantizer)),
        ]));
    }
    let header = Json::obj(vec![
        ("config", qm.config.to_json()),
        ("tensors", Json::Arr(dir)),
        ("kind", Json::str("quantized")),
    ]);
    write_container(path, &header, &payload)
}

/// Everything parsed out of a quantized container, before choosing an
/// execution representation (dequantized [`ModelWeights`] vs packed
/// [`ExecModel`]). Every packed linear has passed
/// [`QuantizedLinear::validate`]: truncated packed payloads, non-bijective
/// perms and zero / non-finite channel scales are corrupt-checkpoint errors
/// here, never a panic or NaN weights downstream.
struct QuantizedParts {
    config: ModelConfig,
    fp: BTreeMap<String, (Vec<usize>, usize)>,
    linears: BTreeMap<(usize, &'static str), QuantizedLinear>,
    quantizers: BTreeMap<(usize, &'static str), String>,
    payload: Vec<u8>,
}

impl QuantizedParts {
    /// Fetch + shape-check one FP tensor from the payload.
    fn fp_tensor(&self, name: &str, shape: &[usize]) -> Result<Vec<f32>> {
        let (s, off) = self
            .fp
            .get(name)
            .with_context(|| format!("tensor {name} missing from checkpoint"))?;
        if s != shape {
            bail!("tensor {name}: shape {s:?} != expected {shape:?}");
        }
        let n: usize = shape.iter().product();
        Ok(bytes_to_f32s(payload_slice(&self.payload, *off, 4 * n)?))
    }
}

fn read_quantized_parts(path: &Path) -> Result<QuantizedParts> {
    let (header, payload) = read_container(path)?;
    let config = ModelConfig::from_json(header.get("config"))
        .context("bad config in checkpoint header")?;
    let mut fp: BTreeMap<String, (Vec<usize>, usize)> = BTreeMap::new();
    let mut packed: BTreeMap<String, Json> = BTreeMap::new();
    for t in header.get("tensors").as_arr().unwrap_or(&[]) {
        let name = t.get("name").as_str().unwrap_or("").to_string();
        if t.get("encoding").as_str() == Some("packed") {
            packed.insert(name, t.clone());
        } else {
            fp.insert(
                name,
                (t.get("shape").usize_vec(), t.get("offset").as_usize().unwrap_or(0)),
            );
        }
    }
    let mut linears: BTreeMap<(usize, &'static str), QuantizedLinear> = BTreeMap::new();
    let mut quantizers: BTreeMap<(usize, &'static str), String> = BTreeMap::new();
    for (name, t) in &packed {
        let shape = t.get("shape").usize_vec();
        if shape.len() != 2 {
            bail!("tensor {name}: packed tensors must be 2-D, got {shape:?}");
        }
        let (rows, cols) = (shape[0], shape[1]);
        let bits = t.get("bits").as_usize().context("bits")? as u8;
        let group_size = t.get("group_size").as_usize().context("group_size")?;
        if !matches!(bits, 1..=8) || group_size == 0 || rows == 0 || cols == 0 {
            bail!("tensor {name}: bad packed geometry (bits {bits}, group {group_size}, [{rows}, {cols}])");
        }
        let wpr = t.get("words_per_row").as_usize().context("words_per_row")?;
        // A short word count would make `get`/`unpack` read out of bounds —
        // reject the checkpoint as corrupt instead.
        if wpr != PackedInts::words_needed(cols, bits) {
            bail!(
                "tensor {name}: corrupt packed payload (words_per_row {wpr} != {} for {cols} cols at {bits} bits)",
                PackedInts::words_needed(cols, bits)
            );
        }
        let n_g = cols.div_ceil(group_size);
        let mut off = t.get("offset").as_usize().context("offset")?;
        let scales = Matrix::from_vec(
            rows,
            n_g,
            bytes_to_f32s(payload_slice(&payload, off, 4 * rows * n_g)?),
        );
        off += 4 * rows * n_g;
        let zeros = Matrix::from_vec(
            rows,
            n_g,
            bytes_to_f32s(payload_slice(&payload, off, 4 * rows * n_g)?),
        );
        off += 4 * rows * n_g;
        let mut qweight = Vec::with_capacity(rows);
        for _ in 0..rows {
            let words = bytes_to_u32s(payload_slice(&payload, off, 4 * wpr)?);
            off += 4 * wpr;
            qweight.push(PackedInts { bits, len: cols, words });
        }
        let perm = if t.get("perm").as_bool().unwrap_or(false) {
            let p = bytes_to_u32s(payload_slice(&payload, off, 4 * cols)?);
            off += 4 * cols;
            Some(p)
        } else {
            None
        };
        let channel_scales = if t.get("channel_scales").as_bool().unwrap_or(false) {
            Some(bytes_to_f32s(payload_slice(&payload, off, 4 * cols)?))
        } else {
            None
        };
        let q = QuantizedLinear {
            rows,
            cols,
            bits,
            group_size,
            qweight,
            scales,
            zeros,
            perm,
            channel_scales,
        };
        q.validate().map_err(|e| anyhow::anyhow!("tensor {name}: {e}"))?;
        let (idx, kind) = name
            .strip_prefix("layers.")
            .and_then(|r| r.split_once('.'))
            .context("bad packed tensor name")?;
        let kind_static = LinearKind::ALL
            .iter()
            .find(|k| k.label() == kind)
            .context("unknown linear kind")?
            .label();
        let idx: usize = idx.parse()?;
        if let Some(qname) = t.get("quantizer").as_str() {
            if !qname.is_empty() {
                quantizers.insert((idx, kind_static), qname.to_string());
            }
        }
        linears.insert((idx, kind_static), q);
    }
    Ok(QuantizedParts { config, fp, linears, quantizers, payload })
}

/// Load a quantized model; linears are dequantized into `weights` and the
/// packed forms returned alongside.
pub fn load_quantized(path: &Path) -> Result<QuantizedModel> {
    let parts = read_quantized_parts(path)?;
    let weights = ModelWeights::from_named(parts.config, |name, shape| {
        if parts.fp.contains_key(name) {
            return parts.fp_tensor(name, shape);
        }
        // packed linear: dequantize
        let (idx, kind) = name
            .strip_prefix("layers.")
            .and_then(|r| r.split_once('.'))
            .with_context(|| format!("missing tensor {name}"))?;
        let key = (
            idx.parse::<usize>()?,
            LinearKind::ALL
                .iter()
                .find(|k| k.label() == kind)
                .with_context(|| format!("missing tensor {name}"))?
                .label(),
        );
        let q = parts.linears.get(&key).with_context(|| format!("missing packed {name}"))?;
        if (q.rows, q.cols) != (shape[0], shape[1]) {
            bail!("tensor {name}: packed shape [{}, {}] != expected {shape:?}", q.rows, q.cols);
        }
        Ok(q.dequantize().data)
    })?;
    Ok(QuantizedModel {
        config: parts.config,
        weights,
        linears: parts.linears,
        quantizers: parts.quantizers,
    })
}

/// Load a quantized checkpoint for *packed execution*: every packed linear
/// becomes a [`LinearOp::Packed`] running the fused dequant kernels — no
/// dense weight matrix is ever materialized for them. Linears stored f32
/// (mixed checkpoints) run dense; norms/embedding/head are always FP.
pub fn load_quantized_packed(path: &Path) -> Result<ExecModel> {
    let mut parts = read_quantized_parts(path)?;
    let cfg = parts.config;
    let (d, f, v) = (cfg.d_model, cfg.ffn, cfg.vocab);
    let mat = |parts: &QuantizedParts, name: &str, r: usize, c: usize| -> Result<Matrix> {
        Ok(Matrix::from_vec(r, c, parts.fp_tensor(name, &[r, c])?))
    };
    let embed = mat(&parts, "embed", v, d)?;
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let p = |n: &str| format!("layers.{i}.{n}");
        let mut op = |kind: LinearKind, r: usize, c: usize| -> Result<LinearOp> {
            match parts.linears.remove(&(i, kind.label())) {
                Some(q) => {
                    if (q.rows, q.cols) != (r, c) {
                        bail!(
                            "tensor {}: packed shape [{}, {}] != expected [{r}, {c}]",
                            p(kind.label()),
                            q.rows,
                            q.cols
                        );
                    }
                    Ok(LinearOp::Packed(q))
                }
                None => Ok(LinearOp::Dense(mat(&parts, &p(kind.label()), r, c)?)),
            }
        };
        let wq = op(LinearKind::Wq, d, d)?;
        let wk = op(LinearKind::Wk, d, d)?;
        let wv = op(LinearKind::Wv, d, d)?;
        let wo = op(LinearKind::Wo, d, d)?;
        let w1 = op(LinearKind::W1, f, d)?;
        let w3 = op(LinearKind::W3, f, d)?;
        let w2 = op(LinearKind::W2, d, f)?;
        let ln1 = parts.fp_tensor(&p("ln1"), &[d])?;
        let ln2 = parts.fp_tensor(&p("ln2"), &[d])?;
        layers.push(ExecLayer { wq, wk, wv, wo, w1, w3, w2, ln1, ln2 });
    }
    let ln_f = parts.fp_tensor("ln_f", &[d])?;
    let head = mat(&parts, "head", v, d)?;
    Ok(ExecModel { config: cfg, embed, layers, ln_f, head })
}

fn write_container(path: &Path, header: &Json, payload: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let hbytes = header.to_string().into_bytes();
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(hbytes.len() as u32).to_le_bytes())?;
    f.write_all(&hbytes)?;
    f.write_all(payload)?;
    Ok(())
}

fn read_container(path: &Path) -> Result<(Json, Vec<u8>)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open checkpoint {}", path.display()))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a TSGO checkpoint");
    }
    let mut word = [0u8; 4];
    f.read_exact(&mut word)?;
    let version = u32::from_le_bytes(word);
    if !(MIN_VERSION..=VERSION).contains(&version) {
        bail!("unsupported checkpoint version {version} (supported: {MIN_VERSION}..={VERSION})");
    }
    f.read_exact(&mut word)?;
    let hlen = u32::from_le_bytes(word) as usize;
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow::anyhow!("header parse: {e}"))?;
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;
    Ok((header, payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Preset;
    use crate::quant::scale::{compute_group_scales, QuantSpec, ScaleMetric};
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("tsgo_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn fp_roundtrip() {
        let mut rng = Rng::new(1);
        let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
        let p = tmp("fp.tsr");
        save_model(&p, &w).unwrap();
        let w2 = load_model(&p).unwrap();
        assert_eq!(w.config, w2.config);
        assert_eq!(w.embed, w2.embed);
        assert_eq!(w.layers[1].w2, w2.layers[1].w2);
        assert_eq!(w.ln_f, w2.ln_f);
    }

    #[test]
    fn quantized_roundtrip() {
        let mut rng = Rng::new(2);
        let cfg = Preset::Tiny.config();
        let w = ModelWeights::init(cfg, &mut rng);
        let spec = QuantSpec::new(2, 32);
        // quantize every linear with RTN to build a QuantizedModel
        let mut weights = w.clone();
        let mut linears = BTreeMap::new();
        let mut quantizers = BTreeMap::new();
        for li in 0..cfg.n_layers {
            for kind in LinearKind::ALL {
                let m = w.layers[li].linear(kind).clone();
                let scales = compute_group_scales(&m, &spec, ScaleMetric::L2, None);
                let q = crate::quant::rtn::rtn_quantize(&m, &scales, &spec);
                *weights.layers[li].linear_mut(kind) = q.dequantize();
                linears.insert((li, kind.label()), q);
                quantizers.insert((li, kind.label()), "rtn".to_string());
            }
        }
        let qm = QuantizedModel { config: cfg, weights, linears, quantizers };
        let p = tmp("quant.tsr");
        save_quantized(&p, &qm).unwrap();
        let qm2 = load_quantized(&p).unwrap();
        assert_eq!(qm2.config, cfg);
        assert_eq!(qm2.quantizers, qm.quantizers, "quantizer provenance must round-trip");
        // dequantized weights must match exactly
        for li in 0..cfg.n_layers {
            for kind in LinearKind::ALL {
                let a = qm.weights.layers[li].linear(kind);
                let b = qm2.weights.layers[li].linear(kind);
                assert_eq!(a, b, "layer {li} {}", kind.label());
            }
        }
        // packed payload is much smaller than fp32 would be
        let fp_bytes: usize =
            qm.linears.values().map(|q| q.rows * q.cols * 4).sum();
        // 2-bit + per-group overhead at group 32 ⇒ ~4 bits/weight ⇒ ≥6×.
        assert!(
            qm2.packed_bytes() * 6 <= fp_bytes,
            "2-bit payload should be ≥6x smaller: {} vs {}",
            qm2.packed_bytes(),
            fp_bytes
        );
    }

    #[test]
    fn heterogeneous_checkpoint_roundtrips_perm_and_channel_scales() {
        // Mixed bits/methods in one checkpoint: wq via act-order (perm),
        // w1 via AWQ (channel scales), everything else plain RTN at a
        // different bit width — all must round-trip exactly.
        let mut rng = Rng::new(7);
        let cfg = Preset::Tiny.config();
        let w = ModelWeights::init(cfg, &mut rng);
        let mut weights = w.clone();
        let mut linears = BTreeMap::new();
        let mut quantizers = BTreeMap::new();
        for li in 0..cfg.n_layers {
            for kind in LinearKind::ALL {
                let m = w.layers[li].linear(kind).clone();
                let x = Matrix::randn(m.cols, 2 * m.cols, 1.0, &mut rng);
                let h = x.matmul_bt(&x);
                let (q, name) = match kind {
                    LinearKind::Wq => {
                        let spec = QuantSpec::new(4, 32);
                        let pq = crate::quant::actorder::gptq_quantize_actorder(
                            &m,
                            &h,
                            &spec,
                            ScaleMetric::L2,
                            &crate::quant::GptqConfig::default(),
                        )
                        .unwrap();
                        (pq.into_quantized_linear(), "actorder")
                    }
                    LinearKind::W1 => {
                        let spec = QuantSpec::new(4, 32);
                        let aq = crate::quant::awq::awq_quantize(&m, &h, &spec);
                        (aq.into_quantized_linear(), "awq")
                    }
                    _ => {
                        let spec = QuantSpec::new(2, 32);
                        let scales = compute_group_scales(&m, &spec, ScaleMetric::L2, None);
                        (crate::quant::rtn::rtn_quantize(&m, &scales, &spec), "rtn")
                    }
                };
                *weights.layers[li].linear_mut(kind) = q.dequantize();
                linears.insert((li, kind.label()), q);
                quantizers.insert((li, kind.label()), name.to_string());
            }
        }
        let qm = QuantizedModel { config: cfg, weights, linears, quantizers };
        let p = tmp("hetero.tsr");
        save_quantized(&p, &qm).unwrap();
        let qm2 = load_quantized(&p).unwrap();
        for li in 0..cfg.n_layers {
            // per-linear spec + metadata survive
            let wq = &qm2.linears[&(li, "wq")];
            assert_eq!(wq.bits, 4);
            assert!(wq.perm.is_some(), "act-order perm must round-trip");
            let w1 = &qm2.linears[&(li, "w1")];
            assert!(w1.channel_scales.is_some(), "awq channel scales must round-trip");
            assert_eq!(qm2.linears[&(li, "wo")].bits, 2);
            // dequantized weights identical
            for kind in LinearKind::ALL {
                assert_eq!(
                    qm.weights.layers[li].linear(kind),
                    qm2.weights.layers[li].linear(kind),
                    "layer {li} {}",
                    kind.label()
                );
            }
        }
        assert_eq!(qm2.quantizers, qm.quantizers);
    }

    #[test]
    fn corrupted_perm_and_channel_scales_error_not_panic() {
        let mut rng = Rng::new(9);
        let cfg = Preset::Tiny.config();
        let w = ModelWeights::init(cfg, &mut rng);
        let spec = QuantSpec::new(2, 32);
        let build = |mangle: &dyn Fn(&mut crate::quant::QuantizedLinear)| {
            let mut weights = w.clone();
            let mut linears = BTreeMap::new();
            for li in 0..cfg.n_layers {
                for kind in LinearKind::ALL {
                    let m = w.layers[li].linear(kind).clone();
                    let scales = compute_group_scales(&m, &spec, ScaleMetric::L2, None);
                    let mut q = crate::quant::rtn::rtn_quantize(&m, &scales, &spec);
                    // splice dense weights first: the mangled metadata is
                    // meant to be caught by load, not dequantized here
                    *weights.layers[li].linear_mut(kind) = q.dequantize();
                    if li == 0 && kind == LinearKind::Wq {
                        mangle(&mut q);
                    }
                    linears.insert((li, kind.label()), q);
                }
            }
            QuantizedModel { config: cfg, weights, linears, quantizers: BTreeMap::new() }
        };
        // out-of-range perm entry
        let qm = build(&|q| q.perm = Some(vec![q.cols as u32; q.cols]));
        let p = tmp("bad_perm.tsr");
        save_quantized(&p, &qm).unwrap();
        let err = load_quantized(&p).unwrap_err().to_string();
        assert!(err.contains("perm entry out of range"), "{err}");
        // zero channel divisor
        let qm = build(&|q| q.channel_scales = Some(vec![0.0; q.cols]));
        let p = tmp("bad_cs.tsr");
        save_quantized(&p, &qm).unwrap();
        let err = load_quantized(&p).unwrap_err().to_string();
        assert!(err.contains("channel scale"), "{err}");
        // truncated packed words (words_per_row no longer covers cols·bits):
        // both load paths must reject it as corrupt, not panic in get/unpack
        let qm = build(&|q| {
            for row in &mut q.qweight {
                row.words.pop();
            }
        });
        let p = tmp("bad_words.tsr");
        save_quantized(&p, &qm).unwrap();
        let err = load_quantized(&p).unwrap_err().to_string();
        assert!(err.contains("corrupt packed payload"), "{err}");
        let err = load_quantized_packed(&p).unwrap_err().to_string();
        assert!(err.contains("corrupt packed payload"), "{err}");
    }

    #[test]
    fn packed_load_matches_dense_dequant_load() {
        // The --packed load path must produce the same model function as the
        // dequantize-at-load path, without materializing dense linears.
        let mut rng = Rng::new(21);
        let cfg = Preset::Tiny.config();
        let w = ModelWeights::init(cfg, &mut rng);
        let spec = QuantSpec::new(4, 32);
        let mut weights = w.clone();
        let mut linears = BTreeMap::new();
        for li in 0..cfg.n_layers {
            for kind in LinearKind::ALL {
                let m = w.layers[li].linear(kind).clone();
                let scales = compute_group_scales(&m, &spec, ScaleMetric::L2, None);
                let q = crate::quant::rtn::rtn_quantize(&m, &scales, &spec);
                *weights.layers[li].linear_mut(kind) = q.dequantize();
                linears.insert((li, kind.label()), q);
            }
        }
        let qm = QuantizedModel { config: cfg, weights, linears, quantizers: BTreeMap::new() };
        let p = tmp("packed_exec.tsr");
        save_quantized(&p, &qm).unwrap();

        let dense = load_quantized(&p).unwrap();
        let packed = load_quantized_packed(&p).unwrap();
        assert_eq!(packed.packed_linears(), 7 * cfg.n_layers);
        let tokens: Vec<u8> = (0..10).map(|i| i * 23).collect();
        let a = crate::model::forward_logits(&dense.weights, &tokens);
        let b = crate::model::forward_logits(&packed, &tokens);
        let scale = a.data.iter().fold(1.0f32, |m, v| m.max(v.abs()));
        assert!(
            a.max_abs_diff(&b) < 1e-3 * scale,
            "packed exec diverged: {}",
            a.max_abs_diff(&b)
        );
    }

    #[test]
    fn rejects_garbage_file() {
        let p = tmp("garbage.tsr");
        std::fs::write(&p, b"NOTATSGOFILE").unwrap();
        assert!(load_model(&p).is_err());
    }

    #[test]
    fn missing_file_errors() {
        assert!(load_model(Path::new("/nonexistent/x.tsr")).is_err());
    }
}
