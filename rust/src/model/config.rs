//! Model configuration and presets.

use crate::util::json::Json;

/// Llamette hyper-parameters. All linear dimensions are multiples of 64 so
/// the paper's group sizes (32, 64) tile exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn: usize,
    /// Training / evaluation context length.
    pub seq_len: usize,
}

/// Named size presets (see DESIGN.md §6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    /// ~0.2 M params — unit/integration tests.
    Tiny,
    /// ~3.4 M params — default for examples and table benches.
    Small,
    /// ~19 M params — larger table runs and perf work.
    Base,
}

impl Preset {
    pub fn parse(s: &str) -> Option<Preset> {
        match s {
            "tiny" => Some(Preset::Tiny),
            "small" => Some(Preset::Small),
            "base" => Some(Preset::Base),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Preset::Tiny => "tiny",
            Preset::Small => "small",
            Preset::Base => "base",
        }
    }

    pub fn config(&self) -> ModelConfig {
        match self {
            Preset::Tiny => ModelConfig {
                vocab: 256,
                d_model: 64,
                n_layers: 2,
                n_heads: 2,
                ffn: 128,
                seq_len: 64,
            },
            Preset::Small => ModelConfig {
                vocab: 256,
                d_model: 256,
                n_layers: 4,
                n_heads: 4,
                ffn: 704,
                seq_len: 128,
            },
            Preset::Base => ModelConfig {
                vocab: 256,
                d_model: 512,
                n_layers: 6,
                n_heads: 8,
                ffn: 1408,
                seq_len: 128,
            },
        }
    }
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        let attn = 4 * self.d_model * self.d_model;
        let mlp = 3 * self.d_model * self.ffn;
        let norms = 2 * self.d_model;
        self.vocab * self.d_model * 2 // embed + untied head
            + self.n_layers * (attn + mlp + norms)
            + self.d_model // final norm
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vocab", Json::num(self.vocab as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("ffn", Json::num(self.ffn as f64)),
            ("seq_len", Json::num(self.seq_len as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ModelConfig> {
        Some(ModelConfig {
            vocab: j.get("vocab").as_usize()?,
            d_model: j.get("d_model").as_usize()?,
            n_layers: j.get("n_layers").as_usize()?,
            n_heads: j.get("n_heads").as_usize()?,
            ffn: j.get("ffn").as_usize()?,
            seq_len: j.get("seq_len").as_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse() {
        for p in [Preset::Tiny, Preset::Small, Preset::Base] {
            assert_eq!(Preset::parse(p.label()), Some(p));
        }
        assert_eq!(Preset::parse("huge"), None);
    }

    #[test]
    fn dims_are_group_aligned() {
        for p in [Preset::Tiny, Preset::Small, Preset::Base] {
            let c = p.config();
            assert_eq!(c.d_model % 64, 0, "{p:?}");
            assert_eq!(c.ffn % 64, 0, "{p:?}");
            assert_eq!(c.d_model % c.n_heads, 0, "{p:?}");
        }
    }

    #[test]
    fn param_counts_in_expected_band() {
        assert!(Preset::Tiny.config().n_params() < 500_000);
        let small = Preset::Small.config().n_params();
        assert!((3_000_000..5_000_000).contains(&small), "small={small}");
        let base = Preset::Base.config().n_params();
        assert!((15_000_000..30_000_000).contains(&base), "base={base}");
    }

    #[test]
    fn json_roundtrip() {
        let c = Preset::Small.config();
        let j = c.to_json();
        assert_eq!(ModelConfig::from_json(&j), Some(c));
    }
}
