//! The execution interface every model backend plugs into.
//!
//! [`LinearOp`] is one linear projection in whichever representation it is
//! deployed — dense f32 or packed group-quantized ints executed by the
//! fused dequant kernels. [`BlockLinears`] / [`ModelExec`] abstract one
//! transformer block / a whole model over that choice, so the forward pass,
//! KV-cached decoding, the serve batcher and the eval harness are written
//! once and run on either representation. The SIMD unpack tables (PR 3) and
//! the layer-sharded pipeline topology (`crate::shard`, PR 5) both slot in
//! behind these same two traits — the byte-accounting methods below are
//! what the shard planner balances ranges with.

use super::config::ModelConfig;
use super::weights::{LayerWeights, LinearKind, ModelWeights};
use crate::quant::format::QuantizedLinear;
use crate::tensor::Matrix;

/// One linear projection (`y = x Wᵀ`), dense or packed.
#[derive(Clone, Debug)]
pub enum LinearOp {
    /// Dense f32 `[out, in]` weights.
    Dense(Matrix),
    /// Packed group-quantized weights executed by the fused dequant GEMV.
    Packed(QuantizedLinear),
}

impl LinearOp {
    /// Output dimension (rows of W).
    pub fn out_dim(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.rows,
            LinearOp::Packed(q) => q.rows,
        }
    }

    /// Input dimension (cols of W).
    pub fn in_dim(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.cols,
            LinearOp::Packed(q) => q.cols,
        }
    }

    pub fn is_packed(&self) -> bool {
        matches!(self, LinearOp::Packed(_))
    }

    /// `x @ Wᵀ` — dense GEMM or fused group-wise dequant GEMM.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        match self {
            LinearOp::Dense(w) => x.matmul_bt(w),
            LinearOp::Packed(q) => q.forward(x),
        }
    }

    /// Weight bytes read per full application — the memory-bandwidth number
    /// the packed path exists to shrink.
    pub fn weight_bytes(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.data.len() * 4,
            LinearOp::Packed(q) => q.nbytes(),
        }
    }
}

/// One transformer block's quantizable pieces, representation-agnostic.
pub trait BlockLinears: Sync {
    fn ln1(&self) -> &[f32];
    fn ln2(&self) -> &[f32];
    /// Apply projection `kind`: `x @ W_kindᵀ`.
    fn apply(&self, kind: LinearKind, x: &Matrix) -> Matrix;
    /// Bytes this block's deployed weights occupy (linears in their stored
    /// representation plus the two norm gains) — what the shard planner
    /// balances contiguous layer ranges by, so a mixed-precision checkpoint
    /// shards by its *actual* per-layer footprint, not the layer count.
    fn weight_bytes(&self) -> usize;
}

impl BlockLinears for LayerWeights {
    fn ln1(&self) -> &[f32] {
        &self.ln1
    }

    fn ln2(&self) -> &[f32] {
        &self.ln2
    }

    fn apply(&self, kind: LinearKind, x: &Matrix) -> Matrix {
        x.matmul_bt(self.linear(kind))
    }

    fn weight_bytes(&self) -> usize {
        let linears: usize =
            LinearKind::ALL.iter().map(|&k| self.linear(k).data.len() * 4).sum();
        linears + (self.ln1.len() + self.ln2.len()) * 4
    }
}

/// A whole executable model: embedding + blocks + final norm + LM head.
/// Implemented by the dense [`ModelWeights`], the packed-capable
/// [`super::ExecModel`], and the plan-carrying
/// [`crate::shard::ShardedModel`]; the forward pass, [`super::DecodeState`],
/// the serve batcher and eval are generic over it.
pub trait ModelExec: Sync {
    type Layer: BlockLinears;

    fn config(&self) -> &ModelConfig;
    /// Embedding row for one token id.
    fn embed_row(&self, token: u8) -> &[f32];
    fn layers(&self) -> &[Self::Layer];
    fn ln_f(&self) -> &[f32];
    /// LM head: `x @ W_headᵀ` → `[T, vocab]`.
    fn apply_head(&self, x: &Matrix) -> Matrix;
    /// Bytes of the token-embedding table. The shard planner charges these
    /// to the **first** pipeline shard, which owns embedding lookup.
    fn embed_bytes(&self) -> usize;
    /// Bytes of the final norm + untied LM head, charged to the **last**
    /// pipeline shard, which owns logit production.
    fn head_bytes(&self) -> usize;
}

impl ModelExec for ModelWeights {
    type Layer = LayerWeights;

    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn embed_row(&self, token: u8) -> &[f32] {
        self.embed.row(token as usize)
    }

    fn layers(&self) -> &[LayerWeights] {
        &self.layers
    }

    fn ln_f(&self) -> &[f32] {
        &self.ln_f
    }

    fn apply_head(&self, x: &Matrix) -> Matrix {
        x.matmul_bt(&self.head)
    }

    fn embed_bytes(&self) -> usize {
        self.embed.data.len() * 4
    }

    fn head_bytes(&self) -> usize {
        (self.head.data.len() + self.ln_f.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn_quantize;
    use crate::quant::scale::{compute_group_scales, QuantSpec, ScaleMetric};
    use crate::util::rng::Rng;

    #[test]
    fn dense_and_packed_ops_agree() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(24, 32, 1.0, &mut rng);
        let spec = QuantSpec::new(8, 16);
        let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
        let q = rtn_quantize(&w, &scales, &spec);
        let dense = LinearOp::Dense(q.dequantize());
        let packed = LinearOp::Packed(q);
        assert_eq!(dense.out_dim(), packed.out_dim());
        assert_eq!(dense.in_dim(), packed.in_dim());
        assert!(!dense.is_packed() && packed.is_packed());
        assert!(packed.weight_bytes() < dense.weight_bytes());
        let x = Matrix::randn(3, 32, 1.0, &mut rng);
        let a = dense.forward(&x);
        let b = packed.forward(&x);
        assert!(a.max_abs_diff(&b) < 1e-4, "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn byte_accounting_matches_shapes() {
        // The shard planner's inputs derive from the actual tensor shapes.
        let mut rng = Rng::new(2);
        let cfg = crate::model::Preset::Tiny.config();
        let w = ModelWeights::init(cfg, &mut rng);
        let per_layer =
            (4 * cfg.d_model * cfg.d_model + 3 * cfg.d_model * cfg.ffn + 2 * cfg.d_model) * 4;
        assert_eq!(w.layers[0].weight_bytes(), per_layer);
        assert_eq!(w.embed_bytes(), cfg.vocab * cfg.d_model * 4);
        assert_eq!(w.head_bytes(), (cfg.vocab * cfg.d_model + cfg.d_model) * 4);
    }
}
