//! Native Rust forward pass (mirror of `python/compile/model.py`).
//!
//! Three entry points:
//! * [`forward_logits`] — full-sequence logits, used as the runtime fallback
//!   for perplexity evaluation and by tests that cross-check the HLO
//!   artifact;
//! * [`forward_captures`] — the same pass but recording the inputs of every
//!   linear projection (what the quantization pipeline accumulates
//!   Hessians from);
//! * [`DecodeState`] — incremental KV-cached decoding for the serve path.
//!
//! All three are generic over the [`ModelExec`] / [`BlockLinears`]
//! execution traits, so the identical code path runs dense f32 weights
//! ([`ModelWeights`]) or packed group-quantized ints through the fused
//! dequant kernels ([`super::ExecModel`]).
//!
//! Numerics must match the JAX model: RMSNorm ε = 1e-5, rotary embeddings
//! over pairs `(x[2i], x[2i+1])` with base 10000, pre-norm residual blocks.

use super::config::ModelConfig;
use super::kvcache::{KvSpec, LayerKv};
use super::linear::{BlockLinears, ModelExec};
use super::weights::{LinearKind, ModelWeights};
use crate::tensor::Matrix;

const RMS_EPS: f32 = 1e-5;
const ROPE_BASE: f32 = 10_000.0;

/// RMSNorm over the last axis of `[T, d]`.
fn rmsnorm(x: &Matrix, gain: &[f32]) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for t in 0..x.rows {
        let row = x.row(t);
        let ms: f64 =
            row.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / x.cols as f64;
        let inv = 1.0 / (ms + RMS_EPS as f64).sqrt() as f32;
        let orow = out.row_mut(t);
        for c in 0..x.cols {
            orow[c] = row[c] * inv * gain[c];
        }
    }
    out
}

/// Apply rotary embeddings in place to `[T, d]` laid out as heads of
/// `head_dim`, rotating pairs `(2i, 2i+1)` at angle `pos · base^(−2i/hd)`.
fn rope_inplace(x: &mut Matrix, n_heads: usize, pos_offset: usize) {
    let d = x.cols;
    let hd = d / n_heads;
    for t in 0..x.rows {
        let pos = (pos_offset + t) as f32;
        let row = x.row_mut(t);
        for h in 0..n_heads {
            let base = h * hd;
            for i in 0..hd / 2 {
                let theta = pos / ROPE_BASE.powf(2.0 * i as f32 / hd as f32);
                let (sin, cos) = theta.sin_cos();
                let a = row[base + 2 * i];
                let b = row[base + 2 * i + 1];
                row[base + 2 * i] = a * cos - b * sin;
                row[base + 2 * i + 1] = a * sin + b * cos;
            }
        }
    }
}

/// Causal multi-head attention over full sequences.
/// `q, k, v` are `[T, d]`; returns the pre-`wo` context `[T, d]`.
fn attention(q: &Matrix, k: &Matrix, v: &Matrix, n_heads: usize) -> Matrix {
    let t_len = q.rows;
    let d = q.cols;
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = Matrix::zeros(t_len, d);
    for h in 0..n_heads {
        let base = h * hd;
        for tq in 0..t_len {
            // scores over keys 0..=tq
            let qrow = &q.row(tq)[base..base + hd];
            let mut scores = Vec::with_capacity(tq + 1);
            let mut maxs = f32::NEG_INFINITY;
            for tk in 0..=tq {
                let krow = &k.row(tk)[base..base + hd];
                let s = crate::tensor::matrix::dot(qrow, krow) * scale;
                maxs = maxs.max(s);
                scores.push(s);
            }
            let mut denom = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - maxs).exp();
                denom += *s;
            }
            let crow = ctx.row_mut(tq);
            for (tk, p) in scores.iter().enumerate() {
                let w = p / denom;
                let vrow = &v.row(tk)[base..base + hd];
                for i in 0..hd {
                    crow[base + i] += w * vrow[i];
                }
            }
        }
    }
    ctx
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Inputs of every linear projection in one block, laid out `[T, in]`.
/// These are the `X` matrices the paper's Hessians `E[XXᵀ]` are built from.
#[derive(Clone, Debug)]
pub struct LayerCaptures {
    /// Input to wq/wk/wv (post-ln1).
    pub x_attn: Matrix,
    /// Input to wo (attention context).
    pub x_wo: Matrix,
    /// Input to w1/w3 (post-ln2).
    pub x_mlp: Matrix,
    /// Input to w2 (SwiGLU activations).
    pub x_w2: Matrix,
}

/// One block over any representation. Returns the new hidden state;
/// optionally records captures. Public so the quantization pipeline can
/// advance per-layer running hidden states (O(L) total blocks instead of
/// O(L²) full forwards).
pub fn block_forward<L: BlockLinears + ?Sized>(
    l: &L,
    h: &Matrix,
    n_heads: usize,
    captures: Option<&mut LayerCaptures>,
) -> Matrix {
    let x_attn = rmsnorm(h, l.ln1());
    let mut q = l.apply(LinearKind::Wq, &x_attn);
    let mut k = l.apply(LinearKind::Wk, &x_attn);
    let v = l.apply(LinearKind::Wv, &x_attn);
    rope_inplace(&mut q, n_heads, 0);
    rope_inplace(&mut k, n_heads, 0);
    let ctx = attention(&q, &k, &v, n_heads);
    let attn_out = l.apply(LinearKind::Wo, &ctx);
    let mut h1 = h.clone();
    h1.add_inplace(&attn_out);

    let x_mlp = rmsnorm(&h1, l.ln2());
    let gate = l.apply(LinearKind::W1, &x_mlp);
    let up = l.apply(LinearKind::W3, &x_mlp);
    let mut act = Matrix::zeros(gate.rows, gate.cols);
    for i in 0..gate.data.len() {
        act.data[i] = silu(gate.data[i]) * up.data[i];
    }
    let down = l.apply(LinearKind::W2, &act);
    let mut h2 = h1;
    h2.add_inplace(&down);

    if let Some(cap) = captures {
        *cap = LayerCaptures { x_attn, x_wo: ctx, x_mlp, x_w2: act };
    }
    h2
}

pub fn embed_tokens<M: ModelExec>(m: &M, tokens: &[u8]) -> Matrix {
    let d = m.config().d_model;
    let mut h = Matrix::zeros(tokens.len(), d);
    for (t, &tok) in tokens.iter().enumerate() {
        h.row_mut(t).copy_from_slice(m.embed_row(tok));
    }
    h
}

/// Full-sequence forward: `tokens` → logits `[T, vocab]`.
pub fn forward_logits<M: ModelExec>(m: &M, tokens: &[u8]) -> Matrix {
    let mut h = embed_tokens(m, tokens);
    let n_heads = m.config().n_heads;
    for l in m.layers() {
        h = block_forward(l, &h, n_heads, None);
    }
    let f = rmsnorm(&h, m.ln_f());
    m.apply_head(&f)
}

/// Forward with per-layer linear-input capture (for Hessian accumulation).
pub fn forward_captures(w: &ModelWeights, tokens: &[u8]) -> (Matrix, Vec<LayerCaptures>) {
    let mut h = embed_tokens(w, tokens);
    let mut caps = Vec::with_capacity(w.layers.len());
    for l in &w.layers {
        let mut c = LayerCaptures {
            x_attn: Matrix::zeros(0, 0),
            x_wo: Matrix::zeros(0, 0),
            x_mlp: Matrix::zeros(0, 0),
            x_w2: Matrix::zeros(0, 0),
        };
        h = block_forward(l, &h, w.config.n_heads, Some(&mut c));
        caps.push(c);
    }
    let f = rmsnorm(&h, &w.ln_f);
    (f.matmul_bt(&w.head), caps)
}

/// Mean cross-entropy of next-token prediction over a sequence.
pub fn sequence_nll<M: ModelExec>(m: &M, tokens: &[u8]) -> f64 {
    let logits = forward_logits(m, tokens);
    let mut total = 0.0f64;
    let n = tokens.len() - 1;
    for t in 0..n {
        let row = logits.row(t);
        let target = tokens[t + 1] as usize;
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f64 = row.iter().map(|v| ((v - maxv) as f64).exp()).sum::<f64>().ln()
            + maxv as f64;
        total += lse - row[target] as f64;
    }
    total / n as f64
}

/// One transformer block's KV-cached decode step over a **span** of `T`
/// positions: append the span's K/V rows to the layer's cache and advance
/// the `[T, d_model]` hidden block in place. `pos` is the chain position of
/// the span's first row (== cached rows before this call).
///
/// This is the per-layer core of [`DecodeState::step_span`] and the chunked
/// prefill path: the span's Q/K/V come from **one** batched GEMM per
/// projection (each output row of the tiled packed GEMM / dense `matmul_bt`
/// is an independent fixed-order dot, so a T-row apply is bitwise equal to
/// T one-row applies), and attention then runs row by row in the exact op
/// order of the historical one-token step, with span row `t` attending to
/// cached rows `0..pos+t+1` via the `_limit` attend primitives. The
/// one-token [`decode_layer_step`] is a T=1 wrapper around this function,
/// so chunked and token-at-a-time execution cannot diverge structurally —
/// the bit-identity guarantee across `--shards N`, kernel tables, and
/// prefill chunk sizes is shared code, not tested-in.
pub fn decode_layer_span<L: BlockLinears + ?Sized>(
    l: &L,
    cfg: &ModelConfig,
    pos: usize,
    h: &mut Matrix,
    kv: &mut LayerKv,
) {
    let t_len = h.rows;
    let d = cfg.d_model;
    let n_heads = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    debug_assert_eq!(h.cols, d);
    debug_assert_eq!(kv.rows(), pos, "span must start where the cache ends");

    let xa = rmsnorm(h, l.ln1());
    let mut q = l.apply(LinearKind::Wq, &xa);
    let mut k = l.apply(LinearKind::Wk, &xa);
    let v = l.apply(LinearKind::Wv, &xa);
    rope_inplace(&mut q, n_heads, pos);
    rope_inplace(&mut k, n_heads, pos);

    // append the whole span to the cache (quantizing on the fly when
    // packed) before attending: row t then masks itself to `pos + t + 1`.
    kv.append_span(&k, &v);

    // attention against the cache, row by row and head by head: fused
    // dequant scores + softmax + fused dequant probs·V accumulation — the
    // same per-row sequence the one-token step always ran.
    let mut ctx = Matrix::zeros(t_len, d);
    let mut scores: Vec<f32> = Vec::with_capacity(kv.k.rows());
    for t in 0..t_len {
        let limit = pos + t + 1;
        for hh in 0..n_heads {
            let base = hh * hd;
            kv.k.head_scores_limit(hh, q.row(t), scale, limit, &mut scores);
            let mut maxs = f32::NEG_INFINITY;
            for &s in scores.iter() {
                maxs = maxs.max(s);
            }
            let mut denom = 0.0;
            for s in scores.iter_mut() {
                *s = (*s - maxs).exp();
                denom += *s;
            }
            for s in scores.iter_mut() {
                *s /= denom;
            }
            kv.v.head_axpy_limit(hh, &scores, limit, &mut ctx.row_mut(t)[base..base + hd]);
        }
    }
    let attn_out = l.apply(LinearKind::Wo, &ctx);
    h.add_inplace(&attn_out);

    let xm = rmsnorm(h, l.ln2());
    let gate = l.apply(LinearKind::W1, &xm);
    let up = l.apply(LinearKind::W3, &xm);
    let mut act = Matrix::zeros(gate.rows, gate.cols);
    for i in 0..act.data.len() {
        act.data[i] = silu(gate.data[i]) * up.data[i];
    }
    let down = l.apply(LinearKind::W2, &act);
    h.add_inplace(&down);
}

/// One transformer block's KV-cached decode step for a single position —
/// the T=1 span (see [`decode_layer_span`]; kept because the hidden state
/// of a one-token step is naturally a `[d_model]` slice, and as the
/// historical contract the span refactor is measured against).
pub fn decode_layer_step<L: BlockLinears + ?Sized>(
    l: &L,
    cfg: &ModelConfig,
    pos: usize,
    h: &mut [f32],
    kv: &mut LayerKv,
) {
    let mut hx = Matrix::from_vec(1, cfg.d_model, h.to_vec());
    decode_layer_span(l, cfg, pos, &mut hx, kv);
    h.copy_from_slice(&hx.data);
}

/// Final norm + LM head over a `[T, d_model]` span of hidden states —
/// returns `[T, vocab]` logits. Row-wise rmsnorm and a row-independent head
/// GEMM, so row `t` equals what a one-position [`decode_head`] of that row
/// would produce.
pub fn decode_head_span<M: ModelExec>(m: &M, h: &Matrix) -> Matrix {
    let f = rmsnorm(h, m.ln_f());
    m.apply_head(&f)
}

/// Final norm + LM head for one decoded position — the tail of
/// [`DecodeState::step`], shared with the *last* pipeline shard (which owns
/// the head, per the shard plan). Serving feeds only a span's last row
/// through this: prefill logits at other rows are never sampled.
pub fn decode_head<M: ModelExec>(m: &M, h: Vec<f32>) -> Vec<f32> {
    let hx = Matrix::from_vec(1, m.config().d_model, h);
    decode_head_span(m, &hx).data
}

/// Incremental KV-cached decoding state for one sequence (serve path),
/// generic over the execution representation — the packed serve path runs
/// exactly this code with fused dequant GEMVs behind [`BlockLinears`].
///
/// The K/V caches themselves are representation-pluggable too
/// ([`KvSpec`]): the default [`KvSpec::DenseF32`] keeps f32 rows
/// (bit-identical to the historical decode path), while
/// [`KvSpec::PackedGroupwise`] RTN-quantizes appended rows with per-head
/// group-wise scales and attends straight from the packed words
/// (`tsgo serve --kv-bits 8 --kv-group 64`).
pub struct DecodeState<'a, M: ModelExec> {
    model: &'a M,
    /// Per layer: cached K and V rows in the configured representation.
    kv: Vec<LayerKv>,
    spec: KvSpec,
    pub pos: usize,
}

impl<'a, M: ModelExec> DecodeState<'a, M> {
    pub fn new(model: &'a M) -> DecodeState<'a, M> {
        Self::with_kv(model, KvSpec::DenseF32)
    }

    /// Decode with an explicit KV-cache representation.
    pub fn with_kv(model: &'a M, spec: KvSpec) -> DecodeState<'a, M> {
        Self::with_kv_pool(model, spec, None)
    }

    /// Decode drawing KV pages from a budget-bounded pool when one is given
    /// (paged caches, bit-identical to the contiguous ones), contiguous
    /// otherwise.
    pub fn with_kv_pool(
        model: &'a M,
        spec: KvSpec,
        pool: Option<&crate::kvpool::KvPool>,
    ) -> DecodeState<'a, M> {
        let cfg = model.config();
        let n = cfg.n_layers;
        // Store and report the *effective* spec (group clamped to head_dim).
        let spec = spec.effective(cfg);
        DecodeState {
            model,
            kv: (0..n).map(|_| LayerKv::new_in(spec, cfg, pool)).collect(),
            spec,
            pos: 0,
        }
    }

    /// The configured KV representation (group post-clamp).
    pub fn kv_spec(&self) -> KvSpec {
        self.spec
    }

    /// Bytes currently held by all layers' K+V caches.
    pub fn kv_bytes(&self) -> usize {
        self.kv.iter().map(|c| c.nbytes()).sum()
    }

    /// Total storage-growth events across all caches — O(layers · log pos)
    /// by the amortized-growth contract. Always 0 when pooled: paged caches
    /// never grow a buffer.
    pub fn kv_grow_events(&self) -> usize {
        self.kv.iter().map(|c| c.grow_events()).sum()
    }

    /// Pool pages currently held across all layers (0 when not pooled).
    pub fn kv_pages_used(&self) -> usize {
        self.kv.iter().map(|c| c.pages_used()).sum()
    }

    /// Feed a span of tokens in one call; returns `[T, vocab]` logits, one
    /// row per fed position (row `t` predicts the token after `tokens[t]`).
    ///
    /// This is the chunked-prefill primitive: the span runs through the
    /// batched GEMM path layer by layer ([`decode_layer_span`]) with the
    /// causal mask applied per row, so the returned logits are bit-identical
    /// to feeding the same tokens through [`DecodeState::step`] one at a
    /// time — under every KV representation, kernel table, and shard count.
    pub fn step_span(&mut self, tokens: &[u8]) -> Matrix {
        assert!(!tokens.is_empty(), "step_span needs at least one token");
        let m = self.model;
        let mut h = embed_tokens(m, tokens);
        for (l, kv) in m.layers().iter().zip(self.kv.iter_mut()) {
            decode_layer_span(l, m.config(), self.pos, &mut h, kv);
        }
        self.pos += tokens.len();
        decode_head_span(m, &h)
    }

    /// Feed one token; returns the logits for the next position.
    ///
    /// A T=1 [`DecodeState::step_span`] — the same primitives the sharded
    /// pipeline executor runs per shard, so sharded, unsharded, chunked and
    /// token-at-a-time decode all share one op sequence.
    pub fn step(&mut self, token: u8) -> Vec<f32> {
        self.step_span(&[token]).data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Preset;
    use crate::util::rng::Rng;

    fn tiny_model(seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        ModelWeights::init(Preset::Tiny.config(), &mut rng)
    }

    #[test]
    fn logits_shape() {
        let w = tiny_model(1);
        let tokens: Vec<u8> = (0..10).collect();
        let l = forward_logits(&w, &tokens);
        assert_eq!((l.rows, l.cols), (10, 256));
        assert!(l.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        // Changing a future token must not change past logits.
        let w = tiny_model(2);
        let a: Vec<u8> = vec![5, 6, 7, 8, 9, 10];
        let mut b = a.clone();
        b[5] = 99;
        let la = forward_logits(&w, &a);
        let lb = forward_logits(&w, &b);
        for t in 0..5 {
            for c in 0..la.cols {
                assert!(
                    (la[(t, c)] - lb[(t, c)]).abs() < 1e-5,
                    "position {t} leaked future info"
                );
            }
        }
    }

    #[test]
    fn position_matters() {
        // RoPE: the same token at different positions gives different logits.
        let w = tiny_model(3);
        let l = forward_logits(&w, &[42, 42, 42, 42]);
        let r0: Vec<f32> = l.row(1).to_vec();
        let r1: Vec<f32> = l.row(3).to_vec();
        let diff: f32 = r0.iter().zip(&r1).map(|(a, b)| (a - b).abs()).sum();
        // a freshly initialized model is nearly position-invariant, so the
        // difference is small — but RoPE must make it strictly nonzero.
        assert!(diff > 1e-6, "rope seems inert (diff={diff})");
    }

    #[test]
    fn captures_shapes() {
        let w = tiny_model(4);
        let cfg = w.config;
        let tokens: Vec<u8> = (0..12).collect();
        let (logits, caps) = forward_captures(&w, &tokens);
        assert_eq!(caps.len(), cfg.n_layers);
        for c in &caps {
            assert_eq!((c.x_attn.rows, c.x_attn.cols), (12, cfg.d_model));
            assert_eq!((c.x_wo.rows, c.x_wo.cols), (12, cfg.d_model));
            assert_eq!((c.x_mlp.rows, c.x_mlp.cols), (12, cfg.d_model));
            assert_eq!((c.x_w2.rows, c.x_w2.cols), (12, cfg.ffn));
        }
        // capture pass must not change the logits
        let plain = forward_logits(&w, &tokens);
        assert!(logits.max_abs_diff(&plain) < 1e-6);
    }

    #[test]
    fn capture_reconstructs_linear_outputs() {
        // x_w2 @ w2ᵀ must equal the MLP residual contribution; check via
        // directly recomputing one layer output from captures.
        let w = tiny_model(5);
        let tokens: Vec<u8> = (3..15).collect();
        let (_, caps) = forward_captures(&w, &tokens);
        let c = &caps[0];
        let l = &w.layers[0];
        // q from capture equals wq applied to x_attn (pre-rope)
        let q = c.x_attn.matmul_bt(&l.wq);
        assert_eq!((q.rows, q.cols), (12, w.config.d_model));
        // finite + nonzero
        assert!(q.frob2() > 0.0);
        let down = c.x_w2.matmul_bt(&l.w2);
        assert!(down.frob2() > 0.0);
    }

    #[test]
    fn kv_decode_matches_full_forward() {
        let w = tiny_model(6);
        let tokens: Vec<u8> = vec![10, 20, 30, 40, 50, 60, 70];
        let full = forward_logits(&w, &tokens);
        let mut st = DecodeState::new(&w);
        for (t, &tok) in tokens.iter().enumerate() {
            let step_logits = st.step(tok);
            let frow = full.row(t);
            let maxdiff = step_logits
                .iter()
                .zip(frow)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(maxdiff < 1e-4, "pos {t}: maxdiff {maxdiff}");
        }
    }

    #[test]
    fn quantized_kv_decode_tracks_full_forward() {
        // int8 per-head group-wise KV must track the exact (cache-free)
        // full forward closely; int4 more loosely. Dense-KV decode already
        // matches to 1e-4 (test above), so the slack here is the KV
        // quantization error alone.
        let w = tiny_model(8);
        let tokens: Vec<u8> = vec![3, 141, 59, 26, 53, 58, 97, 93];
        let full = forward_logits(&w, &tokens);
        for (bits, tol) in [(8u8, 5e-2f32), (4, 3e-1)] {
            let spec = KvSpec::PackedGroupwise { bits, group: 64 };
            let mut st = DecodeState::with_kv(&w, spec);
            assert_eq!(st.kv_spec(), KvSpec::PackedGroupwise { bits, group: 32 });
            for (t, &tok) in tokens.iter().enumerate() {
                let step_logits = st.step(tok);
                let maxdiff = step_logits
                    .iter()
                    .zip(full.row(t))
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(maxdiff < tol, "bits={bits} pos {t}: maxdiff {maxdiff}");
            }
            // cache accounting: K+V across layers, spec-predicted size
            let per_tok = st.kv_spec().bytes_per_token(&w.config);
            assert_eq!(st.kv_bytes(), tokens.len() * w.config.n_layers * per_tok);
            let dense_per_tok = KvSpec::DenseF32.bytes_per_token(&w.config);
            assert!(per_tok * 2 < dense_per_tok, "int{bits} KV not smaller");
        }
    }

    #[test]
    fn step_span_bit_identical_to_one_token_loop() {
        // The chunked-prefill spine at unit granularity: feeding a sequence
        // in spans of any chunk size must reproduce the one-token loop's
        // logits bit for bit at every position, for dense and packed KV.
        let w = tiny_model(9);
        let tokens: Vec<u8> = (0..13).map(|i| (i * 41 % 251) as u8).collect();
        for spec in [KvSpec::DenseF32, KvSpec::PackedGroupwise { bits: 8, group: 16 }] {
            let mut st_loop = DecodeState::with_kv(&w, spec);
            let loop_logits: Vec<Vec<f32>> =
                tokens.iter().map(|&t| st_loop.step(t)).collect();
            for chunk in [1usize, 3, 5, 64] {
                let mut st_span = DecodeState::with_kv(&w, spec);
                let mut span_logits: Vec<Vec<f32>> = Vec::new();
                for c in tokens.chunks(chunk) {
                    let l = st_span.step_span(c);
                    assert_eq!((l.rows, l.cols), (c.len(), 256));
                    for t in 0..l.rows {
                        span_logits.push(l.row(t).to_vec());
                    }
                }
                assert_eq!(st_span.pos, st_loop.pos);
                for (t, (a, b)) in loop_logits.iter().zip(&span_logits).enumerate() {
                    for (i, (x, y)) in a.iter().zip(b).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "{} chunk={chunk} pos {t} logit {i}: loop {x} vs span {y}",
                            spec.label()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn nll_near_uniform_for_random_init() {
        // A freshly initialized model should predict ~uniform over 256 bytes.
        let w = tiny_model(7);
        let tokens: Vec<u8> = (0..32).map(|i| (i * 37 % 251) as u8).collect();
        let nll = sequence_nll(&w, &tokens);
        let uniform = (256f64).ln();
        assert!((nll - uniform).abs() < 0.35, "nll={nll} vs ln256={uniform}");
    }
}
