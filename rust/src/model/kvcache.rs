//! Group-wise quantized KV cache — the second packed data plane.
//!
//! PR 2/3 made weights execute straight from packed ints, which leaves
//! decode bandwidth dominated by the f32 K/V cache: every generated token
//! re-reads the entire cache once for `q·kᵀ` and once for `probs·V`. This
//! module applies the paper's group-wise affine format to those
//! *activations*: appended K/V rows are RTN-quantized on the fly with
//! **per-head, per-group** asymmetric (min/max) scales and stored in the
//! same little-endian packed-word layout as [`PackedInts`], and the attend
//! kernels fuse dequantization into the attention dot products so the cache
//! is never materialized in f32.
//!
//! Differences from the weight plane that shape the design:
//!
//! * **Written incrementally at decode time.** Weights are read-only; the KV
//!   cache grows one row per token. Both the dense and the packed variants
//!   use amortized doubling growth (tracked by [`KvCache::grow_events`]) so
//!   the serve path never reallocates per token.
//! * **Scales live per row.** A row is quantized once when appended and its
//!   `(scale, zero)` pairs are fixed forever — no global calibration pass,
//!   matching the KIVI/KVQuant observation that per-token K/V quantization
//!   works because each row's dynamic range is known exactly at append time.
//! * **Groups never cross heads.** Attention reads the cache head by head,
//!   so the group grid subdivides each head's `head_dim` span (`group` is
//!   clamped to `head_dim`); every attend span is then a whole number of
//!   groups and the fused kernels can factor the zero point per group:
//!
//!   ```text
//!   q·k̂ᵀ  = Σ_g s_g (Σ_{j∈g} k_j q_j − z_g Σ_{j∈g} q_j)      (dot_span)
//!   ctx  += Σ_t w_t · s_g (k_j − z_g) = Σ_t (a q_j + b)       (axpy_span)
//!   ```
//!
//! Both fused kernels route through the runtime-dispatched table in
//! [`crate::tensor::kernels`], and the forced-scalar table reproduces the
//! dispatched numerics bit for bit (`dot_span` by the lane-striped identity,
//! `axpy_span` structurally — it is elementwise).

use super::config::ModelConfig;
use crate::kvpool::{KvPool, PagedKv};
use crate::tensor::packed::{axpy_span, dot_span, PackedInts};
use anyhow::{bail, Result};

/// How a [`KvCache`] stores appended rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvSpec {
    /// Plain f32 rows (the default; numerically identical to the
    /// pre-KV-cache-quantization decode path).
    DenseF32,
    /// Group-wise asymmetric RTN on append: packed ints + per-head,
    /// per-group `(scale, zero)` pairs. `group` is clamped to `head_dim` at
    /// construction so groups never cross a head boundary.
    PackedGroupwise { bits: u8, group: usize },
}

impl KvSpec {
    /// Build from the `--kv-bits` / `--kv-group` CLI flags
    /// (`kv_bits == 0` means the f32 cache).
    pub fn from_flags(kv_bits: usize, kv_group: usize) -> Result<KvSpec> {
        match kv_bits {
            0 => Ok(KvSpec::DenseF32),
            1..=8 => {
                if kv_group == 0 {
                    bail!("--kv-group must be positive");
                }
                Ok(KvSpec::PackedGroupwise { bits: kv_bits as u8, group: kv_group })
            }
            _ => bail!("--kv-bits must be 0 (f32) or 1..=8, got {kv_bits}"),
        }
    }

    pub fn is_packed(&self) -> bool {
        matches!(self, KvSpec::PackedGroupwise { .. })
    }

    /// The spec a cache actually stores for `cfg`: the group clamped to
    /// `head_dim` (groups never cross heads). Banners and bench rows must
    /// label with this, not the requested spec.
    pub fn effective(&self, cfg: &ModelConfig) -> KvSpec {
        match *self {
            KvSpec::DenseF32 => KvSpec::DenseF32,
            KvSpec::PackedGroupwise { bits, group } => KvSpec::PackedGroupwise {
                bits,
                group: group.clamp(1, cfg.head_dim()),
            },
        }
    }

    /// Short human label ("f32", "int8 g64") for banners and bench rows.
    pub fn label(&self) -> String {
        match self {
            KvSpec::DenseF32 => "f32".to_string(),
            KvSpec::PackedGroupwise { bits, group } => format!("int{bits} g{group}"),
        }
    }

    /// Bytes appended per decoded token per layer (K **and** V rows,
    /// including scale/zero overhead) — the bytes-per-token column of the
    /// serving bench. Uses the effective (head-clamped) group size, so the
    /// number reflects what the cache actually stores for `cfg`.
    pub fn bytes_per_token(&self, cfg: &ModelConfig) -> usize {
        match *self {
            KvSpec::DenseF32 => 2 * cfg.d_model * 4,
            KvSpec::PackedGroupwise { bits, group } => {
                let hd = cfg.head_dim();
                let geff = group.clamp(1, hd);
                let groups_per_row = cfg.n_heads * hd.div_ceil(geff);
                2 * (PackedInts::words_needed(cfg.d_model, bits) * 4 + groups_per_row * 8)
            }
        }
    }
}

/// The packed-row geometry plus the per-row quantize/attend math shared by
/// the contiguous [`PackedKv`] and the paged [`PagedKv`] caches. Keeping the
/// per-row code here — and calling it from both layouts — is what makes the
/// paged attend bit-identical to the contiguous attend: both hand
/// byte-identical row slices to the same fused kernels in the same order,
/// so the storage layout (flat vector vs page table) cannot perturb a
/// single f32 bit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct PackedLayout {
    pub(crate) bits: u8,
    /// Effective group size after clamping to `head_dim`.
    pub(crate) group: usize,
    pub(crate) n_heads: usize,
    pub(crate) head_dim: usize,
    pub(crate) d: usize,
    pub(crate) words_per_row: usize,
    pub(crate) groups_per_head: usize,
}

impl PackedLayout {
    pub(crate) fn new(bits: u8, group: usize, cfg: &ModelConfig) -> PackedLayout {
        assert!(matches!(bits, 1..=8), "kv bits must be 1..=8");
        let hd = cfg.head_dim();
        let geff = group.clamp(1, hd);
        PackedLayout {
            bits,
            group: geff,
            n_heads: cfg.n_heads,
            head_dim: hd,
            d: cfg.d_model,
            words_per_row: PackedInts::words_needed(cfg.d_model, bits),
            groups_per_head: hd.div_ceil(geff),
        }
    }

    pub(crate) fn groups_per_row(&self) -> usize {
        self.n_heads * self.groups_per_head
    }

    /// Quantize one `[d_model]` row and push its packed words and per-group
    /// `(scale, zero)` pairs. Per (head, group): asymmetric min/max range,
    /// `scale = (max − min) / (2^bits − 1)`, f32 zero-point `z = −min/scale`
    /// (un-rounded, like the weight format's stored zeros), so `min` and
    /// `max` dequantize exactly. The bit layout is produced by
    /// [`PackedInts::pack`] itself — one source of truth for the word format
    /// the `dot_span`/`axpy_span` kernels read.
    pub(crate) fn quantize_row_into(
        &self,
        row: &[f32],
        words: &mut Vec<u32>,
        scales: &mut Vec<f32>,
        zeros: &mut Vec<f32>,
    ) {
        debug_assert_eq!(row.len(), self.d);
        let maxq = ((1u32 << self.bits) - 1) as f32;
        let mut qvals = vec![0u8; self.d];
        for h in 0..self.n_heads {
            let base = h * self.head_dim;
            for g in 0..self.groups_per_head {
                let c0 = base + g * self.group;
                let c1 = (c0 + self.group).min(base + self.head_dim);
                let slice = &row[c0..c1];
                let lo = slice.iter().copied().fold(f32::INFINITY, f32::min);
                let hi = slice.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let range = hi - lo;
                let scale = if range > 0.0 { range / maxq } else { 1.0 };
                scales.push(scale);
                zeros.push(-lo / scale);
                for (q, &v) in qvals[c0..c1].iter_mut().zip(slice) {
                    *q = (((v - lo) / scale).round()).clamp(0.0, maxq) as u8;
                }
            }
        }
        let packed = PackedInts::pack(&qvals, self.bits);
        debug_assert_eq!(packed.words.len(), self.words_per_row);
        words.extend_from_slice(&packed.words);
    }

    /// Per-group query sums for `head` — the shared zero-point term,
    /// computed once per (head, step) and reused across every cached row.
    pub(crate) fn head_gsums(&self, q: &[f32], head: usize, gsum: &mut [f32]) {
        let base = head * self.head_dim;
        debug_assert!(q.len() >= base + self.head_dim);
        for (g, chunk) in q[base..base + self.head_dim].chunks(self.group).enumerate() {
            gsum[g] = chunk.iter().sum();
        }
    }

    /// One row's fused attend score for `head` (caller applies the 1/√d
    /// scale): `words` is the row's packed words, `srow`/`zrow` its
    /// `groups_per_head` scale/zero slices for this head, `gsum` from
    /// [`Self::head_gsums`].
    pub(crate) fn row_score(
        &self,
        words: &[u32],
        srow: &[f32],
        zrow: &[f32],
        head: usize,
        q: &[f32],
        gsum: &[f32],
    ) -> f32 {
        let base = head * self.head_dim;
        let mut y = 0.0f32;
        for g in 0..self.groups_per_head {
            let c0 = base + g * self.group;
            let c1 = (c0 + self.group).min(base + self.head_dim);
            let qdot = dot_span(words, self.bits, c0, c1, q);
            y += srow[g] * (qdot - zrow[g] * gsum[g]);
        }
        y
    }

    /// Accumulate `w · dequant(row)[head span]` into `ctx_head` through the
    /// fused dequant-axpy kernel.
    pub(crate) fn row_axpy(
        &self,
        words: &[u32],
        srow: &[f32],
        zrow: &[f32],
        head: usize,
        w: f32,
        ctx_head: &mut [f32],
    ) {
        let base = head * self.head_dim;
        for g in 0..self.groups_per_head {
            let c0 = base + g * self.group;
            let c1 = (c0 + self.group).min(base + self.head_dim);
            let a = w * srow[g];
            let b = -(a * zrow[g]);
            axpy_span(words, self.bits, c0, c1, a, b, &mut ctx_head[c0 - base..c1 - base]);
        }
    }

    /// Dequantize one packed row (its full `groups_per_row` scale/zero
    /// slices) back to f32, reconstructing through [`PackedInts`] so reads
    /// share pack's layout code.
    pub(crate) fn dequant_row_from(&self, words: &[u32], srow: &[f32], zrow: &[f32]) -> Vec<f32> {
        let packed =
            PackedInts { bits: self.bits, len: self.d, words: words.to_vec() };
        let qvals = packed.unpack();
        let mut out = vec![0.0f32; self.d];
        for h in 0..self.n_heads {
            let base = h * self.head_dim;
            for g in 0..self.groups_per_head {
                let gi = h * self.groups_per_head + g;
                let (s, z) = (srow[gi], zrow[gi]);
                let c0 = base + g * self.group;
                let c1 = (c0 + self.group).min(base + self.head_dim);
                for (o, &qv) in out[c0..c1].iter_mut().zip(&qvals[c0..c1]) {
                    *o = s * (qv as f32 - z);
                }
            }
        }
        out
    }
}

/// Dense f32 cache rows with amortized doubling growth (the seed
/// implementation rebuilt a `Matrix` per appended token — O(T²) copies over
/// a decode).
#[derive(Clone, Debug)]
pub struct DenseKv {
    d: usize,
    head_dim: usize,
    rows: usize,
    data: Vec<f32>,
    grows: usize,
}

/// Packed group-wise cache: one quantized row per appended token, flat word
/// storage (`rows × words_per_row`) plus per-row `(scale, zero)` pairs
/// (`rows × groups_per_row`), all with doubling growth. The quantize and
/// per-row attend math lives on [`PackedLayout`], shared with the paged
/// variant.
#[derive(Clone, Debug)]
pub struct PackedKv {
    lay: PackedLayout,
    rows: usize,
    words: Vec<u32>,
    scales: Vec<f32>,
    zeros: Vec<f32>,
    grows: usize,
}

/// One K or V cache for one layer, in whichever representation the decode
/// was configured with: contiguous with doubling growth (`Dense`/`Packed`),
/// or page-table backed by a budget-bounded [`KvPool`] (`Paged`, PR 6).
#[derive(Clone, Debug)]
pub enum KvCache {
    Dense(DenseKv),
    Packed(PackedKv),
    Paged(PagedKv),
}

impl KvCache {
    /// A contiguous (non-pooled) cache — `new_in` with no pool.
    pub fn new(spec: KvSpec, cfg: &ModelConfig) -> KvCache {
        KvCache::new_in(spec, cfg, None)
    }

    /// A cache for `spec`: paged out of `pool` when one is given, otherwise
    /// contiguous with doubling growth.
    pub fn new_in(spec: KvSpec, cfg: &ModelConfig, pool: Option<&KvPool>) -> KvCache {
        if let Some(pool) = pool {
            return KvCache::Paged(PagedKv::new(spec, cfg, pool));
        }
        match spec {
            KvSpec::DenseF32 => KvCache::Dense(DenseKv {
                d: cfg.d_model,
                head_dim: cfg.head_dim(),
                rows: 0,
                data: Vec::new(),
                grows: 0,
            }),
            KvSpec::PackedGroupwise { bits, group } => KvCache::Packed(PackedKv {
                lay: PackedLayout::new(bits, group, cfg),
                rows: 0,
                words: Vec::new(),
                scales: Vec::new(),
                zeros: Vec::new(),
                grows: 0,
            }),
        }
    }

    /// The spec this cache was built with (group reported post-clamp).
    pub fn spec(&self) -> KvSpec {
        match self {
            KvCache::Dense(_) => KvSpec::DenseF32,
            KvCache::Packed(c) => {
                KvSpec::PackedGroupwise { bits: c.lay.bits, group: c.lay.group }
            }
            KvCache::Paged(c) => c.spec(),
        }
    }

    /// Cached rows (= tokens seen so far).
    pub fn rows(&self) -> usize {
        match self {
            KvCache::Dense(c) => c.rows,
            KvCache::Packed(c) => c.rows,
            KvCache::Paged(c) => c.rows(),
        }
    }

    /// Bytes currently used by cached rows (not capacity).
    pub fn nbytes(&self) -> usize {
        match self {
            KvCache::Dense(c) => c.rows * c.d * 4,
            KvCache::Packed(c) => {
                c.rows * (c.lay.words_per_row * 4 + c.lay.groups_per_row() * 8)
            }
            KvCache::Paged(c) => c.nbytes(),
        }
    }

    /// How many times the backing storage grew. Only meaningful for the
    /// contiguous variants — their appends amortize to O(log rows) grows
    /// (the long-sequence append test rides on it). A paged cache never
    /// grows a buffer (pages are fixed-size and pre-sized), so it reports 0
    /// rather than conflating the two storage disciplines.
    pub fn grow_events(&self) -> usize {
        match self {
            KvCache::Dense(c) => c.grows,
            KvCache::Packed(c) => c.grows,
            KvCache::Paged(_) => 0,
        }
    }

    /// Pool pages held (0 for the contiguous variants).
    pub fn pages_used(&self) -> usize {
        match self {
            KvCache::Paged(c) => c.pages_used(),
            _ => 0,
        }
    }

    /// Append one `[d_model]` row (quantizing it on the fly when packed).
    pub fn append(&mut self, row: &[f32]) {
        match self {
            KvCache::Dense(c) => c.append(row),
            KvCache::Packed(c) => c.append(row),
            KvCache::Paged(c) => c.append(row),
        }
    }

    /// Attention scores for one head against every cached row:
    /// `scores[t] = (q[base..base+hd] · row_t[base..base+hd]) · scale`,
    /// where `q` is the **full** `[d_model]` query row. `scores` is cleared
    /// and refilled.
    pub fn head_scores(&self, head: usize, q: &[f32], scale: f32, scores: &mut Vec<f32>) {
        self.head_scores_limit(head, q, scale, self.rows(), scores);
    }

    /// Like [`Self::head_scores`], but only against the first `limit` cached
    /// rows. This is the causal mask of chunked prefill: span row `t`
    /// attends to rows `0..pos+t+1` even though the whole span's K/V was
    /// appended up front. `limit == rows()` reproduces `head_scores` exactly
    /// — there is **one** loop, so the one-token step and the span step
    /// cannot diverge structurally.
    pub fn head_scores_limit(
        &self,
        head: usize,
        q: &[f32],
        scale: f32,
        limit: usize,
        scores: &mut Vec<f32>,
    ) {
        debug_assert!(limit <= self.rows());
        scores.clear();
        match self {
            KvCache::Dense(c) => {
                let base = head * c.head_dim;
                let qh = &q[base..base + c.head_dim];
                for t in 0..limit {
                    let krow = &c.data[t * c.d + base..t * c.d + base + c.head_dim];
                    scores.push(crate::tensor::matrix::dot(qh, krow) * scale);
                }
            }
            KvCache::Packed(c) => c.head_scores(head, q, scale, limit, scores),
            KvCache::Paged(c) => c.head_scores_limit(head, q, scale, limit, scores),
        }
    }

    /// Accumulate the softmax-weighted value rows of one head into
    /// `ctx_head` (`[head_dim]`): `ctx_head[i] += Σ_t probs[t] · row_t[base+i]`.
    pub fn head_axpy(&self, head: usize, probs: &[f32], ctx_head: &mut [f32]) {
        self.head_axpy_limit(head, probs, self.rows(), ctx_head);
    }

    /// Like [`Self::head_axpy`], but only over the first `limit` cached rows
    /// (the span-prefill causal mask — see [`Self::head_scores_limit`]).
    pub fn head_axpy_limit(
        &self,
        head: usize,
        probs: &[f32],
        limit: usize,
        ctx_head: &mut [f32],
    ) {
        debug_assert!(limit <= self.rows());
        match self {
            KvCache::Dense(c) => {
                let base = head * c.head_dim;
                debug_assert!(probs.len() >= limit && ctx_head.len() >= c.head_dim);
                for (t, &w) in probs.iter().enumerate().take(limit) {
                    let vrow = &c.data[t * c.d + base..t * c.d + base + c.head_dim];
                    for (o, &v) in ctx_head.iter_mut().zip(vrow) {
                        *o += w * v;
                    }
                }
            }
            KvCache::Packed(c) => c.head_axpy(head, probs, limit, ctx_head),
            KvCache::Paged(c) => c.head_axpy_limit(head, probs, limit, ctx_head),
        }
    }

    /// Dequantize one cached row back to f32 (dense rows copy). Test and
    /// debugging aid — the decode path never calls this.
    pub fn dequant_row(&self, t: usize) -> Vec<f32> {
        match self {
            KvCache::Dense(c) => c.data[t * c.d..(t + 1) * c.d].to_vec(),
            KvCache::Packed(c) => c.dequant_row(t),
            KvCache::Paged(c) => c.dequant_row(t),
        }
    }
}

/// One layer's K and V caches as a unit — what a decode step advances and
/// what a pipeline shard owns per sequence: [`DecodeState`] holds one per
/// model layer, while each shard worker holds one per layer *in its range*
/// (the "shard-local half" of a sequence's cache). Keeping the pair together
/// means the per-layer decode step ([`super::forward::decode_layer_step`])
/// has a single mutable argument and both topologies share it verbatim.
///
/// [`DecodeState`]: super::DecodeState
#[derive(Clone, Debug)]
pub struct LayerKv {
    pub k: KvCache,
    pub v: KvCache,
}

impl LayerKv {
    pub fn new(spec: KvSpec, cfg: &ModelConfig) -> LayerKv {
        LayerKv::new_in(spec, cfg, None)
    }

    /// Like [`LayerKv::new`], but paged out of `pool` when one is given.
    pub fn new_in(spec: KvSpec, cfg: &ModelConfig, pool: Option<&KvPool>) -> LayerKv {
        LayerKv {
            k: KvCache::new_in(spec, cfg, pool),
            v: KvCache::new_in(spec, cfg, pool),
        }
    }

    /// Append a whole span's K and V rows (`k`/`v` are `[T, d_model]`) in
    /// one call — the multi-row append of chunked prefill. Rows land in the
    /// exact order the one-token step appends them (`k` row then `v` row,
    /// position by position), so pooled page tables allocate pages in the
    /// same interleaving a T-step loop would and the stored bytes are
    /// identical by construction.
    pub fn append_span(&mut self, k: &crate::tensor::Matrix, v: &crate::tensor::Matrix) {
        debug_assert_eq!(k.rows, v.rows);
        for t in 0..k.rows {
            self.k.append(k.row(t));
            self.v.append(v.row(t));
        }
    }

    /// Bytes currently held by this layer's K+V rows.
    pub fn nbytes(&self) -> usize {
        self.k.nbytes() + self.v.nbytes()
    }

    /// Storage-growth events across both caches (amortization contract).
    pub fn grow_events(&self) -> usize {
        self.k.grow_events() + self.v.grow_events()
    }

    /// Cached rows (= tokens this layer has seen).
    pub fn rows(&self) -> usize {
        self.k.rows()
    }

    /// Pool pages held across both caches (0 when not paged).
    pub fn pages_used(&self) -> usize {
        self.k.pages_used() + self.v.pages_used()
    }
}

/// Grow `v` so it can hold `need` more elements without reallocating,
/// doubling capacity (with a floor) when it can't. Returns `true` when a
/// grow happened — callers count those to verify amortization.
fn reserve_doubling<T>(v: &mut Vec<T>, need: usize, floor: usize) -> bool {
    let want = v.len() + need;
    if want <= v.capacity() {
        return false;
    }
    let target = (v.capacity() * 2).max(want).max(floor);
    v.reserve_exact(target - v.len());
    true
}

impl DenseKv {
    fn append(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d);
        if reserve_doubling(&mut self.data, self.d, 16 * self.d) {
            self.grows += 1;
        }
        self.data.extend_from_slice(row);
        self.rows += 1;
    }
}

impl PackedKv {
    /// Quantize + append one row (the math lives on
    /// [`PackedLayout::quantize_row_into`]; this layer only owns the
    /// doubling-growth storage).
    fn append(&mut self, row: &[f32]) {
        let wpr = self.lay.words_per_row;
        let gpr = self.lay.groups_per_row();
        let mut grew = false;
        grew |= reserve_doubling(&mut self.words, wpr, 16 * wpr);
        grew |= reserve_doubling(&mut self.scales, gpr, 16 * gpr);
        grew |= reserve_doubling(&mut self.zeros, gpr, 16 * gpr);
        if grew {
            self.grows += 1;
        }
        self.lay.quantize_row_into(row, &mut self.words, &mut self.scales, &mut self.zeros);
        self.rows += 1;
    }

    /// Scores against the first `limit` cached rows (`limit == rows` is the
    /// full-cache attend; the enum wrapper passes the causal span limit).
    fn head_scores(
        &self,
        head: usize,
        q: &[f32],
        scale: f32,
        limit: usize,
        scores: &mut Vec<f32>,
    ) {
        let lay = self.lay;
        let gph = lay.groups_per_head;
        let gpr = lay.groups_per_row();
        let mut gsum = crate::util::scratch::take_f32(gph);
        lay.head_gsums(q, head, &mut gsum);
        scores.reserve(limit);
        for t in 0..limit {
            let words = &self.words[t * lay.words_per_row..(t + 1) * lay.words_per_row];
            let srow = &self.scales[t * gpr + head * gph..t * gpr + (head + 1) * gph];
            let zrow = &self.zeros[t * gpr + head * gph..t * gpr + (head + 1) * gph];
            scores.push(lay.row_score(words, srow, zrow, head, q, &gsum) * scale);
        }
    }

    fn head_axpy(&self, head: usize, probs: &[f32], limit: usize, ctx_head: &mut [f32]) {
        let lay = self.lay;
        debug_assert!(probs.len() >= limit && ctx_head.len() >= lay.head_dim);
        let gph = lay.groups_per_head;
        let gpr = lay.groups_per_row();
        for (t, &w) in probs.iter().enumerate().take(limit) {
            let words = &self.words[t * lay.words_per_row..(t + 1) * lay.words_per_row];
            let srow = &self.scales[t * gpr + head * gph..t * gpr + (head + 1) * gph];
            let zrow = &self.zeros[t * gpr + head * gph..t * gpr + (head + 1) * gph];
            lay.row_axpy(words, srow, zrow, head, w, ctx_head);
        }
    }

    fn dequant_row(&self, t: usize) -> Vec<f32> {
        let lay = self.lay;
        let gpr = lay.groups_per_row();
        lay.dequant_row_from(
            &self.words[t * lay.words_per_row..(t + 1) * lay.words_per_row],
            &self.scales[t * gpr..(t + 1) * gpr],
            &self.zeros[t * gpr..(t + 1) * gpr],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Preset;
    use crate::tensor::kernels::{set_forced, ForcedKernel};
    use crate::util::rng::Rng;

    fn cfg() -> ModelConfig {
        Preset::Tiny.config() // d=64, 2 heads, head_dim=32
    }

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_vec(d, 1.0)).collect()
    }

    #[test]
    fn from_flags_parses_and_rejects() {
        assert_eq!(KvSpec::from_flags(0, 64).unwrap(), KvSpec::DenseF32);
        assert_eq!(
            KvSpec::from_flags(8, 64).unwrap(),
            KvSpec::PackedGroupwise { bits: 8, group: 64 }
        );
        assert!(KvSpec::from_flags(9, 64).is_err());
        assert!(KvSpec::from_flags(4, 0).is_err());
    }

    #[test]
    fn group_is_clamped_to_head_dim() {
        let c = KvCache::new(KvSpec::PackedGroupwise { bits: 8, group: 64 }, &cfg());
        // head_dim = 32 < requested 64 → per-head single group
        assert_eq!(c.spec(), KvSpec::PackedGroupwise { bits: 8, group: 32 });
    }

    #[test]
    fn quantize_dequant_roundtrip_hits_group_extrema() {
        let cfg = cfg();
        let mut c = KvCache::new(KvSpec::PackedGroupwise { bits: 8, group: 16 }, &cfg);
        let r = rows(5, cfg.d_model, 3);
        for row in &r {
            c.append(row);
        }
        assert_eq!(c.rows(), 5);
        for (t, row) in r.iter().enumerate() {
            let deq = c.dequant_row(t);
            // every element within scale/2; group min/max exact
            for (g, chunk) in row.chunks(16).enumerate() {
                let lo = chunk.iter().copied().fold(f32::INFINITY, f32::min);
                let hi = chunk.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let half_step = (hi - lo) / 255.0 / 2.0 + 1e-6;
                for (j, &v) in chunk.iter().enumerate() {
                    let d = deq[g * 16 + j];
                    assert!(
                        (d - v).abs() <= half_step * 1.01 + 1e-5,
                        "t={t} g={g} j={j}: {d} vs {v} (half step {half_step})"
                    );
                }
            }
        }
    }

    #[test]
    fn constant_group_is_exact() {
        // max == min → scale falls back to 1.0 and the value round-trips.
        let cfg = cfg();
        let mut c = KvCache::new(KvSpec::PackedGroupwise { bits: 4, group: 32 }, &cfg);
        c.append(&vec![0.75f32; cfg.d_model]);
        let deq = c.dequant_row(0);
        assert!(deq.iter().all(|&v| (v - 0.75).abs() < 1e-6), "{deq:?}");
    }

    #[test]
    fn fused_attend_matches_dequant_reference() {
        // head_scores / head_axpy computed from the packed words must equal
        // the explicit dequantize-then-dense-attend reference — the same
        // equivalence the packed weight path proves against dequantized
        // GEMMs.
        let cfg = cfg();
        let hd = cfg.head_dim();
        for bits in [4u8, 8] {
            let mut c =
                KvCache::new(KvSpec::PackedGroupwise { bits, group: 16 }, &cfg);
            let r = rows(7, cfg.d_model, 11);
            for row in &r {
                c.append(row);
            }
            let mut rng = Rng::new(99);
            let q: Vec<f32> = rng.normal_vec(cfg.d_model, 1.0);
            let probs: Vec<f32> = (0..7).map(|i| (i as f32 + 1.0) / 28.0).collect();
            let scale = 1.0 / (hd as f32).sqrt();
            for h in 0..cfg.n_heads {
                let base = h * hd;
                let mut scores = Vec::new();
                c.head_scores(h, &q, scale, &mut scores);
                for (t, &s) in scores.iter().enumerate() {
                    let deq = c.dequant_row(t);
                    let want =
                        crate::tensor::matrix::dot(&q[base..base + hd], &deq[base..base + hd])
                            * scale;
                    assert!(
                        (s - want).abs() <= 1e-4 * want.abs().max(1.0),
                        "bits={bits} h={h} t={t}: {s} vs {want}"
                    );
                }
                let mut ctx = vec![0.0f32; hd];
                c.head_axpy(h, &probs, &mut ctx);
                for (i, &got) in ctx.iter().enumerate() {
                    let want: f32 = (0..7)
                        .map(|t| probs[t] * c.dequant_row(t)[base + i])
                        .sum();
                    assert!(
                        (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                        "bits={bits} h={h} i={i}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn attend_bit_identical_scalar_vs_dispatched() {
        // The dispatch invariant extended to the KV plane: forced-scalar and
        // detected-best tables must produce identical f32 bits for both
        // attend primitives (trivial off AVX2; real on it).
        let cfg = cfg();
        let _guard = crate::tensor::kernels::force_test_lock();
        for bits in [2u8, 3, 4, 8] {
            let mut c = KvCache::new(KvSpec::PackedGroupwise { bits, group: 16 }, &cfg);
            for row in &rows(9, cfg.d_model, 21) {
                c.append(row);
            }
            let mut rng = Rng::new(7);
            let q: Vec<f32> = rng.normal_vec(cfg.d_model, 1.0);
            let probs: Vec<f32> = (0..9).map(|i| 1.0 / (i as f32 + 2.0)).collect();
            for h in 0..cfg.n_heads {
                let (mut a, mut b) = (Vec::new(), Vec::new());
                set_forced(ForcedKernel::Scalar);
                c.head_scores(h, &q, 0.25, &mut a);
                let mut ctx_a = vec![0.0f32; cfg.head_dim()];
                c.head_axpy(h, &probs, &mut ctx_a);
                set_forced(ForcedKernel::Best);
                c.head_scores(h, &q, 0.25, &mut b);
                let mut ctx_b = vec![0.0f32; cfg.head_dim()];
                c.head_axpy(h, &probs, &mut ctx_b);
                set_forced(ForcedKernel::Auto);
                let eq_bits = |x: &[f32], y: &[f32]| {
                    x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                };
                assert!(eq_bits(&a, &b), "bits={bits} h={h}: scores diverged");
                assert!(eq_bits(&ctx_a, &ctx_b), "bits={bits} h={h}: ctx diverged");
            }
        }
    }

    #[test]
    fn paged_cache_attends_bit_identically() {
        // The tentpole invariant at cache granularity: a page-table cache
        // fed the same rows must produce bit-identical scores/ctx to the
        // contiguous cache, under both kernel tables — the storage layout
        // must be invisible to the attend math.
        use crate::kvpool::{KvPool, PoolCfg};
        let cfg = cfg();
        let _guard = crate::tensor::kernels::force_test_lock();
        let eq_bits = |x: &[f32], y: &[f32]| {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        };
        for spec in [
            KvSpec::DenseF32,
            KvSpec::PackedGroupwise { bits: 3, group: 16 },
            KvSpec::PackedGroupwise { bits: 8, group: 16 },
        ] {
            let pool = KvPool::new(
                PoolCfg { budget_bytes: 1 << 20, page_tokens: 4 },
                spec,
                &cfg,
            );
            let mut flat = KvCache::new(spec, &cfg);
            let mut paged = KvCache::new_in(spec, &cfg, Some(&pool));
            for row in &rows(11, cfg.d_model, 31) {
                flat.append(row);
                paged.append(row);
            }
            assert_eq!(paged.pages_used(), 3, "11 rows / 4-token pages");
            assert_eq!(flat.pages_used(), 0);
            assert_eq!(paged.nbytes(), flat.nbytes());
            let mut rng = Rng::new(17);
            let q: Vec<f32> = rng.normal_vec(cfg.d_model, 1.0);
            let probs: Vec<f32> = (0..11).map(|i| 1.0 / (i as f32 + 2.0)).collect();
            for forced in [ForcedKernel::Scalar, ForcedKernel::Best] {
                set_forced(forced);
                for h in 0..cfg.n_heads {
                    let (mut a, mut b) = (Vec::new(), Vec::new());
                    flat.head_scores(h, &q, 0.25, &mut a);
                    paged.head_scores(h, &q, 0.25, &mut b);
                    assert!(eq_bits(&a, &b), "{}: paged scores diverged (h={h})", spec.label());
                    let mut ctx_a = vec![0.0f32; cfg.head_dim()];
                    let mut ctx_b = vec![0.0f32; cfg.head_dim()];
                    flat.head_axpy(h, &probs, &mut ctx_a);
                    paged.head_axpy(h, &probs, &mut ctx_b);
                    assert!(
                        eq_bits(&ctx_a, &ctx_b),
                        "{}: paged ctx diverged (h={h})",
                        spec.label()
                    );
                    assert_eq!(flat.dequant_row(h), paged.dequant_row(h));
                }
            }
            set_forced(ForcedKernel::Auto);
        }
    }

    #[test]
    fn dense_cache_matches_reference_attend() {
        let cfg = cfg();
        let hd = cfg.head_dim();
        let mut c = KvCache::new(KvSpec::DenseF32, &cfg);
        let r = rows(6, cfg.d_model, 5);
        for row in &r {
            c.append(row);
        }
        let mut rng = Rng::new(55);
        let q: Vec<f32> = rng.normal_vec(cfg.d_model, 1.0);
        let mut scores = Vec::new();
        c.head_scores(1, &q, 0.5, &mut scores);
        for (t, &s) in scores.iter().enumerate() {
            let want =
                crate::tensor::matrix::dot(&q[hd..2 * hd], &r[t][hd..2 * hd]) * 0.5;
            assert_eq!(s.to_bits(), want.to_bits(), "t={t}");
        }
    }

    #[test]
    fn long_append_is_amortized_for_both_variants() {
        // The seed bug: the dense cache rebuilt its Matrix per token. Both
        // variants must now grow O(log n) times over a long decode.
        let cfg = cfg();
        for spec in [KvSpec::DenseF32, KvSpec::PackedGroupwise { bits: 8, group: 32 }] {
            let mut c = KvCache::new(spec, &cfg);
            let r = rows(1, cfg.d_model, 1);
            for _ in 0..2048 {
                c.append(&r[0]);
            }
            assert_eq!(c.rows(), 2048);
            assert!(
                c.grow_events() <= 12,
                "{}: {} grow events for 2048 appends",
                spec.label(),
                c.grow_events()
            );
        }
    }

    #[test]
    fn bytes_per_token_ratios() {
        // The serving-shape compression story: ≥ 3.5× at int8 g64 on the
        // base preset (head_dim 64), and the per-cache accounting agrees
        // with what append actually stores.
        let base = Preset::Base.config();
        let f32_b = KvSpec::DenseF32.bytes_per_token(&base);
        let int8 = KvSpec::PackedGroupwise { bits: 8, group: 64 }.bytes_per_token(&base);
        let int4 = KvSpec::PackedGroupwise { bits: 4, group: 64 }.bytes_per_token(&base);
        assert!(
            f32_b as f64 / int8 as f64 >= 3.5,
            "int8 ratio {} < 3.5",
            f32_b as f64 / int8 as f64
        );
        assert!(f32_b as f64 / int4 as f64 >= 6.0);
        // nbytes of an actual cache == rows × (bytes_per_token / 2)  (one of
        // the K/V pair)
        let cfg = cfg();
        let spec = KvSpec::PackedGroupwise { bits: 8, group: 64 };
        let mut c = KvCache::new(spec, &cfg);
        for row in &rows(3, cfg.d_model, 9) {
            c.append(row);
        }
        assert_eq!(c.nbytes(), 3 * spec.bytes_per_token(&cfg) / 2);
    }
}
