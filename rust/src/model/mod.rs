//! The "Llamette" transformer under quantization.
//!
//! Same architecture family as the paper's Llama targets — RMSNorm, rotary
//! position embeddings, multi-head causal attention, SwiGLU MLP, untied LM
//! head — scaled to presets that train from scratch on CPU in minutes. The
//! canonical forward/backward lives in JAX (`python/compile/model.py`, AOT'd
//! to HLO); this module carries the *mirror* definition: configuration,
//! weight containers, checkpoint I/O, and a native Rust forward used for
//! activation capture in the quantization pipeline, as the runtime fallback,
//! and for KV-cached decoding in the serve path.
//!
//! Execution is representation-generic: [`linear`] defines the
//! [`LinearOp`]/[`BlockLinears`]/[`ModelExec`] interface, [`exec`] the
//! deployable [`ExecModel`] that runs packed quantized linears through the
//! fused dequant kernels — the `--packed` serve/eval path.

pub mod config;
pub mod exec;
pub mod forward;
pub mod kvcache;
pub mod linear;
pub mod store;
pub mod weights;

pub use config::{ModelConfig, Preset};
pub use exec::{ExecLayer, ExecModel};
pub use forward::{
    decode_head, decode_head_span, decode_layer_span, decode_layer_step, embed_tokens,
    forward_captures, forward_logits, DecodeState, LayerCaptures,
};
pub use kvcache::{KvCache, KvSpec, LayerKv};
pub use linear::{BlockLinears, LinearOp, ModelExec};
pub use weights::{LayerWeights, LinearKind, ModelWeights};
