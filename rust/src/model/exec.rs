//! [`ExecModel`] — the deployable model: FP norms/embedding/head plus one
//! [`LinearOp`] per projection, dense or packed per linear.
//!
//! This is what `tsgo serve --packed` / `eval --packed` run: quantized
//! checkpoints execute through the fused dequant kernels without ever
//! materializing a dense weight matrix, and mixed checkpoints (some linears
//! packed, some f32) work per-projection. Built either from dense
//! [`ModelWeights`] or from a [`QuantizedModel`]'s packed linears.

use super::config::ModelConfig;
use super::linear::{BlockLinears, LinearOp, ModelExec};
use super::store::QuantizedModel;
use super::weights::{LayerWeights, LinearKind, ModelWeights};
use crate::tensor::Matrix;

/// One block, each projection in its deployed representation.
#[derive(Clone, Debug)]
pub struct ExecLayer {
    pub wq: LinearOp,
    pub wk: LinearOp,
    pub wv: LinearOp,
    pub wo: LinearOp,
    pub w1: LinearOp,
    pub w3: LinearOp,
    pub w2: LinearOp,
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
}

impl ExecLayer {
    pub fn op(&self, kind: LinearKind) -> &LinearOp {
        match kind {
            LinearKind::Wq => &self.wq,
            LinearKind::Wk => &self.wk,
            LinearKind::Wv => &self.wv,
            LinearKind::Wo => &self.wo,
            LinearKind::W1 => &self.w1,
            LinearKind::W3 => &self.w3,
            LinearKind::W2 => &self.w2,
        }
    }

    pub fn op_mut(&mut self, kind: LinearKind) -> &mut LinearOp {
        match kind {
            LinearKind::Wq => &mut self.wq,
            LinearKind::Wk => &mut self.wk,
            LinearKind::Wv => &mut self.wv,
            LinearKind::Wo => &mut self.wo,
            LinearKind::W1 => &mut self.w1,
            LinearKind::W3 => &mut self.w3,
            LinearKind::W2 => &mut self.w2,
        }
    }

    fn from_dense(l: LayerWeights) -> ExecLayer {
        ExecLayer {
            wq: LinearOp::Dense(l.wq),
            wk: LinearOp::Dense(l.wk),
            wv: LinearOp::Dense(l.wv),
            wo: LinearOp::Dense(l.wo),
            w1: LinearOp::Dense(l.w1),
            w3: LinearOp::Dense(l.w3),
            w2: LinearOp::Dense(l.w2),
            ln1: l.ln1,
            ln2: l.ln2,
        }
    }
}

impl BlockLinears for ExecLayer {
    fn ln1(&self) -> &[f32] {
        &self.ln1
    }

    fn ln2(&self) -> &[f32] {
        &self.ln2
    }

    fn apply(&self, kind: LinearKind, x: &Matrix) -> Matrix {
        self.op(kind).forward(x)
    }

    fn weight_bytes(&self) -> usize {
        let linears: usize =
            LinearKind::ALL.iter().map(|&k| self.op(k).weight_bytes()).sum();
        linears + (self.ln1.len() + self.ln2.len()) * 4
    }
}

/// A whole executable model (see module docs).
#[derive(Clone, Debug)]
pub struct ExecModel {
    pub config: ModelConfig,
    /// `[vocab, d_model]` token embedding (always FP).
    pub embed: Matrix,
    pub layers: Vec<ExecLayer>,
    pub ln_f: Vec<f32>,
    /// `[vocab, d_model]` untied output head (always FP).
    pub head: Matrix,
}

impl ExecModel {
    /// Wrap dense weights — every projection a [`LinearOp::Dense`]. Moves
    /// the matrices; no copies.
    pub fn from_dense(w: ModelWeights) -> ExecModel {
        ExecModel {
            config: w.config,
            embed: w.embed,
            layers: w.layers.into_iter().map(ExecLayer::from_dense).collect(),
            ln_f: w.ln_f,
            head: w.head,
        }
    }

    /// Build the packed execution form of a quantized model: every linear
    /// with a packed form runs [`LinearOp::Packed`]; norms/embedding/head
    /// come from the FP side. The dense (dequantized) linears in
    /// `qm.weights` are *not* used.
    pub fn from_quantized(qm: &QuantizedModel) -> ExecModel {
        let layers = qm
            .weights
            .layers
            .iter()
            .enumerate()
            .map(|(li, l)| {
                let pick = |kind: LinearKind| -> LinearOp {
                    match qm.get(li, kind) {
                        Some(q) => LinearOp::Packed(q.clone()),
                        None => LinearOp::Dense(l.linear(kind).clone()),
                    }
                };
                ExecLayer {
                    wq: pick(LinearKind::Wq),
                    wk: pick(LinearKind::Wk),
                    wv: pick(LinearKind::Wv),
                    wo: pick(LinearKind::Wo),
                    w1: pick(LinearKind::W1),
                    w3: pick(LinearKind::W3),
                    w2: pick(LinearKind::W2),
                    ln1: l.ln1.clone(),
                    ln2: l.ln2.clone(),
                }
            })
            .collect();
        ExecModel {
            config: qm.config,
            embed: qm.weights.embed.clone(),
            layers,
            ln_f: qm.weights.ln_f.clone(),
            head: qm.weights.head.clone(),
        }
    }

    /// How many of the model's linears execute packed.
    pub fn packed_linears(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| LinearKind::ALL.iter().map(|&k| l.op(k)))
            .filter(|op| op.is_packed())
            .count()
    }

    /// Total number of linears (packed + dense).
    pub fn total_linears(&self) -> usize {
        self.layers.len() * LinearKind::ALL.len()
    }

    /// f32 bytes the same linears would occupy dense — the denominator of
    /// the packed bytes-touched ratio, derived from the actual layer shapes
    /// rather than re-assuming them.
    pub fn dense_linear_bytes(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| LinearKind::ALL.iter().map(|&k| l.op(k)))
            .map(|op| op.out_dim() * op.in_dim() * 4)
            .sum()
    }

    /// Weight bytes read by one full token step across all linears — the
    /// bytes-touched column of the packed-GEMV bench (embedding/head are FP
    /// in both representations and excluded).
    pub fn linear_weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| LinearKind::ALL.iter().map(|&k| l.op(k)))
            .map(|op| op.weight_bytes())
            .sum()
    }

    /// One-line dequant-kernel dispatch summary for this model's packed
    /// linears under the currently active kernel table, e.g.
    /// `"avx2 [INT2→avx2-srlv, INT4→avx2-srlv]"` — printed by the serve/eval
    /// `--packed` banners and `tsgo kernels` so a deployment log always
    /// records which unpack paths actually ran.
    pub fn kernel_dispatch(&self) -> String {
        let table = crate::tensor::kernels::active_table();
        let mut widths: Vec<u8> = self
            .layers
            .iter()
            .flat_map(|l| LinearKind::ALL.iter().map(|&k| l.op(k)))
            .filter_map(|op| match op {
                LinearOp::Packed(q) => Some(q.bits),
                LinearOp::Dense(_) => None,
            })
            .collect();
        widths.sort_unstable();
        widths.dedup();
        if widths.is_empty() {
            return format!("{} [no packed linears]", table.name);
        }
        let per_width: Vec<String> = widths
            .iter()
            .map(|&b| format!("INT{b}→{}", table.labels[b as usize]))
            .collect();
        format!("{} [{}]", table.name, per_width.join(", "))
    }
}

impl ModelExec for ExecModel {
    type Layer = ExecLayer;

    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn embed_row(&self, token: u8) -> &[f32] {
        self.embed.row(token as usize)
    }

    fn layers(&self) -> &[ExecLayer] {
        &self.layers
    }

    fn ln_f(&self) -> &[f32] {
        &self.ln_f
    }

    fn apply_head(&self, x: &Matrix) -> Matrix {
        x.matmul_bt(&self.head)
    }

    fn embed_bytes(&self) -> usize {
        self.embed.data.len() * 4
    }

    fn head_bytes(&self) -> usize {
        (self.head.data.len() + self.ln_f.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Preset;
    use crate::model::forward_logits;
    use crate::quant::scale::{compute_group_scales, QuantSpec, ScaleMetric};
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn quantized_tiny(seed: u64, bits: u8) -> (ModelWeights, QuantizedModel) {
        let cfg = Preset::Tiny.config();
        let mut rng = Rng::new(seed);
        let w = ModelWeights::init(cfg, &mut rng);
        let spec = QuantSpec::new(bits, 32);
        let mut weights = w.clone();
        let mut linears = BTreeMap::new();
        for li in 0..cfg.n_layers {
            for kind in LinearKind::ALL {
                let m = w.layers[li].linear(kind).clone();
                let scales = compute_group_scales(&m, &spec, ScaleMetric::L2, None);
                let q = crate::quant::rtn::rtn_quantize(&m, &scales, &spec);
                *weights.layers[li].linear_mut(kind) = q.dequantize();
                linears.insert((li, kind.label()), q);
            }
        }
        (
            w,
            QuantizedModel { config: cfg, weights, linears, quantizers: BTreeMap::new() },
        )
    }

    #[test]
    fn dense_wrap_preserves_logits() {
        let cfg = Preset::Tiny.config();
        let mut rng = Rng::new(3);
        let w = ModelWeights::init(cfg, &mut rng);
        let tokens: Vec<u8> = (0..8).collect();
        let want = forward_logits(&w, &tokens);
        let em = ExecModel::from_dense(w);
        assert_eq!(em.packed_linears(), 0);
        let got = forward_logits(&em, &tokens);
        assert!(got.max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn kernel_dispatch_names_packed_widths() {
        let (_, qm) = quantized_tiny(6, 2);
        let em = ExecModel::from_quantized(&qm);
        let s = em.kernel_dispatch();
        assert!(s.contains("INT2"), "{s}");
        let dense = ExecModel::from_dense(qm.weights.clone());
        assert!(dense.kernel_dispatch().contains("no packed linears"));
    }

    #[test]
    fn packed_exec_matches_dequantized_dense() {
        // The tentpole end-to-end equivalence at model level: running the
        // packed ints through the fused kernels == running the dequantized
        // dense weights.
        let (_, qm) = quantized_tiny(4, 4);
        let em = ExecModel::from_quantized(&qm);
        assert_eq!(em.packed_linears(), 7 * qm.config.n_layers);
        let dense_bytes = ExecModel::from_dense(qm.weights.clone()).linear_weight_bytes();
        assert!(em.linear_weight_bytes() * 4 < dense_bytes);
        let tokens: Vec<u8> = (0..12).map(|i| i * 19).collect();
        let dense = forward_logits(&qm.weights, &tokens);
        let packed = forward_logits(&em, &tokens);
        let scale = dense.data.iter().fold(1.0f32, |m, v| m.max(v.abs()));
        assert!(
            packed.max_abs_diff(&dense) < 1e-3 * scale,
            "diff {}",
            packed.max_abs_diff(&dense)
        );
    }
}
