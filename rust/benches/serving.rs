//! Serving benchmark: batched generation throughput and latency percentiles,
//! FP32 vs INT2-quantized weights, across batch sizes — the deployment
//! motivation of §2.2 (decode is memory-bound, so weight compression buys
//! capacity). Also reports the dynamic batcher's coalescing behaviour.
//!
//! `cargo bench --bench serving`

use std::sync::Arc;
use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::kvpool::{KvPool, PoolCfg};
use tsgo::model::{ExecModel, KvSpec, ModelExec, ModelWeights, Preset};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantSpec;
use tsgo::serve::client::ClientResponse;
use tsgo::serve::server::serve_in_background;
use tsgo::serve::{request_generation, BatcherConfig, ServerConfig};
use tsgo::util::bench::Table;
use tsgo::util::rng::Rng;

/// Serve `weights` with the given batcher config, drive it with `clients`
/// concurrent connections each sending a `prompt_len`-token prompt, and
/// return (responses, wall seconds).
fn run_server<M: ModelExec + Send + Sync + 'static>(
    weights: Arc<M>,
    clients: usize,
    prompt_len: usize,
    max_new: usize,
    batcher: BatcherConfig,
) -> (Vec<ClientResponse>, f64) {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        batcher,
        max_connections: Some(clients),
        ..Default::default()
    };
    let (addr, handle) = serve_in_background(weights, cfg).unwrap();
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 50_000, 11);
    let t0 = std::time::Instant::now();
    let joins: Vec<_> = (0..clients)
        .map(|i| {
            let addr = addr.to_string();
            let prompt = corpus.bytes[i * 64..i * 64 + prompt_len].to_vec();
            std::thread::spawn(move || request_generation(&addr, &prompt, max_new).unwrap())
        })
        .collect();
    let responses: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    handle.join().unwrap();
    (responses, wall)
}

fn percentiles(responses: &[ClientResponse], wall: f64) -> (f64, f64, f64) {
    let lat: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
    let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
    (
        toks as f64 / wall,
        tsgo::util::percentile(&lat, 50.0),
        tsgo::util::percentile(&lat, 95.0),
    )
}

fn measure<M: ModelExec + Send + Sync + 'static>(
    weights: Arc<M>,
    clients: usize,
    prompt_len: usize,
    max_new: usize,
    kv: KvSpec,
) -> (f64, f64, f64, usize) {
    measure_sharded(weights, clients, prompt_len, max_new, kv, 1)
}

fn measure_sharded<M: ModelExec + Send + Sync + 'static>(
    weights: Arc<M>,
    clients: usize,
    prompt_len: usize,
    max_new: usize,
    kv: KvSpec,
    shards: usize,
) -> (f64, f64, f64, usize) {
    let batcher = BatcherConfig { max_batch: clients.max(1), kv, shards, ..Default::default() };
    let (responses, wall) = run_server(weights, clients, prompt_len, max_new, batcher);
    let (tps, p50, p95) = percentiles(&responses, wall);
    let maxb = responses.iter().map(|r| r.batch_size).max().unwrap_or(1);
    (tps, p50, p95, maxb)
}

/// Constrained-pool variant (`--kv-pool-mb`): same drive, plus the
/// preemption total and per-sequence peak page count from the responses.
fn measure_pooled<M: ModelExec + Send + Sync + 'static>(
    weights: Arc<M>,
    clients: usize,
    prompt_len: usize,
    max_new: usize,
    kv: KvSpec,
    pool: PoolCfg,
) -> (f64, f64, f64, usize, usize) {
    let batcher = BatcherConfig {
        max_batch: clients.max(1),
        kv,
        pool: Some(pool),
        ..Default::default()
    };
    let (responses, wall) = run_server(weights, clients, prompt_len, max_new, batcher);
    let (tps, p50, p95) = percentiles(&responses, wall);
    let preempts: usize = responses.iter().map(|r| r.preemptions).sum();
    let peak = responses.iter().map(|r| r.kv_pages_used).max().unwrap_or(0);
    (tps, p50, p95, preempts, peak)
}

fn main() {
    // model: trained checkpoint when present, else tiny init (keeps the
    // bench fast everywhere).
    let fp = match tsgo::model::store::load_model(std::path::Path::new("model.tsr")) {
        Ok(w) => w,
        Err(_) => {
            let mut rng = Rng::new(4);
            ModelWeights::init(Preset::Tiny.config(), &mut rng)
        }
    };
    println!(
        "serving bench on {:.2}M params (d={})",
        fp.config.n_params() as f64 / 1e6,
        fp.config.d_model
    );
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 100_000, 1);
    let calib = calibration_batches(&corpus.bytes, 8, fp.config.seq_len.min(64), 4, 3);
    let (qm, _) = quantize_model(
        &fp,
        &calib,
        &PipelineConfig::new(QuantSpec::new(2, 64), "ours"),
    )
    .unwrap();
    let fp_mb = (fp.config.n_params() * 4) as f64 / 1e6;
    let q_mb = qm.packed_bytes() as f64 / 1e6;

    let mut table = Table::new(&[
        "weights", "kv", "clients", "tok/s", "p50 ms", "p95 ms", "max batch",
    ]);
    let packed = Arc::new(ExecModel::from_quantized(&qm));
    let lin_fp_bytes = packed.dense_linear_bytes();
    let fp = Arc::new(fp);
    let q = Arc::new(qm.weights);
    let max_new = 24;
    let kv8 = KvSpec::PackedGroupwise { bits: 8, group: 64 };
    let kv4 = KvSpec::PackedGroupwise { bits: 4, group: 64 };
    for clients in [1usize, 4, 8] {
        let rows = [
            ("FP32", KvSpec::DenseF32),
            ("INT2-dequant", KvSpec::DenseF32),
            ("INT2-packed", KvSpec::DenseF32),
            ("INT2-packed", kv8),
            ("INT2-packed", kv4),
        ];
        for (label, kv) in rows {
            let (tps, p50, p95, maxb) = match label {
                "FP32" => measure(fp.clone(), clients, 16, max_new, kv),
                "INT2-dequant" => measure(q.clone(), clients, 16, max_new, kv),
                _ => measure(packed.clone(), clients, 16, max_new, kv),
            };
            table.row(vec![
                label.into(),
                kv.effective(&fp.config).label(),
                clients.to_string(),
                format!("{tps:.1}"),
                format!("{p50:.1}"),
                format!("{p95:.1}"),
                maxb.to_string(),
            ]);
        }
    }
    table.print("serving throughput / latency");

    // -- pipeline-parallel shard scaling ------------------------------------
    // The same packed model served through `--shards N`: layers split over N
    // shard threads with channel activation handoff, driven by the
    // step-level scheduler. Shard counts above the model's layer count
    // clamp (the plan gives every shard ≥1 layer), so on shallow bench
    // models the 4-shard row measures the clamped plan.
    let mut shard_table =
        Table::new(&["weights", "shards", "clients", "prompt", "tok/s", "p50 ms", "p95 ms"]);
    for shards in [1usize, 2, 4] {
        for clients in [1usize, 8] {
            for prompt_len in [16usize, 32] {
                let (tps, p50, p95, _) = measure_sharded(
                    packed.clone(),
                    clients,
                    prompt_len,
                    max_new,
                    KvSpec::DenseF32,
                    shards,
                );
                shard_table.row(vec![
                    "INT2-packed".into(),
                    shards.to_string(),
                    clients.to_string(),
                    prompt_len.to_string(),
                    format!("{tps:.1}"),
                    format!("{p50:.1}"),
                    format!("{p95:.1}"),
                ]);
            }
        }
    }
    shard_table.print("pipeline-parallel serving (`--shards N`, step-level scheduler)");

    // -- budget-bounded paged KV pool (`--kv-pool-mb`) ----------------------
    // The same packed model with every KV cache paged out of one shared
    // pool. "ample" holds the full 8-client working set, so only admission
    // accounting runs; "half" holds ~56% of it, forcing mid-decode
    // preemption + re-prefill. Generated tokens are unchanged either way
    // (greedy decode is deterministic) — the pressure shows up in p95 and
    // the preemption column.
    let pt = PoolCfg::DEFAULT_PAGE_TOKENS;
    let probe = KvPool::new(
        PoolCfg { budget_bytes: 1 << 30, page_tokens: pt },
        KvSpec::DenseF32,
        &fp.config,
    );
    let mut pool_table = Table::new(&[
        "pool", "pages", "clients", "prompt", "tok/s", "p50 ms", "p95 ms", "preempt",
        "peak pages",
    ]);
    for prompt_len in [16usize, 32] {
        let per_seq = 2 * fp.config.n_layers * probe.pages_for_rows(prompt_len + max_new);
        for (label, pages) in [("ample", 8 * per_seq), ("half", 9 * per_seq / 2)] {
            let pc = PoolCfg { budget_bytes: pages * probe.page_bytes(), page_tokens: pt };
            let (tps, p50, p95, preempts, peak) =
                measure_pooled(packed.clone(), 8, prompt_len, max_new, KvSpec::DenseF32, pc);
            pool_table.row(vec![
                label.into(),
                pages.to_string(),
                "8".into(),
                prompt_len.to_string(),
                format!("{tps:.1}"),
                format!("{p50:.1}"),
                format!("{p95:.1}"),
                preempts.to_string(),
                peak.to_string(),
            ]);
        }
    }
    pool_table.print("paged KV pool (`--kv-pool-mb`: budget admission + preemption)");

    // -- KV-cache bytes per decoded token (all layers, K+V) -----------------
    // The decode-bandwidth story once weights are packed: the f32 KV cache
    // is what is left to shrink. Reported for the bench model's shape and
    // the serving presets (the ≥3.5× int8 bar holds from head_dim 64 up —
    // per-head scale/zero overhead fades as heads widen).
    let mut kvt = Table::new(&["model", "kv format", "KV B/token", "vs f32"]);
    for (mlabel, c) in [
        ("bench model", fp.config),
        ("small", Preset::Small.config()),
        ("base", Preset::Base.config()),
    ] {
        let dense = KvSpec::DenseF32.bytes_per_token(&c) * c.n_layers;
        for spec in [KvSpec::DenseF32, kv8, kv4] {
            let b = spec.bytes_per_token(&c) * c.n_layers;
            kvt.row(vec![
                mlabel.into(),
                spec.effective(&c).label(),
                b.to_string(),
                format!("{:.2}x", dense as f64 / b as f64),
            ]);
        }
    }
    kvt.print("KV cache bytes per decoded token (all layers, K+V)");

    println!(
        "weight footprint: {fp_mb:.1} MB fp32 → {q_mb:.1} MB packed ({:.1}× smaller).\n\
         INT2-dequant serves dense weights dequantized at load; INT2-packed executes\n\
         the packed ints through the fused dequant kernels (`tsgo serve --packed`),\n\
         touching {:.1}× fewer linear-weight bytes per token. The kv column shows the\n\
         decode KV-cache representation (`--kv-bits/--kv-group`). Kernel-level\n\
         numbers: `cargo bench --bench packed_gemv`.",
        fp_mb / q_mb,
        lin_fp_bytes as f64 / packed.linear_weight_bytes() as f64
    );
}
