//! **Table 1** — group-wise quantization, group size 64: FP baseline vs
//! {GPTQ, ours} at INT2 and INT3; columns = PPL(synthwiki), PPL(synthc4),
//! 0-shot average, plus our layer-loss and wall-clock diagnostics.
//!
//! `cargo bench --bench table1_group64` (env: TSGO_BENCH_PRESET=tiny|small|base,
//! TSGO_BENCH_CALIB=<n seqs>).

mod common;

use tsgo::util::bench::Table;

fn main() {
    let env = common::setup(common::preset_from_env());
    env.describe("Table 1 — group size 64");

    let mut table = Table::new(&[
        "precision", "method", "synthwiki (↓)", "synthc4 (↓)", "0-shot (↑)",
        "Σ layer loss", "time (s)",
    ]);
    table.row(vec![
        "FP".into(),
        "baseline".into(),
        format!("{:.3}", env.ppl(&env.fp, &env.wiki_test)),
        format!("{:.3}", env.ppl(&env.fp, &env.c4_test)),
        format!("{:.2}", env.zero_shot(&env.fp)),
        "-".into(),
        "-".into(),
    ]);
    for bits in [2u8, 3] {
        for method in ["gptq", "ours"] {
            let r = common::run_cell(&env, bits, 64, method);
            table.row(vec![
                r.precision,
                r.method.into(),
                format!("{:.3}", r.wiki),
                format!("{:.3}", r.c4),
                format!("{:.2}", r.zshot),
                format!("{:.3e}", r.layer_loss),
                format!("{:.1}", r.secs),
            ]);
        }
    }
    table.print("Table 1 reproduction (group=64)");
    println!("paper shape to verify: ours beats GPTQ on every row; INT2 gaps are large, INT3 gaps small.");
}
