//! **Table 3** — ablation of the two stages on 2-bit group-64 quantization:
//! {GPTQ, +stage1, +stage2, +both} × {Wiki2-PPL, C4-PPL, time}. Paper's
//! claims: each stage alone already yields large gains, combining both is
//! best, and total runtime overhead stays small (5.85 → 7.53 min ≈ 1.29×).
//!
//! `cargo bench --bench table3_ablation`

mod common;

use tsgo::util::bench::Table;

fn main() {
    let env = common::setup(common::preset_from_env());
    env.describe("Table 3 — ablation (INT2, group 64)");

    let mut table = Table::new(&[
        "stage1", "stage2", "synthwiki (↓)", "synthc4 (↓)", "Σ layer loss",
        "time (s)", "time vs GPTQ",
    ]);
    let mut base_time = None;
    // the four TwoStage registry cells, in Table-3 row order
    for (method, s1, s2) in [
        ("gptq", "", ""),
        ("stage1", "✓", ""),
        ("stage2", "", "✓"),
        ("ours", "✓", "✓"),
    ] {
        let r = common::run_cell(&env, 2, 64, method);
        let rel = match base_time {
            None => {
                base_time = Some(r.secs);
                "1.00×".to_string()
            }
            Some(b) => format!("{:.2}×", r.secs / b),
        };
        table.row(vec![
            s1.into(),
            s2.into(),
            format!("{:.3}", r.wiki),
            format!("{:.3}", r.c4),
            format!("{:.3e}", r.layer_loss),
            format!("{:.1}", r.secs),
            rel,
        ]);
    }
    table.print("Table 3 reproduction (ablation)");
    println!("paper shape to verify: every ✓ row beats bare GPTQ; both-✓ best; time ratio ≈1.3×.");
}
