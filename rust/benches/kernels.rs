//! Kernel / hot-path microbenchmarks (the §Perf evidence in EXPERIMENTS.md):
//!
//! * native blocked GEMM vs dequantize+GEMM (the simulated-deployment cost);
//! * the fused dequant-matmul HLO artifact (L1 Pallas path) vs native;
//! * Hessian accumulation: native threaded vs the Pallas artifact;
//! * stage-1 grid search and stage-2 CD sweep throughput;
//! * the GPTQ inner sweep.
//!
//! `cargo bench --bench kernels`

use tsgo::pipeline::MomentAccum;
use tsgo::quant::scale::{compute_group_scales, QuantSpec, ScaleMetric};
use tsgo::quant::stage2::Stage2Config;
use tsgo::quant::{gptq_quantize, resolve_quantizer, GptqConfig, QuantContext, QUANTIZER_NAMES};
use tsgo::runtime::{matrix_to_literal, Engine};
use tsgo::tensor::Matrix;
use tsgo::util::bench::{bench_units, print_measurements, Measurement};
use tsgo::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let mut ms: Vec<Measurement> = Vec::new();
    let iters: usize = std::env::var("TSGO_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);

    // ---- GEMM family ---------------------------------------------------
    let (m, k, n) = (256, 704, 128);
    let a = Matrix::randn(m, k, 1.0, &mut rng);
    let b = Matrix::randn(n, k, 1.0, &mut rng); // used transposed
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    ms.push(bench_units(
        &format!("native gemm f32 [{m}x{k}]·[{k}x{n}]"),
        2,
        iters,
        Some(flops),
        &mut || {
            std::hint::black_box(a.matmul_bt(&b));
        },
    ));

    let spec = QuantSpec::new(2, 64);
    let scales = compute_group_scales(&b, &spec, ScaleMetric::L2, None);
    let q = tsgo::quant::rtn::rtn_quantize(&b, &scales, &spec);
    ms.push(bench_units(
        "dequant(INT2) + gemm (deploy path)",
        2,
        iters,
        Some(flops),
        &mut || {
            let w = q.dequantize();
            std::hint::black_box(a.matmul_bt(&w));
        },
    ));

    // ---- Hessian accumulation ------------------------------------------
    let t = 2048;
    let d = 256;
    let x = Matrix::randn(t, d, 1.0, &mut rng);
    let hflops = t as f64 * d as f64 * d as f64;
    ms.push(bench_units(
        &format!("hessian accum native [{t}x{d}]"),
        1,
        iters,
        Some(hflops),
        &mut || {
            let mut acc = MomentAccum::new(d);
            acc.add(&x);
            std::hint::black_box(acc.finalize());
        },
    ));

    // ---- scale search + refinement ---------------------------------------
    let w = Matrix::randn(704, 256, 1.0, &mut rng);
    let xact = Matrix::randn(256, 1024, 1.0, &mut rng);
    let mut h = xact.matmul_bt(&xact);
    h.scale_inplace(1.0 / 1024.0);
    let groups = (w.rows * w.cols / 64) as f64;

    ms.push(bench_units(
        "stage1 grid init (H_ii metric) [704x256]",
        1,
        iters.min(5),
        Some(groups),
        &mut || {
            std::hint::black_box(tsgo::quant::stage1::stage1_init(&w, &h, &spec));
        },
    ));
    ms.push(bench_units(
        "baseline grid init (L2) [704x256]",
        1,
        iters.min(5),
        Some(groups),
        &mut || {
            std::hint::black_box(tsgo::quant::stage1::baseline_init(&w, &spec));
        },
    ));

    let gscales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
    ms.push(bench_units(
        "gptq sweep [704x256] INT2",
        1,
        iters.min(5),
        Some((w.rows * w.cols) as f64),
        &mut || {
            std::hint::black_box(
                gptq_quantize(&w, &h, &gscales, &spec, &GptqConfig::default()).unwrap(),
            );
        },
    ));

    let mut qlin = gptq_quantize(&w, &h, &gscales, &spec, &GptqConfig::default()).unwrap();
    ms.push(bench_units(
        "stage2 CD refine (4 sweeps) [704x256]",
        1,
        iters.min(5),
        Some(groups * 4.0),
        &mut || {
            let mut q2 = qlin.clone();
            std::hint::black_box(tsgo::quant::stage2::refine_quantized_linear(
                &w,
                &mut q2,
                &h,
                None,
                &Stage2Config::default(),
            ));
        },
    ));
    // keep qlin alive for potential artifact comparison below
    let _ = &mut qlin;

    // ---- unified trait path ----------------------------------------------
    // Whole-layer quantization throughput for every registered quantizer —
    // the same entry point the pipeline, CLI and serving path use.
    let ctx = QuantContext::default();
    for name in QUANTIZER_NAMES {
        let quantizer = resolve_quantizer(name).unwrap();
        ms.push(bench_units(
            &format!("layer-quantize '{name}' [704x256] INT2 (trait path)"),
            1,
            iters.min(3),
            Some((w.rows * w.cols) as f64),
            &mut || {
                std::hint::black_box(quantizer.quantize(&w, &h, None, &spec, &ctx).unwrap());
            },
        ));
    }

    // ---- artifact (Pallas) paths ----------------------------------------
    if let Some(engine) = Engine::open_default() {
        let cfg = engine.manifest.config;
        if engine.has_entry("hessian_accum_d") {
            let entry = engine.manifest.entry("hessian_accum_d").unwrap();
            let (ta, da) = (entry.inputs[0].shape[0], entry.inputs[0].shape[1]);
            let xa = Matrix::randn(ta, da, 1.0, &mut rng);
            let lit = matrix_to_literal(&xa).unwrap();
            engine.execute("hessian_accum_d", &[lit]).unwrap(); // compile
            ms.push(bench_units(
                &format!("hessian accum pallas-HLO [{ta}x{da}]"),
                1,
                iters,
                Some(ta as f64 * da as f64 * da as f64),
                &mut || {
                    let lit = matrix_to_literal(&xa).unwrap();
                    std::hint::black_box(engine.execute("hessian_accum_d", &[lit]).unwrap());
                },
            ));
        }
        if engine.has_entry("dequant_matmul") {
            let e = engine.manifest.entry("dequant_matmul").unwrap();
            let (tq, cin) = (e.inputs[0].shape[0], e.inputs[0].shape[1]);
            let (rows, nwords) = (e.inputs[1].shape[0], e.inputs[1].shape[1]);
            let n_g = e.inputs[2].shape[1];
            let xq = Matrix::randn(tq, cin, 1.0, &mut rng);
            let words = vec![0x55AA55AAu32; rows * nwords];
            let sc = Matrix::randn(rows, n_g, 0.05, &mut rng);
            let zs = Matrix::zeros(rows, n_g);
            let run = |engine: &Engine| {
                let inputs = vec![
                    matrix_to_literal(&xq).unwrap(),
                    xla::Literal::vec1(&words)
                        .reshape(&[rows as i64, nwords as i64])
                        .unwrap(),
                    matrix_to_literal(&sc).unwrap(),
                    matrix_to_literal(&zs).unwrap(),
                ];
                engine.execute("dequant_matmul", &inputs).unwrap()
            };
            run(&engine); // compile
            ms.push(bench_units(
                &format!("fused dequant-matmul pallas-HLO [{tq}x{cin}]→[{tq}x{rows}]"),
                1,
                iters,
                Some(2.0 * tq as f64 * cin as f64 * rows as f64),
                &mut || {
                    std::hint::black_box(run(&engine));
                },
            ));
        }
        let _ = cfg;
    } else {
        println!("(artifacts missing — pallas-HLO comparisons skipped; run `make artifacts`)");
    }

    print_measurements("kernel microbenchmarks", &ms);
    println!("\nthroughput column: FLOP/s for gemm/hessian rows, groups/s for scale-search rows, weights/s for the gptq sweep.");
}
