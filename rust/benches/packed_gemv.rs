//! Packed execution microbenchmarks: the fused group-wise dequant GEMV/GEMM
//! against the dense f32 path it replaces, and the dispatched SIMD kernels
//! against the forced-scalar reference.
//!
//! Views, each with a bytes-touched column (the memory-bandwidth story that
//! motivates weight-only quantization — paper §2.2):
//!
//! * single-token GEMV (the decode hot loop) per bit width, forced-scalar
//!   vs dispatched — the per-kernel speedup table;
//! * prefill GEMM scaling with batch size (the two-level blocking means
//!   throughput keeps climbing past the activation row count);
//! * chunked prefill TTFT: a 512-token prompt through `step_span` at
//!   `--prefill-chunk` 1 / 16 / 64 — the GEMV-to-GEMM prefill payoff;
//! * end-to-end KV-cached decode tokens/s, dense [`ExecModel`] vs packed,
//!   paged-pool vs contiguous KV, plus batch-1 pipeline decode at 1/2/4
//!   shards (the per-step handoff overhead floor; batched shard scaling
//!   lives in the serving bench) — and a constrained-pool serving pass
//!   that records the preemption rate under deliberate memory pressure;
//! * fault-plane pricing (PR 8): the packed decode through the scheduler
//!   step surface with the fault plane unarmed vs armed-but-idle — the
//!   pair of rows behind the "zero-cost when unarmed" claim;
//! * telemetry-plane pricing: the same scheduler-surface decode with the
//!   full per-step registry recording (`tsgo::obs`) the serving scheduler
//!   performs — counters, histogram, gauge, trace ring — priced against
//!   the fault-unarmed row (the "relaxed atomics are within noise" claim).
//!
//! Besides the human-readable tables, the run emits a machine-readable
//! baseline to `BENCH_packed_gemv.json` (override with `TSGO_BENCH_JSON`)
//! so the repo carries a perf trajectory across PRs: tokens/s, GB/s and
//! scalar-vs-dispatched speedup per bit width, plus the GEMM batch sweep.
//!
//! `cargo bench --bench packed_gemv` (or `make bench-json` from the repo
//! root, which drops the JSON next to this README).

use std::collections::BTreeMap;
use std::sync::Arc;
use tsgo::kvpool::{KvPool, PoolCfg};
use tsgo::model::{DecodeState, ExecModel, KvSpec, ModelWeights, Preset};
use tsgo::obs::{self, StepEvent, SOURCE_SCHED};
use tsgo::quant::rtn::rtn_quantize;
use tsgo::quant::scale::{compute_group_scales, QuantSpec, ScaleMetric};
use tsgo::quant::QuantizedLinear;
use tsgo::serve::{
    AdmitVerdict, BatcherConfig, DynamicBatcher, GenRequest, LocalBackend, SamplerChain,
    SamplingParams, StepBackend, StepJob,
};
use tsgo::shard::ShardedModel;
use tsgo::tensor::kernels::{self, ForcedKernel};
use tsgo::tensor::Matrix;
use tsgo::util::bench::{bench_units, print_measurements, Measurement, Table};
use tsgo::util::fault::{self, FaultPlan, FaultPoint};
use tsgo::util::json::Json;
use tsgo::util::rng::Rng;

fn quantize(w: &Matrix, bits: u8, group: usize) -> QuantizedLinear {
    let spec = QuantSpec::new(bits, group);
    let scales = compute_group_scales(w, &spec, ScaleMetric::L2, None);
    rtn_quantize(w, &scales, &spec)
}

/// RTN-quantize every linear of a fresh `cfg`-shaped model to INT2 g64 —
/// the decode sections' shared model recipe. Callers build whichever exec
/// forms (packed / dequantized-dense) they actually bench.
fn int2_quantized_model(
    cfg: tsgo::model::ModelConfig,
    rng: &mut Rng,
) -> tsgo::model::store::QuantizedModel {
    let fp = ModelWeights::init(cfg, rng);
    let spec = QuantSpec::new(2, 64);
    let mut weights = fp.clone();
    let mut linears = BTreeMap::new();
    for (li, kind, m) in fp.linears() {
        let scales = compute_group_scales(m, &spec, ScaleMetric::L2, None);
        let q = rtn_quantize(m, &scales, &spec);
        *weights.layers[li].linear_mut(kind) = q.dequantize();
        linears.insert((li, kind.label()), q);
    }
    tsgo::model::store::QuantizedModel {
        config: cfg,
        weights,
        linears,
        quantizers: BTreeMap::new(),
    }
}

fn main() {
    let mut rng = Rng::new(13);
    let iters: usize = std::env::var("TSGO_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    // A w2-shaped linear: [out, in] = [256, 704] at group 64.
    let (out_dim, in_dim, group) = (256usize, 704usize, 64usize);
    let w = Matrix::randn(out_dim, in_dim, 1.0, &mut rng);
    let x1 = Matrix::randn(1, in_dim, 1.0, &mut rng);

    let mut ms: Vec<Measurement> = Vec::new();
    let mut bytes = Table::new(&["path", "weight bytes", "vs dense", "bits/weight"]);
    let dense_bytes = out_dim * in_dim * 4;
    bytes.row(vec!["dense f32".into(), format!("{dense_bytes}"), "1.00x".into(), "32.00".into()]);

    let m_dense_gemv = bench_units("gemv dense f32", 3, iters, Some(1.0), &mut || {
        std::hint::black_box(x1.matmul_bt(&w));
    });
    ms.push(m_dense_gemv.clone());

    // -- per-bit-width GEMV: forced-scalar vs dispatched kernels ------------
    let mut speed = Table::new(&["kernel", "scalar tok/s", "dispatched tok/s", "speedup", "GB/s"]);
    let mut gemv_json: Vec<Json> = Vec::new();
    for bits in [2u8, 3, 4, 8] {
        let q = quantize(&w, bits, group);
        bytes.row(vec![
            format!("packed INT{bits} g{group}"),
            format!("{}", q.nbytes()),
            format!("{:.2}x", dense_bytes as f64 / q.nbytes() as f64),
            format!("{:.2}", q.bits_per_weight()),
        ]);
        kernels::set_forced(ForcedKernel::Scalar);
        let m_scalar = bench_units(
            &format!("gemv packed INT{bits} · forced scalar"),
            3,
            iters,
            Some(1.0),
            &mut || {
                std::hint::black_box(q.forward(&x1));
            },
        );
        kernels::set_forced(ForcedKernel::Best);
        let m_disp = bench_units(
            &format!("gemv packed INT{bits} · dispatched"),
            3,
            iters,
            Some(1.0),
            &mut || {
                std::hint::black_box(q.forward(&x1));
            },
        );
        kernels::set_forced(ForcedKernel::Auto);
        ms.push(m_scalar.clone());
        ms.push(m_disp.clone());
        ms.push(bench_units(
            &format!("gemv dequant(INT{bits}) + dense (old deploy path)"),
            1,
            iters.min(10),
            Some(1.0),
            &mut || {
                let d = q.dequantize();
                std::hint::black_box(x1.matmul_bt(&d));
            },
        ));
        let scalar_tps = m_scalar.throughput().unwrap_or(0.0);
        let disp_tps = m_disp.throughput().unwrap_or(0.0);
        let speedup = m_scalar.mean.as_secs_f64() / m_disp.mean.as_secs_f64().max(1e-12);
        let gbs = q.nbytes() as f64 / m_disp.mean.as_secs_f64().max(1e-12) / 1e9;
        speed.row(vec![
            format!("INT{bits}"),
            format!("{scalar_tps:.1}"),
            format!("{disp_tps:.1}"),
            format!("{speedup:.2}x"),
            format!("{gbs:.2}"),
        ]);
        gemv_json.push(Json::obj(vec![
            ("bits", Json::num(bits as f64)),
            ("weight_bytes", Json::num(q.nbytes() as f64)),
            ("scalar_tokens_per_s", Json::num(scalar_tps)),
            ("dispatched_tokens_per_s", Json::num(disp_tps)),
            ("speedup", Json::num(speedup)),
            ("dispatched_gb_per_s", Json::num(gbs)),
        ]));
    }

    // -- GEMM scaling with batch size (beyond the activation row count) -----
    // Pin the dispatched table explicitly so the JSON baseline records what
    // actually ran even under TSGO_FORCE_SCALAR=1.
    kernels::set_forced(ForcedKernel::Best);
    let mut scaling = Table::new(&["kernel", "batch", "tok/s", "vs dense"]);
    let mut scaling_json: Vec<Json> = Vec::new();
    let batches = [1usize, 8, 32, 128];
    let xts: Vec<Matrix> =
        batches.iter().map(|&t| Matrix::randn(t, in_dim, 1.0, &mut rng)).collect();
    // one dense baseline per batch size, shared across every bit width
    let dense_gemm: Vec<Measurement> = batches
        .iter()
        .zip(&xts)
        .map(|(&t, xt)| {
            bench_units(
                &format!("gemm[{t}] dense f32"),
                1,
                iters.min(10),
                Some(t as f64),
                &mut || {
                    std::hint::black_box(xt.matmul_bt(&w));
                },
            )
        })
        .collect();
    ms.extend(dense_gemm.iter().cloned());
    for bits in [2u8, 4] {
        let q = quantize(&w, bits, group);
        for ((&t, xt), m_d) in batches.iter().zip(&xts).zip(&dense_gemm) {
            let m_p = bench_units(
                &format!("gemm[{t}] packed INT{bits} · dispatched"),
                1,
                iters.min(10),
                Some(t as f64),
                &mut || {
                    std::hint::black_box(q.forward(xt));
                },
            );
            let tps = m_p.throughput().unwrap_or(0.0);
            let vs_dense = m_d.mean.as_secs_f64() / m_p.mean.as_secs_f64().max(1e-12);
            scaling.row(vec![
                format!("INT{bits}"),
                format!("{t}"),
                format!("{tps:.1}"),
                format!("{vs_dense:.2}x"),
            ]);
            scaling_json.push(Json::obj(vec![
                ("bits", Json::num(bits as f64)),
                ("batch", Json::num(t as f64)),
                ("tokens_per_s", Json::num(tps)),
                ("speedup_vs_dense", Json::num(vs_dense)),
            ]));
        }
    }

    // -- end-to-end decode: dense ExecModel vs packed ExecModel -------------
    let cfg = Preset::Tiny.config();
    let qm = int2_quantized_model(cfg, &mut rng);
    let packed = ExecModel::from_quantized(&qm);
    let dense = ExecModel::from_dense(qm.weights.clone());
    let decode_tokens = 24usize;
    let run_decode = |m: &ExecModel, kv: KvSpec| {
        let mut st = DecodeState::with_kv(m, kv);
        let mut logits = st.step(65);
        for _ in 1..decode_tokens {
            let next = tsgo::serve::argmax_token(&logits).unwrap();
            logits = st.step(next);
        }
        logits
    };
    let m_decode_dense = bench_units(
        &format!("decode {decode_tokens} tok · dense exec (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || {
            std::hint::black_box(run_decode(&dense, KvSpec::DenseF32));
        },
    );
    let m_decode_packed = bench_units(
        &format!("decode {decode_tokens} tok · packed INT2 exec (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || {
            std::hint::black_box(run_decode(&packed, KvSpec::DenseF32));
        },
    );
    // Sampled decode (PR 9): the same packed decode, but every token goes
    // through a full sampler chain — repetition penalty, temperature, top-k,
    // top-p, seeded multinomial — pricing the per-token logit transforms
    // against the greedy row above. The seed is fixed, so the token stream
    // (and therefore the work done) is identical across iterations.
    let sampled_params = SamplingParams {
        temperature: 0.8,
        top_k: 40,
        top_p: 0.95,
        repetition_penalty: 1.1,
        seed: 7,
    };
    let m_decode_sampled = bench_units(
        &format!("decode {decode_tokens} tok · packed INT2 · sampled (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || {
            let mut chain = SamplerChain::from_params(&sampled_params).unwrap();
            let mut st = DecodeState::with_kv(&packed, KvSpec::DenseF32);
            let prompt = [65u8];
            let mut out: Vec<u8> = Vec::with_capacity(decode_tokens);
            let mut logits = st.step(65);
            for _ in 1..decode_tokens {
                let next = chain.next_token(&mut logits, &prompt, &out).unwrap();
                out.push(next);
                logits = st.step(next);
            }
            std::hint::black_box(logits);
        },
    );
    // Fault-plane pricing (PR 8): the same packed decode through the
    // scheduler backend's step surface, where the fault points actually
    // live (`run_job` evaluates two per span step). "fault unarmed" is the
    // production configuration — one relaxed atomic load per point;
    // "fault armed-idle" arms a spec whose hit count never fires, pricing
    // the slow path's counter bump. This row pair is the zero-cost claim
    // in ROADMAP "Fault tolerance (PR 8)".
    let sched_packed = Arc::new(ExecModel::from_quantized(&qm));
    let mut sched_be = LocalBackend::new(sched_packed, KvSpec::DenseF32, 1, None);
    let run_sched_decode = |be: &mut LocalBackend<ExecModel>| {
        let slot = match be.admit(1) {
            AdmitVerdict::Slot(s) => s,
            _ => unreachable!("the unpooled backend always admits"),
        };
        let mut logits = be.step(&[StepJob::single(slot, 0, 65)]).pop().unwrap().unwrap();
        for pos in 1..decode_tokens {
            let next = tsgo::serve::argmax_token(&logits).unwrap();
            logits = be.step(&[StepJob::single(slot, pos, next)]).pop().unwrap().unwrap();
        }
        be.retire(slot);
        std::hint::black_box(&logits);
    };
    fault::disarm();
    let m_decode_fault_unarmed = bench_units(
        &format!("decode {decode_tokens} tok · packed INT2 · fault unarmed (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || run_sched_decode(&mut sched_be),
    );
    fault::arm(&FaultPlan::single(
        FaultPoint::StepWorkerSlowMs,
        1,
        1_000_000_000_000,
    ));
    let m_decode_fault_armed = bench_units(
        &format!("decode {decode_tokens} tok · packed INT2 · fault armed-idle (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || run_sched_decode(&mut sched_be),
    );
    fault::disarm();
    // Telemetry-plane pricing (obs): the identical scheduler-surface decode,
    // plus — per step — exactly the registry writes `scheduler_loop`
    // performs: step counter, span-split token counters, a latency-histogram
    // observation, a batch-size gauge store, and a trace-ring record. The
    // delta against "fault unarmed" above is the lock-free claim for
    // `tsgo::obs`: a handful of relaxed atomics per step, within noise.
    let run_sched_decode_metrics = |be: &mut LocalBackend<ExecModel>| {
        let reg = obs::registry();
        let slot = match be.admit(1) {
            AdmitVerdict::Slot(s) => s,
            _ => unreachable!("the unpooled backend always admits"),
        };
        let mut logits = {
            let t0 = std::time::Instant::now();
            let l = be.step(&[StepJob::single(slot, 0, 65)]).pop().unwrap().unwrap();
            let dur = t0.elapsed();
            reg.steps.inc();
            reg.decode_tokens.add(1);
            reg.step_ms.observe(dur);
            reg.running_sequences.set(1);
            reg.trace.record(&StepEvent {
                seq: 0,
                source: SOURCE_SCHED,
                batch: 1,
                prefill_tokens: 0,
                decode_tokens: 1,
                dur_us: dur.as_micros() as u64,
                preempted: 0,
                restarts: 0,
            });
            l
        };
        for pos in 1..decode_tokens {
            let next = tsgo::serve::argmax_token(&logits).unwrap();
            let t0 = std::time::Instant::now();
            logits = be.step(&[StepJob::single(slot, pos, next)]).pop().unwrap().unwrap();
            let dur = t0.elapsed();
            reg.steps.inc();
            reg.decode_tokens.add(1);
            reg.step_ms.observe(dur);
            reg.running_sequences.set(1);
            reg.trace.record(&StepEvent {
                seq: 0,
                source: SOURCE_SCHED,
                batch: 1,
                prefill_tokens: 0,
                decode_tokens: 1,
                dur_us: dur.as_micros() as u64,
                preempted: 0,
                restarts: 0,
            });
        }
        be.retire(slot);
        std::hint::black_box(&logits);
    };
    let m_decode_metrics = bench_units(
        &format!("decode {decode_tokens} tok · packed INT2 · metrics recorded (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || run_sched_decode_metrics(&mut sched_be),
    );
    // Quantized KV cache on top of packed weights: the second packed data
    // plane. Same decode loop, group-wise int8/int4 K/V with fused attend.
    let kv8 = KvSpec::PackedGroupwise { bits: 8, group: 64 };
    let kv4 = KvSpec::PackedGroupwise { bits: 4, group: 64 };
    let m_decode_kv8 = bench_units(
        &format!("decode {decode_tokens} tok · packed INT2 + int8 KV (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || {
            std::hint::black_box(run_decode(&packed, kv8));
        },
    );
    let m_decode_kv4 = bench_units(
        &format!("decode {decode_tokens} tok · packed INT2 + int4 KV (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || {
            std::hint::black_box(run_decode(&packed, kv4));
        },
    );
    // Paged KV (`--kv-pool-mb`): the same decode loop with pages drawn from
    // an ample shared pool — the page-table indirection priced against the
    // contiguous cache above (same bytes, same kernels; pages recycle
    // across iterations so steady-state allocation is free-list pops).
    let page_pool = KvPool::new(
        PoolCfg { budget_bytes: 4 << 20, page_tokens: PoolCfg::DEFAULT_PAGE_TOKENS },
        KvSpec::DenseF32,
        &cfg,
    );
    let m_decode_paged = bench_units(
        &format!("decode {decode_tokens} tok · packed INT2 + paged KV (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || {
            let mut st = DecodeState::with_kv_pool(&packed, KvSpec::DenseF32, Some(&page_pool));
            let mut logits = st.step(65);
            for _ in 1..decode_tokens {
                let next = tsgo::serve::argmax_token(&logits).unwrap();
                logits = st.step(next);
            }
            std::hint::black_box(logits);
        },
    );
    // Constrained-pool serving: a budget deliberately below the client
    // batch's aggregate KV demand, so the scheduler must preempt and
    // re-prefill (correctness is locked in by tests/kv_pool.rs; this
    // records the *rate* for the JSON baseline).
    let (pool_preempt_rate, pool_peak_pages, pool_total_pages) = {
        let m = Arc::new(qm.weights.clone());
        let kv = KvSpec::DenseF32;
        let probe = KvPool::new(PoolCfg { budget_bytes: 1 << 30, page_tokens: 4 }, kv, &cfg);
        let total_pages = 20usize;
        let pc = PoolCfg { budget_bytes: total_pages * probe.page_bytes(), page_tokens: 4 };
        let b = Arc::new(DynamicBatcher::spawn(
            m,
            BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(100),
                kv,
                pool: Some(pc),
                ..Default::default()
            },
        ));
        let joins: Vec<_> = (0..4u8)
            .map(|i| {
                let b = b.clone();
                std::thread::spawn(move || {
                    b.generate(GenRequest {
                        prompt: vec![i * 31, i * 31 + 5, 7, 11],
                        max_new: 12,
                        ..Default::default()
                    })
                    .unwrap()
                })
            })
            .collect();
        let rs: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        let preemptions: usize = rs.iter().map(|r| r.preemptions).sum();
        let peak = rs.iter().map(|r| r.kv_pages_used).max().unwrap_or(0);
        (preemptions as f64 / rs.len() as f64, peak, total_pages)
    };
    // -- sharded pipeline decode (`--shards N`) -----------------------------
    // On the Small preset (4 layers) so 2- and 4-shard plans are distinct.
    // Batch-1 decode cannot overlap microbatches, so these rows price the
    // pipeline's per-step handoff overhead — the floor the batched serving
    // bench (`cargo bench --bench serving`) climbs from.
    let small_qm = int2_quantized_model(Preset::Small.config(), &mut rng);
    let small_packed = std::sync::Arc::new(ExecModel::from_quantized(&small_qm));
    let mut shard_rows: Vec<(usize, Measurement)> = Vec::new();
    for shards in [1usize, 2, 4] {
        let sm = ShardedModel::new(small_packed.clone(), shards);
        let mut dec = sm.decoder(KvSpec::DenseF32);
        let m = bench_units(
            &format!("decode {decode_tokens} tok · packed INT2 · {shards} shards (small)"),
            1,
            iters.min(10),
            Some(decode_tokens as f64),
            &mut || {
                let slot = dec.admit().unwrap();
                let mut logits =
                    dec.step(&[StepJob::single(slot, 0, 65)]).pop().unwrap().unwrap();
                for pos in 1..decode_tokens {
                    let next = tsgo::serve::argmax_token(&logits).unwrap();
                    logits =
                        dec.step(&[StepJob::single(slot, pos, next)]).pop().unwrap().unwrap();
                }
                dec.retire(slot);
                std::hint::black_box(&logits);
            },
        );
        shard_rows.push((shards, m));
    }

    // -- chunked prefill TTFT (`--prefill-chunk`) ---------------------------
    // A 512-token prompt on a tiny-width int2 model with the context to
    // hold it: time-to-first-token as a function of the prefill chunk.
    // Chunk 1 is the historical one-token loop (512 batch-1 GEMVs per
    // linear); larger spans turn the same work into T-row GEMMs, which is
    // the whole TTFT case for chunked prefill. Tokens are bit-identical
    // across the sweep, so the rows differ only in time.
    let long_cfg = tsgo::model::ModelConfig {
        vocab: 256,
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        ffn: 128,
        seq_len: 520,
    };
    let long_qm = int2_quantized_model(long_cfg, &mut rng);
    let long_packed = ExecModel::from_quantized(&long_qm);
    let prompt512: Vec<u8> = (0..512u32).map(|i| (i * 131 % 251) as u8).collect();
    let mut prefill_rows: Vec<(usize, Measurement)> = Vec::new();
    for chunk in [1usize, 16, 64] {
        let m = bench_units(
            &format!("prefill 512 tok · packed INT2 · chunk {chunk}"),
            1,
            iters.min(10),
            Some(prompt512.len() as f64),
            &mut || {
                let mut st = DecodeState::with_kv(&long_packed, KvSpec::DenseF32);
                let mut t = 0usize;
                let mut first = None;
                while t < prompt512.len() {
                    let len = chunk.min(prompt512.len() - t);
                    let logits = st.step_span(&prompt512[t..t + len]);
                    t += len;
                    if t == prompt512.len() {
                        first = tsgo::serve::argmax_token(logits.row(len - 1));
                    }
                }
                std::hint::black_box(first);
            },
        );
        prefill_rows.push((chunk, m));
    }
    let ttft_ms = |m: &Measurement| m.mean.as_secs_f64() * 1e3;
    let mut prefill_table = Table::new(&["chunk", "ttft ms", "vs chunk 1"]);
    for (chunk, m) in &prefill_rows {
        prefill_table.row(vec![
            format!("{chunk}"),
            format!("{:.3}", ttft_ms(m)),
            format!(
                "{:.2}x",
                prefill_rows[0].1.mean.as_secs_f64() / m.mean.as_secs_f64().max(1e-12)
            ),
        ]);
    }

    // capture provenance BEFORE restoring Auto: the scaling + decode
    // sections above ran under the pinned Best table.
    let dispatch_under_test = packed.kernel_dispatch();
    kernels::set_forced(ForcedKernel::Auto);
    ms.push(m_decode_dense.clone());
    ms.push(m_decode_packed.clone());
    ms.push(m_decode_sampled.clone());
    ms.push(m_decode_fault_unarmed.clone());
    ms.push(m_decode_fault_armed.clone());
    ms.push(m_decode_metrics.clone());
    ms.push(m_decode_kv8.clone());
    ms.push(m_decode_kv4.clone());
    ms.push(m_decode_paged.clone());
    for (_, m) in &shard_rows {
        ms.push(m.clone());
    }
    for (_, m) in &prefill_rows {
        ms.push(m.clone());
    }
    bytes.row(vec![
        "tiny model linears, dense".into(),
        format!("{}", dense.linear_weight_bytes()),
        "1.00x".into(),
        "32.00".into(),
    ]);
    bytes.row(vec![
        "tiny model linears, packed INT2 g64".into(),
        format!("{}", packed.linear_weight_bytes()),
        format!(
            "{:.2}x",
            dense.linear_weight_bytes() as f64 / packed.linear_weight_bytes() as f64
        ),
        format!(
            "{:.2}",
            packed.linear_weight_bytes() as f64 * 8.0
                / (dense.linear_weight_bytes() / 4) as f64
        ),
    ]);

    print_measurements("packed dequant GEMV / GEMM vs dense", &ms);
    speed.print(&format!(
        "scalar vs dispatched ({}) — single-token GEMV per bit width",
        kernels::best_table().name
    ));
    scaling.print("packed GEMM scaling with batch size (two-level blocking)");
    prefill_table.print("chunked prefill TTFT — 512-token prompt, packed INT2 (--prefill-chunk)");
    bytes.print("weight bytes touched per full application");
    println!("\nthroughput column: activation rows (tokens) per second.");
    println!("kernel dispatch under test: {dispatch_under_test}");
    println!(
        "constrained kv pool ({pool_total_pages} pages x 4 tok): \
         {pool_preempt_rate:.2} preemptions/request, peak {pool_peak_pages} pages/seq"
    );

    // -- machine-readable baseline ------------------------------------------
    let report = Json::obj(vec![
        ("bench", Json::str("packed_gemv")),
        ("schema", Json::num(1.0)),
        // Marks this file as real measured numbers: `bench_check` only
        // hard-fails against a baseline whose provenance is "measured"
        // (the repo-seeded placeholder baseline says "seeded-unmeasured").
        ("provenance", Json::str("measured")),
        ("threads", Json::num(tsgo::util::threadpool::num_threads() as f64)),
        ("kernel_table", Json::str(kernels::best_table().name)),
        (
            "shape",
            Json::obj(vec![
                ("out", Json::num(out_dim as f64)),
                ("in", Json::num(in_dim as f64)),
                ("group", Json::num(group as f64)),
            ]),
        ),
        (
            "dense",
            Json::obj(vec![
                ("weight_bytes", Json::num(dense_bytes as f64)),
                (
                    "gemv_tokens_per_s",
                    Json::num(m_dense_gemv.throughput().unwrap_or(0.0)),
                ),
            ]),
        ),
        ("gemv", Json::arr(gemv_json)),
        ("gemm_scaling", Json::arr(scaling_json)),
        (
            "decode",
            Json::obj({
                let mut rows = vec![
                    (
                        "dense_tokens_per_s",
                        Json::num(m_decode_dense.throughput().unwrap_or(0.0)),
                    ),
                    (
                        "packed_int2_tokens_per_s",
                        Json::num(m_decode_packed.throughput().unwrap_or(0.0)),
                    ),
                    (
                        "packed_int2_sampled_tokens_per_s",
                        Json::num(m_decode_sampled.throughput().unwrap_or(0.0)),
                    ),
                    (
                        "packed_int2_fault_unarmed_tokens_per_s",
                        Json::num(m_decode_fault_unarmed.throughput().unwrap_or(0.0)),
                    ),
                    (
                        "packed_int2_fault_armed_tokens_per_s",
                        Json::num(m_decode_fault_armed.throughput().unwrap_or(0.0)),
                    ),
                    (
                        "packed_int2_metrics_tokens_per_s",
                        Json::num(m_decode_metrics.throughput().unwrap_or(0.0)),
                    ),
                    (
                        "packed_int2_kv8_tokens_per_s",
                        Json::num(m_decode_kv8.throughput().unwrap_or(0.0)),
                    ),
                    (
                        "packed_int2_kv4_tokens_per_s",
                        Json::num(m_decode_kv4.throughput().unwrap_or(0.0)),
                    ),
                    (
                        "packed_int2_paged_tokens_per_s",
                        Json::num(m_decode_paged.throughput().unwrap_or(0.0)),
                    ),
                ];
                // sharded pipeline decode rows (small preset, batch 1);
                // covered by bench_check like every other decode row
                for (shards, m) in &shard_rows {
                    let key: &'static str = match shards {
                        1 => "packed_int2_shards1_tokens_per_s",
                        2 => "packed_int2_shards2_tokens_per_s",
                        4 => "packed_int2_shards4_tokens_per_s",
                        _ => unreachable!("unbenched shard count"),
                    };
                    rows.push((key, Json::num(m.throughput().unwrap_or(0.0))));
                }
                rows
            }),
        ),
        // chunked prefill TTFT: ms rows (lower is better) — bench_check
        // inverts them into rates before comparing
        (
            "prefill",
            Json::obj({
                let headline = prefill_rows
                    .iter()
                    .find(|(c, _)| *c == 64)
                    .expect("chunk-64 prefill row");
                vec![
                    ("prompt_len", Json::num(512.0)),
                    ("ttft_ms_int2_prompt512", Json::num(ttft_ms(&headline.1))),
                    (
                        "chunk_sweep",
                        Json::arr(
                            prefill_rows
                                .iter()
                                .map(|(chunk, m)| {
                                    Json::obj(vec![
                                        ("chunk", Json::num(*chunk as f64)),
                                        ("ttft_ms", Json::num(ttft_ms(m))),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]
            }),
        ),
        // constrained-pool serving under deliberate KV-memory pressure:
        // rate rows, not throughput — bench_check leaves them advisory
        (
            "pool",
            Json::obj(vec![
                ("page_tokens", Json::num(4.0)),
                ("total_pages", Json::num(pool_total_pages as f64)),
                ("preemptions_per_request", Json::num(pool_preempt_rate)),
                ("peak_pages_per_seq", Json::num(pool_peak_pages as f64)),
            ]),
        ),
        (
            "kv",
            Json::obj(vec![
                (
                    "f32_bytes_per_token",
                    Json::num((KvSpec::DenseF32.bytes_per_token(&cfg) * cfg.n_layers) as f64),
                ),
                (
                    "int8_bytes_per_token",
                    Json::num((kv8.bytes_per_token(&cfg) * cfg.n_layers) as f64),
                ),
                (
                    "int4_bytes_per_token",
                    Json::num((kv4.bytes_per_token(&cfg) * cfg.n_layers) as f64),
                ),
            ]),
        ),
    ]);
    let out_path = std::env::var("TSGO_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_packed_gemv.json".to_string());
    match std::fs::write(&out_path, format!("{report}\n")) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("warning: could not write {out_path}: {e}"),
    }
}
