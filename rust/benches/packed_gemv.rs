//! Packed execution microbenchmarks: the fused group-wise dequant GEMV/GEMM
//! against the dense f32 path it replaces.
//!
//! Three views, each with a bytes-touched column (the memory-bandwidth
//! story that motivates weight-only quantization — paper §2.2):
//!
//! * single-token GEMV (the decode hot loop) per bit width;
//! * prefill GEMM (T = 64) per bit width;
//! * end-to-end KV-cached decode tokens/s, dense [`ExecModel`] vs packed.
//!
//! `cargo bench --bench packed_gemv`

use tsgo::model::{DecodeState, ExecModel, ModelWeights, Preset};
use tsgo::quant::rtn::rtn_quantize;
use tsgo::quant::scale::{compute_group_scales, QuantSpec, ScaleMetric};
use tsgo::quant::QuantizedLinear;
use tsgo::tensor::Matrix;
use tsgo::util::bench::{bench_units, print_measurements, Measurement, Table};
use tsgo::util::rng::Rng;
use std::collections::BTreeMap;

fn quantize(w: &Matrix, bits: u8, group: usize) -> QuantizedLinear {
    let spec = QuantSpec::new(bits, group);
    let scales = compute_group_scales(w, &spec, ScaleMetric::L2, None);
    rtn_quantize(w, &scales, &spec)
}

fn main() {
    let mut rng = Rng::new(13);
    let iters: usize = std::env::var("TSGO_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    // A w2-shaped linear: [out, in] = [256, 704] at group 64.
    let (out_dim, in_dim, group) = (256usize, 704usize, 64usize);
    let w = Matrix::randn(out_dim, in_dim, 1.0, &mut rng);
    let x1 = Matrix::randn(1, in_dim, 1.0, &mut rng);
    let xt = Matrix::randn(64, in_dim, 1.0, &mut rng);

    let mut ms: Vec<Measurement> = Vec::new();
    let mut bytes = Table::new(&["path", "weight bytes", "vs dense", "bits/weight"]);
    let dense_bytes = out_dim * in_dim * 4;
    bytes.row(vec!["dense f32".into(), format!("{dense_bytes}"), "1.00x".into(), "32.00".into()]);

    ms.push(bench_units("gemv dense f32", 3, iters, Some(1.0), &mut || {
        std::hint::black_box(x1.matmul_bt(&w));
    }));
    ms.push(bench_units("gemm[64] dense f32", 1, iters, Some(64.0), &mut || {
        std::hint::black_box(xt.matmul_bt(&w));
    }));

    for bits in [2u8, 3, 4, 8] {
        let q = quantize(&w, bits, group);
        bytes.row(vec![
            format!("packed INT{bits} g{group}"),
            format!("{}", q.nbytes()),
            format!("{:.2}x", dense_bytes as f64 / q.nbytes() as f64),
            format!("{:.2}", q.bits_per_weight()),
        ]);
        ms.push(bench_units(
            &format!("gemv packed INT{bits} (fused dequant)"),
            3,
            iters,
            Some(1.0),
            &mut || {
                std::hint::black_box(q.forward(&x1));
            },
        ));
        ms.push(bench_units(
            &format!("gemv dequant(INT{bits}) + dense (old deploy path)"),
            1,
            iters.min(10),
            Some(1.0),
            &mut || {
                let d = q.dequantize();
                std::hint::black_box(x1.matmul_bt(&d));
            },
        ));
        ms.push(bench_units(
            &format!("gemm[64] packed INT{bits} (fused dequant)"),
            1,
            iters,
            Some(64.0),
            &mut || {
                std::hint::black_box(q.forward(&xt));
            },
        ));
    }

    // -- end-to-end decode: dense ExecModel vs packed ExecModel -------------
    let cfg = Preset::Tiny.config();
    let fp = ModelWeights::init(cfg, &mut rng);
    let spec = QuantSpec::new(2, 64);
    let mut weights = fp.clone();
    let mut linears = BTreeMap::new();
    for (li, kind, m) in fp.linears() {
        let scales = compute_group_scales(m, &spec, ScaleMetric::L2, None);
        let q = rtn_quantize(m, &scales, &spec);
        *weights.layers[li].linear_mut(kind) = q.dequantize();
        linears.insert((li, kind.label()), q);
    }
    let qm = tsgo::model::store::QuantizedModel {
        config: cfg,
        weights,
        linears,
        quantizers: BTreeMap::new(),
    };
    let packed = ExecModel::from_quantized(&qm);
    let dense = ExecModel::from_dense(qm.weights.clone());
    let decode_tokens = 24usize;
    let run_decode = |m: &ExecModel| {
        let mut st = DecodeState::new(m);
        let mut logits = st.step(65);
        for _ in 1..decode_tokens {
            let next = tsgo::serve::argmax_token(&logits).unwrap();
            logits = st.step(next);
        }
        logits
    };
    ms.push(bench_units(
        &format!("decode {decode_tokens} tok · dense exec (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || {
            std::hint::black_box(run_decode(&dense));
        },
    ));
    ms.push(bench_units(
        &format!("decode {decode_tokens} tok · packed INT2 exec (tiny)"),
        1,
        iters.min(10),
        Some(decode_tokens as f64),
        &mut || {
            std::hint::black_box(run_decode(&packed));
        },
    ));
    bytes.row(vec![
        "tiny model linears, dense".into(),
        format!("{}", dense.linear_weight_bytes()),
        "1.00x".into(),
        "32.00".into(),
    ]);
    bytes.row(vec![
        "tiny model linears, packed INT2 g64".into(),
        format!("{}", packed.linear_weight_bytes()),
        format!(
            "{:.2}x",
            dense.linear_weight_bytes() as f64 / packed.linear_weight_bytes() as f64
        ),
        format!(
            "{:.2}",
            packed.linear_weight_bytes() as f64 * 8.0
                / (dense.linear_weight_bytes() / 4) as f64
        ),
    ]);

    print_measurements("packed dequant GEMV / GEMM vs dense", &ms);
    bytes.print("weight bytes touched per full application");
    println!("\nthroughput column: activation rows (tokens) per second.");
}
