//! Extended baseline comparison (beyond the paper's tables, covering the
//! related-work methods its §1–2 discuss): RTN, AWQ-lite (activation-aware
//! scaling, ref [8]), GPTQ natural-order, GPTQ act-order, and the paper's
//! method — every row through the same [`tsgo::quant::LayerQuantizer`]
//! trait path the pipeline/CLI use, on identical layer problems, scored by
//! the true layer-wise reconstruction loss (Eq. 3) under a skewed,
//! correlated input Hessian.
//!
//! `cargo bench --bench baselines`

use tsgo::quant::gptq::prepare_hessian;
use tsgo::quant::metrics::layer_loss;
use tsgo::quant::{resolve_quantizer, QuantContext, QuantSpec};
use tsgo::tensor::Matrix;
use tsgo::util::bench::Table;
use tsgo::util::rng::Rng;

fn problem(out: usize, inp: usize, seed: u64) -> (Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(out, inp, 1.0, &mut rng);
    let t = inp * 6;
    let mut x = Matrix::zeros(inp, t);
    for c in 0..t {
        let mut prev = 0.0f32;
        for r in 0..inp {
            let energy = if r % 8 == 0 { 5.0 } else { 0.4 };
            let v = 0.5 * prev + rng.normal() as f32 * energy;
            x[(r, c)] = v;
            prev = v;
        }
    }
    let mut h = x.matmul_bt(&x);
    h.scale_inplace(1.0 / t as f32);
    (w, h)
}

/// §2.2 motivation: channel-wise (one scale per output channel) vs
/// group-wise at low bits. The paper's premise is that channel-wise INT2
/// collapses under intra-channel variance; group-wise recovers it.
fn channelwise_vs_groupwise() {
    let (out, inp) = (704, 256);
    let ours = resolve_quantizer("ours").unwrap();
    let ctx = QuantContext::default();
    let mut table = Table::new(&["bits", "granularity", "layer loss", "vs channel-wise"]);
    for bits in [2u8, 3] {
        let (w, h) = problem(out, inp, 77 + bits as u64);
        let mut wd = w.clone();
        let hd = prepare_hessian(&h, &mut wd, 0.01);
        let mut base = None;
        for (label, group) in [
            ("channel-wise", inp),
            ("group 128", 128),
            ("group 64", 64),
            ("group 32", 32),
        ] {
            let spec = QuantSpec::new(bits, group);
            let res = ours.quantize(&w, &h, None, &spec, &ctx).unwrap();
            let loss = layer_loss(&w, &res.quantized.dequantize(), &hd);
            let rel = match base {
                None => {
                    base = Some(loss);
                    "100.0%".into()
                }
                Some(b) => format!("{:.1}%", loss / b * 100.0),
            };
            table.row(vec![
                format!("{bits}"),
                label.into(),
                format!("{loss:.4e}"),
                rel,
            ]);
        }
    }
    table.print("granularity sweep (§2.2 motivation: group-wise rescues low-bit)");
}

fn main() {
    let (out, inp) = (704, 256);
    println!("extended baselines on a [{out}x{inp}] layer (skewed AR(1) inputs), group=64");
    let ctx = QuantContext::default();
    let mut table = Table::new(&["bits", "method", "layer loss", "vs RTN", "time"]);
    for bits in [2u8, 3] {
        let (w, h) = problem(out, inp, 1000 + bits as u64);
        let spec = QuantSpec::new(bits, 64);
        let mut wd = w.clone();
        let hd = prepare_hessian(&h, &mut wd, 0.01);

        let mut rtn_loss = None;
        // first name must stay "rtn": the relative column is vs that row
        for name in ["rtn", "awq", "gptq", "actorder", "ours"] {
            let quantizer = resolve_quantizer(name).unwrap();
            let t0 = std::time::Instant::now();
            let res = quantizer.quantize(&w, &h, None, &spec, &ctx).unwrap();
            let dt = t0.elapsed();
            let loss = layer_loss(&w, &res.quantized.dequantize(), &hd);
            let rel = match rtn_loss {
                None => {
                    rtn_loss = Some(loss);
                    "100.0%".to_string()
                }
                Some(b) => format!("{:.1}%", loss / b * 100.0),
            };
            table.row(vec![
                format!("{bits}"),
                name.into(),
                format!("{loss:.4e}"),
                rel,
                tsgo::util::fmt_duration(dt),
            ]);
        }
    }
    table.print("extended baselines (lower loss is better; % relative to RTN)");
    channelwise_vs_groupwise();
}
