//! Shared setup for the paper-table benches.
//!
//! (Not every bench target uses every helper/field — allow dead code here.)
#![allow(dead_code)]
//!
//! All table benches prefer a *trained* checkpoint (`model.tsr`, produced by
//! the e2e example or `tsgo train`) whose config matches the requested
//! preset; otherwise they fall back to a skew-injected random init, which
//! preserves the orderings (who wins) but shrinks absolute PPL gaps — the
//! header line states which model is in use.

use tsgo::calib::{calibration_batches, Batch, Corpus, CorpusKind};
use tsgo::eval::tasks::{build_suite, task_suite, TaskItem};
use tsgo::model::{ModelWeights, Preset};
use tsgo::runtime::Engine;
use tsgo::util::rng::Rng;

pub struct BenchEnv {
    pub fp: ModelWeights,
    pub calib: Vec<Batch>,
    pub wiki_test: Vec<u8>,
    pub c4_test: Vec<u8>,
    pub items: Vec<TaskItem>,
    pub engine: Option<Engine>,
    pub trained: bool,
    pub windows: usize,
}

pub fn preset_from_env() -> Preset {
    std::env::var("TSGO_BENCH_PRESET")
        .ok()
        .and_then(|s| Preset::parse(&s))
        .unwrap_or(Preset::Small)
}

pub fn setup(preset: Preset) -> BenchEnv {
    let cfg = preset.config();
    let (fp, trained) = match tsgo::model::store::load_model(std::path::Path::new("model.tsr"))
    {
        Ok(w) if w.config == cfg => (w, true),
        _ => {
            let mut rng = Rng::new(99);
            let mut w = ModelWeights::init(cfg, &mut rng);
            // inject per-channel energy skew (see pipeline_e2e.rs rationale)
            for r in 0..w.embed.rows {
                for c in 0..w.embed.cols {
                    if c % 7 == 0 {
                        w.embed[(r, c)] *= 6.0;
                    }
                }
            }
            (w, false)
        }
    };
    let wiki = Corpus::generate(CorpusKind::SynthWiki, 400_000, 1);
    let c4 = Corpus::generate(CorpusKind::SynthC4, 200_000, 1);
    let (train_split, wiki_test) = wiki.split(0.1);
    let (_, c4_test) = c4.split(0.2);
    let n_seqs = std::env::var("TSGO_BENCH_CALIB")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let calib = calibration_batches(train_split, n_seqs, cfg.seq_len, 4, 3);
    let items = build_suite(&wiki, 15, 17);
    let engine = Engine::open_default().filter(|e| e.manifest.config == cfg);
    BenchEnv {
        fp,
        calib,
        wiki_test: wiki_test.to_vec(),
        c4_test: c4_test.to_vec(),
        items,
        engine,
        trained,
        windows: 16,
    }
}

impl BenchEnv {
    pub fn describe(&self, what: &str) {
        println!(
            "== {what} ==\nmodel: {} ({}, {:.2}M params) | calib seqs: {} | artifacts: {}",
            if self.trained { "trained checkpoint model.tsr" } else { "skewed random init (train one via the e2e example for sharper gaps)" },
            self.fp.config.d_model,
            self.fp.config.n_params() as f64 / 1e6,
            self.calib.iter().map(|b| b.batch).sum::<usize>(),
            if self.engine.is_some() { "yes" } else { "no (native eval)" },
        );
    }

    pub fn ppl(&self, w: &ModelWeights, data: &[u8]) -> f64 {
        if let Some(e) = &self.engine {
            if let Ok(p) =
                tsgo::runtime::perplexity_artifact(e, w, data, w.config.seq_len, self.windows)
            {
                return p;
            }
        }
        tsgo::eval::perplexity(w, data, w.config.seq_len, self.windows)
    }

    pub fn zero_shot(&self, w: &ModelWeights) -> f64 {
        task_suite(w, &self.items).average
    }
}

/// One (precision, method) table row: PPLs + 0-shot + loss + time.
pub struct Row {
    pub precision: String,
    pub method: &'static str,
    pub wiki: f64,
    pub c4: f64,
    pub zshot: f64,
    pub layer_loss: f64,
    pub secs: f64,
}

/// Run one table cell: the whole pipeline with the named registered
/// quantizer (any of `tsgo::quant::QUANTIZER_NAMES`) at a uniform spec.
pub fn run_cell(env: &BenchEnv, bits: u8, group: usize, method: &'static str) -> Row {
    use tsgo::pipeline::{quantize_model, PipelineConfig};
    let spec = tsgo::quant::QuantSpec::new(bits, group);
    let t0 = std::time::Instant::now();
    let (qm, rep) =
        quantize_model(&env.fp, &env.calib, &PipelineConfig::new(spec, method)).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    Row {
        precision: format!("INT{bits}"),
        method,
        wiki: env.ppl(&qm.weights, &env.wiki_test),
        c4: env.ppl(&qm.weights, &env.c4_test),
        zshot: env.zero_shot(&qm.weights),
        layer_loss: rep.total_loss(),
        secs,
    }
}
