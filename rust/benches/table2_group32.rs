//! **Table 2** — group-wise quantization at group size 32 (more scales,
//! better accuracy for both methods; ours still wins). Columns as Table 1,
//! plus the cross-table claim that group 32 beats group 64 cell-by-cell.
//!
//! `cargo bench --bench table2_group32`

mod common;

use tsgo::util::bench::Table;

fn main() {
    let env = common::setup(common::preset_from_env());
    env.describe("Table 2 — group size 32");

    let mut table = Table::new(&[
        "precision", "method", "synthwiki (↓)", "synthc4 (↓)", "0-shot (↑)",
        "Σ layer loss", "time (s)",
    ]);
    table.row(vec![
        "FP".into(),
        "baseline".into(),
        format!("{:.3}", env.ppl(&env.fp, &env.wiki_test)),
        format!("{:.3}", env.ppl(&env.fp, &env.c4_test)),
        format!("{:.2}", env.zero_shot(&env.fp)),
        "-".into(),
        "-".into(),
    ]);
    let mut improved = 0usize;
    let mut cells = 0usize;
    for bits in [2u8, 3] {
        for method in ["gptq", "ours"] {
            let r32 = common::run_cell(&env, bits, 32, method);
            let r64 = common::run_cell(&env, bits, 64, method);
            cells += 1;
            if r32.layer_loss < r64.layer_loss {
                improved += 1;
            }
            table.row(vec![
                r32.precision,
                r32.method.into(),
                format!("{:.3}", r32.wiki),
                format!("{:.3}", r32.c4),
                format!("{:.2}", r32.zshot),
                format!("{:.3e}", r32.layer_loss),
                format!("{:.1}", r32.secs),
            ]);
        }
    }
    table.print("Table 2 reproduction (group=32)");
    println!(
        "cross-table claim (smaller groups help): {improved}/{cells} cells improve on their group-64 counterpart (layer loss)."
    );
}
