//! Whole-pipeline integration tests on the tiny preset: quantize a model
//! end-to-end with every method cell of Table 3 and check the orderings the
//! paper claims, plus checkpoint round-trips of the results.

use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::model::{store, ModelWeights, Preset};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantSpec;
use tsgo::util::rng::Rng;

fn setup() -> (ModelWeights, Vec<tsgo::calib::Batch>) {
    let cfg = Preset::Tiny.config();
    let mut rng = Rng::new(1234);
    let mut w = ModelWeights::init(cfg, &mut rng);
    // A freshly initialized transformer has nearly isotropic activations,
    // which hides exactly the effect Stage 1 exploits (skewed per-channel
    // input energy — universal in trained LLMs). Skew the embedding so the
    // test model has trained-model-like input statistics.
    for r in 0..w.embed.rows {
        for c in 0..w.embed.cols {
            if c % 7 == 0 {
                w.embed[(r, c)] *= 6.0;
            }
        }
    }
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 50_000, 1);
    let (train, _) = corpus.split(0.1);
    let calib = calibration_batches(train, 6, cfg.seq_len.min(48), 3, 5);
    (w, calib)
}

#[test]
fn ablation_ordering_matches_table3() {
    // Table 3's qualitative claims on the layer-wise loss:
    //   GPTQ > stage1-only, GPTQ > stage2-only, full ours is best or tied.
    let (w, calib) = setup();
    let spec = QuantSpec::new(2, 32);
    let loss = |method: &str| {
        let (_, rep) = quantize_model(&w, &calib, &PipelineConfig::new(spec, method)).unwrap();
        rep.total_loss()
    };
    let l_gptq = loss("gptq");
    let l_s1 = loss("stage1");
    let l_s2 = loss("stage2");
    let l_ours = loss("ours");

    println!("gptq={l_gptq:.4e} s1={l_s1:.4e} s2={l_s2:.4e} ours={l_ours:.4e}");
    assert!(l_s1 < l_gptq, "stage1 should improve on GPTQ: {l_s1} vs {l_gptq}");
    assert!(l_s2 < l_gptq, "stage2 should improve on GPTQ: {l_s2} vs {l_gptq}");
    assert!(
        l_ours <= l_s1.min(l_s2) * 1.02,
        "full method should be at least competitive with each stage alone"
    );
    assert!(l_ours < l_gptq * 0.9, "full method should clearly beat GPTQ");
}

#[test]
fn int3_losses_below_int2() {
    let (w, calib) = setup();
    let l2 = {
        let spec = QuantSpec::new(2, 32);
        let (_, rep) =
            quantize_model(&w, &calib, &PipelineConfig::new(spec, "ours")).unwrap();
        rep.total_loss()
    };
    let l3 = {
        let spec = QuantSpec::new(3, 32);
        let (_, rep) =
            quantize_model(&w, &calib, &PipelineConfig::new(spec, "ours")).unwrap();
        rep.total_loss()
    };
    assert!(l3 < l2, "INT3 must reconstruct better than INT2: {l3} vs {l2}");
}

#[test]
fn smaller_groups_help() {
    // Table 1 vs Table 2: group 32 beats group 64 for the same method.
    let (w, calib) = setup();
    let loss_at = |g: usize| {
        let spec = QuantSpec::new(2, g);
        let (_, rep) =
            quantize_model(&w, &calib, &PipelineConfig::new(spec, "ours")).unwrap();
        rep.total_loss()
    };
    let g64 = loss_at(64);
    let g32 = loss_at(32);
    assert!(g32 < g64, "group 32 should beat group 64: {g32} vs {g64}");
}

#[test]
fn quantized_checkpoint_roundtrip_preserves_eval() {
    let (w, calib) = setup();
    let spec = QuantSpec::new(3, 32);
    let (qm, _) =
        quantize_model(&w, &calib, &PipelineConfig::new(spec, "ours")).unwrap();

    let dir = std::env::temp_dir().join("tsgo_pipeline_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("q.tsr");
    store::save_quantized(&path, &qm).unwrap();
    let qm2 = store::load_quantized(&path).unwrap();

    // logits through the dequantized weights must be identical pre/post save
    let tokens: Vec<u8> = (0..32).map(|i| (i * 11 % 251) as u8).collect();
    let a = tsgo::model::forward_logits(&qm.weights, &tokens);
    let b = tsgo::model::forward_logits(&qm2.weights, &tokens);
    assert!(a.max_abs_diff(&b) < 1e-6);
}

#[test]
fn error_aware_refinement_helps_downstream_loss() {
    // Disabling the R term (Eq. 9 -> Eq. 5 for all layers) should not beat
    // the error-aware run on the *deviation-aware* objective it optimizes.
    let (w, calib) = setup();
    let spec = QuantSpec::new(2, 32);
    let mut cfg = PipelineConfig::new(spec, "ours");
    let (_, rep_aware) = quantize_model(&w, &calib, &cfg).unwrap();
    cfg.error_aware = false;
    let (_, rep_plain) = quantize_model(&w, &calib, &cfg).unwrap();
    // Both must be finite and in the same ballpark; the aware run should not
    // be significantly worse on summed layer loss.
    assert!(rep_aware.total_loss().is_finite());
    assert!(rep_plain.total_loss().is_finite());
    assert!(
        rep_aware.total_loss() < rep_plain.total_loss() * 1.5,
        "error-aware run wildly off: {} vs {}",
        rep_aware.total_loss(),
        rep_plain.total_loss()
    );
}
