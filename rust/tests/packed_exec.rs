//! Packed-execution integration: quantize → save → load packed → decode /
//! serve / eval, asserting the fused dequant path is token-identical to the
//! dense dequantize-at-load path end to end.

use std::collections::BTreeMap;
use std::sync::Arc;
use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::model::store::{
    load_quantized, load_quantized_packed, save_quantized, QuantizedModel,
};
use tsgo::model::{DecodeState, ExecModel, LinearKind, ModelExec, ModelWeights, Preset};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::scale::{compute_group_scales, QuantSpec, ScaleMetric};
use tsgo::quant::QuantPlan;
use tsgo::serve::{request_generation, server::serve_in_background, ServerConfig};
use tsgo::tensor::kernels::{set_forced, ForcedKernel};
use tsgo::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("tsgo_packed_exec");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn greedy<M: ModelExec>(m: &M, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let mut st = DecodeState::new(m);
    let mut logits = Vec::new();
    for &t in prompt {
        logits = st.step(t);
    }
    let mut out = Vec::new();
    for _ in 0..max_new {
        // the server's own checked greedy pick — identical tie-breaking
        let next = tsgo::serve::argmax_token(&logits).unwrap();
        out.push(next);
        logits = st.step(next);
    }
    out
}

/// Quantize a tiny model through the real pipeline with a heterogeneous
/// plan (act-order perm on wq, AWQ channel scales on layer 1, mixed bits),
/// save + reload both ways.
fn pipeline_checkpoint(name: &str, plan: &str) -> (QuantizedModel, ExecModel) {
    let cfg = Preset::Tiny.config();
    let mut rng = Rng::new(1234);
    let w = ModelWeights::init(cfg, &mut rng);
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 1);
    let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
    let plan = QuantPlan::parse_with_defaults(plan, 4, 32).unwrap();
    let (qm, _) = quantize_model(&w, &calib, &PipelineConfig::from_plan(plan)).unwrap();
    let p = tmp(name);
    save_quantized(&p, &qm).unwrap();
    let dense = load_quantized(&p).unwrap();
    let packed = load_quantized_packed(&p).unwrap();
    (dense, packed)
}

#[test]
fn packed_decode_is_token_identical_to_dense() {
    // The acceptance bar: greedy tokens from the packed execution path must
    // equal the dense path's, including act-order and AWQ linears.
    let (dense, packed) = pipeline_checkpoint(
        "hetero_plan.tsr",
        "gptq:bits=4,group=32;wv=actorder;l1=awq",
    );
    assert_eq!(packed.packed_linears(), 7 * dense.config.n_layers);
    for prompt in [vec![65u8, 66, 67], vec![0u8, 255, 128, 9]] {
        let a = greedy(&dense.weights, &prompt, 12);
        let b = greedy(&packed, &prompt, 12);
        assert_eq!(a, b, "packed greedy decode diverged for {prompt:?}");
    }
}

#[test]
fn packed_exec_token_identical_under_forced_scalar_and_simd_dispatch() {
    // The dispatch-layer acceptance bar: decode and perplexity on the packed
    // path must match the dense path under BOTH the forced-scalar table and
    // the detected-best (SIMD where available) table, over a checkpoint that
    // exercises every specialized kernel width (2/3/4/8-bit linears).
    let (dense, packed) = pipeline_checkpoint(
        "kernel_dispatch_plan.tsr",
        "rtn:bits=2,group=32;wv=bits3;wo=bits4;w2=bits8",
    );
    let prompt = [5u8, 10, 15, 20];
    let want_tokens = greedy(&dense.weights, &prompt, 12);
    let corpus = Corpus::generate(CorpusKind::SynthC4, 12_000, 8);
    let want_ppl = tsgo::eval::perplexity(&dense.weights, &corpus.bytes, 32, 2);
    for force in [ForcedKernel::Scalar, ForcedKernel::Best] {
        set_forced(force);
        let got_tokens = greedy(&packed, &prompt, 12);
        let got_ppl = tsgo::eval::perplexity(&packed, &corpus.bytes, 32, 2);
        set_forced(ForcedKernel::Auto);
        assert_eq!(
            got_tokens, want_tokens,
            "packed greedy decode diverged from dense under {force:?}"
        );
        assert!(
            (got_ppl - want_ppl).abs() < 1e-3 * want_ppl,
            "packed ppl {got_ppl} diverged from dense ppl {want_ppl} under {force:?}"
        );
    }
}

#[test]
fn packed_ppl_matches_dense_ppl() {
    let (dense, packed) = pipeline_checkpoint("ppl_plan.tsr", "ours:bits=3,group=32");
    let corpus = Corpus::generate(CorpusKind::SynthC4, 20_000, 5);
    let a = tsgo::eval::perplexity(&dense.weights, &corpus.bytes, 32, 3);
    let b = tsgo::eval::perplexity(&packed, &corpus.bytes, 32, 3);
    assert!(
        (a - b).abs() < 1e-3 * a,
        "packed ppl {b} diverged from dense ppl {a}"
    );
}

#[test]
fn serve_packed_matches_serve_dense() {
    // Full serve stack over both representations of the same checkpoint:
    // identical tokens from identical prompts.
    let (dense, packed) = pipeline_checkpoint("serve_plan.tsr", "rtn:bits=4,group=32");
    let mk_cfg = || ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_connections: Some(1),
        ..Default::default()
    };
    let (addr_d, h_d) = serve_in_background(Arc::new(dense.weights), mk_cfg()).unwrap();
    let (addr_p, h_p) = serve_in_background(Arc::new(packed), mk_cfg()).unwrap();
    let a = request_generation(&addr_d.to_string(), &[10, 20, 30, 40], 8).unwrap();
    let b = request_generation(&addr_p.to_string(), &[10, 20, 30, 40], 8).unwrap();
    assert_eq!(a.tokens, b.tokens, "served tokens diverged between representations");
    h_d.join().unwrap();
    h_p.join().unwrap();
}

#[test]
fn packed_exec_handles_mixed_checkpoints() {
    // A checkpoint where only some linears are packed: the rest must load
    // dense and the model must still run.
    let cfg = Preset::Tiny.config();
    let mut rng = Rng::new(9);
    let w = ModelWeights::init(cfg, &mut rng);
    let spec = QuantSpec::new(8, 32);
    let mut weights = w.clone();
    let mut linears = BTreeMap::new();
    for li in 0..cfg.n_layers {
        // only the attention projections are packed
        for kind in [LinearKind::Wq, LinearKind::Wk, LinearKind::Wv, LinearKind::Wo] {
            let m = w.layers[li].linear(kind).clone();
            let scales = compute_group_scales(&m, &spec, ScaleMetric::L2, None);
            let q = tsgo::quant::rtn::rtn_quantize(&m, &scales, &spec);
            *weights.layers[li].linear_mut(kind) = q.dequantize();
            linears.insert((li, kind.label()), q);
        }
    }
    let qm = QuantizedModel { config: cfg, weights, linears, quantizers: BTreeMap::new() };
    let p = tmp("mixed.tsr");
    save_quantized(&p, &qm).unwrap();
    let packed = load_quantized_packed(&p).unwrap();
    assert_eq!(packed.packed_linears(), 4 * cfg.n_layers);
    let a = greedy(&qm.weights, &[1, 2, 3], 6);
    let b = greedy(&packed, &[1, 2, 3], 6);
    assert_eq!(a, b, "mixed packed/dense decode diverged");
}

#[test]
fn decode_state_matches_full_forward_on_packed() {
    // KV-cached packed decoding must agree with the packed full forward —
    // the same invariant the dense path holds.
    let (_, packed) = pipeline_checkpoint("kv_plan.tsr", "rtn:bits=4,group=32");
    let tokens: Vec<u8> = vec![11, 22, 33, 44, 55];
    let full = tsgo::model::forward_logits(&packed, &tokens);
    let mut st = DecodeState::new(&packed);
    for (t, &tok) in tokens.iter().enumerate() {
        let step = st.step(tok);
        let maxdiff = step
            .iter()
            .zip(full.row(t))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(maxdiff < 1e-3, "pos {t}: maxdiff {maxdiff}");
    }
}
