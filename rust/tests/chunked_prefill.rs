//! Chunked prefill: the PR-7 acceptance battery.
//!
//! The span step contract's spine is *bit-identity*: feeding a prompt in
//! T-token spans must produce exactly the logits — and therefore exactly
//! the greedy tokens — of the historical one-token-per-step loop, for every
//! chunk size, because `decode_layer_span` replays the one-token step's
//! per-position op order inside a batched GEMM. This file pins that across
//! the full configuration matrix:
//!
//! * `DecodeState::step_span` vs the one-token loop, every prefill row's
//!   logits `to_bits`-equal, on dense and mixed 2/3/4/8-bit packed models ×
//!   f32/int8 KV — under the dispatched *and* the forced-scalar kernel
//!   tables, for chunks 1 / 3 / 64 / beyond-prompt;
//! * end-to-end serving tokens identical across `--prefill-chunk` values ×
//!   `--shards {1,2}`;
//! * same with the KV caches paged out of a shared budget-bounded pool.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;
use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::kvpool::PoolCfg;
use tsgo::model::{DecodeState, ExecModel, KvSpec, ModelConfig, ModelExec, ModelWeights};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantPlan;
use tsgo::serve::{BatcherConfig, DynamicBatcher, GenRequest};
use tsgo::tensor::kernels::{set_forced, ForcedKernel};
use tsgo::util::rng::Rng;

/// Serializes tests that flip the process-wide forced-kernel state or make
/// bit-exact comparisons (same rationale as the lock in
/// `tests/sharded_exec.rs`): a concurrent flip mid-decode would make a real
/// scalar/SIMD divergence nondeterministic.
fn force_lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// A 4-layer tiny-width config so 2-shard plans are a real split.
fn cfg4() -> ModelConfig {
    ModelConfig { vocab: 256, d_model: 64, n_layers: 4, n_heads: 2, ffn: 128, seq_len: 96 }
}

fn dense4(seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    ModelWeights::init(cfg4(), &mut rng)
}

/// Mixed-precision packed model: every specialized dequant width
/// (2/3/4/8-bit) in one checkpoint, executed packed.
fn mixed_packed4() -> ExecModel {
    let w = dense4(78);
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 1);
    let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
    let plan = QuantPlan::parse_with_defaults(
        "rtn:bits=2,group=32;wv=bits3;wo=bits4;w2=bits8",
        4,
        32,
    )
    .unwrap();
    let (qm, _) = quantize_model(&w, &calib, &PipelineConfig::from_plan(plan)).unwrap();
    ExecModel::from_quantized(&qm)
}

/// The prompt every test prefills: long enough that chunk 3 needs many
/// spans and chunk 64 fewer, short enough to stay inside `seq_len` with
/// decode headroom.
fn prompt() -> Vec<u8> {
    (0..40u32).map(|i| (i * 37 % 251) as u8).collect()
}

/// Chunk sizes exercised everywhere: the historical one-token loop, a size
/// that never divides the prompt evenly, the default, and one beyond the
/// prompt length (whole-prompt single span).
const CHUNKS: [usize; 4] = [1, 3, 64, 128];

/// Prefill `prompt` through `step_span` in `chunk`-token spans and assert
/// every position's logits are bit-identical to the one-token reference
/// rows. Returns nothing — failure carries the diverging position.
fn assert_span_prefill_bit_identical<M: ModelExec>(
    m: &M,
    kv: KvSpec,
    chunk: usize,
    want_rows: &[Vec<f32>],
    label: &str,
) {
    let prompt = prompt();
    let mut st = DecodeState::with_kv(m, kv);
    let mut row = 0usize;
    let mut t = 0usize;
    while t < prompt.len() {
        let len = chunk.min(prompt.len() - t);
        let logits = st.step_span(&prompt[t..t + len]);
        assert_eq!(logits.rows, len, "{label}: span returned wrong row count");
        for r in 0..len {
            let got = logits.row(r);
            let want = &want_rows[row];
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{label}: chunk={chunk} pos={row} logit {i}: {a} vs {b}"
                );
            }
            row += 1;
        }
        t += len;
    }
    assert_eq!(row, prompt.len());
}

/// Reference: the historical loop — one `step` per prompt token, collecting
/// each position's logits row.
fn one_token_rows<M: ModelExec>(m: &M, kv: KvSpec, prompt: &[u8]) -> Vec<Vec<f32>> {
    let mut st = DecodeState::with_kv(m, kv);
    prompt.iter().map(|&t| st.step(t)).collect()
}

/// Run the chunk sweep for one (model, kv) cell against its one-token
/// reference rows.
fn sweep_chunks<M: ModelExec>(m: &M, kv: KvSpec, label: &str) {
    let want = one_token_rows(m, kv, &prompt());
    for chunk in CHUNKS {
        assert_span_prefill_bit_identical(m, kv, chunk, &want, label);
    }
}

#[test]
fn span_prefill_bit_identical_to_one_token_loop() {
    let _guard = force_lock();
    let dense = dense4(21);
    let packed = mixed_packed4();
    let kv8 = KvSpec::PackedGroupwise { bits: 8, group: 64 };
    for force in [ForcedKernel::Scalar, ForcedKernel::Best] {
        set_forced(force);
        sweep_chunks(&dense, KvSpec::DenseF32, &format!("dense f32-KV under {force:?}"));
        sweep_chunks(
            &packed,
            KvSpec::DenseF32,
            &format!("mixed-packed f32-KV under {force:?}"),
        );
        sweep_chunks(&packed, kv8, &format!("mixed-packed int8-KV under {force:?}"));
    }
    set_forced(ForcedKernel::Auto);
}

#[test]
fn served_tokens_identical_across_chunks_and_shards() {
    let _guard = force_lock();
    // End to end: `--prefill-chunk` must never change the generation, under
    // any shard count. Chunk 1 × shards 1 is the pre-PR-7 behaviour; every
    // other cell must emit the same tokens.
    let m = Arc::new(mixed_packed4());
    let kv8 = KvSpec::PackedGroupwise { bits: 8, group: 64 };
    let req = GenRequest { prompt: prompt(), max_new: 10, ..Default::default() };
    let mut want: Option<Vec<u8>> = None;
    for shards in [1usize, 2] {
        for chunk in CHUNKS {
            let b = DynamicBatcher::spawn(
                m.clone(),
                BatcherConfig { kv: kv8, shards, prefill_chunk: chunk, ..Default::default() },
            );
            let r = b.generate(req.clone()).unwrap();
            assert_eq!(r.tokens.len(), 10);
            match &want {
                None => want = Some(r.tokens),
                Some(w) => assert_eq!(
                    &r.tokens, w,
                    "shards={shards} chunk={chunk} diverged from chunk-1 baseline"
                ),
            }
        }
    }
}

#[test]
fn served_tokens_identical_with_pooled_kv() {
    let _guard = force_lock();
    // Same invariant with the KV caches paged out of a shared pool: span
    // appends cross page boundaries mid-span, and pooled admission charges
    // whole spans — neither may change a byte of the generation.
    let m = Arc::new(dense4(22));
    let req = GenRequest { prompt: prompt(), max_new: 10, ..Default::default() };
    let pc = PoolCfg { budget_bytes: 4 << 20, page_tokens: 8 };
    let baseline = {
        let b = DynamicBatcher::spawn(
            m.clone(),
            BatcherConfig { prefill_chunk: 1, ..Default::default() },
        );
        b.generate(req.clone()).unwrap().tokens
    };
    for chunk in CHUNKS {
        let b = DynamicBatcher::spawn(
            m.clone(),
            BatcherConfig { pool: Some(pc), prefill_chunk: chunk, ..Default::default() },
        );
        let r = b.generate(req.clone()).unwrap();
        assert_eq!(r.tokens, baseline, "pooled chunk={chunk} diverged from contiguous");
    }
}

#[test]
fn prefill_time_is_reported_and_split_from_decode() {
    // The satellite-1 metric split, observed from outside: a served request
    // reports a prefill_time, ttft = queue_wait + prefill_time, and
    // latency = ttft + decode_time.
    let m = Arc::new(dense4(23));
    let b = DynamicBatcher::spawn(
        m,
        BatcherConfig {
            max_wait: Duration::from_millis(1),
            prefill_chunk: 8,
            ..Default::default()
        },
    );
    let r = b.generate(GenRequest { prompt: prompt(), max_new: 4, ..Default::default() }).unwrap();
    assert_eq!(r.tokens.len(), 4);
    assert!(r.prefill_time > Duration::ZERO, "40-token prefill took zero time?");
    assert_eq!(r.ttft(), r.queue_wait + r.prefill_time);
    assert_eq!(r.latency(), r.ttft() + r.decode_time);
}
