//! Paged KV pool: the PR-6 acceptance battery.
//!
//! * paged decode is **bit-identical** to the contiguous caches — dense
//!   f32, the mixed 2/3/4/8-bit packed checkpoint, and int8/int4 KV, under
//!   the dispatched *and* the forced-scalar kernel tables;
//! * a serve run whose pool budget is below the batch's aggregate KV demand
//!   completes every request via preemption + deterministic re-prefill,
//!   with the `kv_pages_used` / `preemptions` counters visible;
//! * page tables release to the free list on retire and the pool recycles
//!   buffers instead of minting (no leak across admit/retire cycles);
//! * oversized prompts are rejected and over-long lone chains error out
//!   instead of livelocking;
//! * a constrained-pool stress leg (`TSGO_KV_POOL_MB`, set in the threads-2
//!   CI matrix job) keeps every response byte-correct;
//! * the sharded pipeline serves correctly out of shard-local sub-pools.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;
use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::kvpool::{KvPool, PoolCfg};
use tsgo::model::{
    DecodeState, ExecModel, KvSpec, ModelConfig, ModelExec, ModelWeights, Preset,
};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantPlan;
use tsgo::serve::{argmax_token, BatcherConfig, DynamicBatcher, GenRequest, GenResponse};
use tsgo::tensor::kernels::{set_forced, ForcedKernel};
use tsgo::util::rng::Rng;

/// Serializes tests that flip the process-wide forced-kernel state, and the
/// bit-exact comparisons a concurrent flip would make nondeterministic
/// (same pattern as `tests/sharded_exec.rs`).
fn force_lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn tiny(seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    ModelWeights::init(Preset::Tiny.config(), &mut rng)
}

/// 4-layer tiny-width config (as in `tests/sharded_exec.rs`), so a 2-shard
/// plan is a real split.
fn cfg4() -> ModelConfig {
    ModelConfig { vocab: 256, d_model: 64, n_layers: 4, n_heads: 2, ffn: 128, seq_len: 64 }
}

/// Mixed-precision packed checkpoint (2/3/4/8-bit linears in one model)
/// over a 4-layer config — every specialized dequant width on the paged
/// decode path at once.
fn mixed_packed4() -> ExecModel {
    let mut rng = Rng::new(77);
    let w = ModelWeights::init(cfg4(), &mut rng);
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 1);
    let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
    let plan = QuantPlan::parse_with_defaults(
        "rtn:bits=2,group=32;wv=bits3;wo=bits4;w2=bits8",
        4,
        32,
    )
    .unwrap();
    let (qm, _) = quantize_model(&w, &calib, &PipelineConfig::from_plan(plan)).unwrap();
    ExecModel::from_quantized(&qm)
}

/// A pool with exactly `pages` pages for `kv`-formatted caches of `cfg`.
fn pool_of_pages(pages: usize, page_tokens: usize, kv: KvSpec, cfg: &ModelConfig) -> PoolCfg {
    let probe = KvPool::new(PoolCfg { budget_bytes: 1 << 30, page_tokens }, kv, cfg);
    PoolCfg { budget_bytes: pages * probe.page_bytes(), page_tokens }
}

/// Greedy reference decode through a plain (contiguous) [`DecodeState`].
fn greedy_direct<M: ModelExec>(m: &M, kv: KvSpec, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let mut st = DecodeState::with_kv(m, kv);
    let mut logits = Vec::new();
    for &t in prompt {
        logits = st.step(t);
    }
    let mut out = Vec::new();
    for _ in 0..max_new {
        let next = argmax_token(&logits).unwrap();
        out.push(next);
        logits = st.step(next);
    }
    out
}

#[test]
fn paged_decode_bit_identical_across_configs_and_kernel_tables() {
    let _guard = force_lock();
    // The tentpole acceptance bar: per-position logits from a pool-backed
    // DecodeState equal the contiguous-cache logits to the last bit, for
    // dense f32 KV, int8 and int4 packed KV, on both the dense-f32 model
    // and the mixed packed checkpoint — and page geometry must not matter
    // (page smaller than, equal to, and larger than a KV group).
    let dense = tiny(21);
    let packed = mixed_packed4();
    let tokens: Vec<u8> = vec![3, 141, 59, 26, 53, 58, 97, 93, 23, 84, 7, 200];
    let specs = [
        KvSpec::DenseF32,
        KvSpec::PackedGroupwise { bits: 8, group: 64 },
        KvSpec::PackedGroupwise { bits: 4, group: 32 },
    ];
    for force in [ForcedKernel::Scalar, ForcedKernel::Best] {
        set_forced(force);
        for kv in specs {
            for pt in [3usize, 16] {
                let lbl = format!("under {force:?}");
                check_paged_matches_contiguous(&dense, kv, pt, &tokens, &lbl);
                check_paged_matches_contiguous(&packed, kv, pt, &tokens, &lbl);
            }
        }
    }
    set_forced(ForcedKernel::Auto);
}

fn check_paged_matches_contiguous<M: ModelExec>(
    m: &M,
    kv: KvSpec,
    page_tokens: usize,
    tokens: &[u8],
    label: &str,
) {
    let cfg = m.config();
    let pc = pool_of_pages(256, page_tokens, kv, cfg);
    let pool = KvPool::new(pc, kv, cfg);
    let mut contiguous = DecodeState::with_kv(m, kv);
    let mut paged = DecodeState::with_kv_pool(m, kv, Some(&pool));
    for (pos, &t) in tokens.iter().enumerate() {
        let want = contiguous.step(t);
        let got = paged.step(t);
        assert_eq!(want.len(), got.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{label}: kv {} page_tokens={page_tokens} pos={pos} logit {i}: {a} vs {b}",
                kv.effective(cfg).label(),
            );
        }
    }
    assert!(paged.kv_pages_used() > 0, "{label}: paged decode held no pages");
}

#[test]
fn exhaustion_preemption_readmission_roundtrip() {
    let _guard = force_lock();
    // A pool below the aggregate demand of two concurrent generations:
    // both are admitted (each fits alone), the pool runs dry mid-decode,
    // the youngest is preempted and re-prefilled — and every returned
    // token still equals the unconstrained direct decode.
    let m = Arc::new(tiny(22));
    let cfg = *m.config();
    let kv = KvSpec::DenseF32;
    // page = 4 tokens; one 16-token chain peaks at 2 layers × K+V × 4
    // pages = 16; two chains need 32. 20 pages admit both but can't hold
    // both to completion.
    let pc = pool_of_pages(20, 4, kv, &cfg);
    let reqs = [
        GenRequest { prompt: vec![10, 20, 30, 40], max_new: 12, ..Default::default() },
        GenRequest { prompt: vec![200, 150, 100, 50], max_new: 12, ..Default::default() },
    ];
    let want: Vec<Vec<u8>> = reqs
        .iter()
        .map(|r| greedy_direct(m.as_ref(), kv, &r.prompt, r.max_new))
        .collect();
    let b = Arc::new(DynamicBatcher::spawn(
        m.clone(),
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(200),
            kv,
            pool: Some(pc),
            ..Default::default()
        },
    ));
    let handles: Vec<_> = reqs
        .iter()
        .cloned()
        .map(|req| {
            let b = b.clone();
            std::thread::spawn(move || b.generate(req).unwrap())
        })
        .collect();
    let responses: Vec<GenResponse> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (i, (resp, want)) in responses.iter().zip(&want).enumerate() {
        assert_eq!(
            &resp.tokens, want,
            "request {i}: preemption/re-prefill changed the tokens"
        );
        assert!(resp.kv_pages_used > 0, "request {i}: no page accounting");
        // each sequence alone peaks at 16 of the 20 pages
        assert!(resp.kv_pages_used <= 16, "request {i}: {}", resp.kv_pages_used);
    }
    // both ran concurrently at some point (else the pool was never under
    // pressure and the test proves nothing)
    assert!(
        responses.iter().any(|r| r.batch_size >= 2),
        "generations never co-ran: sizes {:?}",
        responses.iter().map(|r| r.batch_size).collect::<Vec<_>>()
    );
    let total_preemptions: usize = responses.iter().map(|r| r.preemptions).sum();
    assert!(
        total_preemptions >= 1,
        "a 20-page pool under 32 pages of demand must preempt"
    );
}

#[test]
fn oversized_prompt_rejected_and_lone_overlong_chain_errors() {
    let m = Arc::new(tiny(23));
    let cfg = *m.config();
    let kv = KvSpec::DenseF32;
    // 12 pages of 4 tokens: capacity for one 12-token chain (2 layers ×
    // K+V × 3 pages).
    let pc = pool_of_pages(12, 4, kv, &cfg);
    let b = DynamicBatcher::spawn(
        m,
        BatcherConfig { kv, pool: Some(pc), ..Default::default() },
    );
    // a prompt whose prefill alone exceeds the pool is rejected up front
    let err = b
        .generate(GenRequest { prompt: vec![9; 32], max_new: 2, ..Default::default() })
        .unwrap_err()
        .to_string();
    assert!(err.contains("kv pool too small"), "{err}");
    // a chain that outgrows the pool mid-decode, running alone, errors out
    // (preempting it would just replay into the same wall)
    let err = b
        .generate(GenRequest { prompt: vec![1, 2, 3, 4], max_new: 20, ..Default::default() })
        .unwrap_err()
        .to_string();
    assert!(err.contains("kv pool exhausted"), "{err}");
    // the pool recovered: a fitting request still completes
    let r = b
        .generate(GenRequest { prompt: vec![5, 6], max_new: 4, ..Default::default() })
        .unwrap();
    assert_eq!(r.tokens.len(), 4);
}

#[test]
fn pages_recycle_after_retire() {
    let _guard = force_lock();
    // Page-table teardown returns every page, and later sequences reuse
    // the freed buffers: used returns to 0, free to total, and the minted
    // count stays flat after the first round (no leak, no re-minting).
    let m = tiny(24);
    let kv = KvSpec::PackedGroupwise { bits: 8, group: 64 };
    let pc = pool_of_pages(64, 4, kv, m.config());
    let pool = KvPool::new(pc, kv, m.config());
    let total = pool.total_pages();
    let mut minted_after_first = 0;
    for round in 0..3u8 {
        let mut st = DecodeState::with_kv_pool(&m, kv, Some(&pool));
        for t in 0..10u8 {
            st.step(t * 7 + round);
        }
        assert!(pool.used_pages() > 0, "round {round}: no pages in use");
        drop(st);
        assert_eq!(pool.used_pages(), 0, "round {round}: pages leaked");
        assert_eq!(pool.free_pages(), total, "round {round}");
        if round == 0 {
            minted_after_first = pool.minted_pages();
        } else {
            assert_eq!(
                pool.minted_pages(),
                minted_after_first,
                "round {round}: minted new pages instead of recycling"
            );
        }
    }
}

#[test]
fn tiny_pool_stress_stays_correct() {
    let _guard = force_lock();
    // The CI threads-2 leg runs this under TSGO_KV_POOL_MB=1 (and
    // TSGO_THREADS=2): many concurrent requests of uneven lengths through
    // a small pool; whatever admission deferrals and preemptions happen,
    // every response must be byte-identical to the direct decode.
    let mb: usize = std::env::var("TSGO_KV_POOL_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&v| v > 0)
        .unwrap_or(1);
    let m = Arc::new(tiny(25));
    let kv = KvSpec::DenseF32;
    let pc = PoolCfg::from_flags(mb, 8).unwrap().expect("nonzero MB");
    let b = Arc::new(DynamicBatcher::spawn(
        m.clone(),
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            kv,
            pool: Some(pc),
            ..Default::default()
        },
    ));
    let reqs: Vec<GenRequest> = (0..10u8)
        .map(|i| GenRequest {
            prompt: (0..(2 + i as usize % 4)).map(|j| i * 17 + j as u8).collect(),
            max_new: 3 + (i as usize * 5) % 12,
            ..Default::default()
        })
        .collect();
    let want: Vec<Vec<u8>> = reqs
        .iter()
        .map(|r| greedy_direct(m.as_ref(), kv, &r.prompt, r.max_new))
        .collect();
    let handles: Vec<_> = reqs
        .iter()
        .cloned()
        .map(|req| {
            let b = b.clone();
            std::thread::spawn(move || b.generate(req).unwrap())
        })
        .collect();
    for (i, (h, want)) in handles.into_iter().zip(&want).enumerate() {
        let resp = h.join().unwrap();
        assert_eq!(&resp.tokens, want, "request {i} diverged under pool pressure");
    }
}

#[test]
fn sharded_pooled_serve_matches_unsharded_unpooled() {
    let _guard = force_lock();
    // `--shards 2 --kv-pool-mb M` end to end: shard-local sub-pools plus
    // the scheduler's mirror accounting must leave tokens untouched
    // relative to the plain unsharded, unpooled batcher.
    let em = Arc::new(mixed_packed4());
    let kv = KvSpec::PackedGroupwise { bits: 8, group: 64 };
    let req = GenRequest { prompt: vec![65, 66, 67, 68], max_new: 10, ..Default::default() };
    let plain = DynamicBatcher::spawn(em.clone(), BatcherConfig { kv, ..Default::default() });
    let a = plain.generate(req.clone()).unwrap();
    let pooled = DynamicBatcher::spawn(
        em.clone(),
        BatcherConfig {
            kv,
            shards: 2,
            pool: Some(PoolCfg { budget_bytes: 4 << 20, page_tokens: 8 }),
            ..Default::default()
        },
    );
    let b = pooled.generate(req).unwrap();
    assert_eq!(a.tokens, b.tokens, "sharded pooled serving changed the tokens");
    assert!(b.kv_pages_used > 0, "mirror reported no page usage");
    assert_eq!(b.preemptions, 0, "an ample pool must not preempt");
}
