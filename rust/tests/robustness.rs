//! Failure-injection and robustness tests: corrupted checkpoints, malformed
//! artifacts, degenerate numerical inputs, hostile serve traffic and fuzzed
//! JSON — every failure must surface as an `Err` (or a clean rejection),
//! never a panic or a wrong-but-silent result.

use tsgo::model::{store, ModelWeights, Preset};
use tsgo::quant::scale::{compute_group_scales, QuantSpec, ScaleMetric};
use tsgo::quant::{resolve_quantizer, GptqConfig, QuantContext};
use tsgo::tensor::Matrix;
use tsgo::util::json::Json;
use tsgo::util::proptest::{check, prop_assert};
use tsgo::util::rng::Rng;

fn tmpdir() -> std::path::PathBuf {
    let d = std::env::temp_dir().join("tsgo_robustness");
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn truncated_checkpoint_is_error_not_panic() {
    let mut rng = Rng::new(1);
    let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
    let p = tmpdir().join("trunc.tsr");
    store::save_model(&p, &w).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    // chop the payload at several points, including inside the header
    for cut in [4usize, 8, 11, bytes.len() / 2, bytes.len() - 17] {
        let p2 = tmpdir().join(format!("trunc_{cut}.tsr"));
        std::fs::write(&p2, &bytes[..cut]).unwrap();
        assert!(store::load_model(&p2).is_err(), "cut={cut} should fail");
    }
}

#[test]
fn bitflipped_header_is_error_not_panic() {
    let mut rng = Rng::new(2);
    let w = ModelWeights::init(Preset::Tiny.config(), &mut rng);
    let p = tmpdir().join("flip.tsr");
    store::save_model(&p, &w).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    // corrupt a byte inside the JSON header
    bytes[20] ^= 0xFF;
    let p2 = tmpdir().join("flipped.tsr");
    std::fs::write(&p2, &bytes).unwrap();
    // Either a parse error or a shape/complete-mismatch error — never a panic.
    let _ = store::load_model(&p2);
}

#[test]
fn malformed_hlo_artifact_is_error() {
    let dir = tmpdir().join("bad_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"config":{"vocab":256,"d_model":64,"n_layers":2,"n_heads":2,"ffn":128,"seq_len":64},
            "entries":{"broken":{"file":"broken.hlo.txt","inputs":[],"outputs":[]}}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("broken.hlo.txt"), "HloModule utterly { not valid hlo }").unwrap();
    let engine = tsgo::runtime::Engine::open(&dir).unwrap();
    assert!(engine.execute("broken", &[]).is_err());
}

#[test]
fn quantize_layer_survives_degenerate_inputs() {
    // all-zero weights, rank-deficient Hessian (damping must rescue it),
    // constant rows — every case must return finite results.
    let spec = QuantSpec::new(2, 16);
    let cases: Vec<(Matrix, Matrix)> = vec![
        (Matrix::zeros(4, 32), Matrix::eye(32)),
        (Matrix::from_vec(2, 32, vec![0.5; 64]), Matrix::zeros(32, 32)),
        ({
            let mut rng = Rng::new(3);
            Matrix::randn(4, 32, 1.0, &mut rng)
        }, {
            // rank-1 "hessian"
            let mut rng = Rng::new(4);
            let v = Matrix::randn(32, 1, 1.0, &mut rng);
            v.matmul(&v.transpose())
        }),
    ];
    for (i, (w, h)) in cases.iter().enumerate() {
        let res = resolve_quantizer("ours")
            .unwrap()
            .quantize(w, h, None, &spec, &QuantContext::default())
            .unwrap_or_else(|e| panic!("case {i}: {e}"));
        assert!(res.layer_loss.is_finite(), "case {i}");
        assert!(
            res.quantized.scales.data.iter().all(|s| s.is_finite()),
            "case {i}: non-finite scale"
        );
    }
}

#[test]
fn gptq_handles_extreme_outlier_weights() {
    let mut rng = Rng::new(5);
    let mut w = Matrix::randn(4, 64, 1.0, &mut rng);
    w[(0, 0)] = 1e6;
    w[(3, 63)] = -1e6;
    let x = Matrix::randn(64, 128, 1.0, &mut rng);
    let h = x.matmul_bt(&x);
    let spec = QuantSpec::new(2, 32);
    let scales = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
    let q = tsgo::quant::gptq::gptq_quantize(&w, &h, &scales, &spec, &GptqConfig::default())
        .unwrap();
    assert!(q.dequantize().data.iter().all(|v| v.is_finite()));
}

#[test]
fn serve_rejects_oversized_and_junk_lines() {
    use std::io::{BufRead, BufReader, Write};
    let mut rng = Rng::new(6);
    let w = std::sync::Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng));
    let cfg = tsgo::serve::ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_connections: Some(1),
        ..Default::default()
    };
    let (addr, handle) = tsgo::serve::server::serve_in_background(w, cfg).unwrap();
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();

    // deeply nested junk json
    let junk = format!("{}1{}\n", "[".repeat(200), "]".repeat(200));
    stream.write_all(junk.as_bytes()).unwrap();
    reader.read_line(&mut line).unwrap();
    // nested arrays parse fine but have no prompt -> error response
    assert!(line.contains("error"), "{line}");

    // max_new is clamped server-side (512 cap)
    line.clear();
    stream
        .write_all(b"{\"prompt\": [1,2], \"max_new\": 999999}\n")
        .unwrap();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    let n = resp.get("tokens").usize_vec().len();
    assert!(n <= 512, "server generated {n} tokens");
    drop(stream);
    handle.join().unwrap();
}

#[test]
fn prop_json_roundtrip_fuzz() {
    // generate random JSON values, serialize, reparse, compare.
    fn gen_value(g: &mut tsgo::util::proptest::Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = g.usize_in(0, 8);
                Json::Str((0..n).map(|_| char::from(g.usize_in(32, 126) as u8)).collect())
            }
            4 => {
                let n = g.usize_in(0, 4);
                Json::Arr((0..n).map(|_| gen_value(g, depth - 1)).collect())
            }
            _ => {
                let n = g.usize_in(0, 4);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), gen_value(g, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    check("json serialize/parse roundtrip", 200, |g| {
        let v = gen_value(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
        prop_assert(back == v, &format!("roundtrip mismatch: {text}"))
    });
}

#[test]
fn prop_quantize_layer_loss_nonnegative_and_bounded_by_rtn() {
    check("gptq+stages never worse than plain RTN on layer loss", 8, |g| {
        let out = 2 + g.usize_in(0, 4);
        let inp = 32;
        let seed = g.rng.next_u64();
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(out, inp, 1.0, &mut rng);
        let x = Matrix::randn(inp, 128, 1.0, &mut rng);
        let mut h = x.matmul_bt(&x);
        h.scale_inplace(1.0 / 128.0);
        let spec = QuantSpec::new(2, 16);
        let res = resolve_quantizer("ours")
            .unwrap()
            .quantize(&w, &h, None, &spec, &QuantContext::default())
            .map_err(|e| e.to_string())?;
        let mut wd = w.clone();
        let hd = tsgo::quant::gptq::prepare_hessian(&h, &mut wd, 0.01);
        let rtn = {
            let gs = compute_group_scales(&w, &spec, ScaleMetric::L2, None);
            tsgo::quant::rtn::rtn_quantize(&w, &gs, &spec).dequantize()
        };
        let l_rtn = tsgo::quant::metrics::layer_loss(&w, &rtn, &hd);
        prop_assert(res.layer_loss >= 0.0, "loss must be non-negative")?;
        prop_assert(
            res.layer_loss <= l_rtn * 1.001 + 1e-9,
            &format!("ours {} worse than RTN {l_rtn} (seed {seed})", res.layer_loss),
        )
    });
}

#[test]
fn cli_parser_fuzz_never_panics() {
    use tsgo::util::cli::{Args, OptSpec};
    let specs = [
        OptSpec { name: "a", help: "", default: Some("1"), is_flag: false },
        OptSpec { name: "b", help: "", default: None, is_flag: true },
    ];
    check("cli parse fuzz", 300, |g| {
        let n = g.usize_in(0, 6);
        let argv: Vec<String> = (0..n)
            .map(|_| {
                let len = g.usize_in(0, 6);
                (0..len)
                    .map(|_| char::from(g.usize_in(33, 126) as u8))
                    .collect()
            })
            .collect();
        // must return Ok or Err, never panic
        let _ = Args::parse(&argv, &specs);
        Ok(())
    });
}
