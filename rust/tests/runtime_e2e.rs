//! Cross-language integration tests: the AOT HLO artifacts must agree with
//! the native rust mirrors. Runs against `TSGO_ARTIFACTS` (or ./artifacts);
//! every test is skipped gracefully when `make artifacts` has not produced a
//! usable directory, so `cargo test` stays green pre-AOT.

use tsgo::model::{forward_logits, ModelWeights};
use tsgo::pipeline::MomentAccum;
use tsgo::runtime::{forward_logits_artifact, matrix_to_literal, Engine};
use tsgo::tensor::Matrix;
use tsgo::util::rng::Rng;

fn engine() -> Option<Engine> {
    Engine::open_default()
}

#[test]
fn artifact_forward_matches_native() {
    let Some(engine) = engine() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let cfg = engine.manifest.config;
    let mut rng = Rng::new(11);
    let w = ModelWeights::init(cfg, &mut rng);
    let tokens: Vec<u8> = (0..cfg.seq_len).map(|i| (i * 31 % 251) as u8).collect();

    let native = forward_logits(&w, &tokens);
    let art = forward_logits_artifact(&engine, &w, &tokens).expect("artifact exec");
    assert_eq!((native.rows, native.cols), (art.rows, art.cols));
    let scale = native.data.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    let maxdiff = native.max_abs_diff(&art);
    assert!(
        maxdiff < 2e-3 * scale.max(1.0),
        "native vs artifact logits diverge: {maxdiff} (scale {scale})"
    );
}

#[test]
fn artifact_forward_short_sequence_padding_is_inert() {
    let Some(engine) = engine() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let cfg = engine.manifest.config;
    let mut rng = Rng::new(12);
    let w = ModelWeights::init(cfg, &mut rng);
    let short: Vec<u8> = (0..cfg.seq_len / 2).map(|i| (i * 7 % 200) as u8).collect();
    let art = forward_logits_artifact(&engine, &w, &short).expect("artifact exec");
    let native = forward_logits(&w, &short);
    assert_eq!(art.rows, short.len());
    let scale = native.data.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    assert!(native.max_abs_diff(&art) < 2e-3 * scale.max(1.0));
}

#[test]
fn artifact_hessian_matches_native_accumulator() {
    let Some(engine) = engine() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let cfg = engine.manifest.config;
    let entry_name = "hessian_accum_d";
    let Some(entry) = engine.manifest.entry(entry_name) else {
        eprintln!("skipped: no hessian entry");
        return;
    };
    let t = entry.inputs[0].shape[0];
    let mut rng = Rng::new(13);
    let x = Matrix::randn(t, cfg.d_model, 1.0, &mut rng);

    let out = engine
        .execute(entry_name, &[matrix_to_literal(&x).unwrap()])
        .expect("hessian exec");
    let h_art = tsgo::runtime::literal_to_matrix(&out[0]).unwrap();

    let mut acc = MomentAccum::new(cfg.d_model);
    acc.add(&x);
    let h_native = acc.finalize();
    assert!(
        h_art.max_abs_diff(&h_native) < 1e-3,
        "hessian kernels disagree: {}",
        h_art.max_abs_diff(&h_native)
    );
}

#[test]
fn artifact_stage1_losses_match_native_grid() {
    let Some(engine) = engine() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let cfg = engine.manifest.config;
    let name = format!("stage1_grid_{}x{}", cfg.d_model, cfg.d_model);
    let Some(entry) = engine.manifest.entry(&name) else {
        eprintln!("skipped: no stage1 entry");
        return;
    };
    let n_g = entry.inputs[1].shape[0];
    let g = entry.inputs[1].shape[1];
    let m = entry.inputs[2].shape[0];
    let bits = 2u8; // aot default; manifest records it

    let mut rng = Rng::new(14);
    let w = Matrix::randn(cfg.d_model, cfg.d_model, 1.0, &mut rng);
    // h_blocks from an SPD hessian
    let xact = Matrix::randn(cfg.d_model, 4 * cfg.d_model, 1.0, &mut rng);
    let h = xact.matmul_bt(&xact);
    let mut hblocks = vec![0.0f32; n_g * g * g];
    for gi in 0..n_g {
        let b = h.slice(gi * g, (gi + 1) * g, gi * g, (gi + 1) * g);
        hblocks[gi * g * g..(gi + 1) * g * g].copy_from_slice(&b.data);
    }
    let spec = tsgo::quant::QuantSpec { bits, group_size: g, grid_points: m, beta_min: 0.35 };
    let betas = spec.beta_grid();

    let inputs = vec![
        matrix_to_literal(&w).unwrap(),
        xla::Literal::vec1(&hblocks)
            .reshape(&[n_g as i64, g as i64, g as i64])
            .unwrap(),
        xla::Literal::vec1(&betas),
    ];
    let out = engine.execute(&name, &inputs).expect("stage1 exec");
    let losses: Vec<f32> = out[0].to_vec().unwrap(); // [n_g, M, out]

    // native: loss for group gi, beta mi, row r
    for gi in [0usize, n_g - 1] {
        let hb = h.slice(gi * g, (gi + 1) * g, gi * g, (gi + 1) * g);
        for mi in [0usize, m / 2, m - 1] {
            for r in [0usize, cfg.d_model - 1] {
                let row = &w.row(r)[gi * g..(gi + 1) * g];
                let (s, z) = tsgo::quant::scale::minmax_scale(row, bits, betas[mi]);
                let err = tsgo::quant::scale::group_error(row, s, z, spec.qmax());
                let want = tsgo::tensor::linalg::quad_form(&err, &hb, &err);
                let got = losses[gi * m * cfg.d_model + mi * cfg.d_model + r] as f64;
                let tol = 1e-3 * want.abs().max(1.0);
                assert!(
                    (got - want).abs() < tol,
                    "stage1 mismatch at g{gi} m{mi} r{r}: {got} vs {want}"
                );
            }
        }
    }
}

#[test]
fn artifact_dequant_matmul_matches_native_dequant() {
    let Some(engine) = engine() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let Some(entry) = engine.manifest.entry("dequant_matmul") else {
        eprintln!("skipped: no dequant entry");
        return;
    };
    let t = entry.inputs[0].shape[0];
    let cols = entry.inputs[0].shape[1];
    let rows = entry.inputs[1].shape[0];
    let nwords = entry.inputs[1].shape[1];
    let n_g = entry.inputs[2].shape[1];
    let group = cols / n_g;
    let bits = (32 * nwords / cols) as u8;

    let mut rng = Rng::new(15);
    let qmax = (1u16 << bits) as u32 - 1;
    // random integers + params
    let ints: Vec<Vec<u8>> = (0..rows)
        .map(|_| (0..cols).map(|_| (rng.below(qmax as usize + 1)) as u8).collect())
        .collect();
    let scales = Matrix::randn(rows, n_g, 0.05, &mut rng);
    let scales = Matrix::from_vec(rows, n_g, scales.data.iter().map(|v| v.abs() + 0.01).collect());
    let zeros = Matrix::from_vec(
        rows,
        n_g,
        (0..rows * n_g).map(|_| rng.below(qmax as usize + 1) as f32).collect(),
    );
    let x = Matrix::randn(t, cols, 1.0, &mut rng);

    // pack little-endian per row (the contract shared with python pack_weights)
    let per = 32 / bits as usize;
    let mut words = vec![0u32; rows * nwords];
    for r in 0..rows {
        for c in 0..cols {
            words[r * nwords + c / per] |= (ints[r][c] as u32) << ((c % per) * bits as usize);
        }
    }

    let inputs = vec![
        matrix_to_literal(&x).unwrap(),
        xla::Literal::vec1(&words).reshape(&[rows as i64, nwords as i64]).unwrap(),
        matrix_to_literal(&scales).unwrap(),
        matrix_to_literal(&zeros).unwrap(),
    ];
    let out = engine.execute("dequant_matmul", &inputs).expect("dequant exec");
    let y = tsgo::runtime::literal_to_matrix(&out[0]).unwrap();

    // native: dequantize then matmul
    let q = tsgo::quant::QuantizedLinear::from_ints(&ints, bits, group, scales, zeros);
    let want = x.matmul_bt(&q.dequantize());
    let scale = want.data.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    assert!(
        y.max_abs_diff(&want) < 1e-3 * scale.max(1.0),
        "fused dequant matmul mismatch: {}",
        y.max_abs_diff(&want)
    );
}
